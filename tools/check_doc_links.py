#!/usr/bin/env python3
"""Fail the docs-smoke CI step on broken intra-repo markdown links.

Scans README.md and docs/**/*.md for ``[text](target)`` links and verifies
that every relative target (external schemes and pure #anchors are skipped)
resolves to an existing file or directory, relative to the file containing
the link. Keeps the cross-references between README.md,
docs/serving_internals.md and the source tree honest as files move.
"""
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP = ("http://", "https://", "mailto:", "#")


def main() -> int:
    files = [ROOT / "README.md", *sorted((ROOT / "docs").glob("**/*.md"))]
    bad = []
    n_links = 0
    for f in files:
        for m in LINK.finditer(f.read_text()):
            target = m.group(1)
            if target.startswith(SKIP):
                continue
            n_links += 1
            path = (f.parent / target.split("#", 1)[0]).resolve()
            if not path.exists():
                bad.append(f"{f.relative_to(ROOT)}: {target}")
    if bad:
        print("broken intra-repo links:\n  " + "\n  ".join(bad))
        return 1
    print(f"{len(files)} file(s), {n_links} intra-repo link(s): all resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
