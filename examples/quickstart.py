"""Quickstart: the MF-QAT pipeline end-to-end on a toy model, in one file.

  1. multi-format QAT train a small LM (paper §3.2 schedule),
  2. quantize to the MXINT8 anchor and write the packed checkpoint (§3.5),
  3. Slice-and-Scale to lower formats at 'runtime' and evaluate each (§3.3).

Runs in ~2 minutes on CPU.
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.checkpoint.anchor_ckpt import load_anchor, save_anchor
from repro.configs import get_reduced
from repro.core import (convert, dequantize, get_format, make_anchor,
                        storage_bytes)
from repro.core.anchor import materialize
from repro.core.qat import QATConfig
from repro.data.pipeline import DataConfig, LMDataset, eval_batches
from repro.models import get_model
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, run_training


def main():
    # 1. ---- multi-format QAT -----------------------------------------------
    cfg = get_reduced("qwen3-4b")
    qat = QATConfig(formats=("mxint2", "mxint4", "mxint6", "mxint8"),
                    block_size=32)
    api = get_model(cfg, qat)
    data = LMDataset(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8,
                                n_examples=128))   # paper: 128 examples
    total = data.epoch_steps() * len(qat.formats)  # 1 epoch per format
    print(f"training {cfg.name}-reduced, {total} steps, "
          f"schedule 2->4->6->8 ...")
    out = run_training(api, data, AdamWConfig(lr=3e-3),
                       LoopConfig(total_steps=total, schedule="multiformat"),
                       on_step=lambda s, m: print(
                           f"  step {s:3d} fmt={m['fmt_idx']} "
                           f"loss={m['loss']:.3f}") if s % 16 == 0 else None)
    params = out["state"].params

    # 2. ---- anchor checkpoint ---------------------------------------------
    anchor = make_anchor(params, qat, get_format("mxint8", 32))
    nbytes = save_anchor("out/quickstart_anchor", anchor)
    f32_bytes = sum(x.size * 4 for x in jax.tree_util.tree_leaves(params))
    print(f"anchor checkpoint: {nbytes / 1e3:.0f} kB "
          f"(f32 master: {f32_bytes / 1e3:.0f} kB, "
          f"{f32_bytes / nbytes:.1f}x smaller)")

    # 3. ---- elastic inference: SS to each format, evaluate -----------------
    anchor = load_anchor("out/quickstart_anchor")
    batches = eval_batches(DataConfig(vocab=cfg.vocab, seq_len=64,
                                      global_batch=8), 4)
    loss_fn = jax.jit(lambda p, b: api.train_loss(p, b, None)[1]["ce"])
    print("format  eval_ppl   (from ONE stored anchor, no retraining)")
    for b in (8, 6, 5, 4, 3, 2):
        low = convert(anchor, get_format(f"mxint{b}", 32))
        p_low = materialize(low, params, dtype=jnp.float32)
        losses = [float(loss_fn(p_low, jax.tree_util.tree_map(
            jnp.asarray, bb))) for bb in batches]
        print(f"mxint{b}  {np.exp(np.mean(losses)):8.2f}")


if __name__ == "__main__":
    main()
