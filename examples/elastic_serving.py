"""Elastic-precision serving demo: one anchor checkpoint, load-adaptive
precision, batched requests (deliverable (b), serving flavor).

A burst of requests hits the engine; the FormatPolicy watches queue depth and
drops precision under load (mxint8 -> 6 -> 4), recovering when the queue
drains — all served from a single MXINT8 anchor via Slice-and-Scale.
"""
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_reduced  # noqa: E402
from repro.core import get_format, make_anchor  # noqa: E402
from repro.core.qat import QATConfig  # noqa: E402
from repro.models import get_model  # noqa: E402
from repro.serve.engine import ElasticEngine, Request  # noqa: E402
from repro.serve.policy import FormatPolicy  # noqa: E402


def main():
    cfg = get_reduced("qwen3-4b")
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    qat = QATConfig(formats=("mxint4", "mxint8"), anchor="mxint8",
                    block_size=32)
    anchor = make_anchor(params, qat, get_format("mxint8", 32))

    policy = FormatPolicy(anchor="mxint8",
                          ladder=((12, "mxint4"), (6, "mxint6"),
                                  (0, "mxint8")),
                          hysteresis=1)
    eng = ElasticEngine(api, anchor, batch_slots=4, max_len=64,
                        policy=policy, param_template=params)

    rng = np.random.default_rng(0)
    print("LOW LOAD: 3 requests")
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8)
                    .astype(np.int32), max_new=6) for i in range(3)]
    eng.generate(reqs)
    for r in reqs:
        print(f"  req {r.rid}: fmt={r.fmt_used} tokens={r.out_tokens}")

    print("\nBURST: 20 requests")
    reqs = [Request(rid=100 + i, prompt=rng.integers(0, cfg.vocab, 8)
                    .astype(np.int32), max_new=6) for i in range(20)]
    eng.generate(reqs)
    fmts = sorted({r.fmt_used for r in reqs})
    print(f"  formats used across the burst: {fmts}")
    print(f"\nengine stats: {eng.stats}")
    print("one anchor checkpoint served "
          f"{len(eng.stats['formats_cached'])} precisions; "
          "each switch = one packed-domain Slice-and-Scale pass.")


if __name__ == "__main__":
    main()
