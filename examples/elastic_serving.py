"""Elastic-precision serving demo: one anchor checkpoint, load-adaptive
precision, packed-weight continuous batching.

A burst of requests hits the engine; the FormatPolicy watches queue depth at
each batch admission and drops precision under load (mxint8 -> 6 -> 4),
recovering when the queue drains. Every format is served from a single
MXINT8 anchor via Slice-and-Scale, and the decode tick reads *packed* MX
codes (MXTensor / split-N nibble-packed PackedInt4Leaf) — on TPU each
projection streams them through the fused Pallas dequant-GEMM
(`kernels.dispatch.qmatmul`); elsewhere the dequant runs inside the jitted
step — either way HBM weight traffic is the packed bytes. Requests are
admitted into individual slots (staggered arrivals never re-prefill active
sequences; prompts pad to power-of-two buckets so prefill compiles once per
bucket), and the format is pinned per batch, never switched mid-sequence.

The engine runs with the paged KV cache (kv_layout="paged"): KV HBM is
committed one page at a time as sequences grow and recycled the moment a
request retires, instead of preallocating max_len per slot — token streams
are identical to the dense layout (see docs/serving_internals.md §5).
Decode attention reads the pool through the attn_impl knob: --attn-impl
paged_kernel runs the gather-free block-table kernel
(kernels/paged_attention.py; interpret mode off TPU), --attn-impl gather
materializes each slot's logical view first — token streams are identical
either way, and the stats line reports the attention bytes each path read.

With --prefill-chunk N, admission is *chunked* (docs/serving_internals.md
§6): long prompts stream in N-token chunks interleaved with decode ticks —
at most one chunk of prefill per tick — so running slots' inter-token
latency stays bounded while a long prompt admits. Token streams are
bit-identical either way.

With --speculative, the demo adds self-speculative decoding
(docs/serving_internals.md §9): each decode tick drafts k=4 tokens with
the mxint4 rung of the SAME checkpoint (no second model — Slice-and-Scale
already keeps the cheap rung resident) and verifies all of them in one
multi-query step at the anchor rung, rewinding whatever the anchor
disagrees with (cursor + page rollback). The demo runs the same burst
plain and speculative and prints the acceptance rate, the decode-tick
cut, and the fact that matters: the token streams are bit-identical.

The final section demonstrates the failure model (docs/serving_internals.md
§7): a deterministic FaultInjector makes the lowest rung produce NaN
logits at runtime, and the engine's logit guard escalates the live batch
one rung toward the anchor, replays the tick, quarantines the bad rung,
and completes every request — degradation costs precision, never streams.
"""
import argparse
import sys

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_reduced  # noqa: E402
from repro.core import get_format, make_anchor  # noqa: E402
from repro.core.qat import QATConfig  # noqa: E402
from repro.models import get_model  # noqa: E402
from repro.runtime.fault import FaultInjector  # noqa: E402
from repro.serve.engine import ElasticEngine, Request  # noqa: E402
from repro.serve.policy import FormatPolicy  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked admission: tokens per prefill chunk "
                         "(multiple of the 8-token page size); default "
                         "monolithic")
    ap.add_argument("--attn-impl", default=None,
                    choices=("gather", "paged_kernel"),
                    help="paged decode-attention read path (default: "
                         "kernel on TPU, gather elsewhere)")
    ap.add_argument("--scheduler", default=None,
                    choices=("sequential", "mixed"),
                    help="chunked-tick scheduler: 'mixed' (default with "
                         "--prefill-chunk) coalesces the chunk into the "
                         "decode batch — one executable per tick")
    ap.add_argument("--speculative", action="store_true",
                    help="demo self-speculative decoding: draft k=4 "
                         "tokens/tick at mxint4, verify at the anchor, "
                         "compare streams + ticks against plain decode")
    ap.add_argument("--slo", action="store_true",
                    help="demo SLO-tiered serving: tiered admission + "
                         "cost-model format picks on a bursty two-tenant "
                         "trace (docs/serving_internals.md §10)")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    qat = QATConfig(formats=("mxint4", "mxint8"), anchor="mxint8",
                    block_size=32)
    anchor = make_anchor(params, qat, get_format("mxint8", 32))

    policy = FormatPolicy(anchor="mxint8",
                          ladder=((12, "mxint4"), (6, "mxint6"),
                                  (0, "mxint8")),
                          hysteresis=1)
    chunked = args.prefill_chunk is not None
    eng = ElasticEngine(api, anchor, batch_slots=4, max_len=64,
                        policy=policy, param_template=params,
                        kv_layout="paged", kv_page_size=8,
                        attn_impl=args.attn_impl,
                        prefill_chunk=args.prefill_chunk,
                        scheduler=args.scheduler,
                        kv_num_pages=4 * (7 if chunked else 3) + 1)
    #   pool is live-token sized, not slots*max_len — pages recycle across
    #   the burst (the chunked demo's long prompts need more live pages)

    rng = np.random.default_rng(0)

    if chunked:
        print(f"CHUNKED ADMISSION: short requests admit first and keep "
              f"decoding while a 41-token prompt trickles in "
              f"{args.prefill_chunk}-token chunks behind them")
        reqs = [Request(rid=200 + i, prompt=rng.integers(0, cfg.vocab, 8)
                        .astype(np.int32), max_new=10) for i in range(3)] + \
               [Request(rid=203, prompt=rng.integers(0, cfg.vocab, 41)
                        .astype(np.int32), max_new=4)]
        eng.generate(reqs)
        tt = eng.tick_trace
        print(f"  {len(tt)} scheduler ticks, max prefill tokens in any "
              f"tick: {max(t['prefill_tokens'] for t in tt)} "
              f"(chunk={args.prefill_chunk}; monolithic admission would "
              "run all 63 — the capped length bucket — in one tick)")
        stalled = sum(1 for t in tt if t["decode"] and t["prefill_tokens"])
        print(f"  {stalled} ticks interleaved a prefill chunk with the "
              "running slots' decode step")
        print(f"  scheduler={eng.scheduler}: max executables in any tick = "
              f"{max(t['execs'] for t in tt)} (mixed coalesces chunk + "
              "decode into one mixed_step; sequential runs two)")
        for r in reqs:
            print(f"  req {r.rid}: plen={r.prompt.size} ttft={r.ttft_s:.3f}s"
                  f" n_out={len(r.out_tokens)}")
        print()
    if args.speculative:
        from repro.serve.policy import SpecConfig
        print("SPECULATIVE DECODE: draft k=4 at mxint4, verify at the "
              "anchor in one multi-query step, rewind what it rejects "
              "(docs/serving_internals.md §9)")
        #   +4 draft-ahead tokens per slot past max_new — the verify
        #   frontier runs k positions past the committed length
        spec_pages = 4 * -(-(8 + 10 + 4) // 8) + 1
        runs = {}
        for label, sc in (("plain", None),
                          ("spec", SpecConfig(draft_fmt="mxint4", k=4))):
            e = ElasticEngine(api, anchor, batch_slots=4, max_len=64,
                              param_template=params, kv_layout="paged",
                              kv_page_size=8, kv_num_pages=spec_pages,
                              attn_impl=args.attn_impl, speculative=sc)
            rs = [Request(rid=400 + i,
                          prompt=np.random.default_rng(5)
                          .integers(0, cfg.vocab, (8, 8))[i % 2]
                          .astype(np.int32), max_new=10)
                  for i in range(6)]
            e.generate(rs, greedy=True, fmt_override="mxint8")
            runs[label] = (e, [list(r.out_tokens) for r in rs])
        (ep, sp), (es, ss) = runs["plain"], runs["spec"]
        ssst = es.stats
        print(f"  streams bit-identical to plain anchor decode: {sp == ss}")
        print(f"  decode ticks {ep.stats['ticks']} -> {ssst['ticks']} "
              f"({ssst['spec_ticks']} spec ticks, acceptance rate "
              f"{ssst['spec_acceptance_rate']:.2f}, "
              f"{ssst['spec_accepted']} drafts accepted / "
              f"{ssst['spec_rejected']} rewound)")
        print(f"  pages {ssst['kv_pages_alloc']} alloc / "
              f"{ssst['kv_pages_freed']} freed — rollback returns "
              "draft-ahead pages exactly")
        print()

    if args.slo:
        from repro.serve.slo import CostModel, SLOClass
        print("SLO TIERS: a latency-tier trickle shares the engine with a "
              "best-effort burst arriving at tick 2; admission_order='slo' "
              "serves the latency tenant first and the policy picks the "
              "widest rung whose measured cost fits its TPOT budget "
              "(docs/serving_internals.md §10)")
        pol = FormatPolicy(anchor="mxint8",
                           ladder=((12, "mxint4"), (0, "mxint8")),
                           hysteresis=1,
                           cost=CostModel.from_roofline(
                               cfg, ("mxint4", "mxint8"), max_len=64,
                               kv_layout="paged", kv_page_size=8))
        slo_eng = ElasticEngine(api, anchor, batch_slots=2, max_len=64,
                                policy=pol, param_template=params,
                                kv_layout="paged", kv_page_size=8,
                                kv_num_pages=17,
                                admission_order="slo")
        reqs = [Request(rid=500 + i, prompt=rng.integers(0, cfg.vocab, 8)
                        .astype(np.int32), max_new=6, tenant="burst",
                        arrival_tick=2) for i in range(4)] + \
               [Request(rid=504, prompt=rng.integers(0, cfg.vocab, 8)
                        .astype(np.int32), max_new=6, tenant="vip",
                        arrival_tick=2,
                        slo=SLOClass.latency(ttft_ms=1e4, tpot_ms=1e4))]
        slo_eng.generate(reqs)
        for r in sorted(reqs, key=lambda r: (r.admitted_tick, r.rid)):
            tier = r.slo.tier if r.slo else "best_effort"
            print(f"  req {r.rid} [{r.tenant}/{tier}]: arrived t="
                  f"{r.arrival_tick} admitted t={r.admitted_tick} "
                  f"fmt={r.fmt_used} n_out={len(r.out_tokens)}")
        terms = slo_eng.stats["cost_model"]
        for fmt, t in sorted(terms.items()):
            print(f"  cost[{fmt}]: predict_1row="
                  f"{t['predict_1row_ms']:.2f}ms after "
                  f"{t['ticks_observed']} clean decode ticks "
                  f"(factor {t['factor']:.0f}x roofline on this backend)")
        print()

    print("LOW LOAD: 3 requests")
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8)
                    .astype(np.int32), max_new=6) for i in range(3)]
    eng.generate(reqs)
    for r in reqs:
        print(f"  req {r.rid}: fmt={r.fmt_used} tokens={r.out_tokens}")

    print("\nSTAGGERED: lengths differ, slots retire and refill "
          "independently")
    reqs = [Request(rid=50 + i, prompt=rng.integers(0, cfg.vocab, 8)
                    .astype(np.int32), max_new=3 + 2 * i) for i in range(6)]
    eng.generate(reqs)
    for r in reqs:
        print(f"  req {r.rid}: fmt={r.fmt_used} n_out={len(r.out_tokens)}")

    print("\nBURST: 20 requests")
    reqs = [Request(rid=100 + i, prompt=rng.integers(0, cfg.vocab, 8)
                    .astype(np.int32), max_new=6) for i in range(20)]
    eng.generate(reqs)
    fmts = sorted({r.fmt_used for r in reqs})
    print(f"  formats used across the burst: {fmts}")

    print("\nDEGRADATION LADDER: mxint4 turns out numerically bad at "
          "runtime (injected NaN logits, fmt-scoped) — the guard escalates "
          "the live batch one rung toward the anchor, replays the tick, "
          "and quarantines the bad rung; survivors keep streaming")
    fi = FaultInjector(poison_logits={2: None}, poison_fmt="mxint4")
    chaos = ElasticEngine(api, anchor, batch_slots=4, max_len=64,
                          policy=FormatPolicy(
                              anchor="mxint8",
                              ladder=((12, "mxint4"), (6, "mxint6"),
                                      (0, "mxint8")), hysteresis=1),
                          param_template=params, kv_layout="paged",
                          kv_page_size=8, kv_num_pages=13,
                          fault_injector=fi)
    reqs = [Request(rid=300 + i, prompt=rng.integers(0, cfg.vocab, 8)
                    .astype(np.int32), max_new=6) for i in range(4)]
    chaos.generate(reqs, fmt_override="mxint4")
    cs = chaos.stats
    for ev in cs["escalation_events"]:
        print(f"  tick {ev['tick']}: {ev['from']} -> {ev['to']} "
              f"(quarantined: {sorted(chaos.policy.quarantined)})")
    print(f"  faults detected={cs['faults_detected']} "
          f"ticks replayed={cs['ticks_replayed']} "
          f"statuses={cs['request_statuses']} "
          f"pages {cs['kv_pages_alloc']} alloc / "
          f"{cs['kv_pages_freed']} freed")
    for r in reqs:
        print(f"  req {r.rid}: fmt={r.fmt_used} status={r.status.value} "
              f"n_out={len(r.out_tokens)}")

    st = eng.stats
    contract = "fused Pallas dequant-GEMM" if st["fused"] \
        else "XLA densify-inside-jit"
    print(f"\nengine stats: ticks={st['ticks']} tokens={st['tokens_out']} "
          f"swaps={st['fmt_swaps']} prefill_compiles={st['prefill_traces']} "
          f"contract={contract}")
    for fmt in st["formats_cached"]:
        print(f"  {fmt:>7}: containers={st['containers'][fmt]} "
              f"weight_bytes={st['weight_bytes'][fmt]}")
    print(f"kv cache: layout={st['kv_layout']} "
          f"bytes/slot={st['kv_bytes_per_slot']} "
          f"(pool={st['kv_total_pages']} pages x {st['kv_page_size']} tok, "
          f"high-water {st['kv_pages_hwm']}, "
          f"{st['kv_pages_alloc']} allocs / {st['kv_pages_freed']} frees "
          "-> pages recycled across the burst)")
    print(f"decode attention: impl={st['attn_impl']} "
          f"read {st['attn_read_bytes']} KV bytes total "
          f"({st['attn_tokens_read']} token-positions; the gather path "
          "spans the full logical view every tick, the paged kernel only "
          "the live pages)")
    print("one anchor checkpoint served "
          f"{len(st['formats_cached'])} precisions; each decode tick streams "
          "the PACKED bytes above, not dense bf16.")


if __name__ == "__main__":
    main()
