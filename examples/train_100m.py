"""End-to-end driver: multi-format QAT fine-tune of a ~100M-param model for a
few hundred steps with checkpointing + fault tolerance (deliverable (b)).

The full smollm-135m config IS the ~100M-class model; on this CPU container
we default to --layers 6 (a ~30M slice of the same architecture) so the run
finishes in minutes. Pass --layers 30 for the full depth.

  PYTHONPATH=src python examples/train_100m.py --steps 200
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.qat import QATConfig  # noqa: E402
from repro.data.pipeline import DataConfig, LMDataset  # noqa: E402
from repro.models import get_model  # noqa: E402
from repro.optim.adamw import AdamWConfig  # noqa: E402
from repro.train.loop import LoopConfig, run_training  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--schedule", default="multiformat",
                    choices=["multiformat", "interleaved", "fp"])
    ap.add_argument("--ckpt", default="out/ckpt_100m")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    cfg = dataclasses.replace(cfg, n_layers=args.layers,
                              compute_dtype=jnp.float32, seq_chunk=256)
    qat = QATConfig(formats=("mxint2", "mxint4", "mxint6", "mxint8"),
                    block_size=32)
    api = get_model(cfg, qat)
    data = LMDataset(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                global_batch=args.batch))

    from repro.models.common import count_params
    import jax
    n = count_params(jax.eval_shape(api.init_params,
                                    jax.random.PRNGKey(0)))
    print(f"{args.arch} @ {args.layers}L: {n / 1e6:.1f}M params, "
          f"{args.steps} steps, schedule={args.schedule}")
    print(f"checkpoints -> {args.ckpt} (auto-resumes if present)")

    t0 = time.time()
    out = run_training(
        api, data, AdamWConfig(lr=args.lr),
        LoopConfig(total_steps=args.steps, schedule=args.schedule,
                   ckpt_dir=args.ckpt, ckpt_every=50),
        on_step=lambda s, m: print(
            f"step {s:4d} fmt={m['fmt_idx']} loss={m['loss']:.4f} "
            f"gnorm={m['grad_norm']:.2f} {m['sec'] * 1e3:.0f}ms")
        if s % 10 == 0 else None)
    dt = time.time() - t0
    hist = out["history"]
    print(f"\ndone: {len(hist)} steps in {dt:.0f}s "
          f"({dt / max(len(hist), 1) * 1e3:.0f} ms/step)")
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}; "
          f"stragglers flagged: {len(out['stragglers'])}")


if __name__ == "__main__":
    main()
