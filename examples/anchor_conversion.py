"""Anchor-format conversion walkthrough (§3.3/§3.4 numerics, visible).

Shows the Slice-and-Scale mechanics on real tensors: scales match direct
quantization EXACTLY, element codes differ by at most 1 ulp, and the packed
checkpoint sizes step down 8 -> 4 -> 2 bits.
"""
import sys

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core import (dequantize, get_format, quantize,  # noqa: E402
                        slice_and_scale)
from repro.core.packed import pack_np  # noqa: E402


def main():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(256, 1024)).astype(np.float32) * 0.02)

    print("=== SSMXINT: 8-bit anchor -> {6,4,3,2} bits ===")
    hi = quantize(w, get_format("mxint8", 32), axis=-1)
    print(f"anchor mxint8: codes int8 {hi.codes.shape}, "
          f"scales int8 {hi.scale_exp.shape}")
    for b in (6, 4, 3, 2):
        lo_fmt = get_format(f"mxint{b}", 32)
        ss = slice_and_scale(hi, lo_fmt)
        direct = quantize(w, lo_fmt, axis=-1)
        scale_eq = bool(jnp.all(ss.scale_exp == direct.scale_exp))
        code_diff = int(jnp.max(jnp.abs(ss.codes.astype(jnp.int32)
                                        - direct.codes.astype(jnp.int32))))
        mse_ss = float(jnp.mean((w - dequantize(ss)) ** 2))
        mse_dr = float(jnp.mean((w - dequantize(direct)) ** 2))
        packed, _ = pack_np(np.asarray(ss.codes), b)
        print(f"mxint{b}: scales==direct: {scale_eq}  "
              f"max|code diff|: {code_diff}  "
              f"mse ss/direct: {mse_ss / mse_dr:.3f}  "
              f"packed: {packed.nbytes / 1024:.0f} kB")

    print("\n=== SSMXFP: e4m3 anchor -> e3m3, e3m2, e2m2, e2m1 ===")
    hif = quantize(w, get_format("mxfp8", 32), axis=-1)
    for b in (7, 6, 5, 4):
        lo_fmt = get_format(f"mxfp{b}", 32)
        ss = slice_and_scale(hif, lo_fmt)
        direct = quantize(w, lo_fmt, axis=-1)
        scale_eq = bool(jnp.all(ss.scale_exp == direct.scale_exp))
        mse_ss = float(jnp.mean((w - dequantize(ss)) ** 2))
        mse_dr = float(jnp.mean((w - dequantize(direct)) ** 2))
        print(f"mxfp{b} (e{lo_fmt.ebits}m{lo_fmt.mbits}): "
              f"scales==direct: {scale_eq}  "
              f"mse ss/direct: {mse_ss / mse_dr:.3f}")

    print("\nSS never touches FP32 master weights: MXINT is an integer "
          "shift-round on packed codes; MXFP re-rounds element values. "
          "Scales are exactly the direct-quantization scales (Eq. 4/6).")


if __name__ == "__main__":
    main()
