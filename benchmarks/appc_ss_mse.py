"""Appendix C (Figs 19-20): tensor-level reconstruction MSE.

Exact reproduction of the paper's protocol: average layer-wise MSE on 100
random tensors of shape (1, 1024); direct MXINT/MXFP quantization vs
Slice-and-Scale conversion from the 8-bit anchor. Two sweeps: bit precision
at block size 64, and block size at 4-bit precision.
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import (dequantize, get_format, quantize, slice_and_scale)


def mse_direct(v, fmt):
    return float(jnp.mean((v - dequantize(quantize(v, fmt))) ** 2))


def mse_ss(v, high, low):
    t = slice_and_scale(quantize(v, high), low)
    return float(jnp.mean((v - dequantize(t)) ** 2))


def run(n_tensors=100, dim=1024, seed=0):
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.normal(size=(n_tensors, dim)).astype(np.float32))
    rows = []

    # Sweep 1: bit precision at block size 64 (Figs 19/20 left)
    for kind, bits_list, anchor_b in (("int", range(2, 9), 8),
                                      ("fp", range(4, 9), 8)):
        hi = get_format(f"mx{kind}{anchor_b}", 64)
        for b in bits_list:
            lo = get_format(f"mx{kind}{b}", 64)
            rows.append({
                "sweep": "bits@bs64", "kind": kind, "bits": b,
                "block_size": 64,
                "mse_direct": mse_direct(v, lo),
                "mse_ss": mse_ss(v, hi, lo) if b < anchor_b else
                mse_direct(v, lo),
            })

    # Sweep 2: block size at 4-bit (Figs 19/20 right)
    for kind in ("int", "fp"):
        for bs in (16, 32, 64, 128, 256):
            hi = get_format(f"mx{kind}8", bs)
            lo = get_format(f"mx{kind}4", bs)
            rows.append({
                "sweep": "bs@4bit", "kind": kind, "bits": 4,
                "block_size": bs,
                "mse_direct": mse_direct(v, lo),
                "mse_ss": mse_ss(v, hi, lo),
            })
    return rows


def main(csv=True):
    t0 = time.time()
    rows = run()
    us = (time.time() - t0) * 1e6 / len(rows)
    worst_ratio = max(r["mse_ss"] / max(r["mse_direct"], 1e-30)
                      for r in rows)
    if csv:
        print("# appc_ss_mse: direct vs slice-and-scale reconstruction MSE")
        print("sweep,kind,bits,block_size,mse_direct,mse_ss,ratio")
        for r in rows:
            print(f'{r["sweep"]},{r["kind"]},{r["bits"]},{r["block_size"]},'
                  f'{r["mse_direct"]:.3e},{r["mse_ss"]:.3e},'
                  f'{r["mse_ss"] / max(r["mse_direct"], 1e-30):.3f}')
    print(f"appc_ss_mse,{us:.0f},worst_ss_over_direct={worst_ratio:.3f}")
    return rows


if __name__ == "__main__":
    main()
