"""§Roofline: combine the dry-run artifacts with the analytic cost model.

Per (arch x shape) on the single-pod 16x16 mesh:
  compute term    = FLOPs / (chips x 197 TFLOP/s)
  memory term     = HBM bytes / (chips-local x 819 GB/s)
  collective term = per-chip collective bytes / 50 GB/s link
plus MODEL_FLOPS = 6·N·D (train) / 2·N·D (serve), the useful-compute ratio,
the dominant term, and the compile-verified memory footprint from the
dry-run JSON. Writes a markdown table for EXPERIMENTS.md.
"""
import argparse
import glob
import json
import os

from repro.configs import SHAPES, applicable, get_config, list_archs
from repro.launch import costmodel as cm

MESH = cm.MeshDesc(pod=1, data=16, model=16)


def load_dryrun(out_dir, arch, shape, mesh="16x16", variant="baseline"):
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh}__{variant}.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return None


def cell(arch, shape_name, out_dir, weight_bits=16):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not applicable(cfg, shape):
        return None
    r = cm.roofline(cfg, shape, MESH, weight_bits_decode=weight_bits)
    dr = load_dryrun(out_dir, arch, shape_name)
    if dr and dr.get("status") == "ok":
        r["compiled"] = True
        r["temp_gib"] = dr["memory"]["temp_size_in_bytes"] / 2 ** 30
        r["arg_gib"] = dr["memory"]["argument_size_in_bytes"] / 2 ** 30
        r["hlo_collectives"] = {k: v for k, v in dr["collectives"].items()
                                if v > 0 and k != "total_weighted"}
    else:
        r["compiled"] = bool(dr)
        r["temp_gib"] = r["arg_gib"] = float("nan")
    return r


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.0f}us"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="out/dryrun")
    ap.add_argument("--md", default="out/roofline.md")
    args = ap.parse_args()

    lines = ["| arch | shape | t_comp | t_mem | t_coll | dominant | "
             "roofline_frac | useful(6ND/HLO) | temp GiB/dev | args GiB/dev |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    print("name,us_per_call,derived")
    for arch in list_archs():
        for shape_name in SHAPES:
            r = cell(arch, shape_name, args.out)
            if r is None:
                lines.append(f"| {arch} | {shape_name} | — | — | — | "
                             f"skipped (full attn @500k) | — | — | — | — |")
                continue
            lines.append(
                f"| {arch} | {shape_name} | {fmt_s(r['t_compute'])} | "
                f"{fmt_s(r['t_memory'])} | {fmt_s(r['t_collective'])} | "
                f"{r['dominant']} | {r['roofline_fraction']:.2f} | "
                f"{min(r['useful_ratio'], 9.99):.2f} | "
                f"{r['temp_gib']:.1f} | {r['arg_gib']:.2f} |")
            print(f"roofline_{arch}_{shape_name},"
                  f"{r['step_time_lower_bound'] * 1e6:.0f},"
                  f"dom={r['dominant']}:frac={r['roofline_fraction']:.2f}")
    os.makedirs(os.path.dirname(args.md), exist_ok=True)
    with open(args.md, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"# wrote {args.md}")


if __name__ == "__main__":
    main()
