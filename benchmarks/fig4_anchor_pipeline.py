"""Figure 4: the full pipeline — multi-format QAT *with anchor storage* (§3.5)
vs plain multi-format QAT.

Anchor variant trains with W_t = Q_{A->t}(Q_A(W)) (STE through both) cycling
target formats uniformly, stores only the anchor, and serves every format via
SS. Claim C3: the SS-anchored curve closely matches plain MF-QAT across the
precision range (MXINT nearly indistinguishable; small MXFP gap at
intermediate widths).
"""
import time

from benchmarks._qat_harness import (EVAL_MXFP, EVAL_MXINT, HarnessConfig,
                                     eval_ppl, train_variant)


def run(kind="mxint"):
    if kind == "mxint":
        fmts, evals, anchor = (("mxint2", "mxint4", "mxint6", "mxint8"),
                               EVAL_MXINT, "mxint8")
    else:
        fmts, evals, anchor = (("mxfp4", "mxfp6", "mxfp8"), EVAL_MXFP,
                               "mxfp8")

    plain = train_variant(HarnessConfig(train_formats=fmts), "multiformat")
    anchored = train_variant(
        HarnessConfig(train_formats=fmts, anchor=anchor), "interleaved")

    rows = []
    for ef in evals:
        hc = HarnessConfig(train_formats=fmts, anchor=anchor)
        p_plain = eval_ppl(plain["cfg"], plain["api"], plain["params"],
                           ef, hc)
        p_anchor_ss = eval_ppl(anchored["cfg"], anchored["api"],
                               anchored["params"], ef, hc,
                               use_anchor_ss=True)
        rows.append({"fmt": ef, "ppl_multiformat": p_plain,
                     "ppl_anchor_ss": p_anchor_ss})
    return rows


def main():
    t0 = time.time()
    worst = 0.0
    for kind in ("mxint", "mxfp"):
        rows = run(kind)
        print(f"# fig4 {kind}: plain MF-QAT vs MF-QAT + anchor storage + SS")
        print("fmt,ppl_multiformat,ppl_anchor_ss,rel_gap")
        for r in rows:
            gap = abs(r["ppl_anchor_ss"] - r["ppl_multiformat"]) \
                / r["ppl_multiformat"]
            worst = max(worst, gap)
            print(f'{r["fmt"]},{r["ppl_multiformat"]:.3f},'
                  f'{r["ppl_anchor_ss"]:.3f},{gap:.4f}')
    print(f"fig4_anchor_pipeline,{(time.time() - t0) * 1e6:.0f},"
          f"worst_rel_gap={worst:.4f}")


if __name__ == "__main__":
    main()
