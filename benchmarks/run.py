"""Benchmark driver: one benchmark per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows per benchmark. Heavy QAT
benchmarks train 6 model variants each; pass --fast to skip the two longest
(fig1 / table12).
"""
import argparse
import sys
import time
import traceback


def _run(name, fn):
    print(f"\n===== {name} =====", flush=True)
    t0 = time.time()
    try:
        fn()
        print(f"[{name}] ok in {time.time() - t0:.1f}s", flush=True)
        return True
    except Exception:
        traceback.print_exc()
        print(f"{name},FAILED,", flush=True)
        return False


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    args, rest = ap.parse_known_args()
    sys.argv = [sys.argv[0]]     # sub-benchmarks parse argv themselves

    from benchmarks import (appc_ss_mse, fig1_multiformat_qat, fig23_ss_ppl,
                            fig4_anchor_pipeline, kernels_bench, perf_ladder,
                            roofline, table12_downstream)

    benches = [
        ("appc_ss_mse", appc_ss_mse.main),
        ("fig23_ss_ppl", fig23_ss_ppl.main),
        ("fig4_anchor_pipeline", fig4_anchor_pipeline.main),
        ("kernels_bench", kernels_bench.main),
        ("roofline", roofline.main),
        ("perf_ladder", perf_ladder.main),
    ]
    if not args.fast:
        benches.insert(1, ("fig1_multiformat_qat", fig1_multiformat_qat.main))
        benches.insert(4, ("table12_downstream", table12_downstream.main))

    ok = True
    for name, fn in benches:
        if args.only and args.only != name:
            continue
        ok &= _run(name, fn)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
