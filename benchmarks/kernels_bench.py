"""Kernel microbenchmarks: wall-clock of the jit'd MX ops on this host +
bytes accounting (the HBM-traffic contract the TPU kernels are built to).

CPU wall-clock is not TPU performance; it validates that the fused paths do
less work than the unfused ones and provides the us_per_call CSV row format.

Extras:
  --smoke     fast CI gate: asserts the qmatmul dispatch layer really routes
              to the Pallas kernels (trace-time counters) and matches the
              dense reference — a silent regression to the densify fallback
              fails the build.
  --autotune  sweep tile candidates for the serving GEMM shapes and register
              the winners in the dispatch tile cache (per (shape, fmt)).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from repro.core import get_format                             # noqa: E402
from repro.core.mx import (dequantize, quantize,              # noqa: E402
                           quantize_dequantize)
from repro.core.slice_scale import slice_and_scale            # noqa: E402
from repro.kernels import dispatch, ops                       # noqa: E402
from repro.kernels import paged_attention as pattn            # noqa: E402
from repro.serve.packed_params import pack_leaf_int4          # noqa: E402


def timeit(fn, *args, n=20):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def _leaf_for(w, fmt):
    t = quantize(w, fmt, axis=0)
    if fmt.kind == "int" and fmt.bits == 4:
        return pack_leaf_int4(t)
    return t


# =============================================================================
# qmatmul tile autotuning — winners cached per (shape, fmt) in the dispatch
# tile table so subsequent traces pick them up automatically.
# =============================================================================
def _tile_candidates(m, k, n, fmt, kind):
    bs = fmt.block_size
    n_eff = n // 2 if kind == "int4" else n
    cands = []
    for tm in (8, 32, 128):
        for tn in (64, 128, 256):
            for tk in (bs, 4 * bs, 8 * bs):
                if tm <= max(m, 8) * 4 and tn <= max(n_eff, 64) * 2 \
                        and tk <= max(k, bs) * 2:
                    cands.append((tm, tn, tk))
    base = dispatch.select_tiles(m, k, n, fmt, kind)
    return [base] + [c for c in cands if c != base]


def autotune_qmatmul(m, k, n, fmt_name, *, n_iter=5, verbose=False):
    """Sweep tile candidates for one (M, K, N, fmt) qmatmul; register the
    winner via ``dispatch.register_tiles``. Returns (tiles, us_per_call)."""
    fmt = get_format(fmt_name, 32)
    int4 = fmt.kind == "int" and fmt.bits == 4
    kind = "int4" if int4 else "mx"
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    leaf = _leaf_for(w, fmt)

    best, best_us = None, float("inf")
    for tiles in _tile_candidates(m, k, n, fmt, kind):
        fn = jax.jit(lambda xx, tiles=tiles: dispatch.qmatmul(
            xx, leaf, mode="pallas", tiles=tiles))
        try:
            us = timeit(fn, x, n=n_iter)
        except Exception:          # tile combo the kernel rejects: skip
            continue
        if verbose:
            print(f"#   {fmt_name} ({m},{k},{n}) tiles={tiles}: {us:.1f}us")
        if us < best_us:
            best, best_us = tiles, us
    if best is None:
        raise RuntimeError(
            f"autotune: every tile candidate failed for "
            f"{fmt_name} ({m},{k},{n}) — run one candidate outside the "
            "sweep to see the kernel error")
    dispatch.register_tiles(m, k, n, fmt_name, best, kind,
                            block_size=fmt.block_size)
    return best, best_us


def run_autotune(verbose=True):
    shapes = [(8, 1024, 4096), (8, 4096, 1024), (64, 1024, 1024)]
    rows = []
    for fmt_name in ("mxint8", "mxint4"):
        for (m, k, n) in shapes:
            tiles, us = autotune_qmatmul(m, k, n, fmt_name, verbose=verbose)
            rows.append((f"autotune_{fmt_name}_{m}x{k}x{n}", us,
                         f"tm{tiles[0]}_tn{tiles[1]}_tk{tiles[2]}"))
    return rows


# =============================================================================
# --smoke: the dispatch layer must actually hit the Pallas kernels
# =============================================================================
def smoke():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 128)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(128, 160)).astype(np.float32))
    for fmt_name, counter in (("mxint8", "pallas"), ("mxfp8", "pallas"),
                              ("mxint4", "pallas_int4")):
        fmt = get_format(fmt_name, 32)
        leaf = _leaf_for(w, fmt)
        t = quantize(w, fmt, axis=0)
        want = np.asarray(x @ dequantize(t, jnp.float32))
        dispatch.reset_stats()
        got = np.asarray(dispatch.qmatmul(x, leaf, mode="pallas"))
        st = dispatch.stats()
        assert st[counter] >= 1 and st["densify"] == 0, (
            f"{fmt_name}: dispatch regressed to the fallback: {st}")
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)
        ref = np.asarray(dispatch.qmatmul(x, leaf, mode="densify"))
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-4)
        print(f"smoke {fmt_name}: pallas path live, parity ok ({st})")

    # Paged decode attention: the gather-free kernel must be the path that
    # actually traces under mode="pallas", and must match the gather +
    # masked-softmax fallback on the same pool/block-table.
    b, mp, ps, hkv, g, d = 2, 4, 8, 2, 2, 16
    n_pages = b * mp + 1
    q = jnp.asarray(rng.normal(size=(b, 1, hkv * g, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pages, ps, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, ps, hkv, d)), jnp.float32)
    bt = np.zeros((b, mp), np.int32)
    perm = rng.permutation(np.arange(1, n_pages))
    lens = [9, 24]
    for i, n in enumerate(lens):
        k = -(-n // ps)
        bt[i, :k] = perm[i * mp:i * mp + k]
    bt = jnp.asarray(bt)
    cl = jnp.asarray(lens, jnp.int32)
    pattn.reset_stats()
    got = np.asarray(pattn.paged_decode_attention(q, kp, vp, bt, cl,
                                                  mode="pallas"))
    st = pattn.stats()
    assert st["pallas"] >= 1 and st["fallback"] == 0, (
        f"paged attention regressed to the gather fallback: {st}")
    ref = np.asarray(pattn.paged_decode_attention(q, kp, vp, bt, cl,
                                                  mode="fallback"))
    assert pattn.stats()["fallback"] >= 1
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)
    print(f"smoke paged_attention: pallas path live, parity ok ({st})")

    # Multi-query paged attention (the mixed prefill+decode tick): the MQ
    # kernel must be the traced path under mode="pallas" and must match the
    # gather + masked-softmax fallback on ragged query spans — one chunk row
    # straddling a page boundary, one decode row.
    c = 8
    qm = jnp.asarray(rng.normal(size=(b, c, hkv * g, d)), jnp.float32)
    qo = jnp.asarray([7, 23], jnp.int32)     # row 0: chunk at cursor 7
    ql = jnp.asarray([8, 1], jnp.int32)      # row 1: plain decode
    pattn.reset_stats()
    got = np.asarray(pattn.paged_mixed_attention(qm, kp, vp, bt, qo, ql,
                                                 mode="pallas"))
    st = pattn.stats()
    assert st["pallas_mq"] >= 1 and st["fallback_mq"] == 0, (
        f"mixed paged attention regressed to the gather fallback: {st}")
    ref = np.asarray(pattn.paged_mixed_attention(qm, kp, vp, bt, qo, ql,
                                                 mode="fallback"))
    assert pattn.stats()["fallback_mq"] >= 1
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)
    print(f"smoke paged_attention_mq: pallas path live, parity ok ({st})")
    print("smoke: OK")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast dispatch-layer gate (CI)")
    ap.add_argument("--autotune", action="store_true",
                    help="sweep qmatmul tiles for the serving shapes")
    args = ap.parse_args()

    if args.smoke:
        smoke()
        return

    rng = np.random.default_rng(0)
    shape = (1024, 4096)
    w = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(64, 1024)).astype(np.float32))
    fmt8 = get_format("mxint8", 32)
    fmt4 = get_format("mxint4", 32)

    rows = []

    f_quant = jax.jit(lambda v: quantize(v, fmt8, axis=0).codes)
    rows.append(("core_quantize_mxint8", timeit(f_quant, w),
                 f"{np.prod(shape)} elems"))

    f_fq = jax.jit(lambda v: quantize_dequantize(v, fmt8, axis=0))
    rows.append(("core_fake_quant_mxint8", timeit(f_fq, w), "fused"))

    t8 = quantize(w, fmt8, axis=0)
    f_ss = jax.jit(lambda t: slice_and_scale(t, fmt4).codes)
    rows.append(("core_ss_8to4", timeit(f_ss, t8), "packed-domain"))

    f_deq_mm = jax.jit(lambda xx, t: xx @ dequantize(t, jnp.float32))
    rows.append(("xla_dequant_matmul_int8", timeit(f_deq_mm, x, t8),
                 "XLA fused"))

    # dispatch layer: fused Pallas vs densify fallback on the same leaf
    f_disp_p = jax.jit(lambda xx: dispatch.qmatmul(x=xx, leaf=t8,
                                                   mode="pallas"))
    rows.append(("dispatch_qmatmul_pallas", timeit(f_disp_p, x, n=3),
                 "interpret on cpu"))
    f_disp_d = jax.jit(lambda xx: dispatch.qmatmul(x=xx, leaf=t8,
                                                   mode="densify"))
    rows.append(("dispatch_qmatmul_densify", timeit(f_disp_d, x),
                 "XLA fallback"))

    # Pallas kernels (interpret mode on CPU — correctness-path timing only)
    codes, scales = ops.to_weight_layout(t8)
    rows.append(("pallas_mx_matmul_interp",
                 timeit(lambda: ops.mx_matmul(x, codes, scales, fmt8,
                                              interpret=True), n=3),
                 "interpret=True"))
    rows.append(("pallas_fake_quant_interp",
                 timeit(lambda: ops.fake_quant(w, fmt8, axis=0,
                                               interpret=True), n=3),
                 "interpret=True"))

    if args.autotune:
        rows.extend(run_autotune())

    # bytes accounting: serving weight-read sizes per format
    n_el = int(np.prod(shape))
    for bits, name in ((16, "bf16"), (8, "mxint8"), (4, "mxint4_packed")):
        b = n_el * bits // 8 + (n_el // 32 if bits < 16 else 0)
        rows.append((f"weight_bytes_{name}", 0.0, f"{b}"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
