"""Kernel microbenchmarks: wall-clock of the jit'd MX ops on this host +
bytes accounting (the HBM-traffic contract the TPU kernels are built to).

CPU wall-clock is not TPU performance; it validates that the fused paths do
less work than the unfused ones and provides the us_per_call CSV row format.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import get_format
from repro.core.mx import dequantize, quantize, quantize_dequantize
from repro.core.slice_scale import slice_and_scale
from repro.kernels import ops


def timeit(fn, *args, n=20):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def main():
    rng = np.random.default_rng(0)
    shape = (1024, 4096)
    w = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(64, 1024)).astype(np.float32))
    fmt8 = get_format("mxint8", 32)
    fmt4 = get_format("mxint4", 32)

    rows = []

    f_quant = jax.jit(lambda v: quantize(v, fmt8, axis=0).codes)
    rows.append(("core_quantize_mxint8", timeit(f_quant, w),
                 f"{np.prod(shape)} elems"))

    f_fq = jax.jit(lambda v: quantize_dequantize(v, fmt8, axis=0))
    rows.append(("core_fake_quant_mxint8", timeit(f_fq, w), "fused"))

    t8 = quantize(w, fmt8, axis=0)
    f_ss = jax.jit(lambda t: slice_and_scale(t, fmt4).codes)
    rows.append(("core_ss_8to4", timeit(f_ss, t8), "packed-domain"))

    f_deq_mm = jax.jit(lambda xx, t: xx @ dequantize(t, jnp.float32))
    rows.append(("xla_dequant_matmul_int8", timeit(f_deq_mm, x, t8),
                 "XLA fused"))

    # Pallas kernels (interpret mode on CPU — correctness-path timing only)
    codes, scales = ops.to_weight_layout(t8)
    rows.append(("pallas_mx_matmul_interp",
                 timeit(lambda: ops.mx_matmul(x, codes, scales, fmt8,
                                              interpret=True), n=3),
                 "interpret=True"))
    rows.append(("pallas_fake_quant_interp",
                 timeit(lambda: ops.fake_quant(w, fmt8, axis=0,
                                               interpret=True), n=3),
                 "interpret=True"))

    # bytes accounting: serving weight-read sizes per format
    n_el = int(np.prod(shape))
    for bits, name in ((16, "bf16"), (8, "mxint8"), (4, "mxint4_packed")):
        b = n_el * bits // 8 + (n_el // 32 if bits < 16 else 0)
        rows.append((f"weight_bytes_{name}", 0.0, f"{b}"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
