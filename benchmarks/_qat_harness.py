"""Shared harness for the paper-reproduction benchmarks.

Trains reduced-config models on the deterministic synthetic corpus under the
paper's exact protocol shapes (FP fine-tune / single-format QAT / multi-format
QAT / anchor-storage QAT), then evaluates WikiText-2-style perplexity after
PTQ to each evaluation format (paper §3.2 'Evaluation': every variant is
converted to the target format before measurement).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core import get_format, ptq_pytree
from repro.core.qat import QATConfig
from repro.data.pipeline import DataConfig, LMDataset, eval_batches
from repro.models import get_model
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, run_training

EVAL_MXINT = [f"mxint{b}" for b in range(2, 9)]
EVAL_MXFP = [f"mxfp{b}" for b in range(4, 9)]


@dataclasses.dataclass
class HarnessConfig:
    arch: str = "qwen3-4b"            # reduced family proxy
    train_formats: Sequence[str] = ("mxint2", "mxint4", "mxint6", "mxint8")
    anchor: Optional[str] = None
    block_size: int = 32
    n_examples: int = 128             # paper: 128 WikiText-2 examples
    seq_len: int = 64
    batch: int = 8
    epochs_per_format: int = 1
    lr: float = 5e-4                  # QA-finetune lr (paper sweeps 1e-4..)
    pretrain_steps: int = 600         # paper starts from PRETRAINED models
    pretrain_lr: float = 2e-3
    seed: int = 0
    n_eval_batches: int = 8

    def cache_key(self) -> str:
        return f"{self.arch}_s{self.seed}_p{self.pretrain_steps}"


def _build(hc: HarnessConfig, schedule: str):
    cfg = get_reduced(hc.arch)
    qat = QATConfig(formats=tuple(hc.train_formats), anchor=hc.anchor,
                    block_size=hc.block_size)
    api = get_model(cfg, qat)
    data = LMDataset(DataConfig(vocab=cfg.vocab, seq_len=hc.seq_len,
                                global_batch=hc.batch,
                                n_examples=hc.n_examples, seed=hc.seed))
    total = data.epoch_steps() * hc.epochs_per_format * len(hc.train_formats)
    return cfg, api, data, total


_BASE_CACHE: Dict[str, object] = {}


def pretrained_base(hc: HarnessConfig):
    """Pretrain (once, cached in-process and on disk) the shared base model —
    the stand-in for the paper's pretrained HF checkpoints."""
    import os
    key = hc.cache_key()
    if key in _BASE_CACHE:
        return _BASE_CACHE[key]
    cfg = get_reduced(hc.arch)
    api = get_model(cfg, None)
    ckdir = os.path.join("out", "bench_base", key)
    from repro.checkpoint import io as ckpt_io
    import jax as _jax
    template = _jax.eval_shape(api.init_params,
                               _jax.random.PRNGKey(hc.seed))
    if ckpt_io.latest_step(ckdir) == hc.pretrain_steps:
        params, _ = ckpt_io.restore(ckdir, template)
        params = _jax.tree_util.tree_map(jnp.asarray, params)
    else:
        data = LMDataset(DataConfig(vocab=cfg.vocab, seq_len=hc.seq_len,
                                    global_batch=16, seed=hc.seed))
        out = run_training(api, data, AdamWConfig(lr=hc.pretrain_lr),
                           LoopConfig(total_steps=hc.pretrain_steps,
                                      schedule="fp"),
                           seed=hc.seed)
        params = out["state"].params
        ckpt_io.save(ckdir, hc.pretrain_steps, params, keep_n=1)
    _BASE_CACHE[key] = params
    return params


def train_variant(hc: HarnessConfig, schedule: str) -> Dict:
    """Fine-tune FROM the pretrained base under the given schedule.

    schedule: 'fp' | 'multiformat' | 'interleaved' | 'single:<pos>'.
    """
    from repro.optim.adamw import init_opt_state
    from repro.train.state import TrainState, build_train_step
    from repro.train.loop import make_schedule
    import jax as _jax

    cfg, api, data, total = _build(hc, schedule)
    base = pretrained_base(hc)
    opt_cfg = AdamWConfig(lr=hc.lr)
    n_formats = len(hc.train_formats)
    sched = make_schedule(schedule if schedule != "fp" else "fp",
                          n_formats, total)
    step_fn = _jax.jit(build_train_step(api, opt_cfg))
    state = TrainState(
        params=_jax.tree_util.tree_map(jnp.asarray, base),
        opt=init_opt_state(base, opt_cfg),
        step=jnp.zeros((), jnp.int32))
    history = []
    for step in range(total):
        batch = _jax.tree_util.tree_map(jnp.asarray, data.batch_at(step))
        state, metrics = step_fn(state, batch, jnp.int32(sched[step]))
        history.append({k: float(v) for k, v in metrics.items()})
    return {"cfg": cfg, "api": api, "params": state.params,
            "history": history}


def eval_ppl(cfg, api, params, fmt_name: Optional[str],
             hc: HarnessConfig, use_anchor_ss: bool = False) -> float:
    """PTQ params to fmt (direct, or via anchor+SS) and measure eval PPL."""
    qcfg = QATConfig(formats=("mxint8",), block_size=hc.block_size)
    if fmt_name is None:
        p_eval = params
    elif use_anchor_ss:
        from repro.core import convert, dequantize, make_anchor
        anchor_fmt = get_format(hc.anchor or
                                ("mxint8" if fmt_name.startswith("mxint")
                                 else "mxfp8"), hc.block_size)
        am = make_anchor(params, qcfg, anchor_fmt)
        low = convert(am, get_format(fmt_name, hc.block_size))
        from repro.core.anchor import materialize
        p_eval = materialize(low, params, dtype=jnp.float32)
    else:
        p_eval = ptq_pytree(params, qcfg, get_format(fmt_name, hc.block_size))

    batches = eval_batches(DataConfig(vocab=cfg.vocab, seq_len=hc.seq_len,
                                      global_batch=hc.batch,
                                      seed=hc.seed),
                           hc.n_eval_batches)
    if not hasattr(api, "_jit_ce"):
        api._jit_ce = jax.jit(
            lambda p, b: api.train_loss(p, b, None)[1]["ce"])
    loss_fn = api._jit_ce
    losses = [float(loss_fn(p_eval, jax.tree_util.tree_map(jnp.asarray, b)))
              for b in batches]
    return float(np.exp(np.mean(losses)))


def eval_accuracy(cfg, api, params, fmt_name: Optional[str],
                  hc: HarnessConfig) -> float:
    """Held-out next-token top-1 accuracy (the downstream-task stand-in)."""
    qcfg = QATConfig(formats=("mxint8",), block_size=hc.block_size)
    p_eval = params if fmt_name is None else \
        ptq_pytree(params, qcfg, get_format(fmt_name, hc.block_size))
    batches = eval_batches(DataConfig(vocab=cfg.vocab, seq_len=hc.seq_len,
                                      global_batch=hc.batch, seed=hc.seed),
                           hc.n_eval_batches)

    from repro.models.transformer import (_embed, _lm_head_w, forward_hidden)
    from repro.models.common import QuantCtx

    @jax.jit
    def acc_fn(p, tokens, labels):
        x = _embed(p, cfg, tokens)
        pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                               (x.shape[0], x.shape[1]))
        hid, _, _ = forward_hidden(QuantCtx(), p, cfg, x, pos)
        logits = hid.astype(jnp.float32) @ _lm_head_w(p, cfg) \
            .astype(jnp.float32)
        pred = jnp.argmax(logits, -1)
        return jnp.mean((pred == labels).astype(jnp.float32))

    accs = [float(acc_fn(p_eval, jnp.asarray(b["tokens"]),
                         jnp.asarray(b["labels"]))) for b in batches]
    return float(np.mean(accs))
