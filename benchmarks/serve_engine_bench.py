"""Engine-level serving benchmark: fused-kernel vs densify-inside-jit.

Runs the packed-weight continuous-batching ElasticEngine at dense bf16,
mxint8 (MXTensor codes) and mxint4 (split-N nibble-packed) under BOTH
packed-serving contracts — the Pallas dequant-GEMM dispatch (``fused``) and
the XLA densify-inside-jit fallback (``densify``) — and reports one table:

  - tokens_per_tick: generated tokens / decode ticks (continuous batching
    keeps slots full, so this approaches batch_slots under load)
  - weight_bytes_per_token: the roofline weight-read term — bytes one decode
    tick must stream for the weight pytree, divided by tokens/tick. This is
    the quantity the paper's §3.5 claim is about: packed mxint8/mxint4 cut it
    ~2x/~4x vs dense bf16 (exact ratio depends on the raw-leaf fraction).
    Identical across paths by construction (same packed tree) — the fused
    rows demonstrate the bytes contract is served by the explicit kernels,
    not just hoped for from XLA fusion.
  - kv_bytes_per_slot: resident KV-cache HBM divided by batch slots. The
    dense layout commits max_len tokens per slot up front; the paged layout
    (kv_layout="paged") commits only the page pool, which this bench sizes
    to the workload's live-token demand — the measured (not asserted) memory
    win of block-table paging. Token streams are bit-identical across
    layouts, so the kv rows differ ONLY in this column and wall time.

CPU wall-clock is reported for completeness but is NOT the serving claim —
off-TPU the fused path runs the Pallas interpreter (slow, correctness-only)
and the dequant is not the bottleneck; the bytes column is the modeled
HBM-bound behavior the TPU kernels realize.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax               # noqa: E402
import numpy as np       # noqa: E402

from repro.configs import get_reduced                  # noqa: E402
from repro.core import get_format, make_anchor         # noqa: E402
from repro.core.qat import QATConfig                   # noqa: E402
from repro.models import get_model                     # noqa: E402
from repro.serve.engine import ElasticEngine, Request  # noqa: E402

FORMATS = ("bf16", "mxint8", "mxint4")
PROMPT_LEN = 8


def bench_path(api, anchor, params, fmt, fused, *, slots, max_len,
               n_requests, max_new, vocab, kv_layout="dense", page_size=8):
    kv_kw = {}
    if kv_layout == "paged":
        # Size the pool to the workload's live-token demand (prompt +
        # generated tokens per slot), NOT to slots*max_len — that sizing
        # freedom is the whole point of paging.
        per_slot = -(-(PROMPT_LEN + max_new) // page_size)
        kv_kw = dict(kv_layout="paged", kv_page_size=page_size,
                     kv_num_pages=slots * per_slot + 1)
    eng = ElasticEngine(api, anchor, batch_slots=slots, max_len=max_len,
                        param_template=params, fused=fused, **kv_kw)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, vocab, PROMPT_LEN)
                    .astype(np.int32),
                    max_new=max_new) for i in range(n_requests)]
    eng.generate(reqs[:1], fmt_override=fmt)    # warmup: compile + SS pass
    t0 = time.perf_counter()
    ticks0, toks0 = eng.stats["ticks"], eng.stats["tokens_out"]
    eng.generate(reqs[1:], fmt_override=fmt)
    dt = time.perf_counter() - t0
    st = eng.stats
    ticks = st["ticks"] - ticks0
    # decode tokens only: each admission also samples one token from its
    # prefill logits, which costs no decode tick — excluding them keeps
    # tokens/tick <= batch_slots and bytes/token an honest roofline term
    toks = st["tokens_out"] - toks0 - (len(reqs) - 1)
    wbytes = st["weight_bytes"][fmt]
    tpt = toks / max(ticks, 1)
    return {
        "fmt": fmt,
        "path": ("fused" if fused else "densify") if fmt != "bf16"
                else "dense",
        "kv": kv_layout,
        "containers": "+".join(st["containers"][fmt]),
        "weight_bytes": wbytes,
        "ticks": ticks,
        "tokens": toks,
        "tokens_per_tick": tpt,
        "weight_bytes_per_token": wbytes / max(tpt, 1e-9),
        "kv_bytes_per_slot": st["kv_bytes_per_slot"],
        "wall_s": dt,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=9)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--paths", default="both",
                    choices=("both", "fused", "densify"),
                    help="packed-serving contract(s) to benchmark")
    ap.add_argument("--kv", default="both",
                    choices=("both", "dense", "paged"),
                    help="KV-cache layout(s) to benchmark")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page for the paged layout")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    qat = QATConfig(formats=("mxint4", "mxint8"), anchor="mxint8",
                    block_size=32)
    anchor = make_anchor(params, qat, get_format("mxint8", 32))

    kw = dict(slots=args.slots, max_len=args.max_len,
              n_requests=args.requests, max_new=args.max_new,
              vocab=cfg.vocab, page_size=args.page_size)
    want_fused = args.paths in ("both", "fused")
    want_dense = args.paths in ("both", "densify")
    layouts = ("dense", "paged") if args.kv == "both" else (args.kv,)
    rows = []
    for kv in layouts:
        for fmt in FORMATS:
            if fmt == "bf16":  # dense pseudo-format: one path, no packing
                rows.append(bench_path(api, anchor, params, fmt, False,
                                       kv_layout=kv, **kw))
                continue
            if want_fused:
                rows.append(bench_path(api, anchor, params, fmt, True,
                                       kv_layout=kv, **kw))
            if want_dense:
                rows.append(bench_path(api, anchor, params, fmt, False,
                                       kv_layout=kv, **kw))

    base = next(r for r in rows if r["fmt"] == "bf16")
    # KV ratios are vs the DENSE layout; without a dense row (--kv paged)
    # there is no baseline to compare against, so print n/a rather than a
    # misleading same-layout 1.00x.
    kv_base = next((r for r in rows if r["kv"] == "dense"), None)
    print("fmt,path,kv,containers,weight_bytes,ticks,tokens,tokens_per_tick,"
          "weight_bytes_per_token,bytes_cut_vs_bf16,kv_bytes_per_slot,"
          "kv_cut_vs_dense,wall_s")
    for r in rows:
        cut = base["weight_bytes_per_token"] / r["weight_bytes_per_token"]
        kv_cut = "n/a" if kv_base is None else \
            f"{kv_base['kv_bytes_per_slot'] / max(r['kv_bytes_per_slot'], 1):.2f}x"
        print(f"{r['fmt']},{r['path']},{r['kv']},{r['containers']},"
              f"{r['weight_bytes']},{r['ticks']},{r['tokens']},"
              f"{r['tokens_per_tick']:.2f},"
              f"{r['weight_bytes_per_token']:.0f},{cut:.2f}x,"
              f"{r['kv_bytes_per_slot']},{kv_cut},"
              f"{r['wall_s']:.2f}")


if __name__ == "__main__":
    main()
