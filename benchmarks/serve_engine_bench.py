"""Engine-level serving benchmark: fused-kernel vs densify-inside-jit.

Runs the packed-weight continuous-batching ElasticEngine at dense bf16,
mxint8 (MXTensor codes) and mxint4 (split-N nibble-packed) under BOTH
packed-serving contracts — the Pallas dequant-GEMM dispatch (``fused``) and
the XLA densify-inside-jit fallback (``densify``) — and reports one table:

  - tokens_per_tick: generated tokens / decode ticks (continuous batching
    keeps slots full, so this approaches batch_slots under load)
  - weight_bytes_per_token: the roofline weight-read term — bytes one decode
    tick must stream for the weight pytree, divided by tokens/tick. This is
    the quantity the paper's §3.5 claim is about: packed mxint8/mxint4 cut it
    ~2x/~4x vs dense bf16 (exact ratio depends on the raw-leaf fraction).
    Identical across paths by construction (same packed tree) — the fused
    rows demonstrate the bytes contract is served by the explicit kernels,
    not just hoped for from XLA fusion.

CPU wall-clock is reported for completeness but is NOT the serving claim —
off-TPU the fused path runs the Pallas interpreter (slow, correctness-only)
and the dequant is not the bottleneck; the bytes column is the modeled
HBM-bound behavior the TPU kernels realize.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax               # noqa: E402
import numpy as np       # noqa: E402

from repro.configs import get_reduced                  # noqa: E402
from repro.core import get_format, make_anchor         # noqa: E402
from repro.core.qat import QATConfig                   # noqa: E402
from repro.models import get_model                     # noqa: E402
from repro.serve.engine import ElasticEngine, Request  # noqa: E402

FORMATS = ("bf16", "mxint8", "mxint4")


def bench_path(api, anchor, params, fmt, fused, *, slots, max_len,
               n_requests, max_new, vocab):
    eng = ElasticEngine(api, anchor, batch_slots=slots, max_len=max_len,
                        param_template=params, fused=fused)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, vocab, 8).astype(np.int32),
                    max_new=max_new) for i in range(n_requests)]
    eng.generate(reqs[:1], fmt_override=fmt)    # warmup: compile + SS pass
    t0 = time.perf_counter()
    ticks0, toks0 = eng.stats["ticks"], eng.stats["tokens_out"]
    eng.generate(reqs[1:], fmt_override=fmt)
    dt = time.perf_counter() - t0
    st = eng.stats
    ticks = st["ticks"] - ticks0
    # decode tokens only: each admission also samples one token from its
    # prefill logits, which costs no decode tick — excluding them keeps
    # tokens/tick <= batch_slots and bytes/token an honest roofline term
    toks = st["tokens_out"] - toks0 - (len(reqs) - 1)
    wbytes = st["weight_bytes"][fmt]
    tpt = toks / max(ticks, 1)
    return {
        "fmt": fmt,
        "path": ("fused" if fused else "densify") if fmt != "bf16"
                else "dense",
        "containers": "+".join(st["containers"][fmt]),
        "weight_bytes": wbytes,
        "ticks": ticks,
        "tokens": toks,
        "tokens_per_tick": tpt,
        "weight_bytes_per_token": wbytes / max(tpt, 1e-9),
        "wall_s": dt,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=9)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--paths", default="both",
                    choices=("both", "fused", "densify"),
                    help="packed-serving contract(s) to benchmark")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    qat = QATConfig(formats=("mxint4", "mxint8"), anchor="mxint8",
                    block_size=32)
    anchor = make_anchor(params, qat, get_format("mxint8", 32))

    kw = dict(slots=args.slots, max_len=args.max_len,
              n_requests=args.requests, max_new=args.max_new,
              vocab=cfg.vocab)
    want_fused = args.paths in ("both", "fused")
    want_dense = args.paths in ("both", "densify")
    rows = []
    for fmt in FORMATS:
        if fmt == "bf16":      # dense pseudo-format: one path, no packing
            rows.append(bench_path(api, anchor, params, fmt, False, **kw))
            continue
        if want_fused:
            rows.append(bench_path(api, anchor, params, fmt, True, **kw))
        if want_dense:
            rows.append(bench_path(api, anchor, params, fmt, False, **kw))

    base = next(r for r in rows if r["fmt"] == "bf16")
    print("fmt,path,containers,weight_bytes,ticks,tokens,tokens_per_tick,"
          "weight_bytes_per_token,bytes_cut_vs_bf16,wall_s")
    for r in rows:
        cut = base["weight_bytes_per_token"] / r["weight_bytes_per_token"]
        print(f"{r['fmt']},{r['path']},{r['containers']},"
              f"{r['weight_bytes']},{r['ticks']},{r['tokens']},"
              f"{r['tokens_per_tick']:.2f},"
              f"{r['weight_bytes_per_token']:.0f},{cut:.2f}x,"
              f"{r['wall_s']:.2f}")


if __name__ == "__main__":
    main()
