"""Engine-level serving benchmark: fused-kernel vs densify-inside-jit,
dense vs paged KV, monolithic vs chunked prefill admission, and gather vs
gather-free paged decode attention.

Runs the packed-weight continuous-batching ElasticEngine at dense bf16,
mxint8 (MXTensor codes) and mxint4 (split-N nibble-packed) under BOTH
packed-serving contracts — the Pallas dequant-GEMM dispatch (``fused``) and
the XLA densify-inside-jit fallback (``densify``) — and reports one table:

  - tokens_per_tick: generated tokens / decode ticks (continuous batching
    keeps slots full, so this approaches batch_slots under load)
  - weight_bytes_per_token: the roofline weight-read term — bytes one decode
    tick must stream for the weight pytree, divided by tokens/tick. This is
    the quantity the paper's §3.5 claim is about: packed mxint8/mxint4 cut it
    ~2x/~4x vs dense bf16 (exact ratio depends on the raw-leaf fraction).
    Identical across paths by construction (same packed tree) — the fused
    rows demonstrate the bytes contract is served by the explicit kernels,
    not just hoped for from XLA fusion.
  - kv_bytes_per_slot: resident KV-cache HBM divided by batch slots. The
    dense layout commits max_len tokens per slot up front; the paged layout
    (kv_layout="paged") commits only the page pool, which this bench sizes
    to the workload's live-token demand — the measured (not asserted) memory
    win of block-table paging. Token streams are bit-identical across
    layouts, so the kv rows differ ONLY in this column and wall time.
  - attn_bytes_per_token: decode-attention KV reads per generated token
    (per-layer K+V bytes actually spanned, from the engine's host-side
    accounting). The paged rows run BOTH attn impls: ``gather``
    materializes every slot's full logical view (max_pages×page_size
    tokens) each tick, the gather-free kernel (``paged_kernel``,
    kernels/paged_attention.py) reads only ``ceil(cache_len/page)`` pages
    per slot — the measured roofline win of block-table attention. Token
    streams are bit-identical across impls; the bench verifies that like
    the cross-admission check.
  - ttft_p50_ms / ttft_p99_ms / stall_p99_ms / max_pf_tok: the admission
    latency columns. The workload mixes short prompts with long ones
    (every ``--long-every``-th request is ``--long-len`` tokens), and the
    engine's per-tick trace records how much prefill work shared a tick
    with decoding. Monolithic admission stalls every running slot for a
    whole prompt (max_pf_tok ~ the long bucket; stall_p99 ~ a full
    prefill); chunked admission (``prefill_chunk``) bounds per-tick prefill
    work to one chunk, so the decode-stall tail collapses while token
    streams stay BIT-IDENTICAL — the bench verifies that identity and
    prints it.
  - decode_occupancy / tick_exec / adm_decode_tpt: the unified-tick
    columns, from the engine's per-tick ``rows`` / ``decode_rows`` /
    ``execs`` counters. ``decode_occupancy`` is the fraction of dispatched
    batch rows that were live decoders; ``tick_exec`` the mean executables
    per work tick — 1.0 under ``scheduler="mixed"`` (the chunk rides the
    decode batch), up to 2.0 under ``"sequential"`` (chunk then decode);
    ``adm_decode_tpt`` the decode tokens per tick over ticks that carried
    prefill work — the "decode does not starve during a long admission"
    number, comparable against the monolithic baseline rows. The chunked
    rows run BOTH schedulers and the bench verifies their token streams
    are bit-identical, same as the cross-admission check.

CPU wall-clock is reported for completeness but is NOT the serving claim —
off-TPU the fused path runs the Pallas interpreter (slow, correctness-only)
and the dequant is not the bottleneck; the bytes column is the modeled
HBM-bound behavior the TPU kernels realize. The *relative* stall/TTFT tail
between admission modes, however, is a scheduling property and survives the
interpreter overhead.
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax               # noqa: E402
import numpy as np       # noqa: E402

from repro.configs import get_reduced                  # noqa: E402
from repro.core import get_format, make_anchor         # noqa: E402
from repro.core.qat import QATConfig                   # noqa: E402
from repro.models import get_model                     # noqa: E402
from repro.serve.engine import ElasticEngine, Request  # noqa: E402

FORMATS = ("bf16", "mxint8", "mxint4")
PROMPT_LEN = 8
WARMUP = 2               # first short + first long request: compiles every
#                          prefill bucket / chunk executable before timing


def _pct(xs, q):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))] if xs else 0.0


def bench_path(api, anchor, params, fmt, fused, *, slots, max_len,
               n_requests, max_new, vocab, kv_layout="dense", page_size=8,
               admission="monolithic", prefill_chunk=8, long_every=3,
               long_len=40, attn_impl="gather", scheduler="sequential"):
    kv_kw = {}
    if kv_layout == "paged":
        # Size the pool to the workload's live-token demand (longest prompt
        # + generated tokens per slot), NOT to slots*max_len — that sizing
        # freedom is the whole point of paging.
        per_slot = -(-(long_len + max_new) // page_size)
        kv_kw = dict(kv_layout="paged", kv_page_size=page_size,
                     kv_num_pages=slots * per_slot + 1,
                     attn_impl=attn_impl)
    eng = ElasticEngine(
        api, anchor, batch_slots=slots, max_len=max_len,
        param_template=params, fused=fused,
        prefill_chunk=prefill_chunk if admission == "chunked" else None,
        scheduler=scheduler if admission == "chunked" else None,
        **kv_kw)
    rng = np.random.default_rng(0)
    # every long_every-th request is long (long_every=1 => all long); the
    # offset keeps one long prompt inside the warmup window so its bucket /
    # chunk executables compile before timing starts
    is_long = lambda i: i % long_every == 1 % long_every
    reqs = [Request(rid=i,
                    prompt=rng.integers(
                        0, vocab,
                        long_len if is_long(i) else PROMPT_LEN)
                    .astype(np.int32),
                    max_new=max_new) for i in range(n_requests)]
    eng.generate(reqs[:WARMUP], fmt_override=fmt)  # warmup: compile + SS
    t0 = time.perf_counter()
    ticks0, toks0 = eng.stats["ticks"], eng.stats["tokens_out"]
    attn0 = eng.stats["attn_read_bytes"]
    eng.generate(reqs[WARMUP:], fmt_override=fmt)
    dt = time.perf_counter() - t0
    st = eng.stats
    ticks = st["ticks"] - ticks0
    # decode tokens only: each admission also samples one token from its
    # prefill logits, which costs no decode tick — excluding them keeps
    # tokens/tick <= batch_slots and bytes/token an honest roofline term
    toks = st["tokens_out"] - toks0 - (len(reqs) - WARMUP)
    wbytes = st["weight_bytes"][fmt]
    tpt = toks / max(ticks, 1)
    ttfts = [r.ttft_s for r in reqs[WARMUP:]]
    stalls = [t["wall_s"] for t in eng.tick_trace if t["decode"]]
    work = [t for t in eng.tick_trace if t["rows"] > 0]
    adm = [t for t in eng.tick_trace if t["prefill_tokens"] > 0]
    return {
        "fmt": fmt,
        "path": ("fused" if fused else "densify") if fmt != "bf16"
                else "dense",
        "kv": kv_layout,
        "attn": st["attn_impl"],
        "attn_bytes_per_token": (st["attn_read_bytes"] - attn0)
        / max(toks, 1),
        "admission": admission,
        "scheduler": eng.scheduler,
        "decode_occupancy": sum(t["decode_rows"] for t in work)
        / max(sum(t["rows"] for t in work), 1),
        "tick_exec": sum(t["execs"] for t in work) / max(len(work), 1),
        "adm_decode_tpt": sum(t["decode_rows"] for t in adm)
        / max(len(adm), 1),
        "adm_decode_tps": sum(t["decode_rows"] for t in adm)
        / max(sum(t["wall_s"] for t in adm), 1e-9),
        "containers": "+".join(st["containers"][fmt]),
        "weight_bytes": wbytes,
        "ticks": ticks,
        "tokens": toks,
        "tokens_per_tick": tpt,
        "weight_bytes_per_token": wbytes / max(tpt, 1e-9),
        "kv_bytes_per_slot": st["kv_bytes_per_slot"],
        "ttft_p50_ms": _pct(ttfts, 0.50) * 1e3,
        "ttft_p99_ms": _pct(ttfts, 0.99) * 1e3,
        "stall_p99_ms": _pct(stalls, 0.99) * 1e3,
        "max_pf_tok": max((t["prefill_tokens"] for t in eng.tick_trace),
                          default=0),
        "wall_s": dt,
        "streams": [list(r.out_tokens) for r in reqs],
    }


def bench_chaos(api, anchor, params, *, slots, max_len, n_requests,
                max_new, vocab, rates, seed=0):
    """The --chaos sweep (docs/serving_internals.md §7): one row per fault
    rate, all at the ANCHOR rung so every injected fault is either
    recovered by a same-format replay (transient crash), absorbed by the
    capacity path (alloc failure -> requeue), or confined to one request
    (row poison -> FAILED_NUMERIC). Two hard gates, both process-failing:

      - stream identity: every request that COMPLETED under chaos carries a
        token stream bit-identical to the fault-free (rate 0) run;
      - page accounting: kv_pages_alloc == kv_pages_freed at drain — chaos
        must not leak the free list.

    A final "ladder" demo row starts at mxint4 with a format-following
    poison and reports the escalation walk instead of the identity gate
    (its streams are the escalated rung's, deliberately different)."""
    from repro.runtime.fault import FaultInjector, random_plan
    from repro.serve.engine import RequestStatus
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, vocab, PROMPT_LEN).astype(np.int32)
               for _ in range(n_requests)]

    def run(fi, fmt):
        eng = ElasticEngine(api, anchor, batch_slots=slots, max_len=max_len,
                            param_template=params, kv_layout="paged",
                            kv_page_size=8, fault_injector=fi)
        reqs = [Request(rid=i, prompt=prompts[i].copy(), max_new=max_new)
                for i in range(n_requests)]
        t0 = time.perf_counter()
        eng.generate(reqs, fmt_override=fmt)
        dt = time.perf_counter() - t0
        st = eng.stats
        if st["kv_pages_alloc"] != st["kv_pages_freed"]:
            raise SystemExit(
                f"chaos leaked KV pages: {st['kv_pages_alloc']} allocated, "
                f"{st['kv_pages_freed']} freed")
        if not all(r.done and r.status.terminal for r in reqs):
            raise SystemExit("chaos left a request without a terminal "
                             "status")
        return eng, reqs, st, dt

    print("chaos,fault_rate,injected,recovered_ticks,escalations,"
          "completed,failed_numeric,failed_capacity,timed_out,cancelled,"
          "requeues,tokens,wall_s")

    def emit(label, rate, fi, eng, reqs, st, dt):
        counts = st["request_statuses"]
        print(f"{label},{rate},{len(fi.events) if fi else 0},"
              f"{st['ticks_replayed']},{st['fmt_escalations']},"
              f"{counts.get('completed', 0)},"
              f"{counts.get('failed_numeric', 0)},"
              f"{counts.get('failed_capacity', 0)},"
              f"{counts.get('timed_out', 0)},{counts.get('cancelled', 0)},"
              f"{st['admission_requeues']},"
              f"{sum(len(r.out_tokens) for r in reqs)},{dt:.2f}")

    base_streams = None
    for rate in rates:
        fi = random_plan(seed, rate, horizon=64, slots=slots) \
            if rate > 0 else None
        eng, reqs, st, dt = run(fi, "mxint8")
        emit("sweep", rate, fi, eng, reqs, st, dt)
        streams = {r.rid: list(r.out_tokens) for r in reqs
                   if r.status is RequestStatus.COMPLETED}
        if rate == 0:
            base_streams = streams
        elif base_streams is not None:
            diverged = [rid for rid, s in streams.items()
                        if base_streams.get(rid) != s]
            if diverged:
                raise SystemExit(
                    f"chaos rate {rate}: surviving streams diverged from "
                    f"the fault-free run for rids {diverged} — fault "
                    "isolation broke bit-identity")
    if base_streams is not None and len(rates) > 1:
        print("# chaos survivors bit-identical to the fault-free run "
              "across all rates = True")

    # Degradation-ladder demo: a rung that fails at runtime walks toward
    # the anchor and the wave still completes.
    fi = FaultInjector(poison_logits={2: None}, poison_fmt="mxint4")
    eng, reqs, st, dt = run(fi, "mxint4")
    emit("ladder", "-", fi, eng, reqs, st, dt)
    ev = st["escalation_events"]
    print(f"# ladder: {' -> '.join([ev[0]['from']] + [e['to'] for e in ev])}"
          f" (quarantined: {','.join(st['quarantined_formats'])}); "
          f"completed {st['request_statuses'].get('completed', 0)}"
          f"/{n_requests}")


def bench_speculative(api, anchor, params, *, slots, max_len, n_requests,
                      max_new, vocab, draft_fmt="mxint4", k=4, page_size=8,
                      long_every=3, long_len=40):
    """The --speculative sweep (docs/serving_internals.md §9): plain anchor
    decode vs self-speculative decode (draft at ``draft_fmt``, verify at the
    pinned anchor rung) over both packed contracts x both paged attention
    impls. Two outputs:

      - an acceptance column set: spec_ticks, acceptance_rate,
        accepted_tok_per_tick — the measured usefulness of the cheap rung's
        guesses on this workload;
      - a HARD stream-identity gate (process-failing): every request's
        token stream under speculation must be bit-identical to plain
        anchor decode — speculation is a pure speed knob, never a token
        knob. A second gate requires a decode-tick win (fewer verify ticks
        than plain ticks for the same tokens): if drafting ever stops
        paying for itself on this deterministic workload, the bench fails
        rather than shipping a regression silently.
    """
    from repro.serve.policy import SpecConfig
    rng = np.random.default_rng(0)
    is_long = lambda i: i % long_every == 1 % long_every
    prompts = [rng.integers(0, vocab,
                            long_len if is_long(i) else PROMPT_LEN)
               .astype(np.int32) for i in range(n_requests)]
    # draft-ahead headroom: the verify frontier runs k tokens past the
    # committed length, so size the pool for it
    per_slot = -(-(long_len + max_new + k) // page_size)

    def run(spec, fused, attn):
        eng = ElasticEngine(
            api, anchor, batch_slots=slots, max_len=max_len,
            param_template=params, fused=fused, kv_layout="paged",
            kv_page_size=page_size, kv_num_pages=slots * per_slot + 1,
            attn_impl=attn, speculative=spec)
        reqs = [Request(rid=i, prompt=prompts[i].copy(), max_new=max_new)
                for i in range(n_requests)]
        eng.generate(reqs[:WARMUP], fmt_override="mxint8")
        t0 = time.perf_counter()
        ticks0 = eng.stats["ticks"]
        eng.generate(reqs[WARMUP:], fmt_override="mxint8")
        dt = time.perf_counter() - t0
        st = eng.stats
        if st["kv_pages_alloc"] != st["kv_pages_freed"]:
            raise SystemExit(
                f"speculative run leaked KV pages: {st['kv_pages_alloc']} "
                f"allocated, {st['kv_pages_freed']} freed")
        return (st["ticks"] - ticks0, st,
                [list(r.out_tokens) for r in reqs], dt)

    print("spec,path,attn,draft,k,ticks_plain,ticks_spec,spec_ticks,"
          "acceptance_rate,accepted_tok_per_tick,tok_per_tick_plain,"
          "tok_per_tick_spec,wall_plain_s,wall_spec_s")
    wins = []
    for fused in (False, True):
        for attn in ("gather", "paged_kernel"):
            ticks_p, _, streams_p, dt_p = run(None, fused, attn)
            sc = SpecConfig(draft_fmt=draft_fmt, k=k)
            ticks_s, st, streams_s, dt_s = run(sc, fused, attn)
            if streams_s != streams_p:
                raise SystemExit(
                    f"speculative streams diverged from plain anchor "
                    f"decode (fused={fused}, attn={attn}) — the draft/"
                    f"verify/rollback loop broke bit-identity")
            toks = sum(len(s) for s in streams_s[WARMUP:]) \
                - (n_requests - WARMUP)
            rate = st["spec_acceptance_rate"]
            acc_pt = st["spec_accepted"] / max(st["spec_ticks"], 1)
            path = "fused" if fused else "densify"
            print(f"spec,{path},{attn},{draft_fmt},{k},{ticks_p},{ticks_s},"
                  f"{st['spec_ticks']},"
                  f"{-1.0 if rate is None else rate:.2f},{acc_pt:.2f},"
                  f"{toks / max(ticks_p, 1):.2f},{toks / max(ticks_s, 1):.2f},"
                  f"{dt_p:.2f},{dt_s:.2f}")
            wins.append((ticks_p, ticks_s))
    print(f"# speculative vs plain: token streams identical across all "
          f"configs = True; decode ticks "
          f"{sum(p for p, _ in wins)} -> {sum(s for _, s in wins)} "
          f"({sum(p for p, _ in wins) / max(sum(s for _, s in wins), 1):.2f}x"
          f" cut at draft={draft_fmt}, k={k})")
    if not all(s < p for p, s in wins):
        raise SystemExit("speculation won no decode ticks — drafting is "
                         "not paying for itself on this workload")


def bench_mesh(api, anchor, params, *, mesh_spec, slots, max_len,
               n_requests, max_new, vocab, page_size=8, long_every=3,
               long_len=40):
    """The --mesh sweep (docs/serving_internals.md §11): the single-device
    engine vs the tensor-parallel engine on a (data, model) mesh, SAME
    workload, across {dense, paged} x every format. Two outputs:

      - a HARD stream-identity gate (process-failing): greedy and seeded
        token streams on the mesh must be bit-identical to the
        single-device engine — sharding is a placement knob, never a
        token knob;
      - the per-chip weight stream: each chip reads only its shard, so
        weight_bytes_per_chip must land near 1/n_model of the global
        bytes (replicated norm vectors keep it just above exact).

    On CPU run under XLA_FLAGS=--xla_force_host_platform_device_count=N
    to expose enough host devices.
    """
    from repro.launch.mesh import parse_mesh
    n_data, n_model = parse_mesh(mesh_spec)
    need = n_data * n_model
    if len(jax.devices()) < need:
        raise SystemExit(
            f"--mesh {mesh_spec} needs {need} devices; only "
            f"{len(jax.devices())} visible — on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
    mesh = jax.make_mesh((n_data, n_model), ("data", "model"))
    rng = np.random.default_rng(0)
    is_long = lambda i: i % long_every == 1 % long_every
    prompts = [rng.integers(0, vocab,
                            long_len if is_long(i) else PROMPT_LEN)
               .astype(np.int32) for i in range(n_requests)]
    per_slot = -(-(long_len + max_new) // page_size)

    def run(m, fmt, kv, greedy):
        kv_kw = dict(kv_layout="paged", kv_page_size=page_size,
                     kv_num_pages=slots * per_slot + 1) \
            if kv == "paged" else {}
        eng = ElasticEngine(api, anchor, batch_slots=slots,
                            max_len=max_len, param_template=params,
                            fused=False, seed=0, mesh=m, temperature=0.9,
                            top_p=0.95, **kv_kw)
        reqs = [Request(rid=i, prompt=prompts[i].copy(), max_new=max_new)
                for i in range(n_requests)]
        eng.generate(reqs[:WARMUP], fmt_override=fmt, greedy=greedy)
        t0 = time.perf_counter()
        ticks0 = eng.stats["ticks"]
        eng.generate(reqs[WARMUP:], fmt_override=fmt, greedy=greedy)
        dt = time.perf_counter() - t0
        st = eng.stats
        if st["kv_pages_alloc"] != st["kv_pages_freed"]:
            raise SystemExit(
                f"--mesh leaked KV pages: {st['kv_pages_alloc']} "
                f"allocated, {st['kv_pages_freed']} freed")
        return ([list(r.out_tokens) for r in reqs], st,
                st["ticks"] - ticks0, dt)

    print(f"# mesh {n_data}x{n_model} vs single device, "
          f"{n_requests} requests, slots={slots}")
    print("mesh,fmt,kv,sampling,weight_bytes,weight_bytes_per_chip,"
          "chip_ratio,ticks_single,ticks_mesh,wall_single_s,wall_mesh_s")
    checked = 0
    for kv in ("dense", "paged"):
        for fmt in FORMATS:
            for greedy in (True, False):
                s1, _, t1, w1 = run(None, fmt, kv, greedy)
                s2, st, t2, w2 = run(mesh, fmt, kv, greedy)
                if s1 != s2:
                    raise SystemExit(
                        f"--mesh streams diverged from the single-device "
                        f"engine (fmt={fmt}, kv={kv}, greedy={greedy}) — "
                        f"sharding broke bit-identity")
                checked += 1
                wb = st["weight_bytes"][fmt]
                wbc = st["weight_bytes_per_chip"][fmt]
                print(f"{st['mesh']},{fmt},{kv},"
                      f"{'greedy' if greedy else 'seeded'},{wb},{wbc},"
                      f"{wbc / wb:.3f},{t1},{t2},{w1:.2f},{w2:.2f}")
    print(f"# mesh vs single device: token streams identical across "
          f"{checked} configs = True")
    ratios = []
    for fmt in FORMATS:
        # per-chip stream must approach 1/n_model (norms stay replicated)
        eng = ElasticEngine(api, anchor, batch_slots=slots,
                            max_len=max_len, param_template=params,
                            fused=False, mesh=mesh)
        st_w = eng.weights_for(fmt)  # noqa: F841 — populates stats
        st = eng.stats
        ratios.append(st["weight_bytes_per_chip"][fmt]
                      / st["weight_bytes"][fmt])
    lo, hi = 1.0 / n_model, 1.0 / n_model + 0.06
    if not all(lo <= r < hi for r in ratios):
        raise SystemExit(
            f"per-chip weight stream ratios {ratios} outside "
            f"[{lo:.3f}, {hi:.3f}) — the packed leaves are not sharded")
    print(f"# per-chip weight stream: {ratios[0]:.3f}/"
          f"{ratios[1]:.3f}/{ratios[2]:.3f} of global bytes "
          f"(bf16/mxint8/mxint4) at n_model={n_model} = gate passed")


def bench_slo(api, anchor, params, *, slots, max_len, horizon, wl_seed,
              page_size=8, burst_thresh=6):
    """The --slo sweep (docs/serving_internals.md §10): SLO-tiered serving
    from the measured cost model vs the static queue-depth policy, on the
    SAME deterministic bursty multi-tenant workload.

    Run A (static): FIFO admission, threshold-table policy — the pre-SLO
    engine. It doubles as the calibration run: the per-tier TTFT budgets
    are set from ITS measured percentiles, so the attainment gates are
    machine-speed-independent. Run B (slo): tiered admission
    (latency > throughput > best-effort), roofline-seeded + online-
    calibrated CostModel driving the rung pick against the wave's tightest
    TPOT budget.

    Hard gates (process-failing):
      - page accounting: kv_pages_alloc == kv_pages_freed in both runs;
      - per-tier stream identity: every COMPLETED run-B request's stream
        is bit-identical to a plain non-SLO engine serving the same
        (rid, prompt) at run B's chosen format — SLO machinery moves
        requests and formats, never tokens;
      - tier ordering: run B's latency-tier TTFT attainment >= its
        throughput-tier's (same budget, so this isolates admission order);
      - the win: run B's latency-tier mean queue wait (ticks, arrival ->
        admission — deterministic) <= run A's, at equal-or-better
        aggregate decode ticks (B <= 1.05x A for the same token count).
    """
    from repro.serve.policy import FormatPolicy
    from repro.serve.slo import CostModel
    from repro.serve.engine import RequestStatus
    from workloads import (TenantSpec, default_tenants, generate_workload,
                           tenant_summary)

    cfg = api.cfg
    ladder = ((burst_thresh, "mxint4"), (0, "mxint8"))
    eng_kw = dict(batch_slots=slots, max_len=max_len, param_template=params,
                  fused=False, kv_layout="paged", kv_page_size=page_size,
                  prefill_chunk="auto")

    def make_workload(ttft_ms=None, tpot_ms=None):
        tenants = default_tenants(ttft_ms=ttft_ms, tpot_ms=tpot_ms)
        if ttft_ms is not None:
            # Same TTFT budget on the throughput tenant: the attainment
            # gap between tiers then measures admission order alone.
            tenants = [dataclasses.replace(t, ttft_ms=ttft_ms)
                       if t.tier == "throughput" else t for t in tenants]
        return tenants, generate_workload(
            tenants, horizon=horizon, vocab=cfg.vocab,
            prompt_cap=max_len - 1, seed=wl_seed)

    def run(reqs, policy, order):
        eng = ElasticEngine(api, anchor, policy=policy,
                            admission_order=order, **eng_kw)
        # Warm every ladder rung's executables (full + partial chunk,
        # decode) before the timed wave: TTFT budgets must measure
        # scheduling, not jit compiles — and the warmup's clean decode
        # ticks hand the cost model measured factors for BOTH rungs, so
        # run B's picks are cost-driven from its first wave.
        wrng = np.random.default_rng(2**20)
        for nf, wfmt in enumerate(dict.fromkeys(f for _, f in ladder)):
            eng.generate(
                [Request(rid=10_000 + 10 * nf + j,
                         prompt=wrng.integers(1, cfg.vocab, size=pl)
                         .astype(np.int32), max_new=3)
                 for j, pl in enumerate((8, 13))],
                fmt_override=wfmt)
        t0 = time.perf_counter()
        eng.generate(reqs)
        dt = time.perf_counter() - t0
        st = eng.stats
        if st["kv_pages_alloc"] != st["kv_pages_freed"]:
            raise SystemExit(
                f"--slo ({order}) leaked KV pages: "
                f"{st['kv_pages_alloc']} allocated, "
                f"{st['kv_pages_freed']} freed")
        return eng, st, dt

    def ttft_from_arrival_ms(r):
        if r.ttft_s is None or r.arrival_s is None:
            return None
        return (r.ttft_s - r.arrival_s) * 1e3

    def tier_of(r):
        return r.slo.tier if r.slo is not None else "best_effort"

    def tier_rows(reqs, ttft_budget_ms):
        rows = {}
        for tier in ("latency", "throughput", "best_effort"):
            sub = [r for r in reqs if tier_of(r) == tier]
            if not sub:
                continue
            ttfts = [t for t in map(ttft_from_arrival_ms, sub)
                     if t is not None]
            waits = [r.admitted_tick - r.arrival_tick for r in sub
                     if r.admitted_tick is not None]
            # Both runs score against the SAME calibrated budget (run A's
            # requests carry no SLOClass budgets — they predate the SLO)
            budget = ttft_budget_ms if tier != "best_effort" else None
            attain = None
            if budget is not None and ttfts:
                attain = sum(t <= budget for t in ttfts) / len(ttfts)
            rows[tier] = {
                "n": len(sub),
                "completed": sum(r.status is RequestStatus.COMPLETED
                                 for r in sub),
                "ttft_attain": attain,
                "ttft_p50_ms": _pct(ttfts, 0.5),
                "wait_p50": _pct(waits, 0.5),
                "wait_max": max(waits, default=0),
                "wait_mean": sum(waits) / max(len(waits), 1),
            }
        return rows

    # ---- run A: static queue-depth policy, FIFO admission (also the
    # budget-calibration run) ---------------------------------------------
    _, reqs_a = make_workload()
    pol_a = FormatPolicy(anchor="mxint8", ladder=ladder)
    eng_a, st_a, dt_a = run(reqs_a, pol_a, "fifo")
    ttfts_a = [t for t in map(ttft_from_arrival_ms, reqs_a)
               if t is not None]
    decode_walls = [t["wall_s"] * 1e3 for t in eng_a.tick_trace
                    if t["decode"]]
    ttft_budget = _pct(ttfts_a, 0.6)
    tpot_budget = _pct(decode_walls, 0.75)

    # ---- run B: measured-cost-model policy, tiered admission ------------
    _, reqs_b = make_workload(ttft_ms=ttft_budget, tpot_ms=tpot_budget)
    cost = CostModel.from_roofline(
        cfg, [f for _, f in ladder], max_len=max_len, kv_layout="paged",
        kv_page_size=page_size, block_size=32)
    pol_b = FormatPolicy(anchor="mxint8", ladder=ladder, cost=cost)
    eng_b, st_b, dt_b = run(reqs_b, pol_b, "slo")

    # ---- per-tier attainment table --------------------------------------
    rows_a = tier_rows(reqs_a, ttft_budget)
    rows_b = tier_rows(reqs_b, ttft_budget)
    toks_a = sum(len(r.out_tokens) for r in reqs_a)
    toks_b = sum(len(r.out_tokens) for r in reqs_b)
    print(f"# workload: {len(reqs_a)} requests / {horizon} arrival ticks "
          f"(seed {wl_seed}); budgets calibrated from run A: "
          f"ttft<={ttft_budget:.1f}ms (p60), tpot<={tpot_budget:.1f}ms "
          f"(p75 decode tick)")
    print("slo,run,tier,requests,completed,ttft_attain,ttft_p50_ms,"
          "wait_p50_ticks,wait_mean_ticks,wait_max_ticks")
    for label, rows in (("static", rows_a), ("slo", rows_b)):
        for tier, d in rows.items():
            att = "n/a" if d["ttft_attain"] is None \
                else f"{d['ttft_attain']:.2f}"
            print(f"slo,{label},{tier},{d['n']},{d['completed']},{att},"
                  f"{d['ttft_p50_ms']:.1f},{d['wait_p50']},"
                  f"{d['wait_mean']:.2f},{d['wait_max']}")
    for label, st, toks, dt, pol in (("static", st_a, toks_a, dt_a, pol_a),
                                     ("slo", st_b, toks_b, dt_b, pol_b)):
        fmts = ",".join(f"{f}:{pol.history.count(f)}"
                        for f in dict.fromkeys(pol.history))
        print(f"# {label}: {toks} tokens / {st['ticks']} decode ticks "
              f"({toks / max(st['ticks'], 1):.2f} tok/tick, "
              f"{toks / max(dt, 1e-9):.0f} tok/s wall), "
              f"requeues={st['admission_requeues']}, "
              f"failed_capacity="
              f"{st['request_statuses'].get('failed_capacity', 0)}, "
              f"picks=[{fmts}]")
    print("# per-tenant (slo run):")
    for name, d in sorted(tenant_summary(reqs_b).items()):
        print(f"#   {name}: {d['requests']} reqs, {d['tokens_out']} tok, "
              f"wait p50/max {d['wait_ticks_p50']}/{d['wait_ticks_max']} "
              f"ticks, statuses {d['statuses']}")
    if st_b["cost_model"]:
        terms = {f: f"{v['predict_1row_ms']:.2f}ms*"
                 if not v["ticks_observed"] else
                 f"{v['predict_1row_ms']:.2f}ms({v['ticks_observed']}t)"
                 for f, v in st_b["cost_model"].items()}
        print(f"# cost model (1-row tick, * = prior-only): {terms}")

    # ---- gate: per-format stream identity vs a plain non-SLO engine -----
    by_fmt = {}
    for r in reqs_b:
        if r.status is RequestStatus.COMPLETED:
            by_fmt.setdefault(r.fmt_used, []).append(r)
    for fmt, group in sorted(by_fmt.items()):
        eng_ref = ElasticEngine(api, anchor, **eng_kw)
        refs = [Request(rid=r.rid, prompt=np.asarray(r.prompt).copy(),
                        max_new=r.max_new) for r in group]
        eng_ref.generate(refs, fmt_override=fmt)
        diverged = [ref.rid for ref, r in zip(refs, group)
                    if ref.out_tokens != r.out_tokens]
        if diverged:
            raise SystemExit(
                f"--slo streams diverged from the plain non-SLO engine at "
                f"{fmt} for rids {diverged} — SLO machinery must never "
                f"change tokens")
    print(f"# streams bit-identical to the plain non-SLO engine across "
          f"{sum(len(g) for g in by_fmt.values())} completed requests in "
          f"{len(by_fmt)} format group(s) = True")

    # ---- gate: tier ordering within run B -------------------------------
    att_lat = rows_b.get("latency", {}).get("ttft_attain")
    att_thr = rows_b.get("throughput", {}).get("ttft_attain")
    if att_lat is not None and att_thr is not None and att_lat < att_thr:
        raise SystemExit(
            f"latency-tier TTFT attainment ({att_lat:.2f}) fell below "
            f"throughput-tier's ({att_thr:.2f}) under tiered admission")

    # ---- gate: the win over the static policy ---------------------------
    att_lat_a = rows_a.get("latency", {}).get("ttft_attain")
    if att_lat_a is not None and att_lat is not None \
            and att_lat < att_lat_a:
        raise SystemExit(
            f"slo run's latency-tier TTFT attainment ({att_lat:.2f}) fell "
            f"below the static policy's ({att_lat_a:.2f})")
    wait_a = rows_a.get("latency", {}).get("wait_mean", 0.0)
    wait_b = rows_b.get("latency", {}).get("wait_mean", 0.0)
    if wait_b > wait_a:
        raise SystemExit(
            f"slo run's latency-tier mean queue wait ({wait_b:.2f} ticks) "
            f"exceeds the static policy's ({wait_a:.2f}) — tiered "
            f"admission lost to FIFO")
    if st_b["ticks"] > 1.05 * max(st_a["ticks"], 1):
        raise SystemExit(
            f"slo run spent {st_b['ticks']} decode ticks vs the static "
            f"policy's {st_a['ticks']} (> 1.05x) — the SLO win is not "
            f"allowed to cost aggregate throughput")
    print(f"# gates: latency wait {wait_a:.2f} -> {wait_b:.2f} ticks "
          f"(static -> slo), attain lat/thr "
          f"{'n/a' if att_lat is None else f'{att_lat:.2f}'}/"
          f"{'n/a' if att_thr is None else f'{att_thr:.2f}'}, decode ticks "
          f"{st_a['ticks']} -> {st_b['ticks']} = all passed")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=9)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--paths", default="both",
                    choices=("both", "fused", "densify"),
                    help="packed-serving contract(s) to benchmark")
    ap.add_argument("--kv", default="both",
                    choices=("both", "dense", "paged"),
                    help="KV-cache layout(s) to benchmark")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page for the paged layout")
    ap.add_argument("--admission", default="both",
                    choices=("both", "monolithic", "chunked"),
                    help="prompt admission mode(s) to benchmark")
    ap.add_argument("--attn", default="both",
                    choices=("both", "gather", "paged_kernel"),
                    help="paged decode-attention impl(s) to benchmark "
                         "(paged rows only; dense KV has no block table)")
    ap.add_argument("--scheduler", default="both",
                    choices=("both", "sequential", "mixed"),
                    help="chunked-tick scheduler(s) to benchmark "
                         "(chunked rows only; monolithic admission has no "
                         "chunk to coalesce)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunk size for the chunked admission rows "
                         "(default: one KV page, min 8)")
    ap.add_argument("--long-every", type=int, default=3,
                    help="every Nth request gets the long prompt")
    ap.add_argument("--long-len", type=int, default=40,
                    help="long-prompt length (the admission-stall driver)")
    ap.add_argument("--chaos", action="store_true",
                    help="run the fault-injection sweep instead of the "
                         "perf matrix: seeded chaos at increasing fault "
                         "rates, with hard gates on survivor-stream "
                         "identity and page accounting, plus a format-"
                         "ladder degradation demo")
    ap.add_argument("--fault-rates", default="0,0.1,0.25",
                    help="comma-separated per-tick fault rates for --chaos")
    ap.add_argument("--speculative", action="store_true",
                    help="run the self-speculative sweep instead of the "
                         "perf matrix: plain vs draft-and-verify decode "
                         "with a hard stream-identity gate, an acceptance-"
                         "rate column, and a decode-tick-win gate")
    ap.add_argument("--draft-fmt", default="mxint4",
                    help="draft rung for --speculative")
    ap.add_argument("--k", type=int, default=4,
                    help="draft depth for --speculative")
    ap.add_argument("--slo", action="store_true",
                    help="run the SLO-tier sweep instead of the perf "
                         "matrix: static queue-depth policy vs measured-"
                         "cost-model policy on a deterministic bursty "
                         "multi-tenant workload, with per-tier TTFT/wait "
                         "attainment columns and hard identity/ordering/"
                         "throughput gates")
    ap.add_argument("--horizon", type=int, default=24,
                    help="arrival-window ticks for the --slo workload")
    ap.add_argument("--wl-seed", type=int, default=0,
                    help="workload seed for --slo")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="run the tensor-parallel sweep instead of the "
                         "perf matrix: single-device vs meshed engine on "
                         "a (data, model) mesh, with a hard stream-"
                         "identity gate and the per-chip weight-stream "
                         "ratio (e.g. --mesh 1x2; on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=2)")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    qat = QATConfig(formats=("mxint4", "mxint8"), anchor="mxint8",
                    block_size=32)
    anchor = make_anchor(params, qat, get_format("mxint8", 32))

    if args.mesh:
        bench_mesh(api, anchor, params, mesh_spec=args.mesh,
                   slots=args.slots, max_len=args.max_len,
                   n_requests=args.requests, max_new=args.max_new,
                   vocab=cfg.vocab, page_size=args.page_size,
                   long_every=args.long_every, long_len=args.long_len)
        return

    if args.chaos:
        bench_chaos(api, anchor, params, slots=args.slots,
                    max_len=args.max_len, n_requests=args.requests,
                    max_new=args.max_new, vocab=cfg.vocab,
                    rates=[float(x) for x in args.fault_rates.split(",")])
        return

    if args.slo:
        bench_slo(api, anchor, params, slots=args.slots,
                  max_len=args.max_len, horizon=args.horizon,
                  wl_seed=args.wl_seed, page_size=args.page_size)
        return

    if args.speculative:
        bench_speculative(api, anchor, params, slots=args.slots,
                          max_len=args.max_len, n_requests=args.requests,
                          max_new=args.max_new, vocab=cfg.vocab,
                          draft_fmt=args.draft_fmt, k=args.k,
                          page_size=args.page_size,
                          long_every=args.long_every,
                          long_len=args.long_len)
        return

    # default chunk: one KV page (floored at the minimum prefill bucket) so
    # the chunked rows satisfy the page-alignment rule for any --page-size
    chunk = args.prefill_chunk or max(args.page_size, 8)
    kw = dict(slots=args.slots, max_len=args.max_len,
              n_requests=args.requests, max_new=args.max_new,
              vocab=cfg.vocab, page_size=args.page_size,
              prefill_chunk=chunk,
              long_every=args.long_every, long_len=args.long_len)
    want_fused = args.paths in ("both", "fused")
    want_dense = args.paths in ("both", "densify")
    layouts = ("dense", "paged") if args.kv == "both" else (args.kv,)
    admissions = ("monolithic", "chunked") if args.admission == "both" \
        else (args.admission,)
    attns = ("gather", "paged_kernel") if args.attn == "both" \
        else (args.attn,)
    schedulers = ("sequential", "mixed") if args.scheduler == "both" \
        else (args.scheduler,)
    rows = []
    for adm in admissions:
        for sched in (schedulers if adm == "chunked" else ("sequential",)):
            for kv in layouts:
                for attn in (attns if kv == "paged" else ("gather",)):
                    for fmt in FORMATS:
                        if fmt == "bf16":  # dense pseudo-format: one path
                            rows.append(bench_path(
                                api, anchor, params, fmt, False,
                                kv_layout=kv, admission=adm,
                                attn_impl=attn, scheduler=sched, **kw))
                            continue
                        if want_fused:
                            rows.append(bench_path(
                                api, anchor, params, fmt, True,
                                kv_layout=kv, admission=adm,
                                attn_impl=attn, scheduler=sched, **kw))
                        if want_dense:
                            rows.append(bench_path(
                                api, anchor, params, fmt, False,
                                kv_layout=kv, admission=adm,
                                attn_impl=attn, scheduler=sched, **kw))

    base = next(r for r in rows if r["fmt"] == "bf16")
    # KV ratios are vs the DENSE layout; without a dense row (--kv paged)
    # there is no baseline to compare against, so print n/a rather than a
    # misleading same-layout 1.00x.
    kv_base = next((r for r in rows if r["kv"] == "dense"), None)
    print("fmt,path,kv,attn,admission,scheduler,containers,weight_bytes,"
          "ticks,tokens,tokens_per_tick,weight_bytes_per_token,"
          "bytes_cut_vs_bf16,kv_bytes_per_slot,kv_cut_vs_dense,"
          "attn_bytes_per_token,decode_occupancy,tick_exec,adm_decode_tpt,"
          "ttft_p50_ms,ttft_p99_ms,stall_p99_ms,max_pf_tok,wall_s")
    for r in rows:
        cut = base["weight_bytes_per_token"] / r["weight_bytes_per_token"]
        kv_cut = "n/a" if kv_base is None else \
            f"{kv_base['kv_bytes_per_slot'] / max(r['kv_bytes_per_slot'], 1):.2f}x"
        print(f"{r['fmt']},{r['path']},{r['kv']},{r['attn']},"
              f"{r['admission']},{r['scheduler']},{r['containers']},"
              f"{r['weight_bytes']},{r['ticks']},{r['tokens']},"
              f"{r['tokens_per_tick']:.2f},"
              f"{r['weight_bytes_per_token']:.0f},{cut:.2f}x,"
              f"{r['kv_bytes_per_slot']},{kv_cut},"
              f"{r['attn_bytes_per_token']:.0f},"
              f"{r['decode_occupancy']:.2f},{r['tick_exec']:.2f},"
              f"{r['adm_decode_tpt']:.2f},"
              f"{r['ttft_p50_ms']:.1f},{r['ttft_p99_ms']:.1f},"
              f"{r['stall_p99_ms']:.1f},{r['max_pf_tok']},"
              f"{r['wall_s']:.2f}")

    if len(attns) == 2 and "paged" in layouts:
        # The attention-impl contract: the gather-free kernel changes the
        # bytes read, never the tokens produced.
        keyed = {}
        for r in rows:
            if r["kv"] != "paged":
                continue
            keyed.setdefault((r["fmt"], r["path"], r["admission"],
                              r["scheduler"]), {})[r["attn"]] = r
        pairs = [p for p in keyed.values() if len(p) == 2]
        identical = all(p["gather"]["streams"] == p["paged_kernel"]["streams"]
                        for p in pairs)
        g_bytes = _pct([p["gather"]["attn_bytes_per_token"]
                        for p in pairs], 0.5)
        k_bytes = _pct([p["paged_kernel"]["attn_bytes_per_token"]
                        for p in pairs], 0.5)
        print(f"# paged_kernel vs gather: token streams identical across "
              f"all configs = {identical}; median attn bytes/token "
              f"{g_bytes:.0f} -> {k_bytes:.0f} "
              f"({g_bytes / max(k_bytes, 1e-9):.2f}x cut)")
        if not identical:
            raise SystemExit("token streams diverged between attention "
                             "impls — the paged kernel broke bit-identity")

    if len(schedulers) == 2 and "chunked" in admissions:
        # The unified-tick contract: coalescing the chunk into the decode
        # batch is a pure re-scheduling — same tokens, ~1 executable/tick.
        keyed = {}
        for r in rows:
            if r["admission"] != "chunked":
                continue
            keyed.setdefault((r["fmt"], r["path"], r["kv"], r["attn"]),
                             {})[r["scheduler"]] = r
        pairs = [p for p in keyed.values() if len(p) == 2]
        identical = all(p["sequential"]["streams"] == p["mixed"]["streams"]
                        for p in pairs)
        s_exec = _pct([p["sequential"]["tick_exec"] for p in pairs], 0.5)
        m_exec = _pct([p["mixed"]["tick_exec"] for p in pairs], 0.5)
        print(f"# mixed vs sequential: token streams identical across all "
              f"configs = {identical}; median executables/tick "
              f"{s_exec:.2f} -> {m_exec:.2f}")
        if not identical:
            raise SystemExit("token streams diverged between schedulers — "
                             "the mixed tick broke bit-identity")

    if len(admissions) == 2:
        # The chunked-admission contract: same tokens, smaller stall tail,
        # and decode throughput during a long admission no worse than the
        # monolithic baseline. One scheduler's chunked rows suffice — the
        # cross-scheduler check above pins mixed == sequential.
        keyed = {}
        adm_sched = "sequential" if "sequential" in schedulers \
            else schedulers[0]
        for r in rows:
            if r["admission"] == "chunked" and r["scheduler"] != adm_sched:
                continue
            keyed.setdefault((r["fmt"], r["path"], r["kv"], r["attn"]),
                             {})[r["admission"]] = r
        identical = all(p["monolithic"]["streams"] == p["chunked"]["streams"]
                        for p in keyed.values() if len(p) == 2)
        pairs = [p for p in keyed.values() if len(p) == 2]
        mono_stall = _pct([p["monolithic"]["stall_p99_ms"] for p in pairs],
                          0.5)
        chnk_stall = _pct([p["chunked"]["stall_p99_ms"] for p in pairs], 0.5)
        mono_adm = _pct([p["monolithic"]["adm_decode_tpt"] for p in pairs],
                        0.5)
        chnk_adm = _pct([p["chunked"]["adm_decode_tpt"] for p in pairs], 0.5)
        mono_tps = _pct([p["monolithic"]["adm_decode_tps"] for p in pairs],
                        0.5)
        chnk_tps = _pct([p["chunked"]["adm_decode_tps"] for p in pairs], 0.5)
        # tokens/tick alone flatters monolithic: its one admission tick
        # counts the freshly admitted slots' first decodes while stalling
        # everything for the whole prompt — the per-second number is the
        # decode throughput running slots actually see during an admission.
        print(f"# chunked vs monolithic: token streams identical across all "
              f"configs = {identical}; median stall_p99 "
              f"{mono_stall:.1f}ms -> {chnk_stall:.1f}ms; decode during "
              f"admission {mono_adm:.2f} -> {chnk_adm:.2f} tokens/tick, "
              f"{mono_tps:.0f} -> {chnk_tps:.0f} tokens/s")
        if not identical:
            raise SystemExit("token streams diverged between admission "
                             "modes — chunked prefill broke bit-identity")


if __name__ == "__main__":
    main()
