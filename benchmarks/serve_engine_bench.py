"""Engine-level serving benchmark: fused-kernel vs densify-inside-jit,
dense vs paged KV, monolithic vs chunked prefill admission, and gather vs
gather-free paged decode attention.

Runs the packed-weight continuous-batching ElasticEngine at dense bf16,
mxint8 (MXTensor codes) and mxint4 (split-N nibble-packed) under BOTH
packed-serving contracts — the Pallas dequant-GEMM dispatch (``fused``) and
the XLA densify-inside-jit fallback (``densify``) — and reports one table:

  - tokens_per_tick: generated tokens / decode ticks (continuous batching
    keeps slots full, so this approaches batch_slots under load)
  - weight_bytes_per_token: the roofline weight-read term — bytes one decode
    tick must stream for the weight pytree, divided by tokens/tick. This is
    the quantity the paper's §3.5 claim is about: packed mxint8/mxint4 cut it
    ~2x/~4x vs dense bf16 (exact ratio depends on the raw-leaf fraction).
    Identical across paths by construction (same packed tree) — the fused
    rows demonstrate the bytes contract is served by the explicit kernels,
    not just hoped for from XLA fusion.
  - kv_bytes_per_slot: resident KV-cache HBM divided by batch slots. The
    dense layout commits max_len tokens per slot up front; the paged layout
    (kv_layout="paged") commits only the page pool, which this bench sizes
    to the workload's live-token demand — the measured (not asserted) memory
    win of block-table paging. Token streams are bit-identical across
    layouts, so the kv rows differ ONLY in this column and wall time.
  - attn_bytes_per_token: decode-attention KV reads per generated token
    (per-layer K+V bytes actually spanned, from the engine's host-side
    accounting). The paged rows run BOTH attn impls: ``gather``
    materializes every slot's full logical view (max_pages×page_size
    tokens) each tick, the gather-free kernel (``paged_kernel``,
    kernels/paged_attention.py) reads only ``ceil(cache_len/page)`` pages
    per slot — the measured roofline win of block-table attention. Token
    streams are bit-identical across impls; the bench verifies that like
    the cross-admission check.
  - ttft_p50_ms / ttft_p99_ms / stall_p99_ms / max_pf_tok: the admission
    latency columns. The workload mixes short prompts with long ones
    (every ``--long-every``-th request is ``--long-len`` tokens), and the
    engine's per-tick trace records how much prefill work shared a tick
    with decoding. Monolithic admission stalls every running slot for a
    whole prompt (max_pf_tok ~ the long bucket; stall_p99 ~ a full
    prefill); chunked admission (``prefill_chunk``) bounds per-tick prefill
    work to one chunk, so the decode-stall tail collapses while token
    streams stay BIT-IDENTICAL — the bench verifies that identity and
    prints it.
  - decode_occupancy / tick_exec / adm_decode_tpt: the unified-tick
    columns, from the engine's per-tick ``rows`` / ``decode_rows`` /
    ``execs`` counters. ``decode_occupancy`` is the fraction of dispatched
    batch rows that were live decoders; ``tick_exec`` the mean executables
    per work tick — 1.0 under ``scheduler="mixed"`` (the chunk rides the
    decode batch), up to 2.0 under ``"sequential"`` (chunk then decode);
    ``adm_decode_tpt`` the decode tokens per tick over ticks that carried
    prefill work — the "decode does not starve during a long admission"
    number, comparable against the monolithic baseline rows. The chunked
    rows run BOTH schedulers and the bench verifies their token streams
    are bit-identical, same as the cross-admission check.

CPU wall-clock is reported for completeness but is NOT the serving claim —
off-TPU the fused path runs the Pallas interpreter (slow, correctness-only)
and the dequant is not the bottleneck; the bytes column is the modeled
HBM-bound behavior the TPU kernels realize. The *relative* stall/TTFT tail
between admission modes, however, is a scheduling property and survives the
interpreter overhead.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax               # noqa: E402
import numpy as np       # noqa: E402

from repro.configs import get_reduced                  # noqa: E402
from repro.core import get_format, make_anchor         # noqa: E402
from repro.core.qat import QATConfig                   # noqa: E402
from repro.models import get_model                     # noqa: E402
from repro.serve.engine import ElasticEngine, Request  # noqa: E402

FORMATS = ("bf16", "mxint8", "mxint4")
PROMPT_LEN = 8
WARMUP = 2               # first short + first long request: compiles every
#                          prefill bucket / chunk executable before timing


def _pct(xs, q):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))] if xs else 0.0


def bench_path(api, anchor, params, fmt, fused, *, slots, max_len,
               n_requests, max_new, vocab, kv_layout="dense", page_size=8,
               admission="monolithic", prefill_chunk=8, long_every=3,
               long_len=40, attn_impl="gather", scheduler="sequential"):
    kv_kw = {}
    if kv_layout == "paged":
        # Size the pool to the workload's live-token demand (longest prompt
        # + generated tokens per slot), NOT to slots*max_len — that sizing
        # freedom is the whole point of paging.
        per_slot = -(-(long_len + max_new) // page_size)
        kv_kw = dict(kv_layout="paged", kv_page_size=page_size,
                     kv_num_pages=slots * per_slot + 1,
                     attn_impl=attn_impl)
    eng = ElasticEngine(
        api, anchor, batch_slots=slots, max_len=max_len,
        param_template=params, fused=fused,
        prefill_chunk=prefill_chunk if admission == "chunked" else None,
        scheduler=scheduler if admission == "chunked" else None,
        **kv_kw)
    rng = np.random.default_rng(0)
    # every long_every-th request is long (long_every=1 => all long); the
    # offset keeps one long prompt inside the warmup window so its bucket /
    # chunk executables compile before timing starts
    is_long = lambda i: i % long_every == 1 % long_every
    reqs = [Request(rid=i,
                    prompt=rng.integers(
                        0, vocab,
                        long_len if is_long(i) else PROMPT_LEN)
                    .astype(np.int32),
                    max_new=max_new) for i in range(n_requests)]
    eng.generate(reqs[:WARMUP], fmt_override=fmt)  # warmup: compile + SS
    t0 = time.perf_counter()
    ticks0, toks0 = eng.stats["ticks"], eng.stats["tokens_out"]
    attn0 = eng.stats["attn_read_bytes"]
    eng.generate(reqs[WARMUP:], fmt_override=fmt)
    dt = time.perf_counter() - t0
    st = eng.stats
    ticks = st["ticks"] - ticks0
    # decode tokens only: each admission also samples one token from its
    # prefill logits, which costs no decode tick — excluding them keeps
    # tokens/tick <= batch_slots and bytes/token an honest roofline term
    toks = st["tokens_out"] - toks0 - (len(reqs) - WARMUP)
    wbytes = st["weight_bytes"][fmt]
    tpt = toks / max(ticks, 1)
    ttfts = [r.ttft_s for r in reqs[WARMUP:]]
    stalls = [t["wall_s"] for t in eng.tick_trace if t["decode"]]
    work = [t for t in eng.tick_trace if t["rows"] > 0]
    adm = [t for t in eng.tick_trace if t["prefill_tokens"] > 0]
    return {
        "fmt": fmt,
        "path": ("fused" if fused else "densify") if fmt != "bf16"
                else "dense",
        "kv": kv_layout,
        "attn": st["attn_impl"],
        "attn_bytes_per_token": (st["attn_read_bytes"] - attn0)
        / max(toks, 1),
        "admission": admission,
        "scheduler": eng.scheduler,
        "decode_occupancy": sum(t["decode_rows"] for t in work)
        / max(sum(t["rows"] for t in work), 1),
        "tick_exec": sum(t["execs"] for t in work) / max(len(work), 1),
        "adm_decode_tpt": sum(t["decode_rows"] for t in adm)
        / max(len(adm), 1),
        "adm_decode_tps": sum(t["decode_rows"] for t in adm)
        / max(sum(t["wall_s"] for t in adm), 1e-9),
        "containers": "+".join(st["containers"][fmt]),
        "weight_bytes": wbytes,
        "ticks": ticks,
        "tokens": toks,
        "tokens_per_tick": tpt,
        "weight_bytes_per_token": wbytes / max(tpt, 1e-9),
        "kv_bytes_per_slot": st["kv_bytes_per_slot"],
        "ttft_p50_ms": _pct(ttfts, 0.50) * 1e3,
        "ttft_p99_ms": _pct(ttfts, 0.99) * 1e3,
        "stall_p99_ms": _pct(stalls, 0.99) * 1e3,
        "max_pf_tok": max((t["prefill_tokens"] for t in eng.tick_trace),
                          default=0),
        "wall_s": dt,
        "streams": [list(r.out_tokens) for r in reqs],
    }


def bench_chaos(api, anchor, params, *, slots, max_len, n_requests,
                max_new, vocab, rates, seed=0):
    """The --chaos sweep (docs/serving_internals.md §7): one row per fault
    rate, all at the ANCHOR rung so every injected fault is either
    recovered by a same-format replay (transient crash), absorbed by the
    capacity path (alloc failure -> requeue), or confined to one request
    (row poison -> FAILED_NUMERIC). Two hard gates, both process-failing:

      - stream identity: every request that COMPLETED under chaos carries a
        token stream bit-identical to the fault-free (rate 0) run;
      - page accounting: kv_pages_alloc == kv_pages_freed at drain — chaos
        must not leak the free list.

    A final "ladder" demo row starts at mxint4 with a format-following
    poison and reports the escalation walk instead of the identity gate
    (its streams are the escalated rung's, deliberately different)."""
    from repro.runtime.fault import FaultInjector, random_plan
    from repro.serve.engine import RequestStatus
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, vocab, PROMPT_LEN).astype(np.int32)
               for _ in range(n_requests)]

    def run(fi, fmt):
        eng = ElasticEngine(api, anchor, batch_slots=slots, max_len=max_len,
                            param_template=params, kv_layout="paged",
                            kv_page_size=8, fault_injector=fi)
        reqs = [Request(rid=i, prompt=prompts[i].copy(), max_new=max_new)
                for i in range(n_requests)]
        t0 = time.perf_counter()
        eng.generate(reqs, fmt_override=fmt)
        dt = time.perf_counter() - t0
        st = eng.stats
        if st["kv_pages_alloc"] != st["kv_pages_freed"]:
            raise SystemExit(
                f"chaos leaked KV pages: {st['kv_pages_alloc']} allocated, "
                f"{st['kv_pages_freed']} freed")
        if not all(r.done and r.status.terminal for r in reqs):
            raise SystemExit("chaos left a request without a terminal "
                             "status")
        return eng, reqs, st, dt

    print("chaos,fault_rate,injected,recovered_ticks,escalations,"
          "completed,failed_numeric,failed_capacity,timed_out,cancelled,"
          "requeues,tokens,wall_s")

    def emit(label, rate, fi, eng, reqs, st, dt):
        counts = st["request_statuses"]
        print(f"{label},{rate},{len(fi.events) if fi else 0},"
              f"{st['ticks_replayed']},{st['fmt_escalations']},"
              f"{counts.get('completed', 0)},"
              f"{counts.get('failed_numeric', 0)},"
              f"{counts.get('failed_capacity', 0)},"
              f"{counts.get('timed_out', 0)},{counts.get('cancelled', 0)},"
              f"{st['admission_requeues']},"
              f"{sum(len(r.out_tokens) for r in reqs)},{dt:.2f}")

    base_streams = None
    for rate in rates:
        fi = random_plan(seed, rate, horizon=64, slots=slots) \
            if rate > 0 else None
        eng, reqs, st, dt = run(fi, "mxint8")
        emit("sweep", rate, fi, eng, reqs, st, dt)
        streams = {r.rid: list(r.out_tokens) for r in reqs
                   if r.status is RequestStatus.COMPLETED}
        if rate == 0:
            base_streams = streams
        elif base_streams is not None:
            diverged = [rid for rid, s in streams.items()
                        if base_streams.get(rid) != s]
            if diverged:
                raise SystemExit(
                    f"chaos rate {rate}: surviving streams diverged from "
                    f"the fault-free run for rids {diverged} — fault "
                    "isolation broke bit-identity")
    if base_streams is not None and len(rates) > 1:
        print("# chaos survivors bit-identical to the fault-free run "
              "across all rates = True")

    # Degradation-ladder demo: a rung that fails at runtime walks toward
    # the anchor and the wave still completes.
    fi = FaultInjector(poison_logits={2: None}, poison_fmt="mxint4")
    eng, reqs, st, dt = run(fi, "mxint4")
    emit("ladder", "-", fi, eng, reqs, st, dt)
    ev = st["escalation_events"]
    print(f"# ladder: {' -> '.join([ev[0]['from']] + [e['to'] for e in ev])}"
          f" (quarantined: {','.join(st['quarantined_formats'])}); "
          f"completed {st['request_statuses'].get('completed', 0)}"
          f"/{n_requests}")


def bench_speculative(api, anchor, params, *, slots, max_len, n_requests,
                      max_new, vocab, draft_fmt="mxint4", k=4, page_size=8,
                      long_every=3, long_len=40):
    """The --speculative sweep (docs/serving_internals.md §9): plain anchor
    decode vs self-speculative decode (draft at ``draft_fmt``, verify at the
    pinned anchor rung) over both packed contracts x both paged attention
    impls. Two outputs:

      - an acceptance column set: spec_ticks, acceptance_rate,
        accepted_tok_per_tick — the measured usefulness of the cheap rung's
        guesses on this workload;
      - a HARD stream-identity gate (process-failing): every request's
        token stream under speculation must be bit-identical to plain
        anchor decode — speculation is a pure speed knob, never a token
        knob. A second gate requires a decode-tick win (fewer verify ticks
        than plain ticks for the same tokens): if drafting ever stops
        paying for itself on this deterministic workload, the bench fails
        rather than shipping a regression silently.
    """
    from repro.serve.policy import SpecConfig
    rng = np.random.default_rng(0)
    is_long = lambda i: i % long_every == 1 % long_every
    prompts = [rng.integers(0, vocab,
                            long_len if is_long(i) else PROMPT_LEN)
               .astype(np.int32) for i in range(n_requests)]
    # draft-ahead headroom: the verify frontier runs k tokens past the
    # committed length, so size the pool for it
    per_slot = -(-(long_len + max_new + k) // page_size)

    def run(spec, fused, attn):
        eng = ElasticEngine(
            api, anchor, batch_slots=slots, max_len=max_len,
            param_template=params, fused=fused, kv_layout="paged",
            kv_page_size=page_size, kv_num_pages=slots * per_slot + 1,
            attn_impl=attn, speculative=spec)
        reqs = [Request(rid=i, prompt=prompts[i].copy(), max_new=max_new)
                for i in range(n_requests)]
        eng.generate(reqs[:WARMUP], fmt_override="mxint8")
        t0 = time.perf_counter()
        ticks0 = eng.stats["ticks"]
        eng.generate(reqs[WARMUP:], fmt_override="mxint8")
        dt = time.perf_counter() - t0
        st = eng.stats
        if st["kv_pages_alloc"] != st["kv_pages_freed"]:
            raise SystemExit(
                f"speculative run leaked KV pages: {st['kv_pages_alloc']} "
                f"allocated, {st['kv_pages_freed']} freed")
        return (st["ticks"] - ticks0, st,
                [list(r.out_tokens) for r in reqs], dt)

    print("spec,path,attn,draft,k,ticks_plain,ticks_spec,spec_ticks,"
          "acceptance_rate,accepted_tok_per_tick,tok_per_tick_plain,"
          "tok_per_tick_spec,wall_plain_s,wall_spec_s")
    wins = []
    for fused in (False, True):
        for attn in ("gather", "paged_kernel"):
            ticks_p, _, streams_p, dt_p = run(None, fused, attn)
            sc = SpecConfig(draft_fmt=draft_fmt, k=k)
            ticks_s, st, streams_s, dt_s = run(sc, fused, attn)
            if streams_s != streams_p:
                raise SystemExit(
                    f"speculative streams diverged from plain anchor "
                    f"decode (fused={fused}, attn={attn}) — the draft/"
                    f"verify/rollback loop broke bit-identity")
            toks = sum(len(s) for s in streams_s[WARMUP:]) \
                - (n_requests - WARMUP)
            rate = st["spec_acceptance_rate"]
            acc_pt = st["spec_accepted"] / max(st["spec_ticks"], 1)
            path = "fused" if fused else "densify"
            print(f"spec,{path},{attn},{draft_fmt},{k},{ticks_p},{ticks_s},"
                  f"{st['spec_ticks']},"
                  f"{-1.0 if rate is None else rate:.2f},{acc_pt:.2f},"
                  f"{toks / max(ticks_p, 1):.2f},{toks / max(ticks_s, 1):.2f},"
                  f"{dt_p:.2f},{dt_s:.2f}")
            wins.append((ticks_p, ticks_s))
    print(f"# speculative vs plain: token streams identical across all "
          f"configs = True; decode ticks "
          f"{sum(p for p, _ in wins)} -> {sum(s for _, s in wins)} "
          f"({sum(p for p, _ in wins) / max(sum(s for _, s in wins), 1):.2f}x"
          f" cut at draft={draft_fmt}, k={k})")
    if not all(s < p for p, s in wins):
        raise SystemExit("speculation won no decode ticks — drafting is "
                         "not paying for itself on this workload")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=9)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--paths", default="both",
                    choices=("both", "fused", "densify"),
                    help="packed-serving contract(s) to benchmark")
    ap.add_argument("--kv", default="both",
                    choices=("both", "dense", "paged"),
                    help="KV-cache layout(s) to benchmark")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page for the paged layout")
    ap.add_argument("--admission", default="both",
                    choices=("both", "monolithic", "chunked"),
                    help="prompt admission mode(s) to benchmark")
    ap.add_argument("--attn", default="both",
                    choices=("both", "gather", "paged_kernel"),
                    help="paged decode-attention impl(s) to benchmark "
                         "(paged rows only; dense KV has no block table)")
    ap.add_argument("--scheduler", default="both",
                    choices=("both", "sequential", "mixed"),
                    help="chunked-tick scheduler(s) to benchmark "
                         "(chunked rows only; monolithic admission has no "
                         "chunk to coalesce)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunk size for the chunked admission rows "
                         "(default: one KV page, min 8)")
    ap.add_argument("--long-every", type=int, default=3,
                    help="every Nth request gets the long prompt")
    ap.add_argument("--long-len", type=int, default=40,
                    help="long-prompt length (the admission-stall driver)")
    ap.add_argument("--chaos", action="store_true",
                    help="run the fault-injection sweep instead of the "
                         "perf matrix: seeded chaos at increasing fault "
                         "rates, with hard gates on survivor-stream "
                         "identity and page accounting, plus a format-"
                         "ladder degradation demo")
    ap.add_argument("--fault-rates", default="0,0.1,0.25",
                    help="comma-separated per-tick fault rates for --chaos")
    ap.add_argument("--speculative", action="store_true",
                    help="run the self-speculative sweep instead of the "
                         "perf matrix: plain vs draft-and-verify decode "
                         "with a hard stream-identity gate, an acceptance-"
                         "rate column, and a decode-tick-win gate")
    ap.add_argument("--draft-fmt", default="mxint4",
                    help="draft rung for --speculative")
    ap.add_argument("--k", type=int, default=4,
                    help="draft depth for --speculative")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    qat = QATConfig(formats=("mxint4", "mxint8"), anchor="mxint8",
                    block_size=32)
    anchor = make_anchor(params, qat, get_format("mxint8", 32))

    if args.chaos:
        bench_chaos(api, anchor, params, slots=args.slots,
                    max_len=args.max_len, n_requests=args.requests,
                    max_new=args.max_new, vocab=cfg.vocab,
                    rates=[float(x) for x in args.fault_rates.split(",")])
        return

    if args.speculative:
        bench_speculative(api, anchor, params, slots=args.slots,
                          max_len=args.max_len, n_requests=args.requests,
                          max_new=args.max_new, vocab=cfg.vocab,
                          draft_fmt=args.draft_fmt, k=args.k,
                          page_size=args.page_size,
                          long_every=args.long_every,
                          long_len=args.long_len)
        return

    # default chunk: one KV page (floored at the minimum prefill bucket) so
    # the chunked rows satisfy the page-alignment rule for any --page-size
    chunk = args.prefill_chunk or max(args.page_size, 8)
    kw = dict(slots=args.slots, max_len=args.max_len,
              n_requests=args.requests, max_new=args.max_new,
              vocab=cfg.vocab, page_size=args.page_size,
              prefill_chunk=chunk,
              long_every=args.long_every, long_len=args.long_len)
    want_fused = args.paths in ("both", "fused")
    want_dense = args.paths in ("both", "densify")
    layouts = ("dense", "paged") if args.kv == "both" else (args.kv,)
    admissions = ("monolithic", "chunked") if args.admission == "both" \
        else (args.admission,)
    attns = ("gather", "paged_kernel") if args.attn == "both" \
        else (args.attn,)
    schedulers = ("sequential", "mixed") if args.scheduler == "both" \
        else (args.scheduler,)
    rows = []
    for adm in admissions:
        for sched in (schedulers if adm == "chunked" else ("sequential",)):
            for kv in layouts:
                for attn in (attns if kv == "paged" else ("gather",)):
                    for fmt in FORMATS:
                        if fmt == "bf16":  # dense pseudo-format: one path
                            rows.append(bench_path(
                                api, anchor, params, fmt, False,
                                kv_layout=kv, admission=adm,
                                attn_impl=attn, scheduler=sched, **kw))
                            continue
                        if want_fused:
                            rows.append(bench_path(
                                api, anchor, params, fmt, True,
                                kv_layout=kv, admission=adm,
                                attn_impl=attn, scheduler=sched, **kw))
                        if want_dense:
                            rows.append(bench_path(
                                api, anchor, params, fmt, False,
                                kv_layout=kv, admission=adm,
                                attn_impl=attn, scheduler=sched, **kw))

    base = next(r for r in rows if r["fmt"] == "bf16")
    # KV ratios are vs the DENSE layout; without a dense row (--kv paged)
    # there is no baseline to compare against, so print n/a rather than a
    # misleading same-layout 1.00x.
    kv_base = next((r for r in rows if r["kv"] == "dense"), None)
    print("fmt,path,kv,attn,admission,scheduler,containers,weight_bytes,"
          "ticks,tokens,tokens_per_tick,weight_bytes_per_token,"
          "bytes_cut_vs_bf16,kv_bytes_per_slot,kv_cut_vs_dense,"
          "attn_bytes_per_token,decode_occupancy,tick_exec,adm_decode_tpt,"
          "ttft_p50_ms,ttft_p99_ms,stall_p99_ms,max_pf_tok,wall_s")
    for r in rows:
        cut = base["weight_bytes_per_token"] / r["weight_bytes_per_token"]
        kv_cut = "n/a" if kv_base is None else \
            f"{kv_base['kv_bytes_per_slot'] / max(r['kv_bytes_per_slot'], 1):.2f}x"
        print(f"{r['fmt']},{r['path']},{r['kv']},{r['attn']},"
              f"{r['admission']},{r['scheduler']},{r['containers']},"
              f"{r['weight_bytes']},{r['ticks']},{r['tokens']},"
              f"{r['tokens_per_tick']:.2f},"
              f"{r['weight_bytes_per_token']:.0f},{cut:.2f}x,"
              f"{r['kv_bytes_per_slot']},{kv_cut},"
              f"{r['attn_bytes_per_token']:.0f},"
              f"{r['decode_occupancy']:.2f},{r['tick_exec']:.2f},"
              f"{r['adm_decode_tpt']:.2f},"
              f"{r['ttft_p50_ms']:.1f},{r['ttft_p99_ms']:.1f},"
              f"{r['stall_p99_ms']:.1f},{r['max_pf_tok']},"
              f"{r['wall_s']:.2f}")

    if len(attns) == 2 and "paged" in layouts:
        # The attention-impl contract: the gather-free kernel changes the
        # bytes read, never the tokens produced.
        keyed = {}
        for r in rows:
            if r["kv"] != "paged":
                continue
            keyed.setdefault((r["fmt"], r["path"], r["admission"],
                              r["scheduler"]), {})[r["attn"]] = r
        pairs = [p for p in keyed.values() if len(p) == 2]
        identical = all(p["gather"]["streams"] == p["paged_kernel"]["streams"]
                        for p in pairs)
        g_bytes = _pct([p["gather"]["attn_bytes_per_token"]
                        for p in pairs], 0.5)
        k_bytes = _pct([p["paged_kernel"]["attn_bytes_per_token"]
                        for p in pairs], 0.5)
        print(f"# paged_kernel vs gather: token streams identical across "
              f"all configs = {identical}; median attn bytes/token "
              f"{g_bytes:.0f} -> {k_bytes:.0f} "
              f"({g_bytes / max(k_bytes, 1e-9):.2f}x cut)")
        if not identical:
            raise SystemExit("token streams diverged between attention "
                             "impls — the paged kernel broke bit-identity")

    if len(schedulers) == 2 and "chunked" in admissions:
        # The unified-tick contract: coalescing the chunk into the decode
        # batch is a pure re-scheduling — same tokens, ~1 executable/tick.
        keyed = {}
        for r in rows:
            if r["admission"] != "chunked":
                continue
            keyed.setdefault((r["fmt"], r["path"], r["kv"], r["attn"]),
                             {})[r["scheduler"]] = r
        pairs = [p for p in keyed.values() if len(p) == 2]
        identical = all(p["sequential"]["streams"] == p["mixed"]["streams"]
                        for p in pairs)
        s_exec = _pct([p["sequential"]["tick_exec"] for p in pairs], 0.5)
        m_exec = _pct([p["mixed"]["tick_exec"] for p in pairs], 0.5)
        print(f"# mixed vs sequential: token streams identical across all "
              f"configs = {identical}; median executables/tick "
              f"{s_exec:.2f} -> {m_exec:.2f}")
        if not identical:
            raise SystemExit("token streams diverged between schedulers — "
                             "the mixed tick broke bit-identity")

    if len(admissions) == 2:
        # The chunked-admission contract: same tokens, smaller stall tail,
        # and decode throughput during a long admission no worse than the
        # monolithic baseline. One scheduler's chunked rows suffice — the
        # cross-scheduler check above pins mixed == sequential.
        keyed = {}
        adm_sched = "sequential" if "sequential" in schedulers \
            else schedulers[0]
        for r in rows:
            if r["admission"] == "chunked" and r["scheduler"] != adm_sched:
                continue
            keyed.setdefault((r["fmt"], r["path"], r["kv"], r["attn"]),
                             {})[r["admission"]] = r
        identical = all(p["monolithic"]["streams"] == p["chunked"]["streams"]
                        for p in keyed.values() if len(p) == 2)
        pairs = [p for p in keyed.values() if len(p) == 2]
        mono_stall = _pct([p["monolithic"]["stall_p99_ms"] for p in pairs],
                          0.5)
        chnk_stall = _pct([p["chunked"]["stall_p99_ms"] for p in pairs], 0.5)
        mono_adm = _pct([p["monolithic"]["adm_decode_tpt"] for p in pairs],
                        0.5)
        chnk_adm = _pct([p["chunked"]["adm_decode_tpt"] for p in pairs], 0.5)
        mono_tps = _pct([p["monolithic"]["adm_decode_tps"] for p in pairs],
                        0.5)
        chnk_tps = _pct([p["chunked"]["adm_decode_tps"] for p in pairs], 0.5)
        # tokens/tick alone flatters monolithic: its one admission tick
        # counts the freshly admitted slots' first decodes while stalling
        # everything for the whole prompt — the per-second number is the
        # decode throughput running slots actually see during an admission.
        print(f"# chunked vs monolithic: token streams identical across all "
              f"configs = {identical}; median stall_p99 "
              f"{mono_stall:.1f}ms -> {chnk_stall:.1f}ms; decode during "
              f"admission {mono_adm:.2f} -> {chnk_adm:.2f} tokens/tick, "
              f"{mono_tps:.0f} -> {chnk_tps:.0f} tokens/s")
        if not identical:
            raise SystemExit("token streams diverged between admission "
                             "modes — chunked prefill broke bit-identity")


if __name__ == "__main__":
    main()
