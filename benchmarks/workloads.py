"""Deterministic bursty multi-tenant workload generator.

The "millions of users" scenario (ROADMAP open item 1) made measurable:
each tenant is a traffic class — a Poisson base rate of request arrivals
per scheduler tick, optional periodic bursts on top, heavy-tailed
(lognormal) prompt lengths, a tier (latency / throughput / best_effort
with TTFT/TPOT budgets), and per-tenant sampling params. The generator
flattens every tenant's arrivals into one request list for
``ElasticEngine.generate`` — arrival times ride ``Request.arrival_tick``
(the engine's admission gate), attribution rides ``Request.tenant``.

Everything is driven by ONE ``numpy`` Generator seeded from ``seed``,
and tenants are iterated in list order tick by tick, so the same
``(tenants, horizon, seed)`` triple reproduces the identical trace —
rids, arrival ticks, prompt token-for-token (tests/test_workloads.py
pins this down). That determinism is what lets the bench compare
policies on the *same* workload and lets CI gate on per-tier stream
identity.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serve.engine import Request
from repro.serve.slo import SLOClass, TIERS


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's traffic class.

    ``rate`` is the Poisson mean of arrivals per scheduler tick; every
    ``burst_every`` ticks (0 = never) an extra ``burst_size`` requests
    land at once — the bursty half of "bursty multi-tenant". Prompt
    lengths are lognormal (median ``prompt_median``, log-sigma
    ``prompt_sigma``), clipped to the engine's prompt capacity at
    generation time: a heavy tail, but never an unservable request
    unless ``clip_prompts=False`` asks for admission-reject coverage.
    """

    name: str
    tier: str = "best_effort"
    rate: float = 0.3
    burst_every: int = 0
    burst_size: int = 0
    prompt_median: float = 10.0
    prompt_sigma: float = 0.5
    max_new: int = 8
    temperature: Optional[float] = None
    top_p: Optional[float] = None
    ttft_ms: Optional[float] = None    # budget for this tenant's SLOClass
    tpot_ms: Optional[float] = None

    def __post_init__(self) -> None:
        if self.tier not in TIERS:
            raise ValueError(f"tier must be one of {TIERS}, "
                             f"got {self.tier!r}")
        if self.rate < 0:
            raise ValueError(f"rate must be >= 0, got {self.rate}")

    def slo(self) -> Optional[SLOClass]:
        """The SLO riding each of this tenant's requests (None for a
        budget-less best-effort tenant — the engine's default)."""
        if self.tier == "best_effort" and self.ttft_ms is None \
                and self.tpot_ms is None:
            return None
        return SLOClass(ttft_ms=self.ttft_ms, tpot_ms=self.tpot_ms,
                        tier=self.tier)


def default_tenants(*, ttft_ms: Optional[float] = None,
                    tpot_ms: Optional[float] = None) -> List[TenantSpec]:
    """The bench's reference mix: an interactive latency tenant (steady
    trickle, short prompts), a bulk throughput tenant (bursty, long-tailed
    prompts, more output), and a best-effort scavenger. Budgets default
    to None so the bench can calibrate them from a measured reference
    run (machine-independent gates) before building SLO classes."""
    return [
        TenantSpec(name="interactive", tier="latency", rate=0.25,
                   prompt_median=8.0, prompt_sigma=0.4, max_new=6,
                   ttft_ms=ttft_ms, tpot_ms=tpot_ms),
        TenantSpec(name="bulk", tier="throughput", rate=0.15,
                   burst_every=8, burst_size=3, prompt_median=14.0,
                   prompt_sigma=0.8, max_new=10),
        TenantSpec(name="scavenger", tier="best_effort", rate=0.1,
                   prompt_median=10.0, prompt_sigma=0.6, max_new=8),
    ]


def generate_workload(tenants: Sequence[TenantSpec], *, horizon: int,
                      vocab: int, prompt_cap: int, seed: int = 0,
                      clip_prompts: bool = True) -> List[Request]:
    """Flatten every tenant's arrivals over ``horizon`` ticks into one
    deterministic request list, ordered by (arrival_tick, tenant index)
    with rids dense in that order.

    ``prompt_cap`` should be the engine's ``prompt_capacity``
    (``max_len - 1``); with ``clip_prompts=False`` the lognormal tail may
    exceed it, exercising the engine's fail-fast admission reject path
    instead of being clipped into servability.
    """
    if horizon <= 0:
        raise ValueError(f"horizon must be positive, got {horizon}")
    rng = np.random.default_rng(seed)
    out: List[Request] = []
    rid = 0
    for t in range(horizon):
        for spec in tenants:
            n = int(rng.poisson(spec.rate))
            if spec.burst_every and t and t % spec.burst_every == 0:
                n += spec.burst_size
            for _ in range(n):
                plen = int(round(float(rng.lognormal(
                    math.log(spec.prompt_median), spec.prompt_sigma))))
                plen = max(1, plen)
                if clip_prompts:
                    plen = min(plen, prompt_cap)
                prompt = rng.integers(1, vocab, size=plen,
                                      dtype=np.int64).astype(np.int32)
                out.append(Request(
                    rid=rid, prompt=prompt, max_new=spec.max_new,
                    slo=spec.slo(), tenant=spec.name, arrival_tick=t,
                    temperature=spec.temperature, top_p=spec.top_p))
                rid += 1
    return out


def trace_fingerprint(requests: Sequence[Request]) -> List[tuple]:
    """Hashable per-request summary for determinism assertions: the
    fields the generator controls, prompts included token-for-token."""
    return [(r.rid, r.tenant, r.arrival_tick, int(r.max_new),
             None if r.slo is None else (r.slo.tier, r.slo.ttft_ms,
                                         r.slo.tpot_ms),
             r.temperature, r.top_p,
             tuple(int(x) for x in np.asarray(r.prompt)))
            for r in requests]


def tenant_summary(requests: Sequence[Request]) -> Dict[str, dict]:
    """Per-tenant accounting after a wave: terminal-status counts,
    admission-wait percentiles (ticks from arrival to admission), and
    output-token totals — the fairness/backpressure columns of the
    ``--slo`` bench."""
    by: Dict[str, dict] = {}
    for r in requests:
        name = r.tenant or "?"
        d = by.setdefault(name, {"requests": 0, "tokens_out": 0,
                                 "statuses": {}, "wait_ticks": []})
        d["requests"] += 1
        d["tokens_out"] += len(r.out_tokens)
        d["statuses"][r.status.value] = \
            d["statuses"].get(r.status.value, 0) + 1
        if r.admitted_tick is not None:
            d["wait_ticks"].append(r.admitted_tick - r.arrival_tick)
    for d in by.values():
        w = sorted(d.pop("wait_ticks"))
        d["wait_ticks_p50"] = w[len(w) // 2] if w else None
        d["wait_ticks_max"] = w[-1] if w else None
    return by
