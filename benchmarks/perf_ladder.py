"""§Perf ladder: before/after tables for the three hillclimb cells.

Reads every variant JSON the dry-run wrote for the hillclimb cells and
prints compile-verified deltas (temp memory, HLO collective bytes) next to
the analytic roofline terms for the matching configuration.
"""
import glob
import json
import os

from repro.configs import SHAPES, get_config
from repro.launch import costmodel as cm

MESH = cm.MeshDesc(1, 16, 16)

CELLS = [
    ("jamba-1.5-large-398b", "train_4k",
     ["novjp", "baseline", "sp", "inner", "inner_mb4", "sp_mb4"]),
    ("qwen2-72b", "train_4k",
     ["novjp", "baseline", "sp", "sp_mb4"]),
    ("mixtral-8x22b", "decode_32k",
     ["baseline", "w8", "w4", "w16tp", "w8tp", "w4tp", "w8scan", "w4scan"]),
]

ANALYTIC_DECODE = {
    "baseline": dict(weight_bits_decode=16, weight_stationary=False),
    "w8": dict(weight_bits_decode=8, weight_stationary=False),
    "w4": dict(weight_bits_decode=4, weight_stationary=False),
    "w16tp": dict(weight_bits_decode=16, weight_stationary=True),
    "w8tp": dict(weight_bits_decode=8, weight_stationary=True),
    "w4tp": dict(weight_bits_decode=4, weight_stationary=True),
    "w8scan": dict(weight_bits_decode=8, weight_stationary=True),
    "w4scan": dict(weight_bits_decode=4, weight_stationary=True),
}


def main(out_dir="out/dryrun"):
    print("name,us_per_call,derived")
    for arch, shape_name, variants in CELLS:
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        print(f"\n# {arch} x {shape_name}")
        print("variant,temp_GiB/dev,args_GiB/dev,hlo_coll_GB/dev,"
              "analytic_t_mem_ms,analytic_t_coll_ms,dominant")
        for v in variants:
            path = os.path.join(out_dir,
                                f"{arch}__{shape_name}__16x16__{v}.json")
            if not os.path.exists(path):
                print(f"{v},pending,,,,")
                continue
            r = json.load(open(path))
            if r.get("status") != "ok":
                print(f"{v},ERROR:{r.get('error', '')[:60]},,,,")
                continue
            temp = r["memory"]["temp_size_in_bytes"] / 2 ** 30
            args = r["memory"]["argument_size_in_bytes"] / 2 ** 30
            coll = r["collectives"]["total_weighted"] / 1e9
            kw = ANALYTIC_DECODE.get(v, {}) if shape.kind == "decode" else {}
            ra = cm.roofline(cfg, shape, MESH, **kw)
            print(f"{v},{temp:.1f},{args:.1f},{coll:.2f},"
                  f"{ra['t_memory'] * 1e3:.2f},"
                  f"{ra['t_collective'] * 1e3:.2f},{ra['dominant']}")
            print(f"perf_{arch}_{shape_name}_{v},"
                  f"{ra['step_time_lower_bound'] * 1e6:.0f},"
                  f"temp={temp:.1f}GiB")


if __name__ == "__main__":
    main()
