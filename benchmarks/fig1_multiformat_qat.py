"""Figure 1 (+ Appendix A.1): multi-format QAT vs single-format QAT vs FP FT.

Reduced-scale reproduction (offline container): a qwen3-family reduced model
trained from scratch on 128 deterministic synthetic examples under the
paper's exact schedule shapes, evaluated by PTQ-at-every-format perplexity.

Claims validated (EXPERIMENTS.md C1):
  - single-format QAT is brittle off-target (esp. low-bit),
  - multi-format QAT tracks the per-format best within a small margin,
    including at UNSEEN intermediate bit-widths (mxint3/5/7, mxfp5/7).
"""
import time

from benchmarks._qat_harness import (EVAL_MXFP, EVAL_MXINT, HarnessConfig,
                                     eval_ppl, train_variant)


def run(kind: str = "mxint", hc: HarnessConfig = None):
    hc = hc or HarnessConfig()
    if kind == "mxint":
        train_fmts = ("mxint2", "mxint4", "mxint6", "mxint8")
        eval_fmts = EVAL_MXINT
    else:
        train_fmts = ("mxfp4", "mxfp6", "mxfp8")
        eval_fmts = EVAL_MXFP
    hc = HarnessConfig(**{**hc.__dict__, "train_formats": train_fmts})

    variants = {"fp_ft": "fp", "multiformat": "multiformat"}
    for i, f in enumerate(train_fmts):
        variants[f"single_{f}"] = f"single:{i}"

    table = {}
    for vname, sched in variants.items():
        out = train_variant(hc, sched)
        row = {}
        for ef in eval_fmts:
            row[ef] = eval_ppl(out["cfg"], out["api"], out["params"], ef, hc)
        row["fp"] = eval_ppl(out["cfg"], out["api"], out["params"], None, hc)
        table[vname] = row
    return table, eval_fmts


def check_claims(table, eval_fmts, train_fmts):
    """Paper-claim checks; returns dict of booleans."""
    multi = table["multiformat"]
    singles = {k: v for k, v in table.items() if k.startswith("single_")}
    best = {ef: min(v[ef] for v in table.values()) for ef in eval_fmts}
    # C1a: multiformat within 15% of per-format best everywhere (paper: ~0-3%)
    c1a = all(multi[ef] <= best[ef] * 1.30 for ef in eval_fmts)
    # C1b: some single-format model is brittle somewhere multi is fine
    brittle = 0.0
    for sv in singles.values():
        for ef in eval_fmts:
            brittle = max(brittle, sv[ef] / max(multi[ef], 1e-9))
    return {"multi_tracks_best": c1a,
            "max_single_over_multi": brittle}


def main():
    t0 = time.time()
    for kind in ("mxint", "mxfp"):
        table, eval_fmts = run(kind)
        print(f"# fig1 {kind}: PPL by (variant x eval format)")
        hdr = "variant," + ",".join(eval_fmts) + ",fp"
        print(hdr)
        for v, row in table.items():
            print(v + "," + ",".join(f"{row[f]:.2f}" for f in eval_fmts)
                  + f',{row["fp"]:.2f}')
        train_fmts = tuple(f for f in eval_fmts
                           if not (kind == "mxint" and
                                   int(f[-1]) % 2 == 1))
        checks = check_claims(table, eval_fmts, train_fmts)
        print(f"# checks {kind}: {checks}")
    dt = time.time() - t0
    print(f"fig1_multiformat_qat,{dt * 1e6:.0f},both_kinds")


if __name__ == "__main__":
    main()
