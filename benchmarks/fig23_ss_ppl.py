"""Figures 2-3: end-to-end perplexity, direct quantization vs Slice-and-Scale.

Llama-3.2-1B in the paper -> reduced llama-family (smollm) model here,
briefly fine-tuned, then evaluated with (i) direct PTQ to each format and
(ii) SS conversion from the 8-bit anchor. Sweeps: bits at block 64; block
size at 4 bits. Claim C2: the two curves are nearly identical.
"""
import time

from benchmarks._qat_harness import HarnessConfig, eval_ppl, train_variant


def run():
    hc = HarnessConfig(arch="smollm-135m", train_formats=("mxint8",),
                       block_size=64, epochs_per_format=2)
    out = train_variant(hc, "fp")      # plain fine-tune, like the paper's base
    cfg, api, params = out["cfg"], out["api"], out["params"]

    rows = []
    for kind, bits in (("int", range(2, 9)), ("fp", range(4, 9))):
        for b in bits:
            fmt = f"mx{kind}{b}"
            hcb = HarnessConfig(**{**hc.__dict__,
                                   "anchor": f"mx{kind}8"})
            direct = eval_ppl(cfg, api, params, fmt, hcb)
            ss = eval_ppl(cfg, api, params, fmt, hcb, use_anchor_ss=True)
            rows.append({"sweep": "bits@bs64", "fmt": fmt,
                         "block_size": 64, "ppl_direct": direct,
                         "ppl_ss": ss})
    for kind in ("int", "fp"):
        for bs in (16, 32, 64, 128):
            fmt = f"mx{kind}4"
            hcb = HarnessConfig(**{**hc.__dict__, "block_size": bs,
                                   "anchor": f"mx{kind}8"})
            direct = eval_ppl(cfg, api, params, fmt, hcb)
            ss = eval_ppl(cfg, api, params, fmt, hcb, use_anchor_ss=True)
            rows.append({"sweep": "bs@4bit", "fmt": fmt, "block_size": bs,
                         "ppl_direct": direct, "ppl_ss": ss})
    base = eval_ppl(cfg, api, params, None, hc)
    return rows, base


def main():
    t0 = time.time()
    rows, base = run()
    print("# fig23: direct PTQ vs SS-from-anchor perplexity "
          f"(fp baseline ppl={base:.2f})")
    print("sweep,fmt,block_size,ppl_direct,ppl_ss,rel_gap")
    worst = 0.0
    for r in rows:
        gap = abs(r["ppl_ss"] - r["ppl_direct"]) / r["ppl_direct"]
        # only down-conversions are SS'd; 8-bit rows are identical by constr.
        if not r["fmt"].endswith("8"):
            worst = max(worst, gap)
        print(f'{r["sweep"]},{r["fmt"]},{r["block_size"]},'
              f'{r["ppl_direct"]:.3f},{r["ppl_ss"]:.3f},{gap:.4f}')
    print(f"fig23_ss_ppl,{(time.time() - t0) * 1e6:.0f},"
          f"worst_rel_gap={worst:.4f}")


if __name__ == "__main__":
    main()
