"""Tables 1-2 analog: downstream-task grid (QAT precision x PTQ precision).

No MMLU/MathQA/HellaSwag offline — the stand-in downstream metric is
held-out next-token top-1 accuracy on the synthetic corpus (its copy-motif
structure makes accuracy a meaningful skill metric, not just inverted PPL).
Rows: FP FT, each single-format QAT, multi-format QAT. Columns: every eval
format (starred = unseen during training). Claim: MF-QAT within ~1 point of
the best row per column (3 points at 2-bit), mirroring the paper.
"""
import time

from benchmarks._qat_harness import (EVAL_MXINT, HarnessConfig,
                                     eval_accuracy, train_variant)


def run(hc: HarnessConfig = None):
    hc = hc or HarnessConfig(arch="qwen3-4b")
    variants = {"fp_ft": "fp", "multiformat": "multiformat"}
    for i, f in enumerate(hc.train_formats):
        variants[f"single_{f}"] = f"single:{i}"
    table = {}
    models = {}
    for vname, sched in variants.items():
        out = train_variant(hc, sched)
        models[vname] = out
        table[vname] = {
            ef: eval_accuracy(out["cfg"], out["api"], out["params"], ef, hc)
            for ef in EVAL_MXINT}
    return table


def main():
    t0 = time.time()
    table = run()
    unseen = {"mxint3", "mxint5", "mxint7"}
    print("# table12: accuracy (x100) by QAT variant x PTQ precision "
          "(* = unseen)")
    hdr = "variant," + ",".join(
        (f + "*" if f in unseen else f) for f in EVAL_MXINT)
    print(hdr)
    for v, row in table.items():
        print(v + "," + ",".join(f"{row[f] * 100:.1f}" for f in EVAL_MXINT))
    # claim check: multiformat within margin of best per column
    ok, margin = True, 0.0
    for ef in EVAL_MXINT:
        best = max(table[v][ef] for v in table)
        gap = best - table["multiformat"][ef]
        margin = max(margin, gap)
        tol = 0.05 if ef == "mxint2" else 0.03
        ok &= gap <= tol
    print(f"table12_downstream,{(time.time() - t0) * 1e6:.0f},"
          f"multi_within_margin={ok}:max_gap={margin * 100:.1f}pts")


if __name__ == "__main__":
    main()
