"""Anchor-format (packed MX) checkpoints — the paper's deployment artifact.

Stores element codes bit-packed at their true width (2/4/6/8 bits via
``core.packed``) plus int8 E8M0 scales and fp leaves. An MXINT8 anchor of a
7B model is ~4.2x smaller than its f32 master checkpoint; SS conversion at
load time then serves any lower format from this single artifact (§3.5).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.anchor import AnchorModel
from repro.core.formats import get_format
from repro.core.mx import MXTensor
from repro.core.packed import pack_np, unpack_np


def save_anchor(path: str, model: AnchorModel, keep_tmp: bool = False) -> int:
    """Write a packed anchor checkpoint. Returns bytes written."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    fmt = get_format(model.fmt_name)
    arrays: Dict[str, np.ndarray] = {}
    index = {"fmt": model.fmt_name, "block_size": fmt.block_size,
             "quantized": {}, "raw": []}
    for k, t in model.quantized.items():
        codes = np.asarray(t.codes)
        buf, shape = pack_np(codes, t.fmt.bits)
        arrays[f"q:{k}:codes"] = buf
        arrays[f"q:{k}:scales"] = np.asarray(t.scale_exp)
        index["quantized"][k] = {
            "shape": list(shape), "bits": t.fmt.bits,
            "block_axis": t.block_axis,
            "signed": t.fmt.kind == "int",
            "scale_shape": list(t.scale_exp.shape),
        }
    for k, w in model.raw.items():
        arrays[f"r:{k}"] = np.asarray(w)
        index["raw"].append(k)
    np.savez(os.path.join(tmp, "anchor.npz"), **arrays)
    with open(os.path.join(tmp, "index.json"), "w") as f:
        json.dump(index, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return sum(a.nbytes for a in arrays.values())


def load_anchor(path: str) -> AnchorModel:
    with open(os.path.join(path, "index.json")) as f:
        index = json.load(f)
    fmt = get_format(index["fmt"], index["block_size"])
    quantized = {}
    with np.load(os.path.join(path, "anchor.npz")) as z:
        for k, meta in index["quantized"].items():
            codes = unpack_np(z[f"q:{k}:codes"], meta["bits"],
                              tuple(meta["shape"]), meta["signed"])
            dtype = jnp.int8 if meta["signed"] else jnp.uint8
            quantized[k] = MXTensor(
                codes=jnp.asarray(codes, dtype),
                scale_exp=jnp.asarray(z[f"q:{k}:scales"], jnp.int8),
                fmt=fmt, block_axis=meta["block_axis"])
        raw = {k: jnp.asarray(z[f"r:{k}"]) for k in index["raw"]}
    return AnchorModel(quantized=quantized, raw=raw, fmt_name=index["fmt"])
