from repro.checkpoint import io
from repro.checkpoint.anchor_ckpt import save_anchor, load_anchor
