"""Checkpointing: atomic, manifest-driven, keep-N, resumable, reshardable.

Layout (one directory per step):
    <dir>/step_000123/
        arrays.npz            flat path->array (gathered global views)
        manifest.json         step, keys, dtypes, shapes, framework meta
    <dir>/LATEST              text file: "step_000123"  (atomic rename)

Design points for 1000+-node runs:
  - writes go to a tmp dir then os.rename (atomic on POSIX) — a preempted
    writer never corrupts LATEST;
  - arrays are stored as *global* logical arrays keyed by path, so a restart
    may use a different mesh/topology: load() re-shards onto whatever
    shardings the new run provides (elastic scaling);
  - keep_n garbage-collects old steps only after LATEST moves forward;
  - anchor (packed MX) checkpoints live in ``anchor_ckpt.py`` and share the
    manifest format;
  - ``save_flat``/``restore_flat`` are the template-free twins of
    save/restore: arrays keyed by caller-chosen flat names, loadable
    without knowing the pytree structure up front. ``ElasticEngine``
    snapshots its scheduler state through them (the snapshot's key set —
    per-request prompts, variable-length queues — is only known from the
    manifest, so a structural template cannot exist before the load; see
    docs/serving_internals.md §7).

In a true multi-host deployment each host would write its addressable shards
(orbax-style); this container is single-process, so save() gathers. The
interface (save/restore by step + LATEST pointer) is host-count agnostic.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

MANIFEST = "manifest.json"
LATEST = "LATEST"


def _flat(tree) -> Dict[str, Any]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(p): v for p, v in leaves}


def _unflat_into(template, flat: Dict[str, Any]):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    vals = []
    for p, old in leaves:
        k = jax.tree_util.keystr(p)
        if k not in flat:
            raise KeyError(f"checkpoint missing {k}")
        vals.append(flat[k])
    return jax.tree_util.tree_unflatten(treedef, vals)


def step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:09d}")


def _write_step(root: str, step: int, arrays: Dict[str, np.ndarray],
                extra_meta: Optional[Dict], keep_n: int) -> str:
    """Atomic step writer shared by ``save`` and ``save_flat``: tmp dir +
    rename, LATEST pointer advance, then keep-N garbage collection."""
    os.makedirs(root, exist_ok=True)
    final = step_dir(root, step)
    tmp = final + f".tmp.{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)

    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "time": time.time(),
        "keys": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                 for k, a in arrays.items()},
        "meta": extra_meta or {},
    }
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    # advance LATEST atomically
    ltmp = os.path.join(root, LATEST + ".tmp")
    with open(ltmp, "w") as f:
        f.write(os.path.basename(final))
    os.rename(ltmp, os.path.join(root, LATEST))

    _gc(root, keep_n)
    return final


def save(root: str, step: int, tree, extra_meta: Optional[Dict] = None,
         keep_n: int = 3) -> str:
    flat = _flat(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    return _write_step(root, step, arrays, extra_meta, keep_n)


def save_flat(root: str, step: int, arrays: Dict[str, Any],
              extra_meta: Optional[Dict] = None, keep_n: int = 3) -> str:
    """Like ``save`` but the caller provides flat ``name -> array`` pairs
    verbatim — no pytree flattening, so ``restore_flat`` can hand the same
    names back without a structural template."""
    return _write_step(root, step,
                       {k: np.asarray(v) for k, v in arrays.items()},
                       extra_meta, keep_n)


def _gc(root: str, keep_n: int):
    steps = sorted(d for d in os.listdir(root) if d.startswith("step_")
                   and not d.endswith(".tmp") and ".tmp." not in d)
    for d in steps[:-keep_n] if keep_n > 0 else []:
        shutil.rmtree(os.path.join(root, d), ignore_errors=True)


def latest_step(root: str) -> Optional[int]:
    path = os.path.join(root, LATEST)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(root, name)):
        return None
    return int(name.split("_")[1])


def restore_flat(root: str, step: Optional[int] = None):
    """Template-free load: ``(arrays, manifest)`` with arrays keyed exactly
    as ``save_flat`` stored them (``step=None`` follows LATEST). The caller
    owns re-assembly — this is the entry point for state whose key set is
    data-dependent (e.g. engine snapshots keyed by request id)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = step_dir(root, step)
    with np.load(os.path.join(d, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    with open(os.path.join(d, MANIFEST)) as f:
        manifest = json.load(f)
    return arrays, manifest


def restore(root: str, template, step: Optional[int] = None,
            shardings=None):
    """Load into the structure of `template`; device_put with `shardings`
    (any mesh — enables elastic re-scale on restart)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = step_dir(root, step)
    with np.load(os.path.join(d, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflat_into(template, flat)
    tree = jax.tree_util.tree_map(
        lambda t, x: np.asarray(x).astype(t.dtype)
        if hasattr(t, "dtype") else x, template, tree)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    with open(os.path.join(d, MANIFEST)) as f:
        manifest = json.load(f)
    return tree, manifest
