"""repro: MF-QAT — multi-format QAT + Slice-and-Scale elastic inference,
as a multi-pod JAX training/serving framework."""
__version__ = "1.0.0"
