"""Logical-axis sharding rules (MaxText-style) mapped onto concrete meshes.

Models annotate params/activations with *logical* axis names; a rule table
maps each name to an ordered tuple of candidate mesh axes. At lowering time we
resolve each name against the active mesh:

  - mesh axes that don't exist are dropped (so one model works on the
    single-pod (data, model) and the multi-pod (pod, data, model) mesh),
  - a mapping is only applied if the axis size divides the dim (uneven dims
    fall back to the largest usable prefix, then to replicated),
  - every mesh axis is used at most once per spec (GSPMD requirement).

Policies: per-arch overrides (e.g. Jamba uses true expert parallelism —
experts -> model; Mixtral's 8 experts don't divide model=16, so experts stay
local and the expert FFN dim is tensor-parallel instead).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# name -> ordered candidate mesh axes (subsets applied left-to-right)
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),       # ZeRO-style param/optimizer sharding
    "model": ("model",),           # tensor parallel
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),             # d_ff
    "seq": (),                     # residual-stream seq: replicated (baseline)
    "seq_sp": ("model",),          # sequence-parallel residual (optimized)
    "kv_seq": ("model",),          # decode KV-cache sequence dim
    "experts": ("model",),         # EP (jamba)
    "experts_tp": (),              # placeholder for TP-expert policies
    "none": (),
}


@dataclasses.dataclass
class LogicalRules:
    table: Dict[str, Tuple[str, ...]]

    def lookup(self, name: Optional[str]) -> Tuple[str, ...]:
        if name is None:
            return ()
        return self.table.get(name, ())


_STATE = threading.local()


def set_rules(mesh: Optional[Mesh], rules: Optional[LogicalRules] = None):
    _STATE.mesh = mesh
    _STATE.rules = rules or LogicalRules(dict(DEFAULT_RULES))


def clear_rules():
    _STATE.mesh = None
    _STATE.rules = None


def active_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


def _active_rules() -> Optional[LogicalRules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Optional[LogicalRules] = None):
    prev_mesh, prev_rules = active_mesh(), _active_rules()
    set_rules(mesh, rules)
    try:
        yield
    finally:
        _STATE.mesh = prev_mesh
        _STATE.rules = prev_rules


def spec_for_axes(shape: Sequence[int],
                  logical_axes: Sequence[Optional[str]],
                  mesh: Mesh,
                  rules: Optional[LogicalRules] = None) -> P:
    """Resolve logical names to a PartitionSpec valid for `shape` on `mesh`."""
    rules = rules or _active_rules() or LogicalRules(dict(DEFAULT_RULES))
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used = set()
    entries = []
    for dim, name in zip(shape, logical_axes):
        cands = [a for a in rules.lookup(name)
                 if a in mesh_sizes and a not in used]
        chosen = []
        prod = 1
        for a in cands:
            if dim % (prod * mesh_sizes[a]) == 0:
                chosen.append(a)
                prod *= mesh_sizes[a]
        for a in chosen:
            used.add(a)
        if len(chosen) == 0:
            entries.append(None)
        elif len(chosen) == 1:
            entries.append(chosen[0])
        else:
            entries.append(tuple(chosen))
    return P(*entries)


def shard_act(x: jax.Array, logical_axes: Sequence[Optional[str]]):
    """Annotate an activation with logical axes (no-op without a mesh)."""
    mesh = active_mesh()
    if mesh is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"{logical_axes} vs shape {x.shape}")
    spec = spec_for_axes(x.shape, logical_axes, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def param_shardings(param_axes, params_shapes, mesh: Mesh,
                    rules: Optional[LogicalRules] = None):
    """Map a pytree of logical-axis tuples + shapes -> NamedShardings."""
    def one(axes, shaped):
        shape = shaped.shape if hasattr(shaped, "shape") else shaped
        return NamedSharding(mesh, spec_for_axes(shape, axes, mesh, rules))

    return jax.tree_util.tree_map(
        one, param_axes, params_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
