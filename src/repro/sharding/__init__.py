from repro.sharding.rules import (LogicalRules, shard_act, set_rules,
                                  clear_rules, spec_for_axes, param_shardings,
                                  active_mesh)
