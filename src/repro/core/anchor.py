"""Anchor-format model storage + elastic conversion (paper §3.5).

Inference-time pipeline:
  1. quantize the trained master weights once to the anchor format A
     (MXINT8 / MXFP8)  ->  ``AnchorModel`` (MXTensor leaves + raw leaves),
  2. at runtime, derive any lower-precision format t via Slice-and-Scale,
     *without* access to the full-precision weights,
  3. dequantize W_t (or feed packed codes straight into the dequant-fused
     Pallas GEMM) and serve.

The AnchorModel is a plain pytree, so it jits/shards/checkpoints like params.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.formats import MXFormat, get_format
from repro.core.mx import MXTensor, dequantize, quantize
from repro.core.qat import QATConfig
from repro.core.slice_scale import slice_and_scale


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("quantized", "raw"), meta_fields=("fmt_name",))
@dataclasses.dataclass
class AnchorModel:
    """quantized: dict path -> MXTensor; raw: dict path -> fp leaf."""

    quantized: Dict[str, MXTensor]
    raw: Dict[str, jax.Array]
    fmt_name: str


def _flatten_paths(params) -> Dict[str, jax.Array]:
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    return {jax.tree_util.keystr(p): w for p, w in leaves}


def make_anchor(params, cfg: QATConfig, anchor: MXFormat | None = None
                ) -> AnchorModel:
    """One-time quantization of master weights to the anchor format."""
    from repro.core.qat import pytree_block_axis
    fmt = anchor or cfg.anchor_obj()
    assert fmt is not None, "anchor format required"
    q, raw = {}, {}
    for path, w in _flatten_paths(params).items():
        ax = pytree_block_axis(w)
        if (w.ndim >= 2 and cfg.is_quantized_path(path)
                and w.shape[ax] % fmt.block_size == 0):
            q[path] = quantize(w, fmt, axis=ax)
        else:
            raw[path] = w
    return AnchorModel(quantized=q, raw=raw, fmt_name=fmt.name)


def convert(model: AnchorModel, target: MXFormat) -> AnchorModel:
    """Slice-and-Scale the whole model to a lower-precision format."""
    return AnchorModel(
        quantized={k: slice_and_scale(t, target)
                   for k, t in model.quantized.items()},
        raw=model.raw,
        fmt_name=target.name,
    )


def materialize(model: AnchorModel, treedef_params, dtype=jnp.bfloat16):
    """Rebuild a dense param pytree (for engines without packed-GEMM support).

    ``treedef_params`` is any pytree with the original structure (e.g. the
    ShapeDtypeStruct tree) used to re-nest the flat path->leaf mapping.
    """
    flat = _flatten_paths(treedef_params)
    out = {}
    for path in flat:
        if path in model.quantized:
            out[path] = dequantize(model.quantized[path], dtype=dtype)
        else:
            out[path] = model.raw[path].astype(dtype) \
                if jnp.issubdtype(model.raw[path].dtype, jnp.floating) \
                else model.raw[path]
    leaves_paths = jax.tree_util.tree_flatten_with_path(treedef_params)
    rebuilt = jax.tree_util.tree_unflatten(
        leaves_paths[1],
        [out[jax.tree_util.keystr(p)] for p, _ in leaves_paths[0]])
    return rebuilt


def storage_bytes(model: AnchorModel) -> int:
    """True packed checkpoint size (elements at fmt.bits + E8M0 scales)."""
    total = 0
    for t in model.quantized.values():
        total += t.nbytes_logical
    for w in model.raw.values():
        total += w.size * w.dtype.itemsize
    return total
