"""MX (microscaling) format definitions — OCP MX spec + the paper's extensions.

A microscaling format is defined by (Rouhani et al., 2023a):
  (i)   the scale-factor data type  (E8M0: power-of-two exponent stored in int8),
  (ii)  the element data type and precision (signed int for MXINT, small float for
        MXFP),
  (iii) the scaling block size (k values share one scale).

This module is pure metadata + scalar helpers; array math lives in ``mx.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

# E8M0 scale exponent range (OCP): int8 biased-127, value NaN at 0xFF.
SCALE_EXP_MIN = -127
SCALE_EXP_MAX = 127

# OCP default block size; the paper's MSE/PPL figures use 64.
DEFAULT_BLOCK_SIZE = 32


@dataclasses.dataclass(frozen=True)
class MXFormat:
    """A microscaling numeric format.

    kind:        'int' (MXINT) or 'fp' (MXFP)
    bits:        total element bits (sign included)
    ebits/mbits: exponent / mantissa bits for MXFP (0 for MXINT)
    block_size:  number of elements sharing one E8M0 scale
    """

    name: str
    kind: str
    bits: int
    ebits: int = 0
    mbits: int = 0
    block_size: int = DEFAULT_BLOCK_SIZE

    def __post_init__(self):
        if self.kind not in ("int", "fp"):
            raise ValueError(f"bad kind {self.kind}")
        if self.kind == "fp" and 1 + self.ebits + self.mbits != self.bits:
            raise ValueError(f"{self.name}: 1+{self.ebits}+{self.mbits} != {self.bits}")
        if self.kind == "int" and self.bits < 2:
            raise ValueError("MXINT needs >= 2 bits (sign + >=1 magnitude)")

    # ---- element-format properties ----------------------------------------
    @property
    def emax(self) -> int:
        """Exponent of the largest normal number in the element format.

        MXINT-b: largest element is 2^(b-1)-1, floor(log2) = b-2  (paper §3.3:
        Δe = e_max(b_h) − e_max(b_l) = b_h − b_l, consistent with b-2).
        MXFP(η,μ): bias = 2^(η-1)-1; max exponent field = 2^η − 1 (no inf/nan
        reserved per OCP FP6/FP4; E4M3 reserves only mantissa-all-ones) so
        emax = (2^η − 1) − bias = 2^(η-1).
        """
        if self.kind == "int":
            return self.bits - 2
        return 2 ** (self.ebits - 1)

    @property
    def fp_bias(self) -> int:
        assert self.kind == "fp"
        return 2 ** (self.ebits - 1) - 1

    @property
    def emin(self) -> int:
        """Exponent of the smallest *normal* MXFP number."""
        assert self.kind == "fp"
        return 1 - self.fp_bias

    @property
    def int_maxq(self) -> int:
        """Largest MXINT element magnitude (symmetric: we clip to ±(2^(b-1)-1))."""
        assert self.kind == "int"
        return 2 ** (self.bits - 1) - 1

    @property
    def fp_max(self) -> float:
        """Largest-magnitude MXFP element value."""
        assert self.kind == "fp"
        if self.ebits == 4 and self.mbits == 3:
            # E4M3 (OCP FP8): S.1111.111 is NaN -> max mantissa is 1.75, not 1.875
            return 448.0
        mant = 2.0 - 2.0 ** (-self.mbits)
        return mant * 2.0 ** self.emax

    @property
    def storage_bits(self) -> int:
        """Element bits as stored after packing (== bits; packing is exact)."""
        return self.bits

    def with_block_size(self, block_size: int) -> "MXFormat":
        return dataclasses.replace(self, block_size=block_size)

    def __str__(self) -> str:  # pragma: no cover
        return self.name


def _mk_int(b: int, bs: int = DEFAULT_BLOCK_SIZE) -> MXFormat:
    return MXFormat(name=f"mxint{b}", kind="int", bits=b, block_size=bs)


def _mk_fp(e: int, m: int, bs: int = DEFAULT_BLOCK_SIZE) -> MXFormat:
    return MXFormat(name=f"mxfp{1 + e + m}_e{e}m{m}", kind="fp", bits=1 + e + m,
                    ebits=e, mbits=m, block_size=bs)


# ---- registry ---------------------------------------------------------------
# MXINT 2..8 (paper trains {2,4,6,8}, evals {2..8}).
MXINT: Dict[int, MXFormat] = {b: _mk_int(b) for b in range(2, 9)}

# MXFP per paper §3.2: 4(E2M1), 5(E2M2), 6(E3M2), 7(E3M3), 8(E4M3).
MXFP: Dict[int, MXFormat] = {
    4: _mk_fp(2, 1),
    5: _mk_fp(2, 2),
    6: _mk_fp(3, 2),
    7: _mk_fp(3, 3),
    8: _mk_fp(4, 3),
}

REGISTRY: Dict[str, MXFormat] = {}
for _f in list(MXINT.values()) + list(MXFP.values()):
    REGISTRY[_f.name] = _f
# Friendly aliases (paper naming).
for _b, _f in MXFP.items():
    REGISTRY[f"mxfp{_b}"] = _f

TRAIN_FORMATS_MXINT: Tuple[str, ...] = ("mxint2", "mxint4", "mxint6", "mxint8")
EVAL_FORMATS_MXINT: Tuple[str, ...] = tuple(f"mxint{b}" for b in range(2, 9))
TRAIN_FORMATS_MXFP: Tuple[str, ...] = ("mxfp4", "mxfp6", "mxfp8")
EVAL_FORMATS_MXFP: Tuple[str, ...] = tuple(f"mxfp{b}" for b in range(4, 9))

ANCHOR_MXINT = "mxint8"
ANCHOR_MXFP = "mxfp8"


def get_format(name: str, block_size: int | None = None) -> MXFormat:
    """Look up a format by name, e.g. 'mxint4', 'mxfp6', 'mxfp6_e3m2'."""
    key = name.lower()
    if key not in REGISTRY:
        raise KeyError(f"unknown MX format {name!r}; known: {sorted(REGISTRY)}")
    fmt = REGISTRY[key]
    if block_size is not None and block_size != fmt.block_size:
        fmt = fmt.with_block_size(block_size)
    return fmt


def delta_e(high: MXFormat, low: MXFormat) -> int:
    """Δe of the Slice-and-Scale transform (paper Eqs. 4/6)."""
    if high.kind != low.kind:
        raise ValueError("slice-and-scale requires same-kind formats")
    de = high.emax - low.emax
    if de < 0:
        raise ValueError(f"{high.name} -> {low.name} is not a down-conversion")
    return de
