"""Straight-through-estimator fake quantization for QAT (paper §3.2 / §3.5).

Two forward operators:

  direct:    W_t = Q_t(W_fp)                     (plain QAT, one format)
  anchored:  W_A = Q_A(W_fp);  W_t = Q_{A→t}(W_A)   (anchor-storage pipeline)

Gradients propagate through both with the straight-through estimator
(Yin et al., 2019): d/dW fake_quant(W) := 1.

Multi-format training uses ``fake_quant_switch`` — a ``lax.switch`` over a
static tuple of formats with a *traced* index, so one jitted train step serves
every format in the schedule with no recompilation.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.formats import MXFormat
from repro.core.mx import quantize, dequantize, quantize_dequantize
from repro.core.slice_scale import slice_and_scale


def _ste(w: jax.Array, w_q: jax.Array) -> jax.Array:
    """w + stop_grad(w_q - w): value w_q, gradient identity."""
    return w + jax.lax.stop_gradient(w_q.astype(w.dtype) - w)


def fake_quant(w: jax.Array, fmt: MXFormat, axis: int = -1) -> jax.Array:
    """Direct STE fake-quant: value = dequant(quant(w)), grad = identity."""
    return _ste(w, quantize_dequantize(w, fmt, axis=axis))


def fake_quant_anchored(w: jax.Array, anchor: MXFormat, target: MXFormat,
                        axis: int = -1) -> jax.Array:
    """Anchored STE fake-quant (paper Eq. 7): W_t = Q_{A→t}(Q_A(W))."""
    t_a = quantize(w, anchor, axis=axis)
    t_t = slice_and_scale(t_a, target)
    return _ste(w, dequantize(t_t, dtype=w.dtype))


def fake_quant_switch(w: jax.Array, formats: Sequence[MXFormat],
                      idx: jax.Array, axis: int = -1) -> jax.Array:
    """STE fake-quant with a traced format index over a static format tuple.

    ``idx`` selects which format's quantizer runs this step; out-of-range idx
    (== len(formats)) means "no quantization" (full-precision branch), which
    lets the same jitted step also serve the FP fine-tuning baseline.
    """
    branches = [lambda x, f=f: quantize_dequantize(x, f, axis=axis)
                for f in formats]
    branches.append(lambda x: x.astype(jnp.float32).astype(x.dtype))
    w_q = jax.lax.switch(jnp.clip(idx, 0, len(formats)), branches, w)
    return _ste(w, w_q)


def fake_quant_anchored_switch(w: jax.Array, anchor: MXFormat,
                               targets: Sequence[MXFormat], idx: jax.Array,
                               axis: int = -1) -> jax.Array:
    """Anchored STE fake-quant with traced target-format index."""
    t_a = quantize(w, anchor, axis=axis)

    def mk(f):
        def br(t):
            return dequantize(slice_and_scale(t, f), dtype=w.dtype)
        return br

    branches = [mk(f) for f in targets]
    branches.append(lambda t: dequantize(t, dtype=w.dtype))  # anchor itself
    w_q = jax.lax.switch(jnp.clip(idx, 0, len(targets)), branches, t_a)
    return _ste(w, w_q)
