"""Block-wise MX quantization / dequantization (pure jnp, OCP MX semantics).

Follows the paper's Eqs. (1)-(3)/(5):

    shared_exp = floor(log2(max_i |V_i|)) - e_max(f)
    X          = 2^shared_exp
    P_i        = quantize_f(V_i / X)

Elements are stored as *codes*:
  - MXINT:  int8 two's-complement integer value in [-(2^(b-1)-1), 2^(b-1)-1]
  - MXFP:   uint8 bit pattern  s | e(ebits) | m(mbits)  in the low `bits` bits

Scales are stored as int8 exponents (E8M0, value = 2^scale_exp).

The block axis is arbitrary; blocks are formed along it and its length must be
divisible by ``fmt.block_size``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import (MXFormat, SCALE_EXP_MAX, SCALE_EXP_MIN)


# =============================================================================
# MXTensor container
# =============================================================================
@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("codes", "scale_exp"),
    meta_fields=("fmt", "block_axis"),
)
@dataclasses.dataclass
class MXTensor:
    """A tensor in an MX format.

    codes:      element codes, same shape as the logical tensor (int8/uint8)
    scale_exp:  int8 block-scale exponents; shape = codes.shape with the block
                axis divided by fmt.block_size
    fmt:        the MXFormat (static)
    block_axis: which axis blocks run along (static, non-negative)
    """

    codes: jax.Array
    scale_exp: jax.Array
    fmt: MXFormat
    block_axis: int

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(self.codes.shape)

    @property
    def nbytes_logical(self) -> int:
        """True packed storage footprint in bytes (elements + scales)."""
        n = int(np.prod(self.shape)) if self.shape else 1
        nblocks = n // self.fmt.block_size
        return (n * self.fmt.bits + nblocks * 8 + 7) // 8


def _norm_axis(axis: int, ndim: int) -> int:
    axis = axis % ndim
    return axis


def _to_blocks(x: jax.Array, block_size: int, axis: int) -> jax.Array:
    """(..., n, ...) -> (..., n/bs, bs) with block axis moved last."""
    x = jnp.moveaxis(x, axis, -1)
    n = x.shape[-1]
    if n % block_size != 0:
        raise ValueError(f"block axis length {n} not divisible by block size "
                         f"{block_size}")
    return x.reshape(*x.shape[:-1], n // block_size, block_size)


def _from_blocks(xb: jax.Array, axis: int, ndim: int) -> jax.Array:
    x = xb.reshape(*xb.shape[:-2], xb.shape[-2] * xb.shape[-1])
    return jnp.moveaxis(x, -1, axis)


def _floor_log2(x: jax.Array) -> jax.Array:
    """floor(log2(x)) for x > 0, exact at powers of two (frexp-based)."""
    m, e = jnp.frexp(x)
    del m
    return (e - 1).astype(jnp.int32)


def _exp2i(e: jax.Array, dtype=jnp.float32) -> jax.Array:
    """2^e for integer e (exact, via ldexp)."""
    return jnp.ldexp(jnp.ones_like(e, dtype=dtype), e)


# =============================================================================
# Element quantizers (value domain)
# =============================================================================
def quantize_int_element(y: jax.Array, fmt: MXFormat) -> jax.Array:
    """clip_b(round(y)) -> int8 integer codes. Round half-to-even."""
    assert fmt.kind == "int"
    maxq = fmt.int_maxq
    q = jnp.clip(jnp.round(y), -maxq, maxq)
    return q.astype(jnp.int8)


def quantize_fp_element_value(y: jax.Array, fmt: MXFormat) -> jax.Array:
    """Round-to-nearest-even into the MXFP(η,μ) value set, saturating.

    Returns float32 *values* (each exactly representable in the target format).
    Subnormals are supported; overflow saturates to ±fp_max (OCP conversion).
    """
    assert fmt.kind == "fp"
    y = y.astype(jnp.float32)
    a = jnp.abs(y)
    # Exponent of y (floor log2), clamped at the subnormal boundary.
    _, e_raw = jnp.frexp(jnp.where(a > 0, a, 1.0))
    e = jnp.maximum(e_raw - 1, fmt.emin)
    quantum = _exp2i(e - fmt.mbits)
    q = jnp.round(y / quantum) * quantum
    q = jnp.clip(q, -fmt.fp_max, fmt.fp_max)
    return jnp.where(a > 0, q, jnp.zeros_like(q)).astype(jnp.float32)


# ---- MXFP code <-> value ----------------------------------------------------
def encode_fp(values: jax.Array, fmt: MXFormat) -> jax.Array:
    """Exactly-representable float values -> uint8 bit patterns."""
    assert fmt.kind == "fp"
    v = values.astype(jnp.float32)
    s = (v < 0) | ((v == 0) & (jnp.signbit(v)))
    a = jnp.abs(v)
    _, e_raw = jnp.frexp(jnp.where(a > 0, a, 1.0))
    expo = e_raw - 1                                  # floor(log2 a)
    is_sub = (expo < fmt.emin) | (a == 0)
    # normal: mant field = (a / 2^expo - 1) * 2^mbits
    mant_n = jnp.round((a * _exp2i(-expo) - 1.0) * (1 << fmt.mbits))
    e_field_n = expo + fmt.fp_bias
    # subnormal: mant field = a / 2^(emin - mbits)
    mant_s = jnp.round(a * _exp2i(jnp.full_like(expo, fmt.mbits - fmt.emin)))
    e_field = jnp.where(is_sub, 0, e_field_n).astype(jnp.int32)
    mant = jnp.where(is_sub, mant_s, mant_n).astype(jnp.int32)
    code = (s.astype(jnp.int32) << (fmt.bits - 1)) | (e_field << fmt.mbits) | mant
    return code.astype(jnp.uint8)


def _fp_decode_table(fmt: MXFormat) -> np.ndarray:
    """256-entry LUT: uint8 code -> float32 value (top bits ignored)."""
    assert fmt.kind == "fp"
    codes = np.arange(256, dtype=np.uint32) & ((1 << fmt.bits) - 1)
    s = (codes >> (fmt.bits - 1)) & 1
    e = (codes >> fmt.mbits) & ((1 << fmt.ebits) - 1)
    m = codes & ((1 << fmt.mbits) - 1)
    normal = e > 0
    mag = np.where(
        normal,
        (1.0 + m / (1 << fmt.mbits)) * np.exp2(e.astype(np.float64) - fmt.fp_bias),
        (m / (1 << fmt.mbits)) * np.exp2(float(fmt.emin)),
    )
    vals = np.where(s == 1, -mag, mag).astype(np.float32)
    # OCP E4M3: exponent-all-ones + mantissa-all-ones is NaN.
    if fmt.ebits == 4 and fmt.mbits == 3:
        nan_mask = (e == 15) & (m == 7)
        vals = np.where(nan_mask, np.nan, vals).astype(np.float32)
    return vals


@functools.lru_cache(maxsize=None)
def _fp_decode_table_cached(fmt: MXFormat) -> np.ndarray:
    return _fp_decode_table(fmt)


def decode_fp(codes: jax.Array, fmt: MXFormat, dtype=jnp.float32) -> jax.Array:
    lut = jnp.asarray(_fp_decode_table_cached(fmt), dtype=dtype)
    return jnp.take(lut, codes.astype(jnp.int32), axis=0)


def decode_elements(codes: jax.Array, fmt: MXFormat, dtype=jnp.float32) -> jax.Array:
    if fmt.kind == "int":
        return codes.astype(dtype)
    return decode_fp(codes, fmt, dtype=dtype)


# =============================================================================
# Block quantize / dequantize
# =============================================================================
def compute_scale_exp(v: jax.Array, fmt: MXFormat, axis: int = -1) -> jax.Array:
    """shared_exp per block: floor(log2 max|V|) - emax(f), clipped to E8M0."""
    axis = _norm_axis(axis, v.ndim)
    vb = _to_blocks(v.astype(jnp.float32), fmt.block_size, axis)
    bmax = jnp.max(jnp.abs(vb), axis=-1)
    exp = jnp.where(bmax > 0, _floor_log2(jnp.where(bmax > 0, bmax, 1.0)),
                    SCALE_EXP_MIN + fmt.emax)
    exp = exp - fmt.emax
    exp = jnp.clip(exp, SCALE_EXP_MIN, SCALE_EXP_MAX)
    return exp.astype(jnp.int8)


def quantize(v: jax.Array, fmt: MXFormat, axis: int = -1) -> MXTensor:
    """Direct MX quantization of a float tensor (paper Eqs. 1-3/5)."""
    axis = _norm_axis(axis, v.ndim)
    v32 = v.astype(jnp.float32)
    scale_exp = compute_scale_exp(v32, fmt, axis)
    vb = _to_blocks(v32, fmt.block_size, axis)
    inv_scale = _exp2i(-scale_exp.astype(jnp.int32))[..., None]
    y = vb * inv_scale
    if fmt.kind == "int":
        codes_b = quantize_int_element(y, fmt)
    else:
        codes_b = encode_fp(quantize_fp_element_value(y, fmt), fmt)
    codes = _from_blocks(codes_b, axis, v.ndim)
    return MXTensor(codes=codes, scale_exp=scale_exp, fmt=fmt, block_axis=axis)


def dequantize(t: MXTensor, dtype=jnp.float32) -> jax.Array:
    """V̂_i = X * P_i."""
    vals_b = _to_blocks(decode_elements(t.codes, t.fmt, jnp.float32),
                        t.fmt.block_size, t.block_axis)
    scale = _exp2i(t.scale_exp.astype(jnp.int32))[..., None]
    out = vals_b * scale
    return _from_blocks(out, t.block_axis, t.codes.ndim).astype(dtype)


def quantize_dequantize(v: jax.Array, fmt: MXFormat, axis: int = -1,
                        dtype=None) -> jax.Array:
    """Fused fake-quant value: dequantize(quantize(v)) in one pass.

    Avoids materializing codes; used by the QAT forward path.
    """
    axis = _norm_axis(axis, v.ndim)
    v32 = v.astype(jnp.float32)
    scale_exp = compute_scale_exp(v32, fmt, axis).astype(jnp.int32)
    vb = _to_blocks(v32, fmt.block_size, axis)
    inv_scale = _exp2i(-scale_exp)[..., None]
    scale = _exp2i(scale_exp)[..., None]
    y = vb * inv_scale
    if fmt.kind == "int":
        maxq = float(fmt.int_maxq)
        q = jnp.clip(jnp.round(y), -maxq, maxq)
    else:
        q = quantize_fp_element_value(y, fmt)
    out = _from_blocks(q * scale, axis, v.ndim)
    return out.astype(dtype if dtype is not None else v.dtype)
