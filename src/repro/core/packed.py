"""Bit packing for sub-byte MX element codes.

Two consumers:
  - checkpoint serialization (numpy path): true 2/4/6-bit storage on disk,
  - the optimized serving path (jnp path): int4 nibble-packed weights halve the
    HBM bytes of the decode-critical GEMMs vs. unpacked int8.

Packing layouts (little-endian within a byte, along the last axis):
  2-bit: 4 codes/byte      4-bit: 2 codes/byte      6-bit: 4 codes / 3 bytes
  8-bit: identity          3/5/7-bit: stored at the next packable width
         (3->4, 5->6, 7->8); the *format* stays exact — only storage rounds up.

The serving-side int4 nibble layouts (split-N for the fused kernel, legacy
split-K for densify-only paths) and the conventions around them are
documented in docs/serving_internals.md §3.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

_PACK_WIDTH = {2: 2, 3: 4, 4: 4, 5: 6, 6: 6, 7: 8, 8: 8}


def storage_bits(bits: int) -> int:
    return _PACK_WIDTH[bits]


def _to_unsigned(codes: np.ndarray, bits: int) -> np.ndarray:
    return (codes.astype(np.int16) & ((1 << bits) - 1)).astype(np.uint8)


def _from_unsigned(u: np.ndarray, bits: int, signed: bool) -> np.ndarray:
    u = u.astype(np.int16)
    if signed:
        sign = 1 << (bits - 1)
        u = (u ^ sign) - sign
        return u.astype(np.int8)
    return u.astype(np.uint8)


def pack_np(codes: np.ndarray, bits: int) -> Tuple[np.ndarray, Tuple[int, ...]]:
    """Pack int8/uint8 codes (numpy) -> (uint8 packed buffer, original shape)."""
    shape = codes.shape
    w = storage_bits(bits)
    # Mask at the *storage* width so sign-extension from w bits round-trips
    # (e.g. a 3-bit code stored in a 4-bit slot keeps its sign bit at bit 3).
    flat = _to_unsigned(codes.reshape(-1), w)
    if w == 8:
        return flat.astype(np.uint8), shape
    if w == 2:
        pad = (-flat.size) % 4
        f = np.pad(flat, (0, pad))
        f = f.reshape(-1, 4)
        out = (f[:, 0] | (f[:, 1] << 2) | (f[:, 2] << 4) | (f[:, 3] << 6))
        return out.astype(np.uint8), shape
    if w == 4:
        pad = (-flat.size) % 2
        f = np.pad(flat, (0, pad)).reshape(-1, 2)
        return (f[:, 0] | (f[:, 1] << 4)).astype(np.uint8), shape
    if w == 6:
        pad = (-flat.size) % 4
        f = np.pad(flat, (0, pad)).reshape(-1, 4).astype(np.uint32)
        word = f[:, 0] | (f[:, 1] << 6) | (f[:, 2] << 12) | (f[:, 3] << 18)
        out = np.stack([word & 0xFF, (word >> 8) & 0xFF, (word >> 16) & 0xFF],
                       axis=1).reshape(-1)
        return out.astype(np.uint8), shape
    raise ValueError(w)


def unpack_np(buf: np.ndarray, bits: int, shape: Tuple[int, ...],
              signed: bool) -> np.ndarray:
    """Inverse of pack_np."""
    w = storage_bits(bits)
    n = int(np.prod(shape)) if shape else 1
    if w == 8:
        u = buf[:n]
    elif w == 2:
        b = buf.astype(np.uint8)
        u = np.stack([b & 3, (b >> 2) & 3, (b >> 4) & 3, (b >> 6) & 3],
                     axis=1).reshape(-1)[:n]
    elif w == 4:
        b = buf.astype(np.uint8)
        u = np.stack([b & 0xF, (b >> 4) & 0xF], axis=1).reshape(-1)[:n]
    elif w == 6:
        b = buf.reshape(-1, 3).astype(np.uint32)
        word = b[:, 0] | (b[:, 1] << 8) | (b[:, 2] << 16)
        u = np.stack([word & 63, (word >> 6) & 63, (word >> 12) & 63,
                      (word >> 18) & 63], axis=1).reshape(-1)[:n]
    else:
        raise ValueError(w)
    # Sign-extend from the storage width: an n<w bit signed code stored as its
    # low-w-bit two's-complement pattern round-trips exactly.
    return _from_unsigned(np.asarray(u, np.uint8), w, signed).reshape(shape)


# =============================================================================
# jnp nibble packing (serving path; int4 only — the hot deployment format)
# =============================================================================
def pack_int4_jnp(codes: jnp.ndarray) -> jnp.ndarray:
    """int8 codes in [-8,7] -> uint8 nibble-packed along the last axis (len/2)."""
    if codes.shape[-1] % 2 != 0:
        raise ValueError("last axis must be even for int4 packing")
    u = (codes.astype(jnp.int32) & 0xF).astype(jnp.uint8)
    lo = u[..., 0::2]
    hi = u[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def pack_int4_splitn_jnp(codes: jnp.ndarray) -> jnp.ndarray:
    """int8 codes (..., N) -> uint8 (..., N/2), split-half layout.

    Byte j carries code j in the low nibble and code j + N/2 in the high
    nibble. This is the layout the fused int4 dequant-GEMM kernel reads when
    the last axis is the GEMM's output (N) dimension: an output tile never
    straddles the halves, so the nibble choice is a scalar per grid step.
    """
    if codes.shape[-1] % 2 != 0:
        raise ValueError("last axis must be even for int4 packing")
    half = codes.shape[-1] // 2
    u = (codes.astype(jnp.int32) & 0xF).astype(jnp.uint8)
    return (u[..., :half] | (u[..., half:] << 4)).astype(jnp.uint8)


def unpack_int4_splitn_jnp(packed: jnp.ndarray, dtype=jnp.int8) -> jnp.ndarray:
    """Inverse of pack_int4_splitn_jnp: (..., N/2) uint8 -> (..., N) codes."""
    lo = ((packed & 0xF).astype(jnp.int32) ^ 8) - 8
    hi = (((packed >> 4) & 0xF).astype(jnp.int32) ^ 8) - 8
    return jnp.concatenate([lo, hi], axis=-1).astype(dtype)


def unpack_int4_jnp(packed: jnp.ndarray, dtype=jnp.int8) -> jnp.ndarray:
    """uint8 nibble-packed -> int8 codes (last axis doubled), sign-extended."""
    lo = (packed & 0xF).astype(jnp.int32)
    hi = ((packed >> 4) & 0xF).astype(jnp.int32)
    lo = (lo ^ 8) - 8
    hi = (hi ^ 8) - 8
    out = jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1],
                                               packed.shape[-1] * 2)
    return out.astype(dtype)
