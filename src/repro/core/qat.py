"""Multi-format QAT configuration, schedules, and pytree wiring (paper §3.2).

The paper's protocol:
  - weight-only quantization of decoder-stack matmul weights (embeddings,
    lm_head, norms, biases, and small vector params excluded),
  - sequential schedule in increasing bit order (2→4→6→8), one epoch per
    format; for >2B models one total epoch with formats given equal step
    budgets inside it,
  - the anchor-storage variant cycles target formats uniformly per step.

We express a schedule as an int32 array ``format_ids[num_steps]`` indexing a
static tuple of formats; the train step takes ``format_ids[step]`` as a traced
scalar and dispatches via ``lax.switch`` (no recompiles across formats).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import MXFormat, get_format
from repro.core.fake_quant import (fake_quant_anchored_switch,
                                   fake_quant_switch)

# Default exclusion: anything that is not a >=2D matmul weight, plus
# embeddings/lm_head (paper §3.2) and modality frontends.
DEFAULT_EXCLUDE = (
    r"embed", r"lm_head", r"norm", r"bias", r"scale", r"rope",
    r"router",          # MoE router stays fp (standard practice)
    r"conv",            # mamba conv1d (tiny, sensitive)
    r"A_log", r"\bD\b", r"dt_",   # mamba SSM params
    r"time_", r"decay", r"bonus", r"token_shift",   # rwkv ddlerp vectors
    r"vision", r"frontend",
)


@dataclasses.dataclass(frozen=True)
class QATConfig:
    """Quantization-aware-training configuration attached to a model.

    formats:     static tuple of format names in the training set
    anchor:      anchor format name for the §3.5 pipeline (None = direct QAT)
    block_size:  MX scaling block size
    block_axis:  which weight axis blocks run along (contraction dim = 0 for
                 our (d_in, d_out) weight layout)
    exclude:     regexes of param path fragments NOT quantized
    """

    formats: Tuple[str, ...] = ()
    anchor: Optional[str] = None
    block_size: int = 32
    block_axis: int = 0
    exclude: Tuple[str, ...] = DEFAULT_EXCLUDE

    @property
    def enabled(self) -> bool:
        return len(self.formats) > 0

    def format_objs(self) -> Tuple[MXFormat, ...]:
        return tuple(get_format(n, self.block_size) for n in self.formats)

    def anchor_obj(self) -> Optional[MXFormat]:
        return get_format(self.anchor, self.block_size) if self.anchor else None

    def is_quantized_path(self, path: str) -> bool:
        low = path.lower()
        return not any(re.search(p, low) for p in self.exclude)

    # ------------------------------------------------------------------ #
    def apply(self, w: jax.Array, path: str, fmt_idx: jax.Array) -> jax.Array:
        """Fake-quantize one weight according to the config (STE)."""
        if not self.enabled or not self.is_quantized_path(path) or w.ndim < 2:
            return w
        axis = self.block_axis
        if w.shape[axis] % self.block_size != 0:
            return w  # non-blockable dim (rare; e.g. tiny reduced configs)
        fmts = self.format_objs()
        if self.anchor is not None:
            return fake_quant_anchored_switch(
                w, self.anchor_obj(), fmts, fmt_idx, axis=axis)
        return fake_quant_switch(w, fmts, fmt_idx, axis=axis)


# =============================================================================
# Schedules
# =============================================================================
def sequential_schedule(num_formats: int, steps_per_format: int) -> np.ndarray:
    """Paper default: one 'epoch' (steps_per_format) per format, in order.

    Formats must already be sorted in increasing bit order by the caller —
    ``formats.TRAIN_FORMATS_*`` are.
    """
    return np.repeat(np.arange(num_formats, dtype=np.int32), steps_per_format)


def interleaved_schedule(num_formats: int, total_steps: int) -> np.ndarray:
    """>2B-model variant: equal per-format step counts inside one epoch,
    cycled uniformly (also the anchor-storage §3.5 training schedule)."""
    return (np.arange(total_steps, dtype=np.int32)) % num_formats


def fp_schedule(total_steps: int, num_formats: int) -> np.ndarray:
    """Full-precision fine-tuning baseline: index == len(formats) selects the
    pass-through branch of ``fake_quant_switch``."""
    return np.full(total_steps, num_formats, dtype=np.int32)


def single_format_schedule(fmt_pos: int, total_steps: int) -> np.ndarray:
    """Single-format QAT baseline at format position ``fmt_pos``."""
    return np.full(total_steps, fmt_pos, dtype=np.int32)


# =============================================================================
# Pytree-level PTQ helpers (used at eval / export time)
# =============================================================================
def pytree_block_axis(w) -> int:
    """Contraction axis of a (possibly stacked) weight leaf.

    In-model weights are 2D (d_in, d_out) with blocks along axis 0; in the
    param pytree they appear stacked over scan groups (G, d_in, d_out) and
    experts (G, E, d_in, d_out) — the contraction dim is always ndim-2.
    """
    return max(w.ndim - 2, 0)


def ptq_pytree(params, cfg: QATConfig, fmt: MXFormat):
    """Post-training-quantize every quantizable leaf (quant→dequant values)."""
    from repro.core.mx import quantize_dequantize

    flat = jax.tree_util.tree_flatten_with_path(params)
    leaves, treedef = flat

    def one(path, w):
        p = jax.tree_util.keystr(path)
        ax = pytree_block_axis(w)
        if (w.ndim >= 2 and cfg.is_quantized_path(p)
                and w.shape[ax] % fmt.block_size == 0):
            return quantize_dequantize(w, fmt, axis=ax)
        return w

    return jax.tree_util.tree_unflatten(
        treedef, [one(p, w) for p, w in leaves])
