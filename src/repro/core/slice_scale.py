"""Slice-and-Scale format conversion (paper §3.3 / §3.4).

Converts a high-precision MX representation to a lower-precision one *without*
re-expanding to FP32 master weights:

  SSMXINT (Eq. 4):  P_l = clip_{b_l}(round(P_h / 2^Δe)),  X_l = X_h · 2^Δe,
                    Δe = e_max(b_h) − e_max(b_l) = b_h − b_l.
                    On integer codes this is a right-shift with round — we
                    implement exact round-to-nearest-even on int32 lanes, which
                    agrees bit-for-bit with ``jnp.round`` of the exact quotient.

  SSMXFP  (Eq. 6):  P_l = quantize_{η_l,μ_l}(P_h / 2^Δe),  X_l = X_h · 2^Δe,
                    Δe = e_max(η_h) − e_max(η_l).

Because shared_exp = floor(log2 max|V|) − e_max(f), the SS scale equals the
direct-quantization scale *exactly* (modulo E8M0 saturation); only element
rounding can differ (double rounding), bounded by 1 ulp of the target format.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import MXFormat, SCALE_EXP_MAX, SCALE_EXP_MIN, delta_e
from repro.core.mx import (MXTensor, decode_fp, encode_fp,
                           quantize_fp_element_value, _exp2i)


def _rshift_rne(p: jax.Array, de: int) -> jax.Array:
    """Integer right shift by `de` with round-to-nearest-even (int32 math)."""
    if de == 0:
        return p
    q = p >> de                      # floor division (two's complement)
    r = p - (q << de)                # remainder in [0, 2^de)
    half = 1 << (de - 1)
    round_up = (r > half) | ((r == half) & ((q & 1) == 1))
    return q + round_up.astype(p.dtype)


def ss_mxint(t: MXTensor, low: MXFormat) -> MXTensor:
    """SSMXINT: right-shift-and-round on integer codes + scale bump."""
    assert t.fmt.kind == "int" and low.kind == "int"
    if low.block_size != t.fmt.block_size:
        raise ValueError("slice-and-scale preserves block size")
    de = delta_e(t.fmt, low)
    p = t.codes.astype(jnp.int32)
    q = _rshift_rne(p, de)
    maxq = low.int_maxq
    q = jnp.clip(q, -maxq, maxq).astype(jnp.int8)
    se = jnp.clip(t.scale_exp.astype(jnp.int32) + de,
                  SCALE_EXP_MIN, SCALE_EXP_MAX).astype(jnp.int8)
    return MXTensor(codes=q, scale_exp=se, fmt=low, block_axis=t.block_axis)


def ss_mxfp(t: MXTensor, low: MXFormat) -> MXTensor:
    """SSMXFP: explicit divide + requantize of element values + scale bump."""
    assert t.fmt.kind == "fp" and low.kind == "fp"
    if low.block_size != t.fmt.block_size:
        raise ValueError("slice-and-scale preserves block size")
    de = delta_e(t.fmt, low)
    vals = decode_fp(t.codes, t.fmt, jnp.float32)
    y = vals * _exp2i(jnp.full((), -de, jnp.int32))
    q = quantize_fp_element_value(y, low)
    codes = encode_fp(q, low)
    se = jnp.clip(t.scale_exp.astype(jnp.int32) + de,
                  SCALE_EXP_MIN, SCALE_EXP_MAX).astype(jnp.int8)
    return MXTensor(codes=codes, scale_exp=se, fmt=low, block_axis=t.block_axis)


def slice_and_scale(t: MXTensor, low: MXFormat) -> MXTensor:
    """Dispatch SSMXINT / SSMXFP; identity if formats match."""
    if low.name == t.fmt.name and low.block_size == t.fmt.block_size:
        return t
    if t.fmt.kind != low.kind:
        raise ValueError(
            f"cannot slice-and-scale across kinds ({t.fmt.name} -> {low.name})")
    if t.fmt.kind == "int":
        return ss_mxint(t, low)
    return ss_mxfp(t, low)


def ss_quantize_dequantize(t: MXTensor, low: MXFormat, dtype=jnp.float32):
    """dequantize(slice_and_scale(t, low)) — runtime target weights W_t."""
    from repro.core.mx import dequantize
    return dequantize(slice_and_scale(t, low), dtype=dtype)
