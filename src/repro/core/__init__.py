"""repro.core — the paper's contribution: MX formats, Slice-and-Scale, MF-QAT."""
from repro.core.formats import (MXFormat, MXINT, MXFP, REGISTRY, get_format,
                                delta_e, TRAIN_FORMATS_MXINT,
                                TRAIN_FORMATS_MXFP, EVAL_FORMATS_MXINT,
                                EVAL_FORMATS_MXFP, ANCHOR_MXINT, ANCHOR_MXFP)
from repro.core.mx import (MXTensor, quantize, dequantize,
                           quantize_dequantize, compute_scale_exp,
                           encode_fp, decode_fp, decode_elements,
                           quantize_fp_element_value)
from repro.core.slice_scale import (slice_and_scale, ss_mxint, ss_mxfp,
                                    ss_quantize_dequantize)
from repro.core.fake_quant import (fake_quant, fake_quant_anchored,
                                   fake_quant_switch,
                                   fake_quant_anchored_switch)
from repro.core.qat import (QATConfig, sequential_schedule,
                            interleaved_schedule, fp_schedule,
                            single_format_schedule, ptq_pytree)
from repro.core.anchor import (AnchorModel, make_anchor, convert,
                               materialize, storage_bytes)

__all__ = [n for n in dir() if not n.startswith("_")]
