"""Runtime precision-selection policy for elastic inference.

The paper's deployment story: "the same device might want to serve at
different precisions for different batches based on the current load".
This policy maps load (queue depth / active slots) to a format ladder —
deeper queues pick lower-precision (faster, memory-lighter) formats; an idle
server uses the anchor precision. Thresholds are configurable; hysteresis
avoids thrashing between adjacent formats.

Load is queue depth PLUS the queued prompt tokens still waiting to prefill,
scaled by ``prefill_token_unit``: a queue of two 4k-token prompts is a very
different commitment from two 16-token ones, and under chunked admission
those prompts occupy the engine for many ticks. Counting them up front makes
the downshift fire BEFORE a long admission starts — the format is pinned for
each batch wave, so a decision made from queue depth alone would ride out
the whole admission at too high a precision.

The ladder is also the engine's **degradation axis** (docs/
serving_internals.md §7): when a rung misbehaves at runtime (NaN/Inf tick
logits), the engine walks ``escalate(fmt)`` one rung toward the anchor and
replays the tick, and ``quarantine(fmt)`` keeps ``pick`` from handing out
the misbehaving rung to later batch waves. The anchor itself is never
skipped — it is the checkpoint's native precision, the end of the ladder.

With a ``cost`` model attached (``serve/slo.py::CostModel``, docs §10) the
threshold table becomes the *fallback*: when the wave carries a TPOT budget
and at least one rung has measured cost, ``pick`` instead chooses the
WIDEST (highest-precision) non-quarantined rung whose predicted decode-tick
time fits the batch's tightest budget — quality is the objective, the SLO
is the constraint. If no rung fits, the fastest predicted rung is the best
the hardware can do. With no budget in the wave, or no measurements yet,
the queue-depth table decides exactly as before, so an engine without SLOs
behaves bit-identically to the pre-cost-model policy.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Set, Tuple

from repro.serve.slo import CostModel


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Self-speculative decoding knobs (docs/serving_internals.md §9).

    ``draft_fmt`` names the cheap rung that drafts ``k`` tokens per decode
    tick; the batch-pinned format verifies them in one multi-query step.
    Both come from the same anchor checkpoint via Slice-and-Scale, so the
    draft model is free — no separate weights, no separate KV cache.
    Speculation never changes tokens (the engine commits only the verify
    format's own greedy choices); the policy's ``allow_speculation`` turns
    it off when it stops paying for itself: ``min_acceptance`` is the
    measured per-wave draft acceptance rate below which drafting costs
    more than it saves, judged only after ``window`` speculative ticks of
    evidence.
    """

    draft_fmt: str = "mxint4"
    k: int = 4
    min_acceptance: float = 0.0    # 0 = never disable on acceptance rate
    window: int = 16               # spec ticks before the rate is trusted


@dataclasses.dataclass
class FormatPolicy:
    anchor: str = "mxint8"
    # (queue_depth threshold, format) — checked top-down, first match wins
    ladder: Tuple[Tuple[int, str], ...] = (
        (32, "mxint4"),
        (8, "mxint6"),
        (0, "mxint8"),
    )
    hysteresis: int = 2
    # One queued request "counts double" per this many pending prompt tokens
    # — the ladder thresholds stay in queue-depth units.
    prefill_token_unit: int = 64
    # Measured per-format tick cost (serve/slo.py). None = pure threshold
    # policy; attached, it takes over whenever a wave carries a TPOT budget
    # and at least one rung is measured.
    cost: Optional[CostModel] = None
    _last: str = dataclasses.field(default="", init=False)
    _stable: int = dataclasses.field(default=0, init=False)
    history: List[str] = dataclasses.field(default_factory=list, init=False)
    quarantined: Set[str] = dataclasses.field(default_factory=set,
                                              init=False)

    def escalate(self, fmt: str) -> Optional[str]:
        """One rung toward the anchor on the degradation ladder, or None
        when ``fmt`` is already the anchor / unknown to the ladder (there
        is nowhere safer to go — the caller falls back to per-request
        retirement, docs/serving_internals.md §7). The ladder is ordered
        deepest-queue (lowest precision) first, so "up" is the next entry.
        """
        if fmt == self.anchor:
            return None
        fmts = [f for _, f in self.ladder]
        try:
            i = fmts.index(fmt)
        except ValueError:
            return None
        return fmts[i + 1] if i + 1 < len(fmts) else None

    def quarantine(self, fmt: str) -> None:
        """Bar ``fmt`` from future ``pick``s (the engine calls this when a
        rung's logits go non-finite). The anchor is exempt: it is the
        checkpoint's native precision and the ladder's terminal rung."""
        if fmt != self.anchor:
            self.quarantined.add(fmt)

    def allow_speculation(self, draft_fmt: str, pinned_fmt: str,
                          acceptance_rate: Optional[float] = None,
                          min_acceptance: float = 0.0) -> bool:
        """Should the engine draft at ``draft_fmt`` this tick?

        Three vetoes, mirroring the degradation ladder's logic: a
        quarantined draft rung would poison every draft (the engine falls
        back to plain pinned-format decode — the streams are identical
        either way, only speed changes); a draft rung equal to the pinned
        format has no cheaper model to offer; and a measured
        ``acceptance_rate`` below ``min_acceptance`` means the k draft
        steps cost more than the accepted tokens save (pass None while the
        sample is too small to judge — see ``SpecConfig.window``).
        """
        if draft_fmt in self.quarantined:
            return False
        if draft_fmt == pinned_fmt:
            return False
        if acceptance_rate is not None and acceptance_rate < min_acceptance:
            return False
        return True

    def _cost_pick(self, tpot_budget_ms: Optional[float],
                   decode_rows: Optional[int]) -> Optional[str]:
        """Cost-model rung choice, or None when the threshold table must
        decide (no model, no budget in the wave, or nothing measured yet
        — the degradation contract tests/test_policy.py pins down).

        Among non-quarantined rungs with a cost estimate (anchor always
        eligible — it is exempt from quarantine), take the WIDEST whose
        predicted tick time at ``decode_rows`` occupancy fits the budget;
        if none fits, the fastest predicted rung. Ladder order is
        deepest-queue (narrowest) first, so "widest" is the last match.
        """
        cost = self.cost
        if cost is None or tpot_budget_ms is None:
            return None
        if not cost.any_measured():
            return None
        rows = 1 if decode_rows is None else max(1, int(decode_rows))
        fmts = [f for _, f in self.ladder]          # narrow -> wide
        cands = [f for f in fmts
                 if cost.has_estimate(f)
                 and (f not in self.quarantined or f == self.anchor)]
        if not cands:
            return None
        feasible = [f for f in cands
                    if cost.predict_ms(f, rows) <= tpot_budget_ms]
        if feasible:
            return feasible[-1]
        return min(cands, key=lambda f: cost.predict_ms(f, rows))

    def pick(self, queue_depth: int, active: int = 0,
             prefill_tokens: int = 0, *,
             tpot_budget_ms: Optional[float] = None,
             decode_rows: Optional[int] = None,
             override: Optional[str] = None) -> str:
        """Choose the next batch wave's pinned format.

        ``override`` is operator intent (``generate(fmt_override=...)``):
        it wins over load, cost, quarantine and hysteresis, and leaves the
        hysteresis state untouched so the next free-running pick resumes
        where it left off. ``tpot_budget_ms`` is the tightest per-token
        budget among the wave's requests (None when none carry one);
        ``decode_rows`` the expected live decode rows, for the occupancy
        term of the cost prediction.
        """
        if override is not None:
            self.history.append(override)
            return override
        target = self._cost_pick(tpot_budget_ms, decode_rows)
        if target is None:
            load = queue_depth + prefill_tokens // self.prefill_token_unit
            target = self.anchor
            for thresh, fmt in self.ladder:
                if load >= thresh:
                    target = fmt
                    break
        while target in self.quarantined:
            target = self.escalate(target) or self.anchor
        if self._last and target != self._last:
            self._stable += 1
            if self._stable < self.hysteresis:
                target = self._last
            else:
                self._stable = 0
        else:
            self._stable = 0
        self._last = target
        self.history.append(target)
        return target
