from repro.serve.engine import ElasticEngine, Request
from repro.serve.policy import FormatPolicy, SpecConfig
from repro.serve.slo import CostModel, SLOClass
