from repro.serve.engine import ElasticEngine, Request
from repro.serve.policy import FormatPolicy
