"""Per-request SLO classes and the measured serving cost model.

The paper's deployment story is that ONE checkpoint serves every
precision; what makes that *elastic* rather than merely multi-format is
the runtime choosing the rung against actual objectives.  This module
supplies the two halves the policy needs:

``SLOClass``
    A per-request service objective: a TTFT budget, a TPOT (per-output-
    token) budget, and a tier.  Tiers order admission when the engine
    runs with ``admission_order="slo"`` — ``latency`` ahead of
    ``throughput`` ahead of ``best_effort`` — and the tightest TPOT
    budget in a batch wave is what the policy holds the predicted tick
    time against.

``CostModel``
    Per-format decode-tick cost, *seeded* from the analytic roofline
    terms in ``launch/costmodel.py`` (weight bytes streamed per tick,
    attention bytes read per live row) and *calibrated* online from the
    engine's observed tick wall times and byte counters.  Prediction is
    a two-term roofline::

        predict_s(fmt, rows) = (weight_bytes + rows * attn_bytes_per_row)
                               / hbm_bytes_per_s * factor

    ``factor`` is a per-format EWMA of observed/raw-predicted tick time.
    The analytic seed supplies the *shape* (which rung is cheaper, how
    cost grows with occupancy); the factor learns what the backend
    actually delivers — on CPU the ordering is dispatch-dominated and
    the factors converge far from 1, on TPU they sit near the roofline.
    Either way the model is honest: ``measured(fmt)`` is False until
    ``min_ticks`` clean observations exist, and ``FormatPolicy.pick``
    degrades to its threshold table until at least one rung is measured.

Everything here is host-side bookkeeping — no jax, no effect on emitted
tokens.  Streams stay bit-identical for a fixed (request, format-trace):
the cost model only influences WHICH format a wave pins, never what a
pinned format computes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

TIERS = ("latency", "throughput", "best_effort")


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """A per-request service-level objective.

    ``ttft_ms`` bounds time-to-first-token (admission wait + prefill),
    ``tpot_ms`` bounds time-per-output-token (decode tick cadence);
    ``None`` means "no budget on this axis".  ``tier`` ranks the request
    for tiered admission and for the bench's per-tier attainment
    columns.  Budgets are *objectives the scheduler optimises for*, not
    deadlines — a missed budget shows up as attainment < 1.0, it never
    kills the request (``Request.deadline_s`` remains the kill switch).
    """

    ttft_ms: Optional[float] = None
    tpot_ms: Optional[float] = None
    tier: str = "best_effort"

    def __post_init__(self) -> None:
        if self.tier not in TIERS:
            raise ValueError(
                f"tier must be one of {TIERS}, got {self.tier!r}")
        for name in ("ttft_ms", "tpot_ms"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be positive, got {v}")

    @property
    def rank(self) -> int:
        """Admission priority: lower is served first."""
        return TIERS.index(self.tier)

    @classmethod
    def latency(cls, ttft_ms: float = 200.0,
                tpot_ms: float = 50.0) -> "SLOClass":
        return cls(ttft_ms=ttft_ms, tpot_ms=tpot_ms, tier="latency")

    @classmethod
    def throughput(cls, ttft_ms: Optional[float] = None,
                   tpot_ms: Optional[float] = None) -> "SLOClass":
        return cls(ttft_ms=ttft_ms, tpot_ms=tpot_ms, tier="throughput")

    @classmethod
    def best_effort(cls) -> "SLOClass":
        return cls()

    def to_dict(self) -> dict:
        return {"ttft_ms": self.ttft_ms, "tpot_ms": self.tpot_ms,
                "tier": self.tier}

    @classmethod
    def from_dict(cls, d: dict) -> "SLOClass":
        return cls(ttft_ms=d.get("ttft_ms"), tpot_ms=d.get("tpot_ms"),
                   tier=d.get("tier", "best_effort"))


def tier_rank(slo: Optional[SLOClass]) -> int:
    """Admission rank of a request's SLO; no SLO ranks as best-effort."""
    return slo.rank if slo is not None else TIERS.index("best_effort")


@dataclasses.dataclass
class _FmtTerm:
    """One format's roofline terms, in seconds (bytes / hbm_bytes_per_s
    at seed time; refreshed when the engine measures the real bytes)."""

    base_s: float              # weight stream, once per tick
    per_row_s: float           # attention read, per live decode row
    factor: float = 1.0        # EWMA of observed / raw-predicted
    ticks_observed: int = 0
    last_wall_s: float = 0.0   # diagnostics only


class CostModel:
    """Measured per-format decode-tick cost (see module docstring).

    Thread-unsafe by design — it lives inside one engine's scheduler
    loop.  All quantities are plain Python floats; nothing here touches
    a device.
    """

    def __init__(self, hbm_bytes_per_s: Optional[float] = None,
                 ema: float = 0.25, min_ticks: int = 2) -> None:
        if hbm_bytes_per_s is None:
            from repro.launch.mesh import HBM_BW
            hbm_bytes_per_s = HBM_BW
        if not (0.0 < ema <= 1.0):
            raise ValueError(f"ema must be in (0, 1], got {ema}")
        self.hbm_bytes_per_s = float(hbm_bytes_per_s)
        self.ema = float(ema)
        self.min_ticks = int(min_ticks)
        self.terms: Dict[str, _FmtTerm] = {}

    # -- seeding ---------------------------------------------------------
    def seed(self, fmt: str, weight_bytes: float,
             attn_bytes_per_row: float) -> None:
        """Install (or re-shape) a format's analytic terms.  Preserves an
        existing calibration factor — the engine calls this again with
        *measured* byte counts once a format's packed tree is cached."""
        term = self.terms.get(fmt)
        base = weight_bytes / self.hbm_bytes_per_s
        per_row = attn_bytes_per_row / self.hbm_bytes_per_s
        if term is None:
            self.terms[fmt] = _FmtTerm(base_s=base, per_row_s=per_row)
        else:
            term.base_s, term.per_row_s = base, per_row

    @classmethod
    def from_roofline(cls, cfg, formats, *, max_len: int,
                      kv_layout: str = "dense", kv_page_size: int = 16,
                      block_size: int = 32, n_model: int = 1,
                      hbm_bytes_per_s: Optional[float] = None,
                      ema: float = 0.25, min_ticks: int = 2) -> "CostModel":
        """Seed from ``launch.costmodel.serve_roofline_terms`` for every
        format name in ``formats`` (include ``"bf16"`` for the dense
        pseudo-format).

        ``n_model``: tensor-parallel shards — scales both byte terms to
        the PER-CHIP stream (``HBM_BW`` is a per-chip bandwidth, so a
        meshed engine seeded with global bytes would predict tick times
        ``n_model``x too slow and mis-rank the SLO tiers).
        """
        from repro.launch.costmodel import serve_roofline_terms
        cm = cls(hbm_bytes_per_s=hbm_bytes_per_s, ema=ema,
                 min_ticks=min_ticks)
        for fmt, t in serve_roofline_terms(
                cfg, formats, max_len=max_len, kv_layout=kv_layout,
                kv_page_size=kv_page_size, block_size=block_size,
                n_model=n_model).items():
            cm.seed(fmt, t["weight_bytes"], t["attn_bytes_per_row"])
        return cm

    # -- queries ---------------------------------------------------------
    def has_estimate(self, fmt: str) -> bool:
        return fmt in self.terms

    def measured(self, fmt: str) -> bool:
        """True once ``fmt`` has enough clean tick observations for its
        calibration factor to be trusted."""
        t = self.terms.get(fmt)
        return t is not None and t.ticks_observed >= self.min_ticks

    def any_measured(self) -> bool:
        return any(self.measured(f) for f in self.terms)

    def raw_predict_s(self, fmt: str, rows: int) -> Optional[float]:
        """Uncalibrated roofline time for a decode tick with ``rows``
        live rows, or None for an unseeded format."""
        t = self.terms.get(fmt)
        if t is None:
            return None
        return t.base_s + max(0, int(rows)) * t.per_row_s

    def _prior_factor(self) -> float:
        """Calibration prior for not-yet-measured formats: the median
        factor of the measured ones (1.0 with no measurements). Without
        this, a measured rung's calibrated prediction would compete
        against an unmeasured rung's raw roofline — on backends far from
        the roofline (CPU: dispatch-dominated) that mismatch spans orders
        of magnitude and the comparison means nothing."""
        fs = sorted(t.factor for t in self.terms.values()
                    if t.ticks_observed >= self.min_ticks)
        if not fs:
            return 1.0
        return fs[len(fs) // 2]

    def predict_ms(self, fmt: str, rows: int) -> Optional[float]:
        """Calibrated predicted decode-tick time in milliseconds; an
        unmeasured format borrows ``_prior_factor()``."""
        raw = self.raw_predict_s(fmt, rows)
        if raw is None:
            return None
        t = self.terms[fmt]
        factor = t.factor if t.ticks_observed else self._prior_factor()
        return raw * factor * 1e3

    # -- online update ---------------------------------------------------
    def observe(self, fmt: str, rows: int, wall_s: float,
                attn_bytes_per_row: Optional[float] = None) -> None:
        """Fold one clean decode tick into ``fmt``'s calibration.

        ``wall_s`` is the tick's wall time, ``rows`` its live decode
        rows.  Pass ``attn_bytes_per_row`` when the engine's byte
        counters measured the real attention read — it refreshes the raw
        per-row term so the factor stays a pure backend-efficiency
        ratio.  An unseeded format bootstraps a flat (rows-independent)
        term from the observation itself; seeding first is what buys the
        occupancy slope.
        """
        if wall_s <= 0:
            return
        t = self.terms.get(fmt)
        if t is None:
            t = _FmtTerm(base_s=wall_s, per_row_s=0.0)
            self.terms[fmt] = t
        if attn_bytes_per_row is not None:
            t.per_row_s = attn_bytes_per_row / self.hbm_bytes_per_s
        raw = t.base_s + max(0, int(rows)) * t.per_row_s
        if raw > 0:
            ratio = wall_s / raw
            if t.ticks_observed == 0:
                t.factor = ratio
            else:
                t.factor = (1.0 - self.ema) * t.factor + self.ema * ratio
        t.ticks_observed += 1
        t.last_wall_s = wall_s

    # -- introspection ---------------------------------------------------
    def snapshot(self) -> Dict[str, Dict[str, float]]:
        """Plain-dict dump for ``stats()`` / the bench tables."""
        return {
            fmt: {
                "base_s": t.base_s,
                "per_row_s": t.per_row_s,
                "factor": t.factor,
                "ticks_observed": t.ticks_observed,
                "predict_1row_ms": self.predict_ms(fmt, 1),
            }
            for fmt, t in self.terms.items()
        }
