"""Packed-weight continuous-batching engine for elastic-precision serving.

Implements the paper's §3.5 inference scheme end-to-end: one anchor
checkpoint (MXINT8/MXFP8) is resident; per-format weight caches hold
**packed** pytrees built by ``make_packed_params`` — MXTensor leaves (int8
codes + E8M0 scales) for >=5-bit formats, split-N nibble-packed
``PackedInt4Leaf`` for MXINT4. The decode tick serves straight from the
packed bytes under one of two contracts:

  fused (default on TPU)  — ``make_packed_serve_step(fused=True)``: every
      projection feeds its packed leaf to the Pallas dequant-GEMM via
      ``kernels.dispatch.qmatmul``; weight HBM traffic is exactly the codes
      + scales, streamed tile-by-tile into VMEM (interpret-mode off TPU —
      the test path).
  densify-inside-jit      — the XLA fallback: leaves dequantize inside the
      jitted step and XLA fuses the dequant into the consuming matmuls.

Both contracts read the same codes, so decode — HBM-bound on weight reads —
streams 2x/4x fewer bytes at mxint8/mxint4 than dense bf16, and greedy
token streams are identical across them. Deriving a new format costs one
packed-domain Slice-and-Scale pass and is cached; switching between cached
formats is free.

Slot lifecycle (continuous batching):

  admit   — each request is prefilled individually via
            ``ModelApi.prefill_slot`` into a free slot; active slots are
            never re-prefilled. Prompts are right-padded to power-of-two
            length buckets (exact masking via ``batch["lengths"]``), so the
            prefill executable compiles once per bucket, not once per
            prompt length.
  decode  — one fused serve_step advances every slot per tick; free/finished
            slots are masked (their cache_len stops advancing and their
            sampled tokens are dropped).
  retire  — a slot frees the moment its request reaches ``max_new`` or cache
            capacity, and is re-admissible on the very next tick.

Sampling: greedy argmax, or temperature/top-p with **per-slot RNG streams**
— each admission seeds its slot from ``fold_in(engine_key, rid)`` and every
draw advances only that slot's key, so concurrent identical prompts decode
independently and any request's stream is reproducible from (seed, rid)
alone.

Format selection is **batch-pinned**: the policy picks once, when the engine
transitions from drained to busy, and every request admitted while any slot
is live inherits that format. Numerics therefore never switch mid-sequence
and ``Request.fmt_used`` is exact for every generated token, not just the
admission-time value.

Token draining is host-side: one device->host transfer of the whole
next-token vector per tick (``np.asarray``), with per-slot lengths mirrored
in host counters — no per-slot ``int(...)`` device syncs in the tick loop.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.anchor import AnchorModel, convert, materialize
from repro.core.formats import get_format
from repro.core.mx import MXTensor
from repro.models.transformer import ModelApi
from repro.serve.packed_params import (PackedInt4Leaf, anchor_block_size,
                                       make_packed_params,
                                       make_packed_prefill_slot,
                                       make_packed_serve_step,
                                       weight_stream_bytes)
from repro.serve.policy import FormatPolicy

DENSE_BF16 = "bf16"   # pseudo-format: dense anchor-precision weights

MIN_PREFILL_BUCKET = 8


def _bucket_len(plen: int, cap: int) -> int:
    """Smallest power-of-two bucket >= plen (floor MIN_PREFILL_BUCKET),
    clamped to the cache capacity ``cap``."""
    b = MIN_PREFILL_BUCKET
    while b < plen:
        b *= 2
    return min(b, cap)


def _sample_one(key, logits, temperature, top_p):
    """One temperature/top-p draw; returns (advanced_key, token)."""
    k_next, k_draw = jax.random.split(key)
    lg = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
    probs = jax.nn.softmax(lg)
    order = jnp.argsort(-probs)
    sp = jnp.take(probs, order)
    # nucleus: smallest prefix of descending probs reaching top_p mass
    # (top-1 always kept: its prefix-exclusive cumsum is 0 < top_p)
    keep_sorted = (jnp.cumsum(sp) - sp) < top_p
    keep = jnp.zeros_like(keep_sorted).at[order].set(keep_sorted)
    return k_next, jax.random.categorical(k_draw, jnp.where(keep, lg,
                                                            -jnp.inf))


_sample_batch = jax.jit(jax.vmap(_sample_one, in_axes=(0, 0, None, None)))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    fmt_used: Optional[str] = None
    done: bool = False


class ElasticEngine:
    """Continuous-batching engine serving from packed MX weight caches.

    ``packed=False`` swaps every format's weights for their densified bf16
    equivalent (same codes, dequantized ahead of time) — the reference path
    for packed-vs-dense equivalence tests and roofline baselines. The
    pseudo-format ``"bf16"`` serves dense anchor-precision weights.

    ``fused`` selects the packed-serving contract: the Pallas dequant-GEMM
    dispatch (True) vs XLA densify-inside-jit (False); None = fused on TPU.
    Fixed per engine instance, so each contract gets its own jitted
    executables and no stale-cache hazards exist.

    ``kv_layout`` selects the KV-cache layout: ``"dense"`` preallocates a
    contiguous (slots, max_len) buffer per layer; ``"paged"`` serves from a
    shared page pool plus per-slot block tables, committing HBM one
    ``kv_page_size``-token page at a time as sequences grow. The engine owns
    the host-side free list: pages are allocated at admission (enough to
    hold the prompt plus the first decode write), one page at a time as
    decode crosses page boundaries, and returned the moment a slot retires —
    so the pool only needs to cover the *live* token count, not
    slots × max_len. Exhaustion raises ``RuntimeError`` loudly (never a
    silent truncation); size the pool with ``kv_num_pages`` (None = dense
    capacity: slots × ceil(max_len/page) + 1 scratch page). Token streams
    are bit-identical across layouts (same values at every valid position).
    """

    def __init__(self, api: ModelApi, anchor: AnchorModel, *,
                 batch_slots: int = 4, max_len: int = 256,
                 policy: Optional[FormatPolicy] = None,
                 param_template=None, packed: bool = True,
                 fused: Optional[bool] = None, seed: int = 0,
                 temperature: float = 1.0, top_p: float = 1.0,
                 bucket_prompts: bool = True,
                 kv_layout: str = "dense", kv_page_size: int = 16,
                 kv_num_pages: Optional[int] = None):
        self.api = api
        self.anchor = anchor
        self.slots = batch_slots
        self.max_len = max_len
        self.policy = policy or FormatPolicy(anchor.fmt_name)
        self.packed = packed
        if fused is None:             # auto: fused where Mosaic lowers and
            #                           the family has the qmm hook
            self.fused = jax.default_backend() == "tpu" \
                and api.with_qmm is not None
        else:
            if fused and api.with_qmm is None:
                raise ValueError(
                    f"fused=True but model family {api.cfg.family!r} has no "
                    "qmm hook; use fused=False (densify-inside-jit)")
            self.fused = fused
        self.temperature = temperature
        self.top_p = top_p
        self._template = param_template if param_template is not None else \
            jax.eval_shape(api.init_params, jax.random.PRNGKey(0))
        self._block_size = anchor_block_size(anchor)
        self._weights: Dict[str, object] = {}       # fmt -> serving pytree
        self._fmt_swaps = 0
        self._ticks = 0
        self._tokens_out = 0
        self.current_fmt: Optional[str] = None
        # Length bucketing needs exact masking of right-padded prompts; the
        # recurrent mixers (mamba/rwkv) fold pad tokens into their state, so
        # only pure-attention stacks bucket.
        pure_attn = api.cfg.family not in ("ssm", "encdec") \
            and api.cfg.attn_every <= 0
        self._bucket = bucket_prompts and pure_attn
        # Paged KV: only attention KV has a sequence axis to page over. The
        # pure-attention check itself lives in the model's init_cache (the
        # single source of truth for what a family can page); the eval_shape
        # below surfaces its ValueError at engine construction.
        if kv_layout not in ("dense", "paged"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}; "
                             "one of ('dense', 'paged')")
        self.kv_layout = kv_layout
        self.kv_page_size = kv_page_size
        self.kv_num_pages = kv_num_pages
        self._kv_pages_alloc = 0
        self._kv_pages_freed = 0
        self._kv_pages_hwm = 0
        cache_shape = jax.eval_shape(lambda: self._init_cache(self.slots))
        self._kv_cache_bytes = sum(
            l.size * l.dtype.itemsize
            for l in jax.tree_util.tree_leaves(cache_shape))
        self._kv_total_pages = \
            cache_shape["blocks"][0]["k_pages"].shape[1] \
            if kv_layout == "paged" else 0
        # Per-slot RNG: reseeded from (engine key, rid) at admission.
        self._key = jax.random.PRNGKey(seed)
        self._slot_keys = jax.random.split(self._key, self.slots)
        self._prefill_traces = 0     # host-side compile counter (bucketing)
        # Jitted entry points. Dense and packed trees have different pytree
        # structures, so jit caches one executable per cached format.
        self._dense_step = jax.jit(api.serve_step)
        self._dense_prefill_slot = jax.jit(self._counting(api.prefill_slot))
        self._packed_step = jax.jit(
            make_packed_serve_step(api, self._block_size, fused=self.fused))
        self._packed_prefill_slot = jax.jit(self._counting(
            make_packed_prefill_slot(api, self._block_size,
                                     fused=self.fused)))

    def _counting(self, fn):
        """Wrap a to-be-jitted fn so traces (= compiles) are counted."""
        def wrapped(*args):
            self._prefill_traces += 1    # runs at trace time only
            return fn(*args)
        return wrapped

    # ---- KV cache ---------------------------------------------------------
    def _init_cache(self, b):
        if self.kv_layout == "paged":
            return self.api.init_cache(b, self.max_len, kv_layout="paged",
                                       page_size=self.kv_page_size,
                                       num_pages=self.kv_num_pages)
        return self.api.init_cache(b, self.max_len)

    def _alloc_pages(self, free: List[int], n: int, why: str) -> List[int]:
        """Pop ``n`` physical pages off the free list, or die loudly.

        Exhaustion is an error, never a silent truncation: the caller asked
        for capacity the pool doesn't have, and the fix (bigger
        ``kv_num_pages``, fewer slots, shorter ``max_len``) is an operator
        decision, not something to paper over mid-decode.
        """
        if len(free) < n:
            raise RuntimeError(
                f"KV page pool exhausted at {why}: need {n} page(s), "
                f"{len(free)} free (pool = {self._kv_total_pages} pages x "
                f"{self.kv_page_size} tokens, {self.slots} slots, "
                f"{self._kv_pages_hwm} pages high-water). Increase "
                "kv_num_pages, shrink batch_slots/max_len, or admit less.")
        got = [free.pop() for _ in range(n)]
        self._kv_pages_alloc += n
        in_use = self._kv_total_pages - 1 - len(free)
        self._kv_pages_hwm = max(self._kv_pages_hwm, in_use)
        return got

    # ---- weights ----------------------------------------------------------
    def _serves_packed(self, fmt_name: str) -> bool:
        return self.packed and fmt_name != DENSE_BF16

    def weights_for(self, fmt_name: str):
        """Serving weights at ``fmt_name`` (packed containers by default).

        Cache miss = one Slice-and-Scale pass from the anchor (+ nibble
        packing at 4 bits); hits are free.
        """
        if fmt_name not in self._weights:
            if self._serves_packed(fmt_name):
                w = make_packed_params(self.anchor, self._template,
                                       target_fmt=fmt_name,
                                       dtype=self.api.cfg.compute_dtype)
            else:
                w = self.dense_weights_for(fmt_name)
            self._weights[fmt_name] = w
            self._fmt_swaps += 1
        return self._weights[fmt_name]

    def dense_weights_for(self, fmt_name: str):
        """Dense reference weights at ``fmt_name`` — numerically identical to
        the packed tree (same codes, dequantized eagerly). Not cached."""
        model = self.anchor
        if fmt_name not in (DENSE_BF16, self.anchor.fmt_name):
            model = convert(self.anchor,
                            get_format(fmt_name, self._block_size))
        return materialize(model, self._template,
                           dtype=self.api.cfg.compute_dtype)

    def set_format(self, fmt_name: str):
        self.current_fmt = fmt_name
        return self.weights_for(fmt_name)

    # ---- admission helpers ------------------------------------------------
    def _prefill_batch(self, prompt: np.ndarray):
        """Tokens (+ true length when bucketing) for one admission."""
        plen = prompt.size
        if not self._bucket:
            return {"tokens": jnp.asarray(prompt[None])}
        blen = _bucket_len(plen, self.max_len - 1)
        padded = np.zeros(blen, np.int32)
        padded[:plen] = prompt
        return {"tokens": jnp.asarray(padded[None]),
                "lengths": jnp.asarray([plen], jnp.int32)}

    # ---- serving loop -----------------------------------------------------
    def generate(self, requests: List[Request], greedy: bool = True,
                 fmt_override: Optional[str] = None) -> List[Request]:
        """Serve requests to completion with slot-level continuous batching."""
        pending = list(requests)
        active: List[Optional[Request]] = [None] * self.slots
        slot_len = [0] * self.slots        # host mirror of cache_len
        b = self.slots

        cache = self._init_cache(b)
        cache_len = jnp.zeros((b,), jnp.int32)
        tokens = jnp.zeros((b, 1), jnp.int32)
        pinned: Optional[str] = None       # format for this batch's lifetime
        paged = self.kv_layout == "paged"
        if paged:
            ps = self.kv_page_size
            # host-side page bookkeeping: the block table mirror ships to the
            # device as a (tiny) step argument whenever it changes; page 0 is
            # reserved scratch, so allocatable ids are 1..P-1.
            free_pages = list(range(self._kv_total_pages - 1, 0, -1))
            bt = np.zeros((b, cache["block_table"].shape[1]), np.int32)

        while pending or any(a is not None for a in active):
            if pinned is None:             # engine drained: re-pick format
                pinned = fmt_override or self.policy.pick(
                    queue_depth=len(pending), active=0)
            params = self.set_format(pinned)
            use_packed = self._serves_packed(pinned)
            prefill_slot = self._packed_prefill_slot if use_packed \
                else self._dense_prefill_slot
            step = self._packed_step if use_packed else self._dense_step

            # ---- admit: one request per free slot, active slots untouched
            for i in range(b):
                if active[i] is not None or not pending:
                    continue
                r = pending.pop(0)
                prompt = np.asarray(r.prompt, np.int32)
                assert prompt.size < self.max_len - 1, \
                    f"prompt ({prompt.size}) exceeds cache ({self.max_len})"
                self._slot_keys = self._slot_keys.at[i].set(
                    jax.random.fold_in(self._key, r.rid))
                pbatch = self._prefill_batch(prompt)
                if paged:
                    # Pages to hold the (possibly bucket-padded) prompt AND
                    # the first decode write at position prompt.size.
                    blen = pbatch["tokens"].shape[1]
                    need = max(-(-blen // ps), prompt.size // ps + 1)
                    bt[i, :need] = self._alloc_pages(
                        free_pages, need, f"admission of rid={r.rid}")
                    cache["block_table"] = jnp.asarray(bt)
                logits, cache, new_len = prefill_slot(params, pbatch,
                                                      cache, i)
                cache_len = cache_len.at[i].set(new_len)
                slot_len[i] = prompt.size
                first = int(self._sample(logits[None], greedy, slot=i)[0])
                tokens = tokens.at[i, 0].set(first)
                r.fmt_used = pinned        # pinned for the whole sequence
                r.out_tokens.append(first)
                self._tokens_out += 1
                if len(r.out_tokens) >= r.max_new:
                    r.done = True          # degenerate max_new<=1
                    if paged:              # row -> scratch BEFORE any reuse
                        self._free_slot_pages(free_pages, bt, i)
                        cache["block_table"] = jnp.asarray(bt)
                else:
                    active[i] = r

            if all(a is None for a in active):
                pinned = None              # drained; next wave re-picks
                continue

            # ---- decode tick: fused step over all slots, free slots masked
            mask = np.asarray([a is not None for a in active], np.int32)
            if paged:
                # Map the page each active slot's write position lands in
                # BEFORE the step runs — this is where the pool grows (and
                # where exhaustion surfaces, loudly, mid-stream).
                dirty = False
                for i, r in enumerate(active):
                    if r is None:
                        continue
                    pg = slot_len[i] // ps
                    if bt[i, pg] == 0:
                        bt[i, pg] = self._alloc_pages(
                            free_pages, 1, f"decode tick for rid={r.rid}")[0]
                        dirty = True
                if dirty:
                    cache["block_table"] = jnp.asarray(bt)
            logits, cache = step(params, {"tokens": tokens}, cache, cache_len)
            cache_len = cache_len + jnp.asarray(mask)
            nxt = self._sample(logits, greedy)
            tokens = nxt[:, None].astype(jnp.int32)
            self._ticks += 1

            # ---- retire: ONE host transfer per tick drains every slot
            drained = np.asarray(nxt)
            for i, r in enumerate(active):
                if r is None:
                    continue
                slot_len[i] += 1
                r.out_tokens.append(int(drained[i]))
                self._tokens_out += 1
                if len(r.out_tokens) >= r.max_new or \
                        slot_len[i] >= self.max_len - 1:
                    r.done = True
                    active[i] = None       # slot re-admissible next tick
                    if paged:              # pages recycle on the next admit
                        self._free_slot_pages(free_pages, bt, i)
                        cache["block_table"] = jnp.asarray(bt)
            if all(a is None for a in active):
                pinned = None
        return requests

    def _free_slot_pages(self, free_pages: List[int], bt: np.ndarray,
                         slot: int) -> None:
        """Return a retired slot's pages to the free list and point its
        block-table row at the scratch page (0) so any further masked write
        from the still-batched slot lands there, never on a recycled page."""
        used = bt[slot][bt[slot] != 0]
        free_pages.extend(int(p) for p in used)
        self._kv_pages_freed += used.size
        bt[slot, :] = 0

    def _sample(self, logits, greedy: bool, slot: Optional[int] = None):
        """Greedy argmax, or a temperature/top-p draw from per-slot streams.

        ``slot=None`` advances every slot's key by one draw (the decode
        tick); a slot index draws for that slot only (admission). Free
        slots' draws are discarded by the caller; advancing their keys is
        harmless and keeps the tick one fused vmap.
        """
        if greedy or self.temperature <= 0:
            return jnp.argmax(logits, -1)
        if slot is None:
            self._slot_keys, toks = _sample_batch(
                self._slot_keys, logits, self.temperature, self.top_p)
            return toks
        new_key, toks = _sample_batch(
            self._slot_keys[slot][None], logits, self.temperature,
            self.top_p)
        self._slot_keys = self._slot_keys.at[slot].set(new_key[0])
        return toks

    # ---- introspection ----------------------------------------------------
    @property
    def stats(self):
        def containers(tree):
            kinds = {type(l).__name__
                     for l in jax.tree_util.tree_leaves(
                         tree, is_leaf=lambda x: isinstance(
                             x, (MXTensor, PackedInt4Leaf)))
                     if isinstance(l, (MXTensor, PackedInt4Leaf))}
            return sorted(kinds) or ["dense"]

        return {
            "formats_cached": sorted(self._weights),
            "containers": {f: containers(t)
                           for f, t in self._weights.items()},
            "weight_bytes": {f: weight_stream_bytes(t)
                             for f, t in self._weights.items()},
            "fmt_swaps": self._fmt_swaps,
            "ticks": self._ticks,
            "tokens_out": self._tokens_out,
            "current": self.current_fmt,
            "fused": self.fused,
            "prefill_traces": self._prefill_traces,
            "kv_layout": self.kv_layout,
            "kv_cache_bytes": self._kv_cache_bytes,
            "kv_bytes_per_slot": self._kv_cache_bytes // self.slots,
            "kv_page_size": self.kv_page_size,
            "kv_total_pages": self._kv_total_pages,
            "kv_pages_alloc": self._kv_pages_alloc,
            "kv_pages_freed": self._kv_pages_freed,
            "kv_pages_hwm": self._kv_pages_hwm,
        }
