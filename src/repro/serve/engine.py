"""Elastic-precision serving engine (paper §3.5 inference scheme).

One anchor checkpoint (MXINT8/MXFP8) is held in memory; request batches are
served at whatever precision the runtime policy picks. Format switches cost
one Slice-and-Scale pass (packed-domain, no FP32 re-expansion) and are cached
per format — switching between cached formats is free.

The engine runs a continuous-batching decode loop: slots hold (tokens,
cache_len); prefill admits new requests into free slots; one fused
serve_step advances every active slot per tick.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.anchor import AnchorModel, convert, materialize
from repro.core.formats import get_format
from repro.models.transformer import ModelApi
from repro.serve.policy import FormatPolicy


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    fmt_used: Optional[str] = None
    done: bool = False


class ElasticEngine:
    def __init__(self, api: ModelApi, anchor: AnchorModel, *,
                 batch_slots: int = 4, max_len: int = 256,
                 policy: Optional[FormatPolicy] = None,
                 param_template=None):
        self.api = api
        self.anchor = anchor
        self.slots = batch_slots
        self.max_len = max_len
        self.policy = policy or FormatPolicy(anchor.fmt_name)
        self._template = param_template if param_template is not None else \
            jax.eval_shape(api.init_params, jax.random.PRNGKey(0))
        self._weights: Dict[str, object] = {}       # fmt -> dense params
        self._fmt_swaps = 0
        self.current_fmt: Optional[str] = None
        self._prefill = jax.jit(api.prefill)
        self._step = jax.jit(api.serve_step)

    # ---- weights ----------------------------------------------------------
    def weights_for(self, fmt_name: str):
        """Dense bf16 params at `fmt_name`, derived from the anchor via SS."""
        if fmt_name not in self._weights:
            fmt = get_format(fmt_name, get_format(self.anchor.fmt_name)
                             .block_size)
            low = convert(self.anchor, fmt)          # slice-and-scale
            self._weights[fmt_name] = materialize(
                low, self._template, dtype=self.api.cfg.compute_dtype)
            self._fmt_swaps += 1
        return self._weights[fmt_name]

    def set_format(self, fmt_name: str):
        self.current_fmt = fmt_name
        return self.weights_for(fmt_name)

    # ---- serving loop -----------------------------------------------------
    def generate(self, requests: List[Request], greedy: bool = True,
                 fmt_override: Optional[str] = None) -> List[Request]:
        """Serve a list of requests to completion (continuous batching)."""
        pending = list(requests)
        active: List[Optional[Request]] = [None] * self.slots
        b = self.slots

        cache = self.api.init_cache(b, self.max_len)
        cache_len = jnp.zeros((b,), jnp.int32)
        tokens = jnp.zeros((b, 1), jnp.int32)

        while pending or any(a is not None for a in active):
            fmt = fmt_override or self.policy.pick(
                queue_depth=len(pending),
                active=sum(a is not None for a in active))
            params = self.set_format(fmt)

            # admit: for simplicity slots refill together when all free
            if all(a is None for a in active) and pending:
                batch_reqs = pending[:b]
                pending = pending[b:]
                maxlen = max(len(r.prompt) for r in batch_reqs)
                toks = np.zeros((b, maxlen), np.int32)
                for i, r in enumerate(batch_reqs):
                    toks[i, -len(r.prompt):] = r.prompt   # left-pad
                    active[i] = r
                    r.fmt_used = fmt
                cache = self.api.init_cache(b, self.max_len)
                logits, cache, cache_len = self._prefill(
                    params, {"tokens": jnp.asarray(toks)}, cache)
                nxt = jnp.argmax(logits, -1) if greedy else \
                    jax.random.categorical(jax.random.PRNGKey(0), logits)
                tokens = nxt[:, None].astype(jnp.int32)
                for i, r in enumerate(batch_reqs):
                    r.out_tokens.append(int(nxt[i]))
                continue

            logits, cache = self._step(params, {"tokens": tokens}, cache,
                                       cache_len)
            cache_len = cache_len + 1
            nxt = jnp.argmax(logits, -1)
            tokens = nxt[:, None].astype(jnp.int32)
            for i, r in enumerate(active):
                if r is None:
                    continue
                r.out_tokens.append(int(nxt[i]))
                if len(r.out_tokens) >= r.max_new or \
                        int(cache_len[i]) >= self.max_len - 1:
                    r.done = True
                    active[i] = None
            if all(a is None for a in active):
                # batch drained; next loop admits new requests
                pass
        return requests

    @property
    def stats(self):
        return {"formats_cached": sorted(self._weights),
                "fmt_swaps": self._fmt_swaps,
                "current": self.current_fmt}
