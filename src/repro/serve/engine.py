"""Packed-weight continuous-batching engine for elastic-precision serving.

Implements the paper's §3.5 inference scheme end-to-end: one anchor
checkpoint (MXINT8/MXFP8) is resident; per-format weight caches hold
**packed** pytrees built by ``make_packed_params`` — MXTensor leaves (int8
codes + E8M0 scales) for >=5-bit formats, nibble-packed ``PackedInt4Leaf``
for MXINT4. The decode tick runs ``make_packed_serve_step``, which densifies
*inside* the jitted step: XLA's HBM weight traffic is the packed bytes and
the dequant fuses into the consuming matmuls, so decode — HBM-bound on
weight reads — streams 2x/4x fewer bytes at mxint8/mxint4 than dense bf16
(the Pallas ``mx_matmul`` kernels implement the same contract explicitly on
TPU). Deriving a new format costs one packed-domain Slice-and-Scale pass and
is cached; switching between cached formats is free.

Slot lifecycle (continuous batching):

  admit   — each request is prefilled individually via
            ``ModelApi.prefill_slot`` into a free slot; active slots are
            never re-prefilled.
  decode  — one fused serve_step advances every slot per tick; free/finished
            slots are masked (their cache_len stops advancing and their
            sampled tokens are dropped).
  retire  — a slot frees the moment its request reaches ``max_new`` or cache
            capacity, and is re-admissible on the very next tick.

Format selection is **batch-pinned**: the policy picks once, when the engine
transitions from drained to busy, and every request admitted while any slot
is live inherits that format. Numerics therefore never switch mid-sequence
and ``Request.fmt_used`` is exact for every generated token, not just the
admission-time value.

Token draining is host-side: one device->host transfer of the whole
next-token vector per tick (``np.asarray``), with per-slot lengths mirrored
in host counters — no per-slot ``int(...)`` device syncs in the tick loop.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.anchor import AnchorModel, convert, materialize
from repro.core.formats import get_format
from repro.core.mx import MXTensor
from repro.models.transformer import ModelApi
from repro.serve.packed_params import (PackedInt4Leaf, anchor_block_size,
                                       make_packed_params,
                                       make_packed_prefill_slot,
                                       make_packed_serve_step,
                                       weight_stream_bytes)
from repro.serve.policy import FormatPolicy

DENSE_BF16 = "bf16"   # pseudo-format: dense anchor-precision weights


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    fmt_used: Optional[str] = None
    done: bool = False


class ElasticEngine:
    """Continuous-batching engine serving from packed MX weight caches.

    ``packed=False`` swaps every format's weights for their densified bf16
    equivalent (same codes, dequantized ahead of time) — the reference path
    for packed-vs-dense equivalence tests and roofline baselines. The
    pseudo-format ``"bf16"`` serves dense anchor-precision weights.
    """

    def __init__(self, api: ModelApi, anchor: AnchorModel, *,
                 batch_slots: int = 4, max_len: int = 256,
                 policy: Optional[FormatPolicy] = None,
                 param_template=None, packed: bool = True):
        self.api = api
        self.anchor = anchor
        self.slots = batch_slots
        self.max_len = max_len
        self.policy = policy or FormatPolicy(anchor.fmt_name)
        self.packed = packed
        self._template = param_template if param_template is not None else \
            jax.eval_shape(api.init_params, jax.random.PRNGKey(0))
        self._block_size = anchor_block_size(anchor)
        self._weights: Dict[str, object] = {}       # fmt -> serving pytree
        self._fmt_swaps = 0
        self._ticks = 0
        self._tokens_out = 0
        self.current_fmt: Optional[str] = None
        # Jitted entry points. Dense and packed trees have different pytree
        # structures, so jit caches one executable per cached format.
        self._dense_step = jax.jit(api.serve_step)
        self._dense_prefill_slot = jax.jit(api.prefill_slot)
        self._packed_step = jax.jit(
            make_packed_serve_step(api, self._block_size))
        self._packed_prefill_slot = jax.jit(
            make_packed_prefill_slot(api, self._block_size))

    # ---- weights ----------------------------------------------------------
    def _serves_packed(self, fmt_name: str) -> bool:
        return self.packed and fmt_name != DENSE_BF16

    def weights_for(self, fmt_name: str):
        """Serving weights at ``fmt_name`` (packed containers by default).

        Cache miss = one Slice-and-Scale pass from the anchor (+ nibble
        packing at 4 bits); hits are free.
        """
        if fmt_name not in self._weights:
            if self._serves_packed(fmt_name):
                w = make_packed_params(self.anchor, self._template,
                                       target_fmt=fmt_name,
                                       dtype=self.api.cfg.compute_dtype)
            else:
                w = self.dense_weights_for(fmt_name)
            self._weights[fmt_name] = w
            self._fmt_swaps += 1
        return self._weights[fmt_name]

    def dense_weights_for(self, fmt_name: str):
        """Dense reference weights at ``fmt_name`` — numerically identical to
        the packed tree (same codes, dequantized eagerly). Not cached."""
        model = self.anchor
        if fmt_name not in (DENSE_BF16, self.anchor.fmt_name):
            model = convert(self.anchor,
                            get_format(fmt_name, self._block_size))
        return materialize(model, self._template,
                           dtype=self.api.cfg.compute_dtype)

    def set_format(self, fmt_name: str):
        self.current_fmt = fmt_name
        return self.weights_for(fmt_name)

    # ---- serving loop -----------------------------------------------------
    def generate(self, requests: List[Request], greedy: bool = True,
                 fmt_override: Optional[str] = None) -> List[Request]:
        """Serve requests to completion with slot-level continuous batching."""
        pending = list(requests)
        active: List[Optional[Request]] = [None] * self.slots
        slot_len = [0] * self.slots        # host mirror of cache_len
        b = self.slots

        cache = self.api.init_cache(b, self.max_len)
        cache_len = jnp.zeros((b,), jnp.int32)
        tokens = jnp.zeros((b, 1), jnp.int32)
        pinned: Optional[str] = None       # format for this batch's lifetime

        while pending or any(a is not None for a in active):
            if pinned is None:             # engine drained: re-pick format
                pinned = fmt_override or self.policy.pick(
                    queue_depth=len(pending), active=0)
            params = self.set_format(pinned)
            use_packed = self._serves_packed(pinned)
            prefill_slot = self._packed_prefill_slot if use_packed \
                else self._dense_prefill_slot
            step = self._packed_step if use_packed else self._dense_step

            # ---- admit: one request per free slot, active slots untouched
            for i in range(b):
                if active[i] is not None or not pending:
                    continue
                r = pending.pop(0)
                prompt = np.asarray(r.prompt, np.int32)
                assert prompt.size < self.max_len - 1, \
                    f"prompt ({prompt.size}) exceeds cache ({self.max_len})"
                logits, cache, new_len = prefill_slot(
                    params, {"tokens": jnp.asarray(prompt[None])}, cache, i)
                cache_len = cache_len.at[i].set(new_len)
                slot_len[i] = prompt.size
                first = int(self._sample(logits[None], greedy)[0])
                tokens = tokens.at[i, 0].set(first)
                r.fmt_used = pinned        # pinned for the whole sequence
                r.out_tokens.append(first)
                self._tokens_out += 1
                if len(r.out_tokens) >= r.max_new:
                    r.done = True          # degenerate max_new<=1
                else:
                    active[i] = r

            if all(a is None for a in active):
                pinned = None              # drained; next wave re-picks
                continue

            # ---- decode tick: fused step over all slots, free slots masked
            mask = np.asarray([a is not None for a in active], np.int32)
            logits, cache = step(params, {"tokens": tokens}, cache, cache_len)
            cache_len = cache_len + jnp.asarray(mask)
            nxt = self._sample(logits, greedy)
            tokens = nxt[:, None].astype(jnp.int32)
            self._ticks += 1

            # ---- retire: ONE host transfer per tick drains every slot
            drained = np.asarray(nxt)
            for i, r in enumerate(active):
                if r is None:
                    continue
                slot_len[i] += 1
                r.out_tokens.append(int(drained[i]))
                self._tokens_out += 1
                if len(r.out_tokens) >= r.max_new or \
                        slot_len[i] >= self.max_len - 1:
                    r.done = True
                    active[i] = None       # slot re-admissible next tick
            if all(a is None for a in active):
                pinned = None
        return requests

    def _sample(self, logits, greedy: bool):
        if greedy:
            return jnp.argmax(logits, -1)
        return jax.random.categorical(jax.random.PRNGKey(self._ticks), logits)

    # ---- introspection ----------------------------------------------------
    @property
    def stats(self):
        def containers(tree):
            kinds = {type(l).__name__
                     for l in jax.tree_util.tree_leaves(
                         tree, is_leaf=lambda x: isinstance(
                             x, (MXTensor, PackedInt4Leaf)))
                     if isinstance(l, (MXTensor, PackedInt4Leaf))}
            return sorted(kinds) or ["dense"]

        return {
            "formats_cached": sorted(self._weights),
            "containers": {f: containers(t)
                           for f, t in self._weights.items()},
            "weight_bytes": {f: weight_stream_bytes(t)
                             for f, t in self._weights.items()},
            "fmt_swaps": self._fmt_swaps,
            "ticks": self._ticks,
            "tokens_out": self._tokens_out,
            "current": self.current_fmt,
        }
