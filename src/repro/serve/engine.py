"""Packed-weight continuous-batching engine for elastic-precision serving.

Implements the paper's §3.5 inference scheme end-to-end: one anchor
checkpoint (MXINT8/MXFP8) is resident; per-format weight caches hold
**packed** pytrees built by ``make_packed_params`` — MXTensor leaves (int8
codes + E8M0 scales) for >=5-bit formats, split-N nibble-packed
``PackedInt4Leaf`` for MXINT4. The decode tick serves straight from the
packed bytes under one of two contracts:

  fused (default on TPU)  — ``make_packed_serve_step(fused=True)``: every
      projection feeds its packed leaf to the Pallas dequant-GEMM via
      ``kernels.dispatch.qmatmul``; weight HBM traffic is exactly the codes
      + scales, streamed tile-by-tile into VMEM (interpret-mode off TPU —
      the test path).
  densify-inside-jit      — the XLA fallback: leaves dequantize inside the
      jitted step and XLA fuses the dequant into the consuming matmuls.

Both contracts read the same codes, so decode — HBM-bound on weight reads —
streams 2x/4x fewer bytes at mxint8/mxint4 than dense bf16, and greedy
token streams are identical across them. Deriving a new format costs one
packed-domain Slice-and-Scale pass and is cached; switching between cached
formats is free.

Slot lifecycle (continuous batching; state machine documented in
docs/serving_internals.md "Admission & scheduling"):

  admit   — each request is prefilled individually via
            ``ModelApi.prefill_slot`` into a free slot; active slots are
            never re-prefilled. Prompts are right-padded to power-of-two
            length buckets (exact masking via ``batch["lengths"]``), so the
            prefill executable compiles once per bucket, not once per
            prompt length. With ``prefill_chunk`` set, admission is instead
            *chunked*: the prompt streams in fixed-size chunks via
            ``ModelApi.prefill_chunk_slot`` (one chunk per tick, cursor in
            host state), bounding how long a long prompt can stall the
            running slots.
  decode  — one fused serve_step advances every slot per tick; free,
            finished, and mid-prefill slots are masked (their cache_len
            stops advancing and their sampled tokens are dropped).
  retire  — a slot frees the moment its request reaches ``max_new`` or cache
            capacity, and is re-admissible on the very next tick.

Sampling: greedy argmax, or temperature/top-p with **per-slot RNG streams**
— each admission seeds its slot from ``fold_in(engine_key, rid)`` and every
draw advances only that slot's key, so concurrent identical prompts decode
independently and any request's stream is reproducible from (seed, rid)
alone.

Format selection is **batch-pinned**: the policy picks once, when the engine
transitions from drained to busy, and every request admitted while any slot
is live inherits that format. Numerics therefore never switch mid-sequence
and ``Request.fmt_used`` is exact for every generated token, not just the
admission-time value.

Token draining is host-side: one device->host transfer of the whole
next-token vector per tick (``np.asarray``), with per-slot lengths mirrored
in host counters — no per-slot ``int(...)`` device syncs in the tick loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.anchor import AnchorModel, convert, materialize
from repro.core.formats import get_format
from repro.core.mx import MXTensor
from repro.kernels.paged_attention import pages_read, pages_read_mq
from repro.models.transformer import ModelApi
from repro.serve.packed_params import (PackedInt4Leaf, anchor_block_size,
                                       make_packed_mixed_step,
                                       make_packed_params,
                                       make_packed_prefill_chunk,
                                       make_packed_prefill_slot,
                                       make_packed_serve_step,
                                       weight_stream_bytes)
from repro.serve.policy import FormatPolicy

DENSE_BF16 = "bf16"   # pseudo-format: dense anchor-precision weights

MIN_PREFILL_BUCKET = 8


def _bucket_len(plen: int, cap: int) -> int:
    """Smallest power-of-two bucket >= plen (floor MIN_PREFILL_BUCKET),
    clamped to the cache capacity ``cap``."""
    b = MIN_PREFILL_BUCKET
    while b < plen:
        b *= 2
    return min(b, cap)


def _sample_one(key, logits, temperature, top_p):
    """One temperature/top-p draw; returns (advanced_key, token)."""
    k_next, k_draw = jax.random.split(key)
    lg = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
    probs = jax.nn.softmax(lg)
    order = jnp.argsort(-probs)
    sp = jnp.take(probs, order)
    # nucleus: smallest prefix of descending probs reaching top_p mass
    # (top-1 always kept: its prefix-exclusive cumsum is 0 < top_p)
    keep_sorted = (jnp.cumsum(sp) - sp) < top_p
    keep = jnp.zeros_like(keep_sorted).at[order].set(keep_sorted)
    return k_next, jax.random.categorical(k_draw, jnp.where(keep, lg,
                                                            -jnp.inf))


_sample_batch = jax.jit(jax.vmap(_sample_one, in_axes=(0, 0, None, None)))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    fmt_used: Optional[str] = None
    done: bool = False
    ttft_s: Optional[float] = None  # wall-clock from generate() entry to the
    #                                 first sampled token (set by the engine)


class ElasticEngine:
    """Continuous-batching engine serving from packed MX weight caches.

    ``packed=False`` swaps every format's weights for their densified bf16
    equivalent (same codes, dequantized ahead of time) — the reference path
    for packed-vs-dense equivalence tests and roofline baselines. The
    pseudo-format ``"bf16"`` serves dense anchor-precision weights.

    ``fused`` selects the packed-serving contract: the Pallas dequant-GEMM
    dispatch (True) vs XLA densify-inside-jit (False); None = fused on TPU.
    Fixed per engine instance, so each contract gets its own jitted
    executables and no stale-cache hazards exist.

    ``kv_layout`` selects the KV-cache layout: ``"dense"`` preallocates a
    contiguous (slots, max_len) buffer per layer; ``"paged"`` serves from a
    shared page pool plus per-slot block tables, committing HBM one
    ``kv_page_size``-token page at a time as sequences grow. The engine owns
    the host-side free list: pages are allocated at admission (enough to
    hold the prompt plus the first decode write), one page at a time as
    decode crosses page boundaries, and returned the moment a slot retires —
    so the pool only needs to cover the *live* token count, not
    slots × max_len. Exhaustion raises ``RuntimeError`` loudly (never a
    silent truncation); size the pool with ``kv_num_pages`` (None = dense
    capacity: slots × ceil(max_len/page) + 1 scratch page). Token streams
    are bit-identical across layouts (same values at every valid position).

    ``attn_impl`` selects the paged decode-attention read path:
    ``"paged_kernel"`` consumes the page pools + block table directly in the
    gather-free Pallas kernel (``kernels/paged_attention.py`` — Mosaic on
    TPU, interpret-mode in tests), so per-tick attention reads scale with
    live tokens (``ceil(cache_len/page)`` pages per slot); ``"gather"``
    keeps the original materialize-then-attend pair, whose reads scale with
    ``max_pages*page`` regardless of occupancy. None = kernel on TPU when
    paged, gather elsewhere. Both impls read the same KV values at every
    valid position and reduce in fp32, but the kernel's online softmax
    reorders the reduction, so logits can differ by ulps — token-stream
    equality across impls is an *empirically held* contract (asserted
    exactly by tests and the bench on this backend), not an algebraic one;
    ``stats()["attn_tokens_read"]`` accounts the read-traffic difference and
    ``benchmarks/serve_engine_bench.py`` turns it into attention-bytes/token.
    Requires ``kv_layout="paged"`` — the dense layout has no block table to
    consume.

    ``prefill_chunk`` selects the admission mode (the slot-lifecycle state
    machine is documented in docs/serving_internals.md, "Admission &
    scheduling"). ``None`` (default) admits monolithically: each prompt is
    prefilled in one call, stalling every running slot for the full prompt
    length. An int (or ``"auto"`` = one KV page when paged, else 64) splits
    admission into fixed-size chunks interleaved with decode ticks — the
    scheduler runs AT MOST one prefill chunk per tick before the batched
    decode step, so per-tick work (and therefore running slots' inter-token
    latency) is bounded by one chunk regardless of incoming prompt length.
    Token streams are bit-identical to monolithic admission (greedy and
    seeded sampling). Attention-only; when paged, the chunk must be a
    multiple of ``kv_page_size`` so chunk boundaries fall on pages and each
    chunk's pages are allocated at that chunk, not all upfront.

    ``scheduler`` selects how chunked ticks execute. ``"mixed"`` (the
    default whenever ``prefill_chunk`` is set) coalesces the prefill chunk
    INTO the decode batch: one ``mixed_step`` executable per tick, where
    each row carries a per-slot token budget — decoding slots contribute 1
    query token, the (single) mid-prefill slot contributes its chunk at its
    cursor — so decode never skips a tick during a long admission and
    ``tick_trace`` shows exactly one executable per tick. ``"sequential"``
    keeps the PR 4 shape (chunk executable, then decode executable) as the
    provably equivalent fallback. Sampling-wise the epilogue is fused but
    ordered identically: the batched draw advances every slot key exactly
    once per decode-carrying tick, and a completing admission reseeds its
    slot from ``(engine key, rid)`` AFTER the batch draw — so token streams
    are bit-identical to sequential admission (greedy and seeded) across
    all layout/contract pairings; the tests in tests/test_mixed_batch.py
    hold that line. Requires ``prefill_chunk``.
    """

    def __init__(self, api: ModelApi, anchor: AnchorModel, *,
                 batch_slots: int = 4, max_len: int = 256,
                 policy: Optional[FormatPolicy] = None,
                 param_template=None, packed: bool = True,
                 fused: Optional[bool] = None, seed: int = 0,
                 temperature: float = 1.0, top_p: float = 1.0,
                 bucket_prompts: bool = True,
                 kv_layout: str = "dense", kv_page_size: int = 16,
                 kv_num_pages: Optional[int] = None,
                 attn_impl: Optional[str] = None,
                 prefill_chunk=None,
                 scheduler: Optional[str] = None):
        self.api = api
        self.anchor = anchor
        self.slots = batch_slots
        self.max_len = max_len
        self.policy = policy or FormatPolicy(anchor.fmt_name)
        self.packed = packed
        if fused is None:             # auto: fused where Mosaic lowers and
            #                           the family has the qmm hook
            self.fused = jax.default_backend() == "tpu" \
                and api.with_qmm is not None
        else:
            if fused and api.with_qmm is None:
                raise ValueError(
                    f"fused=True but model family {api.cfg.family!r} has no "
                    "qmm hook; use fused=False (densify-inside-jit)")
            self.fused = fused
        self.temperature = temperature
        self.top_p = top_p
        self._template = param_template if param_template is not None else \
            jax.eval_shape(api.init_params, jax.random.PRNGKey(0))
        self._block_size = anchor_block_size(anchor)
        self._weights: Dict[str, object] = {}       # fmt -> serving pytree
        self._fmt_swaps = 0
        self._ticks = 0
        self._tokens_out = 0
        self.current_fmt: Optional[str] = None
        # Length bucketing needs exact masking of right-padded prompts; the
        # recurrent mixers (mamba/rwkv) fold pad tokens into their state, so
        # only pure-attention stacks bucket.
        pure_attn = api.cfg.family not in ("ssm", "encdec") \
            and api.cfg.attn_every <= 0
        self._bucket = bucket_prompts and pure_attn
        self._pure_attn = pure_attn
        # Paged KV: only attention KV has a sequence axis to page over. The
        # pure-attention check itself lives in the model's init_cache (the
        # single source of truth for what a family can page); the eval_shape
        # below surfaces its ValueError at engine construction.
        if kv_layout not in ("dense", "paged"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}; "
                             "one of ('dense', 'paged')")
        self.kv_layout = kv_layout
        self.kv_page_size = kv_page_size
        self.kv_num_pages = kv_num_pages
        # Paged decode-attention read path (class docstring): auto = the
        # gather-free kernel where Mosaic lowers, the gather fallback
        # elsewhere (tests opt into the kernel explicitly -> interpret mode).
        if attn_impl is None:
            attn_impl = "paged_kernel" if (
                kv_layout == "paged"
                and jax.default_backend() == "tpu") else "gather"
        if attn_impl not in ("gather", "paged_kernel"):
            raise ValueError(f"unknown attn_impl {attn_impl!r}; one of "
                             "('gather', 'paged_kernel')")
        if attn_impl == "paged_kernel" and kv_layout != "paged":
            raise ValueError(
                "attn_impl='paged_kernel' requires kv_layout='paged' — the "
                "dense layout has no block table for the kernel to consume")
        self.attn_impl = attn_impl
        self._attn_tokens_read = 0   # KV tokens decode attention read (host
        #                              mirror; see stats()["attn_tokens_read"])
        cfg = api.cfg
        self._attn_layers = 0 if cfg.family == "ssm" else sum(
            cfg.is_attn_layer(j) for j in range(cfg.scan_group)) \
            * cfg.n_groups
        # Chunked prefill admission (None = monolithic; see class docstring
        # and docs/serving_internals.md "Admission & scheduling").
        if prefill_chunk == "auto":
            prefill_chunk = kv_page_size if kv_layout == "paged" else 64
        if prefill_chunk is not None:
            if not pure_attn or api.cfg.vision_tokens > 0:
                raise ValueError(
                    "prefill_chunk requires a pure-attention text stack; "
                    f"family {api.cfg.family!r} folds the prompt into "
                    "recurrent state (or prepends vision embeds) and cannot "
                    "resume prefill mid-prompt — use prefill_chunk=None")
            if prefill_chunk < MIN_PREFILL_BUCKET:
                raise ValueError(
                    f"prefill_chunk ({prefill_chunk}) must be >= the "
                    f"minimum prefill bucket ({MIN_PREFILL_BUCKET})")
            if kv_layout == "paged" and prefill_chunk % kv_page_size:
                raise ValueError(
                    f"prefill_chunk ({prefill_chunk}) must be a multiple of "
                    f"kv_page_size ({kv_page_size}) so chunk boundaries "
                    "fall on page boundaries")
        self.prefill_chunk = prefill_chunk
        # Unified-tick scheduler (class docstring): "mixed" is the default
        # wherever chunked admission makes a mixed tick possible.
        if scheduler in (None, "auto"):
            scheduler = "mixed" if prefill_chunk is not None else "sequential"
        if scheduler not in ("sequential", "mixed"):
            raise ValueError(f"unknown scheduler {scheduler!r}; one of "
                             "('sequential', 'mixed')")
        if scheduler == "mixed":
            if prefill_chunk is None:
                raise ValueError(
                    "scheduler='mixed' coalesces the prefill chunk into the "
                    "decode batch; set prefill_chunk (or 'auto')")
            if api.mixed_step is None:
                raise ValueError(
                    f"model family {api.cfg.family!r} has no mixed_step "
                    "entry point; use scheduler='sequential'")
        self.scheduler = scheduler
        self._admission_requeues = 0
        self.tick_trace: List[Dict[str, float]] = []   # reset per generate
        self._kv_pages_alloc = 0
        self._kv_pages_freed = 0
        self._kv_pages_hwm = 0
        cache_shape = jax.eval_shape(lambda: self._init_cache(self.slots))
        self._kv_cache_bytes = sum(
            l.size * l.dtype.itemsize
            for l in jax.tree_util.tree_leaves(cache_shape))
        self._kv_total_pages = \
            cache_shape["blocks"][0]["k_pages"].shape[1] \
            if kv_layout == "paged" else 0
        # KV tokens one decode read spans per live slot under the GATHER
        # path (the whole logical view); the kernel path reads only
        # ceil(cache_len/page)*page of it, accounted per tick in generate().
        if kv_layout == "paged":
            self._attn_read_span = \
                cache_shape["block_table"].shape[1] * kv_page_size
        else:
            self._attn_read_span = self.max_len + api.cfg.vision_tokens
        # Per-slot RNG: reseeded from (engine key, rid) at admission.
        self._key = jax.random.PRNGKey(seed)
        self._slot_keys = jax.random.split(self._key, self.slots)
        self._prefill_traces = 0     # host-side compile counter (bucketing)
        # Jitted entry points. Dense and packed trees have different pytree
        # structures, so jit caches one executable per cached format. The
        # decode steps bake attn_impl in at build time (same rationale as
        # `fused`: no stale-jit-cache hazards from flipping a global); the
        # prefill entry points are attn_impl-independent.
        if self.attn_impl == "gather":
            step_api = api
        else:
            if api.with_serving is None:
                raise ValueError(
                    f"model family {api.cfg.family!r} cannot rebuild its "
                    f"serving entry points with attn_impl={attn_impl!r}")
            step_api = api.with_serving(attn_impl=self.attn_impl)
        self._dense_step = jax.jit(step_api.serve_step)
        self._dense_prefill_slot = jax.jit(self._counting(api.prefill_slot))
        self._packed_step = jax.jit(
            make_packed_serve_step(api, self._block_size, fused=self.fused,
                                   attn_impl=self.attn_impl))
        self._packed_prefill_slot = jax.jit(self._counting(
            make_packed_prefill_slot(api, self._block_size,
                                     fused=self.fused)))
        # Chunked-admission entry points (jit is lazy: nothing compiles
        # unless prefill_chunk is actually used). Compiles once per chunk
        # bucket — the cursor is a traced argument.
        self._dense_prefill_chunk = jax.jit(
            self._counting(api.prefill_chunk_slot)) \
            if api.prefill_chunk_slot is not None else None
        self._packed_prefill_chunk = jax.jit(self._counting(
            make_packed_prefill_chunk(api, self._block_size,
                                      fused=self.fused))) \
            if api.prefill_chunk_slot is not None else None
        # Unified mixed-tick entry points (lazy jit, one compile per chunk
        # width bucket — counted like chunk compiles). They bake attn_impl
        # in like the decode steps: the ragged multi-query paged read runs
        # the gather-free MQ kernel under "paged_kernel".
        self._dense_mixed = jax.jit(self._counting(step_api.mixed_step)) \
            if step_api.mixed_step is not None else None
        self._packed_mixed = jax.jit(self._counting(
            make_packed_mixed_step(api, self._block_size, fused=self.fused,
                                   attn_impl=self.attn_impl))) \
            if api.mixed_step is not None else None

    def _counting(self, fn):
        """Wrap a to-be-jitted fn so traces (= compiles) are counted."""
        def wrapped(*args):
            self._prefill_traces += 1    # runs at trace time only
            return fn(*args)
        return wrapped

    # ---- KV cache ---------------------------------------------------------
    def _init_cache(self, b):
        if self.kv_layout == "paged":
            return self.api.init_cache(b, self.max_len, kv_layout="paged",
                                       page_size=self.kv_page_size,
                                       num_pages=self.kv_num_pages)
        return self.api.init_cache(b, self.max_len)

    def _alloc_pages(self, free: List[int], n: int, why: str) -> List[int]:
        """Pop ``n`` physical pages off the free list, or die loudly.

        Exhaustion is an error, never a silent truncation: the caller asked
        for capacity the pool doesn't have, and the fix (bigger
        ``kv_num_pages``, fewer slots, shorter ``max_len``) is an operator
        decision, not something to paper over mid-decode.
        """
        if len(free) < n:
            raise RuntimeError(
                f"KV page pool exhausted at {why}: need {n} page(s), "
                f"{len(free)} free (pool = {self._kv_total_pages} pages x "
                f"{self.kv_page_size} tokens, {self.slots} slots, "
                f"{self._kv_pages_hwm} pages high-water). Increase "
                "kv_num_pages, shrink batch_slots/max_len, or admit less.")
        got = [free.pop() for _ in range(n)]
        self._kv_pages_alloc += n
        in_use = self._kv_total_pages - 1 - len(free)
        self._kv_pages_hwm = max(self._kv_pages_hwm, in_use)
        return got

    # ---- weights ----------------------------------------------------------
    def _serves_packed(self, fmt_name: str) -> bool:
        return self.packed and fmt_name != DENSE_BF16

    def weights_for(self, fmt_name: str):
        """Serving weights at ``fmt_name`` (packed containers by default).

        Cache miss = one Slice-and-Scale pass from the anchor (+ nibble
        packing at 4 bits); hits are free.
        """
        if fmt_name not in self._weights:
            if self._serves_packed(fmt_name):
                w = make_packed_params(self.anchor, self._template,
                                       target_fmt=fmt_name,
                                       dtype=self.api.cfg.compute_dtype)
            else:
                w = self.dense_weights_for(fmt_name)
            self._weights[fmt_name] = w
            self._fmt_swaps += 1
        return self._weights[fmt_name]

    def dense_weights_for(self, fmt_name: str):
        """Dense reference weights at ``fmt_name`` — numerically identical to
        the packed tree (same codes, dequantized eagerly). Not cached."""
        model = self.anchor
        if fmt_name not in (DENSE_BF16, self.anchor.fmt_name):
            model = convert(self.anchor,
                            get_format(fmt_name, self._block_size))
        return materialize(model, self._template,
                           dtype=self.api.cfg.compute_dtype)

    def set_format(self, fmt_name: str):
        self.current_fmt = fmt_name
        return self.weights_for(fmt_name)

    # ---- admission helpers ------------------------------------------------
    @property
    def prompt_capacity(self) -> int:
        """Longest admissible prompt: ``max_len - 1`` tokens.

        THE single home of this invariant (admission asserts against it,
        prompt bucketing clamps to it, retire-at-capacity compares
        ``slot_len`` to it, and the paged block table — sized from
        ``max_len`` — therefore always covers any bucketed length):
        the cache holds ``max_len`` positions and the first generated
        token's KV is written at position ``plen`` before any retire check
        runs, so one position past the prompt must always exist.
        """
        return self.max_len - 1

    def _prefill_batch(self, prompt: np.ndarray):
        """Tokens (+ true length when bucketing) for one admission."""
        plen = prompt.size
        if not self._bucket:
            return {"tokens": jnp.asarray(prompt[None])}
        blen = _bucket_len(plen, self.prompt_capacity)
        padded = np.zeros(blen, np.int32)
        padded[:plen] = prompt
        return {"tokens": jnp.asarray(padded[None]),
                "lengths": jnp.asarray([plen], jnp.int32)}

    # ---- serving loop -----------------------------------------------------
    def generate(self, requests: List[Request], greedy: bool = True,
                 fmt_override: Optional[str] = None) -> List[Request]:
        """Serve requests to completion with slot-level continuous batching.

        Slot lifecycle (docs/serving_internals.md "Admission & scheduling"):
        free -> prefilling(cursor) -> decoding -> retired. With
        ``prefill_chunk`` set, at most ONE slot is mid-prefill at a time and
        each scheduler tick runs at most one prefill chunk before the
        batched decode step; ``tick_trace`` records the per-tick work so
        that bound is testable, and each ``Request.ttft_s`` is stamped when
        its first token is sampled.
        """
        pending = list(requests)
        active: List[Optional[Request]] = [None] * self.slots
        slot_len = [0] * self.slots        # host mirror of cache_len
        b = self.slots
        t0 = time.perf_counter()
        self.tick_trace = []

        cache = self._init_cache(b)
        cache_len = jnp.zeros((b,), jnp.int32)
        tokens = jnp.zeros((b, 1), jnp.int32)
        pinned: Optional[str] = None       # format for this batch's lifetime
        paged = self.kv_layout == "paged"
        chunk = self.prefill_chunk         # None => monolithic admission
        filling: Optional[Request] = None  # the (single) mid-prefill request
        fill_slot, fill_cursor = -1, 0
        wait_pages = False  # requeued admission waits for a retire to free
        #                     pages before trying again (avoids a hot loop)
        if paged:
            ps = self.kv_page_size
            # host-side page bookkeeping: the block table mirror ships to the
            # device as a (tiny) step argument whenever it changes; page 0 is
            # reserved scratch, so allocatable ids are 1..P-1.
            free_pages = list(range(self._kv_total_pages - 1, 0, -1))
            bt = np.zeros((b, cache["block_table"].shape[1]), np.int32)

        def complete_admission(i: int, r: Request, logits) -> None:
            """prefilling -> decoding (or straight to retired): seed the
            slot's RNG stream, sample the first token from the prefill
            logits, stamp TTFT. Seeding happens HERE — at prefill
            completion, right before the first draw — so chunked admission
            (whose mid-prefill slots see decode ticks advance every slot
            key) samples the same stream as monolithic."""
            nonlocal tokens
            self._slot_keys = self._slot_keys.at[i].set(
                jax.random.fold_in(self._key, r.rid))
            first = int(self._sample(logits[None], greedy, slot=i)[0])
            tokens = tokens.at[i, 0].set(first)
            r.fmt_used = pinned            # pinned for the whole sequence
            r.out_tokens.append(first)
            r.ttft_s = time.perf_counter() - t0
            self._tokens_out += 1
            if len(r.out_tokens) >= r.max_new:
                r.done = True              # degenerate max_new<=1
                if paged:                  # row -> scratch BEFORE any reuse
                    self._free_slot_pages(free_pages, bt, i)
                    cache["block_table"] = jnp.asarray(bt)
            else:
                active[i] = r

        while pending or filling is not None \
                or any(a is not None for a in active):
            t_tick = time.perf_counter()
            if pinned is None:             # engine drained: re-pick format
                # Load counts queued requests AND their pending prompt
                # tokens, so a queue of long prompts downshifts before the
                # admissions start, not after (serve/policy.py).
                pinned = fmt_override or self.policy.pick(
                    queue_depth=len(pending), active=0,
                    prefill_tokens=sum(r.prompt.size for r in pending))
            params = self.set_format(pinned)
            use_packed = self._serves_packed(pinned)
            prefill_slot = self._packed_prefill_slot if use_packed \
                else self._dense_prefill_slot
            chunk_fn = self._packed_prefill_chunk if use_packed \
                else self._dense_prefill_chunk
            step = self._packed_step if use_packed else self._dense_step
            mixed_fn = self._packed_mixed if use_packed else self._dense_mixed
            tick_pf_tokens = 0
            tick_pf_chunks = 0
            tick_execs = 0                 # executables dispatched this tick
            tick_rows = 0                  # batch rows those executables ran
            chunk_tok = None               # staged chunk for the mixed tick

            if chunk is None:
                # ---- monolithic admission: one whole prompt per free slot,
                # active slots untouched (but stalled for the full prefill)
                for i in range(b):
                    if active[i] is not None or not pending:
                        continue
                    r = pending.pop(0)
                    prompt = np.asarray(r.prompt, np.int32)
                    assert prompt.size <= self.prompt_capacity, \
                        (f"prompt ({prompt.size}) exceeds capacity "
                         f"({self.prompt_capacity} = max_len - 1)")
                    pbatch = self._prefill_batch(prompt)
                    if paged:
                        # Pages to hold the (possibly bucket-padded) prompt
                        # AND the first decode write at position prompt.size.
                        blen = pbatch["tokens"].shape[1]
                        need = max(-(-blen // ps), prompt.size // ps + 1)
                        bt[i, :need] = self._alloc_pages(
                            free_pages, need, f"admission of rid={r.rid}")
                        cache["block_table"] = jnp.asarray(bt)
                    logits, cache, new_len = prefill_slot(params, pbatch,
                                                          cache, i)
                    tick_pf_tokens += pbatch["tokens"].shape[1]
                    tick_pf_chunks += 1
                    tick_execs += 1
                    tick_rows += 1
                    cache_len = cache_len.at[i].set(new_len)
                    slot_len[i] = prompt.size
                    complete_admission(i, r, logits)
            else:
                # ---- chunked admission bookkeeping: claim the (single)
                # mid-prefill request and allocate THIS chunk's pages
                # (release-and-requeue on exhaustion). Whether the staged
                # chunk runs as its own executable or rides the decode batch
                # is the scheduler's call, below.
                if filling is None and pending and not wait_pages \
                        and None in active:
                    fill_slot = active.index(None)
                    filling, fill_cursor = pending.pop(0), 0
                    assert filling.prompt.size <= self.prompt_capacity, \
                        (f"prompt ({filling.prompt.size}) exceeds capacity "
                         f"({self.prompt_capacity} = max_len - 1)")
                    # The mixed tick reads the fill row's cursor from
                    # cache_len; zero the stale value from the slot's
                    # previous occupant at claim time.
                    cache_len = cache_len.at[fill_slot].set(0)
                if filling is not None:
                    r, i = filling, fill_slot
                    prompt = np.asarray(r.prompt, np.int32)
                    plen = prompt.size
                    start = fill_cursor
                    take = min(chunk, plen - start)
                    final = start + take >= plen
                    padded = take if (final and not self._bucket) else \
                        (_bucket_len(take, chunk) if final else chunk)
                    padded = min(padded, self.max_len - start)
                    ok = True
                    if paged:
                        # This chunk's pages only — chunk N's pages are
                        # allocated at chunk N, never all upfront. The first
                        # decode write's page is the decode tick's job.
                        first_pg = start // ps
                        last_pg = -(-(start + padded) // ps)
                        try:
                            got = self._alloc_pages(
                                free_pages, last_pg - first_pg,
                                f"prefill chunk at {start} of rid={r.rid}")
                        except RuntimeError:
                            # Partial admission must not starve the pool:
                            # release the pages already held, requeue, and
                            # retry once a retire frees pages. With nothing
                            # running, nothing will ever free — re-raise.
                            if not any(a is not None for a in active):
                                raise
                            self._free_slot_pages(free_pages, bt, i)
                            cache["block_table"] = jnp.asarray(bt)
                            pending.insert(0, r)
                            filling = None
                            self._admission_requeues += 1
                            wait_pages = True
                            ok = False
                        if ok:
                            bt[i, first_pg:last_pg] = got
                            cache["block_table"] = jnp.asarray(bt)
                    if ok:
                        ctoks = np.zeros(padded, np.int32)
                        ctoks[:take] = prompt[start:start + take]
                        chunk_tok = (start, take, padded, final)

                # A staged chunk runs as its own executable under the
                # sequential scheduler — and when no slot is decoding, where
                # the two schedulers coincide (one executable either way,
                # identical numerics).
                chunk_ran_alone = False
                if chunk_tok is not None and (
                        self.scheduler == "sequential"
                        or not any(a is not None for a in active)):
                    chunk_ran_alone = True
                    start, take, padded, final = chunk_tok
                    pbatch = {"tokens": jnp.asarray(ctoks[None]),
                              "lengths": jnp.asarray([plen], jnp.int32)}
                    logits, cache, new_len = chunk_fn(params, pbatch,
                                                      cache, i, start)
                    tick_pf_tokens += padded
                    tick_pf_chunks += 1
                    tick_execs += 1
                    tick_rows += 1
                    cache_len = cache_len.at[i].set(new_len)
                    fill_cursor = start + take
                    if final:
                        slot_len[i] = plen
                        complete_admission(i, r, logits)
                        filling = None
                    chunk_tok = None

            all_free = all(a is None for a in active)
            if all_free or (chunk is not None and chunk_ran_alone
                            and self.scheduler == "mixed"):
                # No decode this tick. Under the mixed scheduler a chunk
                # that ran alone ends the tick even when it just completed
                # admission — the new slot's first decode is next tick's
                # (one) executable, never a second one on this tick. The
                # slot's stream is unchanged: its key advances once per
                # decode tick it sits in, wherever that tick falls.
                self._record_tick(tick_pf_tokens, tick_pf_chunks, 0,
                                  time.perf_counter() - t_tick,
                                  execs=tick_execs, rows=tick_rows,
                                  decode_rows=0)
                if all_free and filling is None:
                    pinned = None          # drained; next wave re-picks
                continue

            # ---- decode tick: fused step over all slots; free and
            # mid-prefill slots are masked (their cache_len doesn't advance
            # and their sampled tokens are dropped)
            mask = np.asarray([a is not None for a in active], np.int32)
            if paged:
                # Map the page each active slot's write position lands in
                # BEFORE the step runs — this is where the pool grows (and
                # where exhaustion surfaces, loudly, mid-stream).
                dirty = False
                for i, r in enumerate(active):
                    if r is None:
                        continue
                    pg = slot_len[i] // ps
                    if bt[i, pg] == 0:
                        try:
                            got = self._alloc_pages(
                                free_pages, 1,
                                f"decode tick for rid={r.rid}")
                        except RuntimeError:
                            # A decoding slot outranks a partial admission:
                            # release the mid-prefill slot's pages (this
                            # tick's staged chunk included), requeue it, and
                            # retry. Restarting the admission from chunk 0
                            # later cannot perturb its stream (the slot RNG
                            # seeds at prefill completion). With no
                            # admission to roll back, the pool is genuinely
                            # overcommitted to decoders — die loudly.
                            if filling is None:
                                raise
                            self._free_slot_pages(free_pages, bt, fill_slot)
                            pending.insert(0, filling)
                            filling = None
                            chunk_tok = None
                            self._admission_requeues += 1
                            wait_pages = True
                            dirty = True
                            got = self._alloc_pages(
                                free_pages, 1,
                                f"decode tick for rid={r.rid}")
                        bt[i, pg] = got[0]
                        dirty = True
                if dirty:
                    cache["block_table"] = jnp.asarray(bt)
            if chunk_tok is not None:
                # ---- mixed tick: the staged chunk rides the decode batch as
                # ONE executable. Decode rows keep their 1-token budget in
                # column 0; the fill row carries the whole chunk at its
                # cursor. Free rows stay masked exactly as under serve_step
                # (q_len=1, cursor frozen, scratch-page writes).
                start, take, padded, final = chunk_tok
                tok2d = jnp.zeros((b, padded), jnp.int32) \
                    .at[:, 0].set(tokens[:, 0]) \
                    .at[fill_slot].set(jnp.asarray(ctoks))
                q_len_np = np.ones(b, np.int32)
                q_len_np[fill_slot] = take
                logits, cache = mixed_fn(
                    params, {"tokens": tok2d,
                             "q_len": jnp.asarray(q_len_np)},
                    cache, cache_len)
                adv = mask.copy()
                adv[fill_slot] = take
                cache_len = cache_len + jnp.asarray(adv)
                tick_pf_tokens += padded
                tick_pf_chunks += 1
                tick_execs += 1
                tick_rows += b
            else:
                logits, cache = step(params, {"tokens": tokens},
                                     cache, cache_len)
                cache_len = cache_len + jnp.asarray(mask)
                tick_execs += 1
                tick_rows += b
            # The batched draw advances EVERY slot key once per decode-
            # carrying tick — the fill row's draw is discarded, and if its
            # chunk completed this tick, complete_admission reseeds the key
            # from scratch below, so the stream matches sequential admission
            # bit for bit.
            nxt = self._sample(logits, greedy)
            tokens = nxt[:, None].astype(jnp.int32)
            self._ticks += 1

            # Attention-read accounting for the tick that just ran. Every
            # batch row is processed (free/mid-prefill slots are masked, not
            # removed): gather (and the dense layout) materializes the full
            # logical span for ALL rows; the kernel walks pages_read(...)
            # distinct pages (kernels/paged_attention.py — the one home of
            # that clamp arithmetic) for rows with mapped pages — decoding
            # slots at slot_len+1, the mid-prefill slot at its cursor+1 —
            # and a single clamped-revisit scratch page for zeroed rows
            # (every walk step maps to page 0, so Pallas elides the repeats).
            window = self.api.cfg.sliding_window
            for i in range(b):
                if not (paged and self.attn_impl == "paged_kernel"):
                    self._attn_tokens_read += self._attn_read_span
                elif active[i] is not None:
                    self._attn_tokens_read += \
                        pages_read(slot_len[i] + 1, ps, window) * ps
                elif chunk_tok is not None and i == fill_slot:
                    # Mixed tick: the fill row's ragged query span walks its
                    # own clamped page range (pages_read_mq mirrors the MQ
                    # kernel's arithmetic the way pages_read mirrors the
                    # single-query kernel's).
                    self._attn_tokens_read += \
                        pages_read_mq(start, take, ps, window) * ps
                elif filling is not None and i == fill_slot:
                    self._attn_tokens_read += \
                        pages_read(fill_cursor + 1, ps, window) * ps
                else:
                    self._attn_tokens_read += ps

            # ---- retire: ONE host transfer per tick drains every slot
            drained = np.asarray(nxt)
            for i, r in enumerate(active):
                if r is None:
                    continue
                slot_len[i] += 1
                r.out_tokens.append(int(drained[i]))
                self._tokens_out += 1
                if len(r.out_tokens) >= r.max_new or \
                        slot_len[i] >= self.prompt_capacity:
                    r.done = True
                    active[i] = None       # slot re-admissible next tick
                    if paged:              # pages recycle on the next admit
                        self._free_slot_pages(free_pages, bt, i)
                        cache["block_table"] = jnp.asarray(bt)
                    wait_pages = False     # freed pages: admission may retry
            if chunk_tok is not None:
                # ---- mixed-tick chunk epilogue: advance the cursor, and if
                # the chunk reached the prompt end, complete admission from
                # the fill row's logits — AFTER the batched draw above, so
                # the reseed overwrites the discarded draw's key advance.
                fill_cursor = start + take
                if final:
                    slot_len[fill_slot] = plen
                    complete_admission(fill_slot, filling, logits[fill_slot])
                    filling = None
            self._record_tick(tick_pf_tokens, tick_pf_chunks, 1,
                              time.perf_counter() - t_tick,
                              execs=tick_execs, rows=tick_rows,
                              decode_rows=int(mask.sum()))
            if all(a is None for a in active) and filling is None:
                pinned = None
        return requests

    def _record_tick(self, prefill_tokens: int, prefill_chunks: int,
                     decode: int, wall_s: float, *, execs: int = 0,
                     rows: int = 0, decode_rows: int = 0) -> None:
        """Append one scheduler-tick trace entry (reset per ``generate``).

        ``prefill_tokens`` counts padded prompt tokens prefilled this tick
        (one chunk at most under chunked admission; whole prompts under
        monolithic), ``decode`` is 1 when a batched decode step ran.
        ``execs`` counts device executables dispatched this tick — the
        mixed scheduler's invariant, exactly one per work tick, is asserted
        from it in tests (monolithic admission may run several: one prefill
        per admitted slot plus the decode step). ``rows`` counts batch rows
        those executables processed and ``decode_rows`` the subset that were
        live decoding slots; ``benchmarks/serve_engine_bench.py`` derives
        its decode-occupancy and decode-stall columns from these plus
        ``wall_s``.
        """
        self.tick_trace.append({"prefill_tokens": prefill_tokens,
                                "prefill_chunks": prefill_chunks,
                                "decode": decode, "wall_s": wall_s,
                                "execs": execs, "rows": rows,
                                "decode_rows": decode_rows})

    def _free_slot_pages(self, free_pages: List[int], bt: np.ndarray,
                         slot: int) -> None:
        """Return a retired slot's pages to the free list and point its
        block-table row at the scratch page (0) so any further masked write
        from the still-batched slot lands there, never on a recycled page."""
        used = bt[slot][bt[slot] != 0]
        free_pages.extend(int(p) for p in used)
        self._kv_pages_freed += used.size
        bt[slot, :] = 0

    def _sample(self, logits, greedy: bool, slot: Optional[int] = None):
        """Greedy argmax, or a temperature/top-p draw from per-slot streams.

        ``slot=None`` advances every slot's key by one draw (the decode
        tick); a slot index draws for that slot only (admission). Free
        slots' draws are discarded by the caller; advancing their keys is
        harmless and keeps the tick one fused vmap.
        """
        if greedy or self.temperature <= 0:
            return jnp.argmax(logits, -1)
        if slot is None:
            self._slot_keys, toks = _sample_batch(
                self._slot_keys, logits, self.temperature, self.top_p)
            return toks
        new_key, toks = _sample_batch(
            self._slot_keys[slot][None], logits, self.temperature,
            self.top_p)
        self._slot_keys = self._slot_keys.at[slot].set(new_key[0])
        return toks

    # ---- introspection ----------------------------------------------------
    @property
    def stats(self):
        def containers(tree):
            kinds = {type(l).__name__
                     for l in jax.tree_util.tree_leaves(
                         tree, is_leaf=lambda x: isinstance(
                             x, (MXTensor, PackedInt4Leaf)))
                     if isinstance(l, (MXTensor, PackedInt4Leaf))}
            return sorted(kinds) or ["dense"]

        return {
            "formats_cached": sorted(self._weights),
            "containers": {f: containers(t)
                           for f, t in self._weights.items()},
            "weight_bytes": {f: weight_stream_bytes(t)
                             for f, t in self._weights.items()},
            "fmt_swaps": self._fmt_swaps,
            "ticks": self._ticks,
            "tokens_out": self._tokens_out,
            "current": self.current_fmt,
            "fused": self.fused,
            "prefill_traces": self._prefill_traces,
            "prefill_chunk": self.prefill_chunk,
            "admission_requeues": self._admission_requeues,
            "kv_layout": self.kv_layout,
            "kv_cache_bytes": self._kv_cache_bytes,
            "kv_bytes_per_slot": self._kv_cache_bytes // self.slots,
            "kv_page_size": self.kv_page_size,
            "kv_total_pages": self._kv_total_pages,
            "kv_pages_alloc": self._kv_pages_alloc,
            "kv_pages_freed": self._kv_pages_freed,
            "kv_pages_hwm": self._kv_pages_hwm,
            "attn_impl": self.attn_impl,
            "attn_tokens_read": self._attn_tokens_read,
            "attn_read_bytes": self._attn_tokens_read
            * self._attn_layers * 2 * self.api.cfg.n_kv_heads
            * self.api.cfg.hd
            * jnp.dtype(self.api.cfg.compute_dtype).itemsize,
        }
