"""Packed-weight continuous-batching engine for elastic-precision serving.

Implements the paper's §3.5 inference scheme end-to-end: one anchor
checkpoint (MXINT8/MXFP8) is resident; per-format weight caches hold
**packed** pytrees built by ``make_packed_params`` — MXTensor leaves (int8
codes + E8M0 scales) for >=5-bit formats, split-N nibble-packed
``PackedInt4Leaf`` for MXINT4. The decode tick serves straight from the
packed bytes under one of two contracts:

  fused (default on TPU)  — ``make_packed_serve_step(fused=True)``: every
      projection feeds its packed leaf to the Pallas dequant-GEMM via
      ``kernels.dispatch.qmatmul``; weight HBM traffic is exactly the codes
      + scales, streamed tile-by-tile into VMEM (interpret-mode off TPU —
      the test path).
  densify-inside-jit      — the XLA fallback: leaves dequantize inside the
      jitted step and XLA fuses the dequant into the consuming matmuls.

Both contracts read the same codes, so decode — HBM-bound on weight reads —
streams 2x/4x fewer bytes at mxint8/mxint4 than dense bf16, and greedy
token streams are identical across them. Deriving a new format costs one
packed-domain Slice-and-Scale pass and is cached; switching between cached
formats is free.

Slot lifecycle (continuous batching; state machine documented in
docs/serving_internals.md "Admission & scheduling"):

  admit   — each request is prefilled individually via
            ``ModelApi.prefill_slot`` into a free slot; active slots are
            never re-prefilled. Prompts are right-padded to power-of-two
            length buckets (exact masking via ``batch["lengths"]``), so the
            prefill executable compiles once per bucket, not once per
            prompt length. With ``prefill_chunk`` set, admission is instead
            *chunked*: the prompt streams in fixed-size chunks via
            ``ModelApi.prefill_chunk_slot`` (one chunk per tick, cursor in
            host state), bounding how long a long prompt can stall the
            running slots.
  decode  — one fused serve_step advances every slot per tick; free,
            finished, and mid-prefill slots are masked (their cache_len
            stops advancing and their sampled tokens are dropped).
  retire  — a slot frees the moment its request reaches ``max_new`` or cache
            capacity, and is re-admissible on the very next tick.

Sampling: greedy argmax, or temperature/top-p with **per-slot RNG streams**
— each admission seeds its slot from ``fold_in(engine_key, rid)`` and every
draw advances only that slot's key, so concurrent identical prompts decode
independently and any request's stream is reproducible from (seed, rid)
alone.

Format selection is **batch-pinned**: the policy picks once, when the engine
transitions from drained to busy, and every request admitted while any slot
is live inherits that format. Numerics therefore never switch mid-sequence
and ``Request.fmt_used`` is exact for every generated token, not just the
admission-time value.

Token draining is host-side: one device->host transfer of the whole
next-token vector per tick (``np.asarray``), with per-slot lengths mirrored
in host counters — no per-slot ``int(...)`` device syncs in the tick loop.

Failure domains & degradation (docs/serving_internals.md §7 "Failure model
& degradation ladder"): every request ends in exactly ONE terminal
``RequestStatus`` — a fault confined to one request (oversized prompt,
per-request deadline, cancellation, poisoned logits traced to one row,
page exhaustion with no reclaimable admission) retires that request with
its pages freed and its error recorded in ``stats()["failures"]``, and the
engine keeps serving the rest. Batch-wide numeric faults walk the policy's
format ladder instead: a cheap host-side NaN/Inf check on each tick's
consumed logit rows escalates the batch one rung toward the anchor
(``FormatPolicy.escalate``) and REPLAYS the tick — every attempt is a pure
function of the pre-tick (cache, cache_len, tokens), and sampling /
cache_len advance / token drain only commit after the guard settles, so a
replay cannot perturb surviving streams. Only at the anchor rung does the
engine fall back to per-row retirement (``FAILED_NUMERIC``). Chaos is
driven by a seeded ``runtime.fault.FaultInjector`` hook, and a
``PreemptionGuard`` passed to ``generate`` snapshots the host scheduler
state at the next tick boundary (``checkpoint.io.save_flat``) so
``resume()`` completes the wave with bit-identical remaining streams.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.core.anchor import AnchorModel, convert, materialize
from repro.core.formats import get_format
from repro.core.mx import MXTensor
from repro.kernels.paged_attention import pages_read, pages_read_mq
from repro.models.common import spec_accept_counts
from repro.models.transformer import ModelApi, make_model
from repro.runtime.fault import InjectedFault
from repro.serve.packed_params import (PackedInt4Leaf, anchor_block_size,
                                       make_packed_mixed_step,
                                       make_packed_params,
                                       make_packed_prefill_chunk,
                                       make_packed_prefill_slot,
                                       make_packed_serve_step,
                                       make_packed_verify_step,
                                       packed_param_shardings,
                                       repack_splitn_for_tp,
                                       weight_stream_bytes,
                                       weight_stream_bytes_local)
from repro.serve.policy import FormatPolicy, SpecConfig
from repro.serve.slo import SLOClass, tier_rank

DENSE_BF16 = "bf16"   # pseudo-format: dense anchor-precision weights

MIN_PREFILL_BUCKET = 8


def _bucket_len(plen: int, cap: int) -> int:
    """Smallest power-of-two bucket >= plen (floor MIN_PREFILL_BUCKET),
    clamped to the cache capacity ``cap``."""
    b = MIN_PREFILL_BUCKET
    while b < plen:
        b *= 2
    return min(b, cap)


def _sample_one(key, logits, temperature, top_p):
    """One temperature/top-p draw; returns (advanced_key, token)."""
    k_next, k_draw = jax.random.split(key)
    lg = logits.astype(jnp.float32) / jnp.maximum(temperature, 1e-6)
    probs = jax.nn.softmax(lg)
    order = jnp.argsort(-probs)
    sp = jnp.take(probs, order)
    # nucleus: smallest prefix of descending probs reaching top_p mass
    # (top-1 always kept: its prefix-exclusive cumsum is 0 < top_p)
    keep_sorted = (jnp.cumsum(sp) - sp) < top_p
    keep = jnp.zeros_like(keep_sorted).at[order].set(keep_sorted)
    return k_next, jax.random.categorical(k_draw, jnp.where(keep, lg,
                                                            -jnp.inf))


# Per-slot temperature/top_p lanes: each request samples with its own
# params (Request.temperature/top_p; engine ctor values are the defaults).
# Scalar division/threshold per lane — numerically identical per row to the
# old broadcast-scalar vmap, so streams are bit-stable across the change.
_sample_batch = jax.jit(jax.vmap(_sample_one, in_axes=(0, 0, 0, 0)))


class RequestStatus(str, enum.Enum):
    """Lifecycle of one request. Every request ends in exactly one of the
    terminal states; non-COMPLETED terminals carry ``Request.error`` and a
    record in ``ElasticEngine.stats()["failures"]`` (the per-request
    failure domain: docs/serving_internals.md §7)."""
    QUEUED = "queued"
    RUNNING = "running"
    COMPLETED = "completed"              # reached max_new / cache capacity
    FAILED_NUMERIC = "failed_numeric"    # non-finite logits at anchor rung
    FAILED_CAPACITY = "failed_capacity"  # unservable prompt / pool starved
    TIMED_OUT = "timed_out"              # per-request deadline_s exceeded
    CANCELLED = "cancelled"              # cancel() / injected cancellation

    @property
    def terminal(self) -> bool:
        return self not in (RequestStatus.QUEUED, RequestStatus.RUNNING)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    fmt_used: Optional[str] = None
    done: bool = False
    ttft_s: Optional[float] = None  # wall-clock from generate() entry to the
    #                                 first sampled token (set by the engine)
    deadline_s: Optional[float] = None  # wall-clock budget from generate()
    #                                     entry; exceeded -> TIMED_OUT at the
    #                                     next tick boundary (resume-aware:
    #                                     the clock spans the interruption)
    status: RequestStatus = RequestStatus.QUEUED
    error: Optional[str] = None     # set with any non-COMPLETED terminal
    cancel_requested: bool = False
    # ---- per-request service objectives & sampling (docs §10) ----------
    slo: Optional["SLOClass"] = None    # tier + TTFT/TPOT budgets; None =
    #                                     best-effort, no budgets
    tenant: Optional[str] = None        # workload attribution (fairness
    #                                     accounting in the bench)
    arrival_tick: int = 0           # scheduler tick this request becomes
    #                                 visible to admission (0 = already
    #                                 queued, the pre-SLO behavior)
    arrival_s: Optional[float] = None   # wall clock when it came due
    #                                     (stamped by the engine; TTFT
    #                                     against the SLO is ttft_s minus
    #                                     this)
    admitted_tick: Optional[int] = None  # tick admission claimed it
    temperature: Optional[float] = None  # None -> engine default
    top_p: Optional[float] = None        # None -> engine default

    def cancel(self) -> None:
        """Ask the engine to retire this request as CANCELLED at the next
        tick boundary (queued, mid-prefill, or decoding alike). Safe to
        call from outside the serving loop; already-terminal requests are
        unaffected."""
        self.cancel_requested = True


class ElasticEngine:
    """Continuous-batching engine serving from packed MX weight caches.

    ``packed=False`` swaps every format's weights for their densified bf16
    equivalent (same codes, dequantized ahead of time) — the reference path
    for packed-vs-dense equivalence tests and roofline baselines. The
    pseudo-format ``"bf16"`` serves dense anchor-precision weights.

    ``fused`` selects the packed-serving contract: the Pallas dequant-GEMM
    dispatch (True) vs XLA densify-inside-jit (False); None = fused on TPU.
    Fixed per engine instance, so each contract gets its own jitted
    executables and no stale-cache hazards exist.

    ``kv_layout`` selects the KV-cache layout: ``"dense"`` preallocates a
    contiguous (slots, max_len) buffer per layer; ``"paged"`` serves from a
    shared page pool plus per-slot block tables, committing HBM one
    ``kv_page_size``-token page at a time as sequences grow. The engine owns
    the host-side free list: pages are allocated at admission (enough to
    hold the prompt plus the first decode write), one page at a time as
    decode crosses page boundaries, and returned the moment a slot retires —
    so the pool only needs to cover the *live* token count, not
    slots × max_len. Exhaustion raises ``RuntimeError`` loudly (never a
    silent truncation); size the pool with ``kv_num_pages`` (None = dense
    capacity: slots × ceil(max_len/page) + 1 scratch page). Token streams
    are bit-identical across layouts (same values at every valid position).

    ``attn_impl`` selects the paged decode-attention read path:
    ``"paged_kernel"`` consumes the page pools + block table directly in the
    gather-free Pallas kernel (``kernels/paged_attention.py`` — Mosaic on
    TPU, interpret-mode in tests), so per-tick attention reads scale with
    live tokens (``ceil(cache_len/page)`` pages per slot); ``"gather"``
    keeps the original materialize-then-attend pair, whose reads scale with
    ``max_pages*page`` regardless of occupancy. None = kernel on TPU when
    paged, gather elsewhere. Both impls read the same KV values at every
    valid position and reduce in fp32, but the kernel's online softmax
    reorders the reduction, so logits can differ by ulps — token-stream
    equality across impls is an *empirically held* contract (asserted
    exactly by tests and the bench on this backend), not an algebraic one;
    ``stats()["attn_tokens_read"]`` accounts the read-traffic difference and
    ``benchmarks/serve_engine_bench.py`` turns it into attention-bytes/token.
    Requires ``kv_layout="paged"`` — the dense layout has no block table to
    consume.

    ``prefill_chunk`` selects the admission mode (the slot-lifecycle state
    machine is documented in docs/serving_internals.md, "Admission &
    scheduling"). ``None`` (default) admits monolithically: each prompt is
    prefilled in one call, stalling every running slot for the full prompt
    length. An int (or ``"auto"`` = one KV page when paged, else 64) splits
    admission into fixed-size chunks interleaved with decode ticks — the
    scheduler runs AT MOST one prefill chunk per tick before the batched
    decode step, so per-tick work (and therefore running slots' inter-token
    latency) is bounded by one chunk regardless of incoming prompt length.
    Token streams are bit-identical to monolithic admission (greedy and
    seeded sampling). Attention-only; when paged, the chunk must be a
    multiple of ``kv_page_size`` so chunk boundaries fall on pages and each
    chunk's pages are allocated at that chunk, not all upfront.

    ``speculative`` (a ``serve.policy.SpecConfig``) turns a pure-decode
    tick into a self-speculative one: k greedy draft steps under the
    ``draft_fmt`` packed contract (same slots, same paged pools — drafts
    write through the normal decode-append path against a LOCAL cursor),
    then ONE batched verify step at the pinned format over the k+1
    positions per slot via the multi-query mixed-attention machinery
    (``ModelApi.verify_step``). Each slot accepts its longest
    greedy-matching draft prefix plus the verify step's bonus token;
    rejected tokens roll back by rewinding that slot's ``cache_len`` (no
    copies) and returning pages past the new frontier to the free list.
    Because only verify-format argmaxes are ever committed, greedy token
    streams are **bit-identical to plain pinned-format decode at any
    acceptance rate** — speculation changes speed, never tokens
    (docs/serving_internals.md §9 "Speculative decoding"; the guard /
    quarantine interplay — a quarantined draft rung silently reverts to
    plain decode — is specified there too). Greedy-only: ``generate``
    rejects sampled decoding when speculation is on.

    ``scheduler`` selects how chunked ticks execute. ``"mixed"`` (the
    default whenever ``prefill_chunk`` is set) coalesces the prefill chunk
    INTO the decode batch: one ``mixed_step`` executable per tick, where
    each row carries a per-slot token budget — decoding slots contribute 1
    query token, the (single) mid-prefill slot contributes its chunk at its
    cursor — so decode never skips a tick during a long admission and
    ``tick_trace`` shows exactly one executable per tick. ``"sequential"``
    keeps the PR 4 shape (chunk executable, then decode executable) as the
    provably equivalent fallback. Sampling-wise the epilogue is fused but
    ordered identically: the batched draw advances every slot key exactly
    once per decode-carrying tick, and a completing admission reseeds its
    slot from ``(engine key, rid)`` AFTER the batch draw — so token streams
    are bit-identical to sequential admission (greedy and seeded) across
    all layout/contract pairings; the tests in tests/test_mixed_batch.py
    hold that line. Requires ``prefill_chunk``.
    """

    def __init__(self, api: ModelApi, anchor: AnchorModel, *,
                 batch_slots: int = 4, max_len: int = 256,
                 policy: Optional[FormatPolicy] = None,
                 param_template=None, packed: bool = True,
                 fused: Optional[bool] = None, seed: int = 0,
                 temperature: float = 1.0, top_p: float = 1.0,
                 bucket_prompts: bool = True,
                 kv_layout: str = "dense", kv_page_size: int = 16,
                 kv_num_pages: Optional[int] = None,
                 attn_impl: Optional[str] = None,
                 prefill_chunk=None,
                 scheduler: Optional[str] = None,
                 logit_guard: bool = True,
                 max_step_retries: int = 2,
                 fault_injector=None,
                 speculative: Optional[SpecConfig] = None,
                 admission_order: str = "fifo",
                 mesh=None):
        self.api = api
        self.anchor = anchor
        self.slots = batch_slots
        self.max_len = max_len
        self.policy = policy or FormatPolicy(anchor.fmt_name)
        self.packed = packed
        if fused is None:             # auto: fused where Mosaic lowers and
            #                           the family has the qmm hook
            self.fused = jax.default_backend() == "tpu" \
                and api.with_qmm is not None
        else:
            if fused and api.with_qmm is None:
                raise ValueError(
                    f"fused=True but model family {api.cfg.family!r} has no "
                    "qmm hook; use fused=False (densify-inside-jit)")
            self.fused = fused
        self.temperature = temperature
        self.top_p = top_p
        # Per-slot sampling lanes (defaults now, per-request values set at
        # complete_admission — before the slot's first draw).
        self._slot_temp = np.full((self.slots,), temperature, np.float32)
        self._slot_topp = np.full((self.slots,), top_p, np.float32)
        # Admission ordering among ARRIVED queued requests (docs §10):
        # "fifo" preserves submission order; "slo" serves latency-tier
        # ahead of throughput-tier ahead of best-effort, FIFO within a
        # tier — the structural lever behind per-tier TTFT attainment.
        if admission_order not in ("fifo", "slo"):
            raise ValueError(f"unknown admission_order {admission_order!r};"
                             " one of ('fifo', 'slo')")
        self.admission_order = admission_order
        self._template = param_template if param_template is not None else \
            jax.eval_shape(api.init_params, jax.random.PRNGKey(0))
        self._block_size = anchor_block_size(anchor)
        # ---- tensor parallelism (docs/serving_internals.md §11) ----------
        # mesh: shard the packed leaves / KV pools over the mesh's 'model'
        # axis and run every step function inside shard_map — token streams
        # stay bit-identical to the single-device engine. Other mesh axes
        # must have size 1 (data parallelism = one engine per replica; see
        # serve/replicas.py).
        self.mesh = mesh
        self._tp = 1
        if mesh is not None:
            names = tuple(getattr(mesh, "axis_names", ()))
            if "model" not in names:
                raise ValueError(
                    "ElasticEngine(mesh=...) needs a mesh with a 'model' "
                    f"axis; got axes {names}")
            sizes = dict(zip(names, mesh.devices.shape))
            tp = int(sizes["model"])
            extra = {a: int(n) for a, n in sizes.items()
                     if a != "model" and n != 1}
            if extra:
                raise ValueError(
                    "ElasticEngine shards over the 'model' mesh axis only; "
                    f"axes {extra} have size > 1 — run one engine per "
                    "data-parallel slice (serve.replicas.ReplicaSet)")
            cfg_g = api.cfg
            if cfg_g.family != "dense" or cfg_g.vision_tokens > 0:
                raise ValueError(
                    "tensor-parallel serving supports pure-attention dense "
                    f"text stacks only; family {cfg_g.family!r} is not "
                    "wired for head-sharded step functions")
            bs_tp = self._block_size * tp
            bad = {k: v for k, v in {
                "n_heads": cfg_g.n_heads, "n_kv_heads": cfg_g.n_kv_heads,
                "vocab": cfg_g.vocab, "d_ff": cfg_g.d_ff}.items()
                if v % tp}
            # Row-parallel packed scales tile the contraction dim by the MX
            # block: those dims must split into whole scale rows per shard.
            bad.update({k: v for k, v in {
                "n_heads*head_dim": cfg_g.n_heads * cfg_g.hd,
                "d_ff": cfg_g.d_ff}.items() if v % bs_tp})
            if bad:
                raise ValueError(
                    f"mesh 'model' axis size {tp} cannot shard this "
                    f"config: {bad} not divisible (block_size="
                    f"{self._block_size})")
            self._tp = tp
        self._weights: Dict[str, object] = {}       # fmt -> serving pytree
        self._fmt_swaps = 0
        self._ticks = 0
        self._tokens_out = 0
        self.current_fmt: Optional[str] = None
        # Length bucketing needs exact masking of right-padded prompts; the
        # recurrent mixers (mamba/rwkv) fold pad tokens into their state, so
        # only pure-attention stacks bucket.
        pure_attn = api.cfg.family not in ("ssm", "encdec") \
            and api.cfg.attn_every <= 0
        self._bucket = bucket_prompts and pure_attn
        self._pure_attn = pure_attn
        # Paged KV: only attention KV has a sequence axis to page over. The
        # pure-attention check itself lives in the model's init_cache (the
        # single source of truth for what a family can page); the eval_shape
        # below surfaces its ValueError at engine construction.
        if kv_layout not in ("dense", "paged"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}; "
                             "one of ('dense', 'paged')")
        self.kv_layout = kv_layout
        self.kv_page_size = kv_page_size
        self.kv_num_pages = kv_num_pages
        # Paged decode-attention read path (class docstring): auto = the
        # gather-free kernel where Mosaic lowers, the gather fallback
        # elsewhere (tests opt into the kernel explicitly -> interpret mode).
        if attn_impl is None:
            attn_impl = "paged_kernel" if (
                kv_layout == "paged"
                and jax.default_backend() == "tpu") else "gather"
        if attn_impl not in ("gather", "paged_kernel"):
            raise ValueError(f"unknown attn_impl {attn_impl!r}; one of "
                             "('gather', 'paged_kernel')")
        if attn_impl == "paged_kernel" and kv_layout != "paged":
            raise ValueError(
                "attn_impl='paged_kernel' requires kv_layout='paged' — the "
                "dense layout has no block table for the kernel to consume")
        self.attn_impl = attn_impl
        self._attn_tokens_read = 0   # KV tokens decode attention read (host
        #                              mirror; see stats()["attn_tokens_read"])
        cfg = api.cfg
        self._attn_layers = 0 if cfg.family == "ssm" else sum(
            cfg.is_attn_layer(j) for j in range(cfg.scan_group)) \
            * cfg.n_groups
        # HBM bytes per KV token read (K+V, all attention layers) — the one
        # multiplier behind stats()["attn_read_bytes"] and the cost model's
        # measured attention term.
        self._attn_token_bytes = self._attn_layers * 2 * cfg.n_kv_heads \
            * cfg.hd * jnp.dtype(cfg.compute_dtype).itemsize
        # Per-chip KV read bytes: pools shard over kv heads on the mesh, so
        # each chip streams 1/tp of every token's K+V (exact — n_kv_heads %
        # tp is guarded above). Single chip: identical to the global number.
        self._attn_token_bytes_chip = self._attn_token_bytes // self._tp
        # Chunked prefill admission (None = monolithic; see class docstring
        # and docs/serving_internals.md "Admission & scheduling").
        if prefill_chunk == "auto":
            prefill_chunk = kv_page_size if kv_layout == "paged" else 64
        if prefill_chunk is not None:
            if not pure_attn or api.cfg.vision_tokens > 0:
                raise ValueError(
                    "prefill_chunk requires a pure-attention text stack; "
                    f"family {api.cfg.family!r} folds the prompt into "
                    "recurrent state (or prepends vision embeds) and cannot "
                    "resume prefill mid-prompt — use prefill_chunk=None")
            if prefill_chunk < MIN_PREFILL_BUCKET:
                raise ValueError(
                    f"prefill_chunk ({prefill_chunk}) must be >= the "
                    f"minimum prefill bucket ({MIN_PREFILL_BUCKET})")
            if kv_layout == "paged" and prefill_chunk % kv_page_size:
                raise ValueError(
                    f"prefill_chunk ({prefill_chunk}) must be a multiple of "
                    f"kv_page_size ({kv_page_size}) so chunk boundaries "
                    "fall on page boundaries")
        self.prefill_chunk = prefill_chunk
        # Unified-tick scheduler (class docstring): "mixed" is the default
        # wherever chunked admission makes a mixed tick possible.
        if scheduler in (None, "auto"):
            scheduler = "mixed" if prefill_chunk is not None else "sequential"
        if scheduler not in ("sequential", "mixed"):
            raise ValueError(f"unknown scheduler {scheduler!r}; one of "
                             "('sequential', 'mixed')")
        if scheduler == "mixed":
            if prefill_chunk is None:
                raise ValueError(
                    "scheduler='mixed' coalesces the prefill chunk into the "
                    "decode batch; set prefill_chunk (or 'auto')")
            if api.mixed_step is None:
                raise ValueError(
                    f"model family {api.cfg.family!r} has no mixed_step "
                    "entry point; use scheduler='sequential'")
        self.scheduler = scheduler
        # ---- self-speculative decoding (docs/serving_internals.md §9) ----
        if speculative is not None:
            if api.verify_step is None:
                raise ValueError(
                    f"model family {api.cfg.family!r} has no verify_step "
                    "entry point; speculative decoding needs the "
                    "multi-query mixed-attention machinery "
                    "(pure-attention stacks only)")
            if not pure_attn or api.cfg.vision_tokens > 0:
                raise ValueError(
                    "speculative decoding requires a pure-attention text "
                    f"stack; family {api.cfg.family!r} cannot rewind "
                    "recurrent state (or prepends vision embeds)")
            if speculative.k < 1:
                raise ValueError(
                    f"SpecConfig.k ({speculative.k}) must be >= 1")
            if speculative.draft_fmt == DENSE_BF16:
                raise ValueError(
                    "draft_fmt='bf16' drafts at anchor precision or above — "
                    "drafting must be cheaper than verifying")
        self.speculative = speculative
        self._spec_ticks = 0        # decode ticks that ran draft+verify
        self._spec_accepted = 0     # draft tokens committed to streams
        self._spec_rejected = 0     # draft tokens rolled back
        self._spec_aborts = 0       # spec attempts abandoned mid-tick
        #                             (draft fault / page starvation)
        # ---- fault isolation (docs/serving_internals.md §7) --------------
        # logit_guard: host-side NaN/Inf check on every tick's consumed
        # logit rows; detection escalates the batch format one ladder rung
        # toward the anchor and replays the tick (per-row FAILED_NUMERIC
        # retirement only at the anchor). max_step_retries bounds same-
        # format replays of a crashed step executable (InjectedFault).
        self.logit_guard = logit_guard
        self.max_step_retries = max_step_retries
        self._fault_injector = fault_injector
        self._faults_detected = 0
        self._fmt_escalations = 0
        self._escalation_events: List[dict] = []
        self._ticks_replayed = 0
        self._failures: List[dict] = []
        self._status_counts: Dict[str, int] = {}
        self._snapshots_saved = 0
        self._resumes = 0
        self._alloc_calls = 0
        self._snap_step = 0
        self.last_snapshot: Optional[str] = None
        # Tiny jitted guard: one (rows,) bool transfer per checked tick.
        self._finite_rows = jax.jit(lambda lg: jnp.isfinite(lg).all(axis=-1))
        self._admission_requeues = 0
        self._fmt_decode_ticks: Dict[str, int] = {}  # clean decode ticks
        #                          per format (cost-model compile warmup)
        self.tick_trace: List[Dict[str, float]] = []   # reset per generate
        self._kv_pages_alloc = 0
        self._kv_pages_freed = 0
        self._kv_pages_hwm = 0
        cache_shape = jax.eval_shape(lambda: self._init_cache(self.slots))
        self._kv_cache_bytes = sum(
            l.size * l.dtype.itemsize
            for l in jax.tree_util.tree_leaves(cache_shape))
        self._kv_total_pages = \
            cache_shape["blocks"][0]["k_pages"].shape[1] \
            if kv_layout == "paged" else 0
        # KV tokens one decode read spans per live slot under the GATHER
        # path (the whole logical view); the kernel path reads only
        # ceil(cache_len/page)*page of it, accounted per tick in generate().
        if kv_layout == "paged":
            self._attn_read_span = \
                cache_shape["block_table"].shape[1] * kv_page_size
        else:
            self._attn_read_span = self.max_len + api.cfg.vision_tokens
        # Tensor-parallel cache placement: the 5D leaves (dense K/V
        # (G, B, S, Hkv, D) and paged pools (G, P, ps, Hkv, D)) shard over
        # kv heads (axis 3); the block table and every host-built step
        # argument stay replicated with GLOBAL page ids, so the page
        # bookkeeping in generate() is mesh-oblivious.
        if self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            self._cache_pspecs = jax.tree_util.tree_map(
                lambda l: (PartitionSpec(None, None, None, "model", None)
                           if l.ndim == 5 else PartitionSpec()),
                cache_shape)
            self._cache_shardings = jax.tree_util.tree_map(
                lambda l: NamedSharding(
                    self.mesh,
                    PartitionSpec(None, None, None, "model", None)
                    if l.ndim == 5 else PartitionSpec()),
                cache_shape)
        else:
            self._cache_pspecs = None
            self._cache_shardings = None
        # Per-slot RNG: reseeded from (engine key, rid) at admission.
        self._key = jax.random.PRNGKey(seed)
        self._slot_keys = jax.random.split(self._key, self.slots)
        self._prefill_traces = 0     # host-side compile counter (bucketing)
        # Jitted entry points. Dense and packed trees have different pytree
        # structures, so jit caches one executable per cached format. The
        # decode steps bake attn_impl in at build time (same rationale as
        # `fused`: no stale-jit-cache hazards from flipping a global); the
        # prefill entry points are attn_impl-independent.
        # Tensor parallelism: build every step function from a LOCAL model —
        # the same architecture at per-shard head counts (head_dim pinned:
        # the derived default would recompute it from the full d_model) with
        # the GLOBAL vocab (the head all_gathers its logit slice back) — and
        # run it inside shard_map over the mesh. Two psums per layer (wo,
        # w_down), one psum for the embed lookup, one all_gather at the
        # head; everything else is local math on the shard (docs §11).
        if self.mesh is not None:
            cfg_g = api.cfg
            local_cfg = dataclasses.replace(
                cfg_g, n_heads=cfg_g.n_heads // self._tp,
                n_kv_heads=cfg_g.n_kv_heads // self._tp,
                head_dim=cfg_g.hd)
            src_api = make_model(local_cfg, api.qat, tp_axis="model")
        else:
            src_api = api
        if self.attn_impl == "gather":
            step_api = src_api
        else:
            if src_api.with_serving is None:
                raise ValueError(
                    f"model family {api.cfg.family!r} cannot rebuild its "
                    f"serving entry points with attn_impl={attn_impl!r}")
            step_api = src_api.with_serving(attn_impl=self.attn_impl)
        self._dense_step = self._mesh_jit(step_api.serve_step, 2)
        self._dense_prefill_slot = self._mesh_jit(
            self._counting(src_api.prefill_slot), 3)
        self._packed_step = self._mesh_jit(
            make_packed_serve_step(src_api, self._block_size,
                                   fused=self.fused,
                                   attn_impl=self.attn_impl), 2)
        self._packed_prefill_slot = self._mesh_jit(self._counting(
            make_packed_prefill_slot(src_api, self._block_size,
                                     fused=self.fused)), 3)
        # Chunked-admission entry points (jit is lazy: nothing compiles
        # unless prefill_chunk is actually used). Compiles once per chunk
        # bucket — the cursor is a traced argument.
        self._dense_prefill_chunk = self._mesh_jit(
            self._counting(src_api.prefill_chunk_slot), 3) \
            if src_api.prefill_chunk_slot is not None else None
        self._packed_prefill_chunk = self._mesh_jit(self._counting(
            make_packed_prefill_chunk(src_api, self._block_size,
                                      fused=self.fused)), 3) \
            if src_api.prefill_chunk_slot is not None else None
        # Unified mixed-tick entry points (lazy jit, one compile per chunk
        # width bucket — counted like chunk compiles). They bake attn_impl
        # in like the decode steps: the ragged multi-query paged read runs
        # the gather-free MQ kernel under "paged_kernel".
        self._dense_mixed = self._mesh_jit(
            self._counting(step_api.mixed_step), 2) \
            if step_api.mixed_step is not None else None
        self._packed_mixed = self._mesh_jit(self._counting(
            make_packed_mixed_step(src_api, self._block_size,
                                   fused=self.fused,
                                   attn_impl=self.attn_impl)), 2) \
            if src_api.mixed_step is not None else None
        # Speculative verify entry points (lazy jit — compile only when a
        # spec tick actually runs). Logits come back at ALL k+1 positions
        # (B, C, V), so the guard's finite check reduces the lane axis too.
        self._dense_verify = self._mesh_jit(
            self._counting(step_api.verify_step), 2) \
            if step_api.verify_step is not None else None
        self._packed_verify = self._mesh_jit(self._counting(
            make_packed_verify_step(src_api, self._block_size,
                                    fused=self.fused,
                                    attn_impl=self.attn_impl)), 2) \
            if src_api.verify_step is not None else None
        self._finite_rows_mq = jax.jit(
            lambda lg: jnp.isfinite(lg).all(axis=(-2, -1)))

    def _counting(self, fn):
        """Wrap a to-be-jitted fn so traces (= compiles) are counted."""
        def wrapped(*args):
            self._prefill_traces += 1    # runs at trace time only
            return fn(*args)
        return wrapped

    def _mesh_jit(self, fn, n_out: int):
        """``jax.jit`` — or, on a TP mesh, ``jit(shard_map(fn))``.

        Every step entry point shares one calling convention: the weight
        pytree is argument 0, the cache pytree argument 2, and (of the
        ``n_out`` outputs) the cache comes back at index 1; everything else
        — batch dicts, cursors, cache_len, logits — is replicated. The
        weights' in_specs are read off their committed shardings per call
        and the wrapped executable is cached per spec tree, mirroring
        jit's one-executable-per-pytree-structure behavior across the
        dense/packed/per-format trees. ``check_vma=False``: the replicated
        outputs are bit-identical across shards BY CONSTRUCTION (the head
        all_gathers full logits everywhere), which the static replication
        checker cannot prove through psum-into-bias arithmetic.
        """
        if self.mesh is None:
            return jax.jit(fn)
        from jax.sharding import PartitionSpec
        from repro.train.compression import shard_map
        compiled: Dict = {}

        def call(weights, *rest):
            w_specs = jax.tree_util.tree_map(
                lambda l: l.sharding.spec, weights)
            flat, treedef = jax.tree_util.tree_flatten(w_specs)
            key = (treedef, tuple(flat), len(rest))
            if key not in compiled:
                in_specs = [w_specs] + [PartitionSpec()] * len(rest)
                in_specs[2] = self._cache_pspecs
                out_specs = [PartitionSpec()] * n_out
                out_specs[1] = self._cache_pspecs
                compiled[key] = jax.jit(shard_map(
                    fn, mesh=self.mesh, in_specs=tuple(in_specs),
                    out_specs=tuple(out_specs), check_vma=False))
            return compiled[key](weights, *rest)
        return call

    def _weight_shardings(self, w):
        """NamedShardings placing a serving weight tree on the TP mesh —
        packed containers via ``packed_param_shardings`` (codes follow the
        dense weight's logical axes, scales the moved-last layout), dense
        bf16 trees via the plain logical-axis rules."""
        from repro.sharding.rules import param_shardings
        is_packed = lambda x: isinstance(x, (MXTensor, PackedInt4Leaf))
        if any(is_packed(l) for l in jax.tree_util.tree_leaves(
                w, is_leaf=is_packed)):
            return packed_param_shardings(w, self.api.param_axes(),
                                          self.mesh)
        return param_shardings(self.api.param_axes(), w, self.mesh)

    # ---- KV cache ---------------------------------------------------------
    def _init_cache(self, b):
        if self.kv_layout == "paged":
            return self.api.init_cache(b, self.max_len, kv_layout="paged",
                                       page_size=self.kv_page_size,
                                       num_pages=self.kv_num_pages)
        return self.api.init_cache(b, self.max_len)

    def _alloc_pages(self, free: List[int], n: int, why: str) -> List[int]:
        """Pop ``n`` physical pages off the free list, or die loudly.

        Exhaustion is an error, never a silent truncation — but since PR 7
        it is *contained*, not fatal: ``generate`` routes it through the
        per-request failure path (requeue-and-wait for admissions, largest-
        page-holder retirement with ``FAILED_CAPACITY`` for decode), so it
        escapes the engine only on an internal free-list invariant breach.
        The fault injector's ``fail_allocs`` hook raises ``InjectedFault``
        (a ``RuntimeError``) here so chaos rides the same handling paths.
        """
        self._alloc_calls += 1
        if self._fault_injector is not None:
            self._fault_injector.on_alloc(self._alloc_calls - 1)
        if len(free) < n:
            raise RuntimeError(
                f"KV page pool exhausted at {why}: need {n} page(s), "
                f"{len(free)} free (pool = {self._kv_total_pages} pages x "
                f"{self.kv_page_size} tokens, {self.slots} slots, "
                f"{self._kv_pages_hwm} pages high-water). Increase "
                "kv_num_pages, shrink batch_slots/max_len, or admit less.")
        got = [free.pop() for _ in range(n)]
        self._kv_pages_alloc += n
        in_use = self._kv_total_pages - 1 - len(free)
        self._kv_pages_hwm = max(self._kv_pages_hwm, in_use)
        return got

    # ---- weights ----------------------------------------------------------
    def _serves_packed(self, fmt_name: str) -> bool:
        return self.packed and fmt_name != DENSE_BF16

    def weights_for(self, fmt_name: str):
        """Serving weights at ``fmt_name`` (packed containers by default).

        Cache miss = one Slice-and-Scale pass from the anchor (+ nibble
        packing at 4 bits); hits are free.
        """
        if fmt_name not in self._weights:
            if self._serves_packed(fmt_name):
                w = make_packed_params(self.anchor, self._template,
                                       target_fmt=fmt_name,
                                       dtype=self.api.cfg.compute_dtype)
            else:
                w = self.dense_weights_for(fmt_name)
            if self.mesh is not None:
                shardings = self._weight_shardings(w)
                # split-N int4 nibbles interleave the output halves; a
                # column-sharded leaf must be repacked per shard first
                # (see repack_splitn_for_tp) or half the head / ff-block
                # contributions pair wrong inside shard_map.
                w = repack_splitn_for_tp(w, shardings, self._tp)
                w = jax.device_put(w, shardings)
            self._weights[fmt_name] = w
            self._fmt_swaps += 1
            if self.policy.cost is not None:
                # Replace the format's analytic weight term with the bytes
                # the cached tree actually streams (seed() keeps any
                # learned calibration factor). On a mesh both roofline
                # terms are PER-CHIP: each chip streams only its weight
                # shard and its slice of every KV token.
                wb = (weight_stream_bytes_local(w) if self.mesh is not None
                      else weight_stream_bytes(w))
                self.policy.cost.seed(
                    fmt_name, wb,
                    self._attn_read_span * self._attn_token_bytes_chip)
        return self._weights[fmt_name]

    def dense_weights_for(self, fmt_name: str):
        """Dense reference weights at ``fmt_name`` — numerically identical to
        the packed tree (same codes, dequantized eagerly). Not cached."""
        model = self.anchor
        if fmt_name not in (DENSE_BF16, self.anchor.fmt_name):
            model = convert(self.anchor,
                            get_format(fmt_name, self._block_size))
        return materialize(model, self._template,
                           dtype=self.api.cfg.compute_dtype)

    def set_format(self, fmt_name: str):
        self.current_fmt = fmt_name
        return self.weights_for(fmt_name)

    # ---- admission helpers ------------------------------------------------
    @property
    def prompt_capacity(self) -> int:
        """Longest admissible prompt: ``max_len - 1`` tokens.

        THE single home of this invariant (admission asserts against it,
        prompt bucketing clamps to it, retire-at-capacity compares
        ``slot_len`` to it, and the paged block table — sized from
        ``max_len`` — therefore always covers any bucketed length):
        the cache holds ``max_len`` positions and the first generated
        token's KV is written at position ``plen`` before any retire check
        runs, so one position past the prompt must always exist.
        """
        return self.max_len - 1

    def _prefill_batch(self, prompt: np.ndarray):
        """Tokens (+ true length when bucketing) for one admission."""
        plen = prompt.size
        if not self._bucket:
            return {"tokens": jnp.asarray(prompt[None])}
        blen = _bucket_len(plen, self.prompt_capacity)
        padded = np.zeros(blen, np.int32)
        padded[:plen] = prompt
        return {"tokens": jnp.asarray(padded[None]),
                "lengths": jnp.asarray([plen], jnp.int32)}

    # ---- failure domains (docs/serving_internals.md §7) --------------------
    def _finish(self, r: Request, status: RequestStatus,
                error: Optional[str] = None) -> None:
        """Terminal transition: exactly one per request. Non-COMPLETED
        terminals record their error in ``stats()["failures"]`` — the
        engine-side audit trail a caller reads after a chaotic wave."""
        r.status = status
        r.done = True
        if error is not None:
            r.error = error
        self._status_counts[status.value] = \
            self._status_counts.get(status.value, 0) + 1
        if status is not RequestStatus.COMPLETED:
            self._failures.append({"rid": r.rid, "status": status.value,
                                   "error": error})

    def _max_pages_needed(self, plen: int) -> int:
        """Peak page count one request's admission path will ever hold:
        pages covering the (bucket-padded) prompt plus the first decode
        write. Under chunked admission the peak is at the FINAL chunk
        (earlier chunks hold a prefix of it). The one home of the sizing
        arithmetic that ``_admission_reject`` checks against the whole
        pool."""
        ps = self.kv_page_size
        chunk = self.prefill_chunk
        if chunk is None:
            blen = _bucket_len(plen, self.prompt_capacity) if self._bucket \
                else plen
            return max(-(-blen // ps), plen // ps + 1)
        start = ((plen - 1) // chunk) * chunk        # final chunk's cursor
        take = plen - start
        padded = _bucket_len(take, chunk) if self._bucket else take
        end = min(start + padded, self.max_len)
        return max(-(-end // ps), plen // ps + 1)

    def _admission_reject(self, r: Request) -> Optional[str]:
        """Why this request can NEVER be served (None = admissible): a
        prompt past cache capacity, or (paged) a page demand beyond the
        whole pool even when empty — for those, requeue-and-wait could
        never succeed, so they fail fast instead of wedging the queue."""
        plen = int(np.asarray(r.prompt).size)
        if plen > self.prompt_capacity:
            return (f"prompt ({plen} tokens) exceeds capacity "
                    f"({self.prompt_capacity} = max_len - 1)")
        if self.kv_layout == "paged":
            need = self._max_pages_needed(plen)
            allocatable = self._kv_total_pages - 1    # page 0 is scratch
            if need > allocatable:
                return (f"prompt ({plen} tokens) needs {need} KV page(s) "
                        f"at its admission peak; the pool has only "
                        f"{allocatable} allocatable")
        return None

    def _pop_admissible(self, pending: List[Request],
                        tick: Optional[int] = None) -> Optional[Request]:
        """Next servable ARRIVED request off the queue. Unservable ones
        (``_admission_reject``) terminate FAILED_CAPACITY right here: a
        malformed request costs itself, never the engine or the queue
        behind it.

        ``tick`` gates arrivals (``Request.arrival_tick``; None = treat
        everything as arrived). Among arrived requests, ``admission_order``
        decides: "fifo" takes the earliest-queued; "slo" the best (tier
        rank, queue position) pair — latency-tier first, FIFO within a
        tier, so within-tier fairness is positional and starvation-free
        (a finite workload drains tier by tier).
        """
        while True:
            best_key, idx = None, None
            for j, r in enumerate(pending):
                if tick is not None and r.arrival_tick > tick:
                    continue
                key = (tier_rank(r.slo), j) \
                    if self.admission_order == "slo" else (0, j)
                if best_key is None or key < best_key:
                    best_key, idx = key, j
            if idx is None:
                return None
            r = pending.pop(idx)
            reason = self._admission_reject(r)
            if reason is None:
                if tick is not None:
                    r.admitted_tick = tick
                return r
            self._finish(r, RequestStatus.FAILED_CAPACITY, reason)

    @staticmethod
    def _capacity_victim(active: List[Optional[Request]],
                         bt: np.ndarray) -> Optional[int]:
        """Slot to retire when decode starves the pool with no admission
        to roll back: the largest page-holder (ties -> lowest slot), i.e.
        the retirement that frees the most pages for the survivors."""
        best, best_pages = None, 0
        for j, r in enumerate(active):
            if r is None:
                continue
            held = int((bt[j] != 0).sum())
            if held > best_pages:
                best, best_pages = j, held
        return best

    @staticmethod
    def _nan_pool_page(cache, page: int):
        """NaN-fill physical page ``page`` of every layer's K/V pool —
        injected persistent HBM corruption (chaos only). Unlike a logit
        poison, replays re-read the same poisoned page, so recovery must
        come from escalation/retirement of the rows mapping it; pages no
        row maps (scratch, never-allocated) are provably harmless, and a
        recycled page is fully overwritten by its next prefill."""
        blocks = [dict(blk, **{k: blk[k].at[:, page].set(jnp.nan)
                               for k in ("k_pages", "v_pages") if k in blk})
                  for blk in cache["blocks"]]
        return dict(cache, blocks=blocks)

    def _escalate_or_none(self, fmt: str, tick: int,
                          what: str) -> Optional[str]:
        """One rung toward the anchor (quarantining the rung that just
        misbehaved so later waves never pick it), or None at the anchor —
        the caller then retires the affected rows instead."""
        nxt = self.policy.escalate(fmt)
        if nxt is None:
            return None
        self.policy.quarantine(fmt)
        self._fmt_escalations += 1
        self._escalation_events.append(
            {"tick": tick, "from": fmt, "to": nxt, "at": what})
        self.set_format(nxt)
        return nxt

    def _guarded_prefill(self, attempt, pinned: str, tick: int, what: str):
        """Numeric guardrail around one admission executable (a monolithic
        prompt or a final chunk — the ones whose logits are consumed).
        Escalate-and-replay until finite or at the anchor; each attempt is
        a pure function of the pre-tick cache, so replays are safe.
        Returns ``(logits, cache, new_len, pinned, fail_reason, execs)``.
        """
        execs = 0
        while True:
            logits, cache2, new_len = attempt(pinned)
            execs += 1
            if not self.logit_guard or \
                    bool(np.asarray(self._finite_rows(logits))):
                return logits, cache2, new_len, pinned, None, execs
            self._faults_detected += 1
            nxt = self._escalate_or_none(pinned, tick, what)
            if nxt is None:
                return logits, cache2, new_len, pinned, (
                    f"non-finite prefill logits at the anchor rung "
                    f"({pinned}) during {what}"), execs
            pinned = nxt
            self._ticks_replayed += 1

    def _guarded_decode(self, attempt, pinned: str, consumed: List[int],
                        tick: int, finite_fn=None):
        """Run one decode/mixed/verify executable under the guardrail.

        Replay semantics (docs/serving_internals.md §7): every attempt is
        a pure function of the PRE-tick ``(cache, cache_len, tokens)`` —
        the caller commits sampling, cache_len advance, and token drain
        only after this returns, so per-slot RNG chains stay "seed + one
        advance per decode tick" and surviving streams are bit-identical
        across replays. KV writes are idempotent (positions >= cache_len
        are simply recomputed — a speculative VERIFY attempt likewise
        overwrites every draft-written position before attending, §9, so
        it replays safely too). An ``InjectedFault`` from the step retries
        at the SAME format (transient-crash model, bounded by
        ``max_step_retries``); non-finite logits in any *consumed* row
        escalate the format one rung and replay; at the anchor the dead
        rows are returned for per-row retirement. ``finite_fn`` overrides
        the per-row finiteness reduction (the verify step's (B, C, V)
        logits reduce the lane axis too).
        Returns ``(logits, cache, pinned, dead_rows, execs)``.
        """
        retries = 0
        execs = 0
        while True:
            try:
                logits, cache2 = attempt(pinned)
                execs += 1
            except InjectedFault:
                self._faults_detected += 1
                if retries >= self.max_step_retries:
                    raise
                retries += 1
                self._ticks_replayed += 1
                continue
            if not self.logit_guard or not consumed:
                return logits, cache2, pinned, [], execs
            finite = np.asarray((finite_fn or self._finite_rows)(logits))
            dead = [i for i in consumed if not finite[i]]
            if not dead:
                return logits, cache2, pinned, [], execs
            self._faults_detected += 1
            nxt = self._escalate_or_none(pinned, tick,
                                         f"decode tick {tick}")
            if nxt is None:
                return logits, cache2, pinned, dead, execs
            pinned = nxt
            self._ticks_replayed += 1

    # ---- serving loop -----------------------------------------------------
    def generate(self, requests: List[Request], greedy: bool = True,
                 fmt_override: Optional[str] = None, *,
                 guard=None, snapshot_dir: Optional[str] = None,
                 _state: Optional[dict] = None) -> List[Request]:
        """Serve requests to completion with slot-level continuous batching.

        Slot lifecycle (docs/serving_internals.md "Admission & scheduling"):
        free -> prefilling(cursor) -> decoding -> retired. With
        ``prefill_chunk`` set, at most ONE slot is mid-prefill at a time and
        each scheduler tick runs at most one prefill chunk before the
        batched decode step; ``tick_trace`` records the per-tick work so
        that bound is testable, and each ``Request.ttft_s`` is stamped when
        its first token is sampled.

        Fault isolation (docs/serving_internals.md §7): per-request faults
        (oversized prompt, deadline, cancellation, capacity starvation,
        row-confined NaN at the anchor rung) end that request in a terminal
        ``RequestStatus`` and the loop keeps serving; batch-wide numeric
        faults escalate the pinned format one ladder rung and replay the
        tick from pre-tick state. ``guard`` (a
        ``runtime.fault.PreemptionGuard``) is checked at every tick
        boundary: once triggered, the engine snapshots its host scheduler
        state to ``snapshot_dir`` (if given) and returns with the wave
        incomplete — ``resume(snapshot_dir)`` finishes it with bit-identical
        remaining streams. ``_state`` is the internal resume path; callers
        never pass it.
        """
        if self.speculative is not None and not greedy:
            raise ValueError(
                "speculative decoding is greedy-only: the acceptance rule "
                "compares greedy argmaxes token-for-token; build the "
                "engine without speculative= for sampled decoding")
        b = self.slots
        paged = self.kv_layout == "paged"
        chunk = self.prefill_chunk         # None => monolithic admission
        ps = self.kv_page_size
        fi = self._fault_injector
        if _state is None:
            pending = list(requests)
            active: List[Optional[Request]] = [None] * b
            slot_len = [0] * b             # host mirror of cache_len
            cache = self._init_cache(b)
            if self.mesh is not None:
                # Pools/dense KV shard over kv heads; block table replicated.
                cache = jax.device_put(cache, self._cache_shardings)
            cache_len = jnp.zeros((b,), jnp.int32)
            tokens = jnp.zeros((b, 1), jnp.int32)
            pinned: Optional[str] = None   # format for this batch's lifetime
            filling: Optional[Request] = None   # the (single) mid-prefill
            fill_slot, fill_cursor = -1, 0
            wait_pages = False  # requeued admission waits for a retire to
            #                     free pages before retrying (no hot loop)
            elapsed0 = 0.0
            tick_no = 0     # per-wave scheduler tick: keys the injector and
            #                 survives snapshot/resume (unlike self._ticks,
            #                 which counts only decode ticks, engine-wide)
            if paged:
                # host-side page bookkeeping: the block table mirror ships
                # to the device as a (tiny) step argument whenever it
                # changes; page 0 is reserved scratch, allocatable 1..P-1.
                free_pages = list(range(self._kv_total_pages - 1, 0, -1))
                bt = np.zeros((b, cache["block_table"].shape[1]), np.int32)
            else:
                free_pages, bt = [], None
        else:
            pending = _state["pending"]
            active = _state["active"]
            slot_len = _state["slot_len"]
            cache = _state["cache"]
            cache_len = _state["cache_len"]
            tokens = _state["tokens"]
            pinned = _state["pinned"]
            filling = _state["filling"]
            fill_slot = _state["fill_slot"]
            fill_cursor = _state["fill_cursor"]
            wait_pages = _state["wait_pages"]
            free_pages = _state["free_pages"]
            bt = _state["bt"]
            elapsed0 = _state["elapsed_s"]
            tick_no = _state["tick_no"]
        t0 = time.perf_counter() - elapsed0  # deadline clock spans resumes
        self.tick_trace = []

        def repin(new_fmt: str) -> str:
            # Escalation mid-wave: fmt_used stays exact for every request
            # whose remaining tokens now come from the escalated rung.
            for a in active:
                if a is not None:
                    a.fmt_used = new_fmt
            return new_fmt

        def release_slot(i: int) -> None:
            # Pages back to the free list + block-table row -> scratch.
            nonlocal wait_pages
            if paged:
                self._free_slot_pages(free_pages, bt, i)
                cache["block_table"] = jnp.asarray(bt)
            wait_pages = False     # freed pages: admission may retry

        def complete_admission(i: int, r: Request, logits) -> None:
            """prefilling -> decoding (or straight to retired): seed the
            slot's RNG stream, sample the first token from the prefill
            logits, stamp TTFT. Seeding happens HERE — at prefill
            completion, right before the first draw — so chunked admission
            (whose mid-prefill slots see decode ticks advance every slot
            key) samples the same stream as monolithic."""
            nonlocal tokens
            self._slot_keys = self._slot_keys.at[i].set(
                jax.random.fold_in(self._key, r.rid))
            # Per-request sampling params land with the RNG reseed — before
            # the first draw, so the whole stream (first token included)
            # uses them.
            self._slot_temp[i] = self.temperature \
                if r.temperature is None else r.temperature
            self._slot_topp[i] = self.top_p if r.top_p is None else r.top_p
            first = int(self._sample(logits[None], greedy, slot=i)[0])
            tokens = tokens.at[i, 0].set(first)
            r.fmt_used = pinned            # pinned for the whole sequence
            r.out_tokens.append(first)
            r.ttft_s = time.perf_counter() - t0
            self._tokens_out += 1
            if len(r.out_tokens) >= r.max_new:
                self._finish(r, RequestStatus.COMPLETED)  # max_new<=1
                release_slot(i)            # row -> scratch BEFORE any reuse
            else:
                r.status = RequestStatus.RUNNING
                active[i] = r

        while pending or filling is not None \
                or any(a is not None for a in active):
            t_tick = time.perf_counter()
            # ---- tick boundary: the atomic unit of fault handling. A
            # preemption raised mid-tick (real signal or injector) is acted
            # on HERE, with no executable in flight and host state
            # consistent — snapshot and hand the wave back to the caller.
            if guard is not None and guard.preempted:
                if snapshot_dir is not None:
                    self.last_snapshot = self._save_snapshot(
                        snapshot_dir, requests, dict(
                            pending=pending, active=active,
                            slot_len=slot_len, cache=cache,
                            cache_len=cache_len, tokens=tokens,
                            pinned=pinned, filling=filling,
                            fill_slot=fill_slot, fill_cursor=fill_cursor,
                            wait_pages=wait_pages, free_pages=free_pages,
                            bt=bt, elapsed_s=time.perf_counter() - t0,
                            tick_no=tick_no),
                        greedy, fmt_override)
                    self._snapshots_saved += 1
                return requests
            tick = tick_no
            tick_no += 1
            # ---- per-request sweeps: cancellation (client- or injector-
            # driven) and deadlines, across queued, mid-prefill, and
            # decoding requests alike. Each hit is one terminal status and
            # freed pages; nothing else in the batch is perturbed.
            if fi is not None:
                rid_cancel = fi.cancel_rid(tick)
                if rid_cancel is not None:
                    for r in pending + [a for a in active if a] + \
                            ([filling] if filling is not None else []):
                        if r.rid == rid_cancel:
                            r.cancel_requested = True
            now_elapsed = time.perf_counter() - t0

            def expired(r):
                if r.cancel_requested:
                    return RequestStatus.CANCELLED, "cancelled by client"
                if r.deadline_s is not None and now_elapsed > r.deadline_s:
                    return (RequestStatus.TIMED_OUT,
                            f"deadline {r.deadline_s:.3f}s exceeded "
                            f"({now_elapsed:.3f}s into the wave)")
                return None

            for r in list(pending):
                if r.arrival_s is None and r.arrival_tick <= tick:
                    r.arrival_s = now_elapsed   # came due this tick; SLO
                    #                             TTFT counts from here
                verdict = expired(r)
                if verdict is not None:
                    pending.remove(r)
                    self._finish(r, *verdict)
            if filling is not None:
                verdict = expired(filling)
                if verdict is not None:
                    release_slot(fill_slot)
                    self._finish(filling, *verdict)
                    filling = None
            for i, r in enumerate(active):
                if r is None:
                    continue
                verdict = expired(r)
                if verdict is not None:
                    active[i] = None
                    release_slot(i)
                    self._finish(r, *verdict)
            if not (pending or filling is not None
                    or any(a is not None for a in active)):
                break              # the sweep drained the wave
            # Injected pool corruption lands before any executable runs.
            if fi is not None and paged:
                page = fi.pool_poison_page(tick)
                if page is not None:
                    cache = self._nan_pool_page(cache, page)

            # ---- arrival gating: nothing live and every queued request
            # still in the future (Request.arrival_tick) makes this an
            # idle tick — record it and advance the clock so arrivals come
            # due (the workload generator schedules in scheduler ticks).
            if filling is None and not any(a is not None for a in active) \
                    and not any(r.arrival_tick <= tick for r in pending):
                pinned = None
                self._record_tick(0, 0, 0, time.perf_counter() - t_tick,
                                  execs=0, rows=0, decode_rows=0)
                continue

            if pinned is None:             # engine drained: re-pick format
                # Load counts ARRIVED queued requests AND their pending
                # prompt tokens, so a queue of long prompts downshifts
                # before the admissions start, not after (serve/policy.py).
                # With a cost model attached the wave's tightest TPOT
                # budget and expected decode occupancy drive the pick
                # instead (docs §10); fmt_override remains operator law.
                arrived = [r for r in pending if r.arrival_tick <= tick]
                pinned = self.policy.pick(
                    queue_depth=len(arrived), active=0,
                    prefill_tokens=sum(r.prompt.size for r in arrived),
                    tpot_budget_ms=self._tightest_tpot_ms(arrived),
                    decode_rows=max(1, min(b, len(arrived))),
                    override=fmt_override)
            self.set_format(pinned)
            tick_pf_tokens = 0
            tick_pf_chunks = 0
            tick_execs = 0                 # executables dispatched this tick
            tick_rows = 0                  # batch rows those executables ran
            chunk_tok = None               # staged chunk for the mixed tick

            if chunk is None:
                # ---- monolithic admission: one whole prompt per free slot,
                # active slots untouched (but stalled for the full prefill)
                for i in range(b):
                    if active[i] is not None or wait_pages:
                        continue
                    r = self._pop_admissible(pending, tick)
                    if r is None:
                        break
                    r.status = RequestStatus.RUNNING
                    prompt = np.asarray(r.prompt, np.int32)
                    pbatch = self._prefill_batch(prompt)
                    if paged:
                        # Pages to hold the (possibly bucket-padded) prompt
                        # AND the first decode write at position prompt.size.
                        blen = pbatch["tokens"].shape[1]
                        need = max(-(-blen // ps), prompt.size // ps + 1)
                        try:
                            got = self._alloc_pages(
                                free_pages, need,
                                f"admission of rid={r.rid}")
                        except RuntimeError as e:
                            # Admission never outranks running work: requeue
                            # and wait for a retire to free pages (the
                            # whole-pool check in _pop_admissible guarantees
                            # the wait can end). An injected failure just
                            # retries next tick; a real one with nothing
                            # running means the free list leaked — raise.
                            r.status = RequestStatus.QUEUED
                            pending.insert(0, r)
                            self._admission_requeues += 1
                            if isinstance(e, InjectedFault):
                                break
                            if not any(a is not None for a in active):
                                raise
                            wait_pages = True
                            break
                        bt[i, :need] = got
                        cache["block_table"] = jnp.asarray(bt)

                    def attempt(fmt, pb=pbatch, slot=i):
                        fn = self._packed_prefill_slot \
                            if self._serves_packed(fmt) \
                            else self._dense_prefill_slot
                        lg, c2, nl = fn(self.weights_for(fmt), pb, cache,
                                        slot)
                        if fi is not None:
                            lg = fi.maybe_poison_logits(tick, fmt, lg)
                        return lg, c2, nl

                    logits, cache, new_len, new_pinned, fail, execs = \
                        self._guarded_prefill(attempt, pinned, tick,
                                              f"prefill of rid={r.rid}")
                    if new_pinned != pinned:
                        pinned = repin(new_pinned)
                    tick_pf_tokens += pbatch["tokens"].shape[1]
                    tick_pf_chunks += 1
                    tick_execs += execs
                    tick_rows += execs
                    if fail is not None:
                        release_slot(i)
                        self._finish(r, RequestStatus.FAILED_NUMERIC, fail)
                        continue
                    cache_len = cache_len.at[i].set(new_len)
                    slot_len[i] = prompt.size
                    complete_admission(i, r, logits)
            else:
                # ---- chunked admission bookkeeping: claim the (single)
                # mid-prefill request and allocate THIS chunk's pages
                # (release-and-requeue on exhaustion). Whether the staged
                # chunk runs as its own executable or rides the decode batch
                # is the scheduler's call, below.
                if filling is None and not wait_pages and None in active:
                    cand = self._pop_admissible(pending, tick)
                    if cand is not None:
                        fill_slot = active.index(None)
                        filling, fill_cursor = cand, 0
                        filling.status = RequestStatus.RUNNING
                        # The mixed tick reads the fill row's cursor from
                        # cache_len; zero the stale value from the slot's
                        # previous occupant at claim time.
                        cache_len = cache_len.at[fill_slot].set(0)
                if filling is not None:
                    r, i = filling, fill_slot
                    prompt = np.asarray(r.prompt, np.int32)
                    plen = prompt.size
                    start = fill_cursor
                    take = min(chunk, plen - start)
                    final = start + take >= plen
                    padded = take if (final and not self._bucket) else \
                        (_bucket_len(take, chunk) if final else chunk)
                    padded = min(padded, self.max_len - start)
                    ok = True
                    if paged:
                        # This chunk's pages only — chunk N's pages are
                        # allocated at chunk N, never all upfront. The first
                        # decode write's page is the decode tick's job.
                        first_pg = start // ps
                        last_pg = -(-(start + padded) // ps)
                        try:
                            got = self._alloc_pages(
                                free_pages, last_pg - first_pg,
                                f"prefill chunk at {start} of rid={r.rid}")
                        except RuntimeError as e:
                            # Partial admission must not starve the pool:
                            # release the pages already held, requeue, and
                            # retry once a retire frees pages (injected
                            # failures retry next tick without waiting).
                            # With nothing running and a _pop_admissible-
                            # sized prompt, only a leaked free list gets
                            # here — re-raise.
                            self._free_slot_pages(free_pages, bt, i)
                            cache["block_table"] = jnp.asarray(bt)
                            r.status = RequestStatus.QUEUED
                            pending.insert(0, r)
                            filling = None
                            self._admission_requeues += 1
                            ok = False
                            if isinstance(e, InjectedFault):
                                pass       # transient: retry next tick
                            elif any(a is not None for a in active):
                                wait_pages = True
                            else:
                                raise
                        if ok:
                            bt[i, first_pg:last_pg] = got
                            cache["block_table"] = jnp.asarray(bt)
                    if ok:
                        ctoks = np.zeros(padded, np.int32)
                        ctoks[:take] = prompt[start:start + take]
                        chunk_tok = (start, take, padded, final)

                # A staged chunk runs as its own executable under the
                # sequential scheduler — and when no slot is decoding, where
                # the two schedulers coincide (one executable either way,
                # identical numerics).
                chunk_ran_alone = False
                if chunk_tok is not None and (
                        self.scheduler == "sequential"
                        or not any(a is not None for a in active)):
                    chunk_ran_alone = True
                    start, take, padded, final = chunk_tok
                    pbatch = {"tokens": jnp.asarray(ctoks[None]),
                              "lengths": jnp.asarray([plen], jnp.int32)}

                    def chunk_attempt(fmt, pb=pbatch, slot=i, st=start):
                        fn = self._packed_prefill_chunk \
                            if self._serves_packed(fmt) \
                            else self._dense_prefill_chunk
                        lg, c2, nl = fn(self.weights_for(fmt), pb, cache,
                                        slot, st)
                        if fi is not None:
                            # A non-final chunk's logits are never consumed,
                            # so a poison landing there is invisible — as a
                            # real corruption of unread outputs would be.
                            lg = fi.maybe_poison_logits(tick, fmt, lg)
                        return lg, c2, nl

                    if final:
                        # Only the final chunk's logits are consumed (they
                        # seed the first sampled token) — guard them.
                        (logits, cache, new_len, new_pinned, fail,
                         execs) = self._guarded_prefill(
                             chunk_attempt, pinned, tick,
                             f"final chunk of rid={r.rid}")
                        if new_pinned != pinned:
                            pinned = repin(new_pinned)
                    else:
                        logits, cache, new_len = chunk_attempt(pinned)
                        fail, execs = None, 1
                    tick_pf_tokens += padded
                    tick_pf_chunks += 1
                    tick_execs += execs
                    tick_rows += execs
                    if fail is not None:
                        release_slot(i)
                        self._finish(r, RequestStatus.FAILED_NUMERIC, fail)
                        filling = None
                    else:
                        cache_len = cache_len.at[i].set(new_len)
                        fill_cursor = start + take
                        if final:
                            slot_len[i] = plen
                            complete_admission(i, r, logits)
                            filling = None
                    chunk_tok = None

            # Injected preemption fires mid-tick; the guard's flag is acted
            # on at the NEXT tick boundary, exactly like a real signal.
            if fi is not None and guard is not None:
                fi.maybe_preempt(tick, guard)

            all_free = all(a is None for a in active)
            if all_free or (chunk is not None and chunk_ran_alone
                            and self.scheduler == "mixed"):
                # No decode this tick. Under the mixed scheduler a chunk
                # that ran alone ends the tick even when it just completed
                # admission — the new slot's first decode is next tick's
                # (one) executable, never a second one on this tick. The
                # slot's stream is unchanged: its key advances once per
                # decode tick it sits in, wherever that tick falls.
                self._record_tick(tick_pf_tokens, tick_pf_chunks, 0,
                                  time.perf_counter() - t_tick,
                                  execs=tick_execs, rows=tick_rows,
                                  decode_rows=0)
                if all_free and filling is None:
                    pinned = None          # drained; next wave re-picks
                continue

            # ---- decode tick: fused step over all slots; free and
            # mid-prefill slots are masked (their cache_len doesn't advance
            # and their sampled tokens are dropped)
            if paged:
                # Map the page each active slot's write position lands in
                # BEFORE the step runs — this is where the pool grows (and
                # where exhaustion surfaces, contained, mid-stream).
                dirty = False
                for i in range(b):
                    r = active[i]
                    if r is None:
                        continue
                    pg = slot_len[i] // ps
                    while active[i] is not None and bt[i, pg] == 0:
                        try:
                            got = self._alloc_pages(
                                free_pages, 1,
                                f"decode tick for rid={r.rid}")
                            bt[i, pg] = got[0]
                            dirty = True
                        except RuntimeError as e:
                            dirty = True
                            if filling is not None:
                                # A decoding slot outranks a partial
                                # admission: release the mid-prefill slot's
                                # pages (this tick's staged chunk included),
                                # requeue it, and retry. Restarting the
                                # admission from chunk 0 later cannot
                                # perturb its stream (the slot RNG seeds at
                                # prefill completion).
                                self._free_slot_pages(free_pages, bt,
                                                      fill_slot)
                                filling.status = RequestStatus.QUEUED
                                pending.insert(0, filling)
                                filling = None
                                chunk_tok = None
                                self._admission_requeues += 1
                                wait_pages = True
                                continue
                            # No admission to roll back: the largest page-
                            # holder retires FAILED_CAPACITY and the engine
                            # keeps serving the rest — the pre-PR 7
                            # behavior (raise) destroyed every in-flight
                            # stream. The victim may be this very slot.
                            victim = self._capacity_victim(active, bt)
                            if victim is None:
                                raise      # free-list invariant breach
                            vr = active[victim]
                            held = int((bt[victim] != 0).sum())
                            active[victim] = None
                            self._free_slot_pages(free_pages, bt, victim)
                            wait_pages = False
                            self._finish(
                                vr, RequestStatus.FAILED_CAPACITY,
                                f"KV pool exhausted at decode; retired as "
                                f"largest page-holder ({held} page(s)) "
                                f"after {len(vr.out_tokens)} token(s): {e}")
                if dirty:
                    cache["block_table"] = jnp.asarray(bt)
            if chunk_tok is None and all(a is None for a in active):
                # Victim retirement emptied the batch; nothing left to run
                # this tick. Survivors-to-be (queued work) admit next tick.
                self._record_tick(tick_pf_tokens, tick_pf_chunks, 0,
                                  time.perf_counter() - t_tick,
                                  execs=tick_execs, rows=tick_rows,
                                  decode_rows=0)
                if filling is None:
                    pinned = None
                continue

            mask = np.asarray([a is not None for a in active], np.int32)
            # Rows whose logits this tick actually consumes — the guard
            # checks exactly these (free/masked rows may hold garbage).
            consumed = [i for i in range(b) if active[i] is not None]
            if chunk_tok is not None and chunk_tok[3] \
                    and filling is not None:
                consumed.append(fill_slot)

            # ---- speculative decode tick (docs/serving_internals.md §9):
            # k draft steps at the cheap rung against a LOCAL cursor, one
            # batched pinned-format verify over the k+1 positions, commit
            # the longest greedy-matching prefix + bonus token per slot,
            # rewind the rest. Only on pure-decode ticks (no staged chunk),
            # and only while the policy says drafting pays for itself.
            sc = self.speculative
            spec_now = sc is not None and chunk_tok is None and bool(consumed)
            if spec_now:
                tot = self._spec_accepted + self._spec_rejected
                rate = (self._spec_accepted / tot
                        if self._spec_ticks >= sc.window and tot else None)
                spec_now = self.policy.allow_speculation(
                    sc.draft_fmt, pinned, rate, sc.min_acceptance)
            if spec_now:
                # Burst length this tick: never write past the cache (the
                # verify write frontier is slot_len + k_eff <= max_len - 1)
                # and never draft deeper than the hungriest slot can still
                # commit (budget - 1 drafts + the bonus token).
                buds = {i: min(active[i].max_new
                               - len(active[i].out_tokens),
                               self.prompt_capacity - slot_len[i])
                        for i in consumed}
                k_eff = min(sc.k,
                            self.max_len - 1
                            - max(slot_len[i] for i in consumed),
                            max(buds.values()) - 1)
                spec_now = k_eff >= 1
            if spec_now and paged:
                # Draft-ahead pages covering positions slot_len..slot_len +
                # k_eff per slot, ON TOP of the plain-decode page the loop
                # above already mapped. Speculation never outranks anything:
                # starvation hands the pages back and runs a plain tick.
                spec_extra = []
                try:
                    for i in consumed:
                        base_pg = slot_len[i] // ps
                        for pg in range(base_pg + 1,
                                        (slot_len[i] + k_eff) // ps + 1):
                            if bt[i, pg] == 0:
                                bt[i, pg] = self._alloc_pages(
                                    free_pages, 1,
                                    f"spec draft-ahead for "
                                    f"rid={active[i].rid}")[0]
                                spec_extra.append((i, pg))
                except RuntimeError:
                    for i, pg in spec_extra:
                        free_pages.append(int(bt[i, pg]))
                        bt[i, pg] = 0
                        self._kv_pages_freed += 1
                    spec_extra = []
                    self._spec_aborts += 1
                    spec_now = False
                if spec_extra:
                    cache["block_table"] = jnp.asarray(bt)
            if spec_now:
                # ---- draft phase: k_eff greedy serve_steps at draft_fmt.
                # The committed (cache_len, tokens) never advance — local
                # copies do — so abandoning the burst at any point needs no
                # undo: draft KV sits past every committed cursor, masked,
                # and the next write there overwrites it.
                adv = jnp.asarray(mask)
                loc_len, loc_tok = cache_len, tokens
                drafts = np.zeros((b, k_eff), np.int64)
                draft_execs = 0
                draft_ok = True
                for j in range(k_eff):
                    try:
                        if fi is not None:
                            fi.maybe_raise_step(tick)
                        fn = self._packed_step \
                            if self._serves_packed(sc.draft_fmt) \
                            else self._dense_step
                        lg, cache = fn(self.weights_for(sc.draft_fmt),
                                       {"tokens": loc_tok}, cache, loc_len)
                        if fi is not None:
                            lg = fi.maybe_poison_logits(tick, sc.draft_fmt,
                                                        lg)
                    except InjectedFault:
                        # Transient crash mid-burst: drop the burst, decode
                        # plain this tick (the injector fires once per tick,
                        # so the plain attempt below runs clean).
                        self._faults_detected += 1
                        draft_ok = False
                        break
                    draft_execs += 1
                    if self.logit_guard:
                        finite = np.asarray(self._finite_rows(lg))
                        if not all(finite[i] for i in consumed):
                            # The draft rung itself is sick: quarantine it
                            # (allow_speculation then vetoes the rest of
                            # the wave — plain anchor-side decode from here
                            # on) and abandon the burst. Nothing was
                            # committed, so there is nothing to double-emit.
                            self._faults_detected += 1
                            self.policy.quarantine(sc.draft_fmt)
                            draft_ok = False
                            break
                    d = jnp.argmax(lg, -1)
                    drafts[:, j] = np.asarray(d)
                    loc_tok = d[:, None].astype(jnp.int32)
                    loc_len = loc_len + adv
                if not draft_ok:
                    self._spec_aborts += 1
                    spec_now = False
            if spec_now:
                # ---- verify phase: ONE pinned-format executable scores
                # [last committed token, d_1..d_k] per slot (q_len = k+1;
                # masked rows ride at q_len 1 exactly as in a mixed tick).
                # It writes pinned-format K/V over every draft-written
                # position BEFORE attending, so each attempt is a pure
                # function of committed state — _guarded_decode's
                # escalate-and-replay applies unchanged, and the drafts are
                # never re-run on a replay.
                cdim = k_eff + 1
                tok2d = jnp.zeros((b, cdim), jnp.int32) \
                    .at[:, 0].set(tokens[:, 0]) \
                    .at[:, 1:].set(jnp.asarray(drafts, jnp.int32))
                q_np = np.ones(b, np.int32)
                q_np[mask.astype(bool)] = cdim
                batch_v = {"tokens": tok2d, "q_len": jnp.asarray(q_np)}

                def vattempt(fmt, bv=batch_v):
                    if fi is not None:
                        fi.maybe_raise_step(tick)
                    fn = self._packed_verify if self._serves_packed(fmt) \
                        else self._dense_verify
                    lg, c2 = fn(self.weights_for(fmt), bv, cache, cache_len)
                    if fi is not None:
                        lg = fi.maybe_poison_logits(tick, fmt, lg)
                    return lg, c2

                logits3, cache, new_pinned, dead, vexecs = \
                    self._guarded_decode(vattempt, pinned, consumed, tick,
                                         finite_fn=self._finite_rows_mq)
                if new_pinned != pinned:
                    pinned = repin(new_pinned)
                tick_execs += draft_execs + vexecs
                tick_rows += b * (draft_execs + vexecs)

                # ---- accept/commit: every committed token is the VERIFY
                # format's own argmax (accepted drafts equal it by
                # definition), which is the whole bit-identity guarantee.
                anchor_toks = np.asarray(jnp.argmax(logits3, -1))  # (b, C)
                budgets = np.zeros(b, np.int64)
                for i in consumed:
                    if i not in dead:
                        budgets[i] = buds[i]
                commit = spec_accept_counts(drafts, anchor_toks, budgets)
                cache_len = cache_len + jnp.asarray(commit, jnp.int32) \
                    * jnp.asarray(mask)
                nxt_np = np.array([anchor_toks[i, max(int(commit[i]) - 1, 0)]
                                   for i in range(b)], np.int64)
                tokens = jnp.asarray(nxt_np, jnp.int32)[:, None]
                self._ticks += 1
                self._spec_ticks += 1
                for i in consumed:
                    if i not in dead:
                        acc = int(commit[i]) - 1
                        self._spec_accepted += acc
                        self._spec_rejected += k_eff - acc

                # Attention-read accounting: k_eff single-query walks at a
                # growing cursor plus vexecs multi-query walks per live
                # slot (mirrors the plain tick's arithmetic below).
                window = self.api.cfg.sliding_window
                for i in range(b):
                    if not (paged and self.attn_impl == "paged_kernel"):
                        self._attn_tokens_read += \
                            self._attn_read_span * (draft_execs + vexecs)
                    elif active[i] is not None:
                        for j in range(draft_execs):
                            self._attn_tokens_read += pages_read(
                                slot_len[i] + 1 + j, ps, window) * ps
                        self._attn_tokens_read += vexecs * pages_read_mq(
                            slot_len[i], cdim, ps, window) * ps
                    elif filling is not None and i == fill_slot:
                        self._attn_tokens_read += \
                            (draft_execs + vexecs) * pages_read(
                                fill_cursor + 1, ps, window) * ps
                    else:
                        self._attn_tokens_read += \
                            (draft_execs + vexecs) * ps

                # Dead rows (non-finite verify logits at the anchor rung):
                # retire before the drain, exactly like a plain tick — no
                # draft of theirs was committed (budget forced to 0).
                for i in dead:
                    r_dead = active[i]
                    if r_dead is None:
                        continue
                    active[i] = None
                    release_slot(i)
                    self._finish(
                        r_dead, RequestStatus.FAILED_NUMERIC,
                        f"non-finite logits in this request's row at the "
                        f"anchor rung ({pinned}), verify tick {tick}")

                # ---- drain + rewind: commit[i] tokens enter the stream;
                # pages past the new frontier go straight back to the free
                # list (the KV "rollback" is just these two lines — no data
                # moves, stale positions are masked by cache_len).
                for i, r in enumerate(active):
                    if r is None:
                        continue
                    n_c = int(commit[i])
                    slot_len[i] += n_c
                    r.out_tokens.extend(int(t)
                                        for t in anchor_toks[i, :n_c])
                    self._tokens_out += n_c
                    if paged:
                        self._rollback_slot_pages(free_pages, bt, i,
                                                  slot_len[i])
                    if len(r.out_tokens) >= r.max_new or \
                            slot_len[i] >= self.prompt_capacity:
                        self._finish(r, RequestStatus.COMPLETED)
                        active[i] = None
                        release_slot(i)
                if paged:
                    cache["block_table"] = jnp.asarray(bt)
                self._record_tick(tick_pf_tokens, tick_pf_chunks, 1,
                                  time.perf_counter() - t_tick,
                                  execs=tick_execs, rows=tick_rows,
                                  decode_rows=int(mask.sum()),
                                  draft_execs=draft_execs,
                                  verify_execs=vexecs)
                if all(a is None for a in active) and filling is None:
                    pinned = None
                continue

            if chunk_tok is not None:
                # ---- mixed tick: the staged chunk rides the decode batch as
                # ONE executable. Decode rows keep their 1-token budget in
                # column 0; the fill row carries the whole chunk at its
                # cursor. Free rows stay masked exactly as under serve_step
                # (q_len=1, cursor frozen, scratch-page writes).
                start, take, padded, final = chunk_tok
                tok2d = jnp.zeros((b, padded), jnp.int32) \
                    .at[:, 0].set(tokens[:, 0]) \
                    .at[fill_slot].set(jnp.asarray(ctoks))
                q_len_np = np.ones(b, np.int32)
                q_len_np[fill_slot] = take
                batch_mx = {"tokens": tok2d, "q_len": jnp.asarray(q_len_np)}

                def attempt(fmt, bm=batch_mx):
                    if fi is not None:
                        fi.maybe_raise_step(tick)
                    fn = self._packed_mixed if self._serves_packed(fmt) \
                        else self._dense_mixed
                    lg, c2 = fn(self.weights_for(fmt), bm, cache, cache_len)
                    if fi is not None:
                        lg = fi.maybe_poison_logits(tick, fmt, lg)
                    return lg, c2
            else:
                def attempt(fmt):
                    if fi is not None:
                        fi.maybe_raise_step(tick)
                    fn = self._packed_step if self._serves_packed(fmt) \
                        else self._dense_step
                    lg, c2 = fn(self.weights_for(fmt), {"tokens": tokens},
                                cache, cache_len)
                    if fi is not None:
                        lg = fi.maybe_poison_logits(tick, fmt, lg)
                    return lg, c2

            # Escalate-and-replay runs HERE, against pre-tick state; the
            # commits below (cache_len advance, batched draw, token drain)
            # happen exactly once, after the guard settles.
            logits, cache, new_pinned, dead, execs = self._guarded_decode(
                attempt, pinned, consumed, tick)
            if new_pinned != pinned:
                pinned = repin(new_pinned)
            tick_execs += execs
            tick_rows += b * execs
            if chunk_tok is not None:
                adv = mask.copy()
                adv[fill_slot] = take
                cache_len = cache_len + jnp.asarray(adv)
                tick_pf_tokens += padded
                tick_pf_chunks += 1
            else:
                cache_len = cache_len + jnp.asarray(mask)
            # The batched draw advances EVERY slot key once per decode-
            # carrying tick — the fill row's draw is discarded, and if its
            # chunk completed this tick, complete_admission reseeds the key
            # from scratch below, so the stream matches sequential admission
            # bit for bit.
            nxt = self._sample(logits, greedy)
            tokens = nxt[:, None].astype(jnp.int32)
            self._ticks += 1
            attn_before = self._attn_tokens_read

            # Attention-read accounting for the tick that just ran. Every
            # batch row is processed (free/mid-prefill slots are masked, not
            # removed): gather (and the dense layout) materializes the full
            # logical span for ALL rows; the kernel walks pages_read(...)
            # distinct pages (kernels/paged_attention.py — the one home of
            # that clamp arithmetic) for rows with mapped pages — decoding
            # slots at slot_len+1, the mid-prefill slot at its cursor+1 —
            # and a single clamped-revisit scratch page for zeroed rows
            # (every walk step maps to page 0, so Pallas elides the repeats).
            window = self.api.cfg.sliding_window
            for i in range(b):
                if not (paged and self.attn_impl == "paged_kernel"):
                    self._attn_tokens_read += self._attn_read_span
                elif active[i] is not None:
                    self._attn_tokens_read += \
                        pages_read(slot_len[i] + 1, ps, window) * ps
                elif chunk_tok is not None and i == fill_slot:
                    # Mixed tick: the fill row's ragged query span walks its
                    # own clamped page range (pages_read_mq mirrors the MQ
                    # kernel's arithmetic the way pages_read mirrors the
                    # single-query kernel's).
                    self._attn_tokens_read += \
                        pages_read_mq(start, take, ps, window) * ps
                elif filling is not None and i == fill_slot:
                    self._attn_tokens_read += \
                        pages_read(fill_cursor + 1, ps, window) * ps
                else:
                    self._attn_tokens_read += ps

            # ---- dead rows (non-finite logits at the anchor rung): the
            # fault is confined to these requests — retire them BEFORE the
            # drain so no poisoned token ever enters a stream; every other
            # slot's draw this tick is untouched.
            for i in dead:
                if filling is not None and i == fill_slot:
                    release_slot(i)
                    self._finish(
                        filling, RequestStatus.FAILED_NUMERIC,
                        f"non-finite final-chunk logits in this request's "
                        f"row at the anchor rung ({pinned}), tick {tick}")
                    filling = None
                    continue
                r_dead = active[i]
                if r_dead is None:
                    continue
                active[i] = None
                release_slot(i)
                self._finish(
                    r_dead, RequestStatus.FAILED_NUMERIC,
                    f"non-finite logits in this request's row at the "
                    f"anchor rung ({pinned}), tick {tick}")

            # ---- retire: ONE host transfer per tick drains every slot
            drained = np.asarray(nxt)
            for i, r in enumerate(active):
                if r is None:
                    continue
                slot_len[i] += 1
                r.out_tokens.append(int(drained[i]))
                self._tokens_out += 1
                if len(r.out_tokens) >= r.max_new or \
                        slot_len[i] >= self.prompt_capacity:
                    self._finish(r, RequestStatus.COMPLETED)
                    active[i] = None       # slot re-admissible next tick
                    release_slot(i)        # pages recycle on the next admit
            if chunk_tok is not None:
                # ---- mixed-tick chunk epilogue: advance the cursor, and if
                # the chunk reached the prompt end, complete admission from
                # the fill row's logits — AFTER the batched draw above, so
                # the reseed overwrites the discarded draw's key advance.
                # (A dead fill row already retired FAILED_NUMERIC above.)
                fill_cursor = start + take
                if final and filling is not None:
                    slot_len[fill_slot] = plen
                    complete_admission(fill_slot, filling, logits[fill_slot])
                    filling = None
            # ---- cost-model calibration: only CLEAN pure-decode ticks
            # (no prefill work, exactly one executable — no replays) are
            # attributable to the pinned format's per-tick cost; the
            # measured attention read refreshes the per-row byte term.
            cost = self.policy.cost
            rows_d = int(mask.sum())
            if cost is not None and rows_d and tick_pf_chunks == 0 \
                    and tick_execs == 1:
                seen = self._fmt_decode_ticks.get(pinned, 0)
                self._fmt_decode_ticks[pinned] = seen + 1
                if seen:   # a format's first clean tick pays jit compile —
                    #        warmup, not cost; never fold it into the model
                    cost.observe(
                        pinned, rows_d, time.perf_counter() - t_tick,
                        attn_bytes_per_row=(self._attn_tokens_read
                                            - attn_before)
                        * self._attn_token_bytes / rows_d)
            self._record_tick(tick_pf_tokens, tick_pf_chunks, 1,
                              time.perf_counter() - t_tick,
                              execs=tick_execs, rows=tick_rows,
                              decode_rows=rows_d)
            if all(a is None for a in active) and filling is None:
                pinned = None
        return requests

    @staticmethod
    def _tightest_tpot_ms(reqs: List[Request]) -> Optional[float]:
        """The wave's binding per-token budget: the minimum ``tpot_ms``
        among requests that carry one (None when nobody does — the policy
        then falls back to its threshold table)."""
        vals = [r.slo.tpot_ms for r in reqs
                if r.slo is not None and r.slo.tpot_ms is not None]
        return min(vals) if vals else None

    def _record_tick(self, prefill_tokens: int, prefill_chunks: int,
                     decode: int, wall_s: float, *, execs: int = 0,
                     rows: int = 0, decode_rows: int = 0,
                     draft_execs: int = 0, verify_execs: int = 0) -> None:
        """Append one scheduler-tick trace entry (reset per ``generate``).

        ``prefill_tokens`` counts padded prompt tokens prefilled this tick
        (one chunk at most under chunked admission; whole prompts under
        monolithic), ``decode`` is 1 when a batched decode step ran.
        ``execs`` counts device executables dispatched this tick — the
        mixed scheduler's invariant, exactly one per work tick, is asserted
        from it in tests (monolithic admission may run several: one prefill
        per admitted slot plus the decode step). ``rows`` counts batch rows
        those executables processed and ``decode_rows`` the subset that were
        live decoding slots; ``benchmarks/serve_engine_bench.py`` derives
        its decode-occupancy and decode-stall columns from these plus
        ``wall_s``. ``draft_execs``/``verify_execs`` split ``execs`` on a
        speculative tick (both 0 otherwise), so the execs-per-tick
        invariants stay assertable under speculation: a non-spec tick's
        plain executables are exactly
        ``execs - draft_execs - verify_execs``.
        """
        self.tick_trace.append({"prefill_tokens": prefill_tokens,
                                "prefill_chunks": prefill_chunks,
                                "decode": decode, "wall_s": wall_s,
                                "execs": execs, "rows": rows,
                                "decode_rows": decode_rows,
                                "draft_execs": draft_execs,
                                "verify_execs": verify_execs})

    def _free_slot_pages(self, free_pages: List[int], bt: np.ndarray,
                         slot: int) -> None:
        """Return a retired slot's pages to the free list and point its
        block-table row at the scratch page (0) so any further masked write
        from the still-batched slot lands there, never on a recycled page."""
        used = bt[slot][bt[slot] != 0]
        free_pages.extend(int(p) for p in used)
        self._kv_pages_freed += used.size
        bt[slot, :] = 0

    def _rollback_slot_pages(self, free_pages: List[int], bt: np.ndarray,
                             slot: int, frontier: int) -> None:
        """Speculative rewind, page half: free this slot's pages strictly
        past the one holding position ``frontier - 1`` (the last committed
        token after acceptance). Earlier pages — and every other slot's
        block-table row — are untouched; the freed pages' stale draft KV
        is unreachable (masked by ``cache_len`` until recycled, then
        overwritten by the next occupant's writes before any read). This
        restores the plain-decode steady-state invariant exactly: a slot
        holds ``ceil(slot_len / page)`` pages between ticks, so
        ``alloc == freed`` at retire regardless of accept/reject history.
        """
        keep = -(-frontier // self.kv_page_size)
        tail = bt[slot, keep:]
        drop = tail[tail != 0]
        free_pages.extend(int(p) for p in drop)
        self._kv_pages_freed += drop.size
        bt[slot, keep:] = 0

    def _sample(self, logits, greedy: bool, slot: Optional[int] = None):
        """Greedy argmax, or a temperature/top-p draw from per-slot streams.

        ``slot=None`` advances every slot's key by one draw (the decode
        tick); a slot index draws for that slot only (admission). Free
        slots' draws are discarded by the caller; advancing their keys is
        harmless and keeps the tick one fused vmap.
        """
        if greedy or self.temperature <= 0:
            return jnp.argmax(logits, -1)
        temps = jnp.asarray(self._slot_temp)
        tops = jnp.asarray(self._slot_topp)
        if slot is None:
            self._slot_keys, toks = _sample_batch(
                self._slot_keys, logits, temps, tops)
            return toks
        new_key, toks = _sample_batch(
            self._slot_keys[slot][None], logits, temps[slot][None],
            tops[slot][None])
        self._slot_keys = self._slot_keys.at[slot].set(new_key[0])
        return toks

    # ---- snapshot / resume (docs/serving_internals.md §7) ------------------
    @staticmethod
    def _encode_leaf(x) -> np.ndarray:
        """``np.savez`` degrades ml_dtypes leaves (bfloat16) to opaque void
        bytes; widen them to float32 (exact — every bf16 is an f32) for the
        archive. ``resume`` casts each leaf back through the cache
        template's dtype, so the round trip is bit-faithful."""
        a = np.asarray(x)
        if a.dtype.kind not in "iufb" or a.dtype == np.dtype(jnp.bfloat16):
            a = a.astype(np.float32)
        return a

    def _snapshot_fingerprint(self) -> dict:
        """The engine-config facts a snapshot's cache arrays and scheduler
        state are only meaningful under. ``resume`` refuses a snapshot whose
        fingerprint differs — silently resuming onto a different layout
        would corrupt streams, not fail loudly."""
        return {
            "family": self.api.cfg.family,
            "slots": self.slots,
            "max_len": self.max_len,
            "kv_layout": self.kv_layout,
            "kv_page_size": self.kv_page_size,
            "kv_total_pages": self._kv_total_pages,
            "attn_impl": self.attn_impl,
            "fused": bool(self.fused),
            "packed": self.packed,
            "prefill_chunk": self.prefill_chunk,
            "scheduler": self.scheduler,
            "bucket": self._bucket,
            "temperature": self.temperature,
            "top_p": self.top_p,
            "admission_order": self.admission_order,
            # string-encoded so the JSON manifest round-trips exactly
            "speculative": (f"{self.speculative.draft_fmt}:k"
                            f"{self.speculative.k}"
                            if self.speculative is not None else None),
            # "DxM" mesh shape (None = single device): a snapshot taken on
            # a mesh holds sharded-layout state and must resume on the
            # same mesh shape.
            "mesh": self._mesh_str(),
        }

    def _mesh_str(self) -> Optional[str]:
        if self.mesh is None:
            return None
        n_dev = int(np.prod(self.mesh.devices.shape))
        return f"{n_dev // self._tp}x{self._tp}"

    def _save_snapshot(self, root: str, requests: List[Request], st: dict,
                       greedy: bool, fmt_override: Optional[str]) -> str:
        """Serialize the wave's complete scheduler state at a tick boundary
        via ``checkpoint.io.save_flat`` (atomic, manifest-driven). Arrays:
        the KV cache's flattened leaves, cache_len/tokens, the RNG keys, the
        block-table mirror, and each request's prompt + emitted tokens;
        everything host-structural (queues, cursors, counters, statuses)
        rides the manifest. ``resume`` reconstructs from these alone, so a
        FRESH engine process (same config) can finish the wave."""
        arrays: Dict[str, np.ndarray] = {}
        leaves, _ = jax.tree_util.tree_flatten(st["cache"])
        for n, leaf in enumerate(leaves):
            arrays[f"cache_{n:04d}"] = self._encode_leaf(leaf)
        arrays["cache_len"] = np.asarray(st["cache_len"])
        arrays["tokens"] = np.asarray(st["tokens"])
        arrays["slot_keys"] = np.asarray(self._slot_keys)
        arrays["engine_key"] = np.asarray(self._key)
        arrays["slot_temp"] = self._slot_temp.copy()
        arrays["slot_topp"] = self._slot_topp.copy()
        if st["bt"] is not None:
            arrays["bt"] = np.asarray(st["bt"])
        for r in requests:
            arrays[f"prompt_{r.rid}"] = np.asarray(r.prompt, np.int32)
            # int64 + explicit dtype: an empty out_tokens list must not
            # round-trip as float64.
            arrays[f"out_{r.rid}"] = np.asarray(r.out_tokens, np.int64)
        meta = {
            "kind": "elastic-engine-snapshot",
            "fingerprint": self._snapshot_fingerprint(),
            "greedy": bool(greedy),
            "fmt_override": fmt_override,
            "pinned": st["pinned"],
            "elapsed_s": float(st["elapsed_s"]),
            "tick_no": int(st["tick_no"]),
            "requests": [{"rid": r.rid, "max_new": int(r.max_new),
                          "status": r.status.value, "error": r.error,
                          "fmt_used": r.fmt_used, "ttft_s": r.ttft_s,
                          "deadline_s": r.deadline_s, "done": bool(r.done),
                          "cancel_requested": bool(r.cancel_requested),
                          "slo": (r.slo.to_dict() if r.slo is not None
                                  else None),
                          "tenant": r.tenant,
                          "arrival_tick": int(r.arrival_tick),
                          "arrival_s": r.arrival_s,
                          "admitted_tick": r.admitted_tick,
                          "temperature": r.temperature,
                          "top_p": r.top_p}
                         for r in requests],
            "pending": [r.rid for r in st["pending"]],
            "active": [(a.rid if a is not None else None)
                       for a in st["active"]],
            "slot_len": [int(v) for v in st["slot_len"]],
            "filling": (st["filling"].rid if st["filling"] is not None
                        else None),
            "fill_slot": int(st["fill_slot"]),
            "fill_cursor": int(st["fill_cursor"]),
            "wait_pages": bool(st["wait_pages"]),
            "free_pages": [int(p) for p in st["free_pages"]],
            "quarantined": sorted(self.policy.quarantined),
            "counters": {
                "ticks": self._ticks,
                "tokens_out": self._tokens_out,
                "kv_pages_alloc": self._kv_pages_alloc,
                "kv_pages_freed": self._kv_pages_freed,
                "kv_pages_hwm": self._kv_pages_hwm,
                "faults_detected": self._faults_detected,
                "fmt_escalations": self._fmt_escalations,
                "ticks_replayed": self._ticks_replayed,
                "admission_requeues": self._admission_requeues,
                "attn_tokens_read": self._attn_tokens_read,
                "spec_ticks": self._spec_ticks,
                "spec_accepted": self._spec_accepted,
                "spec_rejected": self._spec_rejected,
                "spec_aborts": self._spec_aborts,
                "status_counts": self._status_counts,
                "failures": self._failures,
                "escalation_events": self._escalation_events,
            },
        }
        self._snap_step += 1
        return ckpt_io.save_flat(root, self._snap_step, arrays,
                                 extra_meta=meta)

    def resume(self, snapshot_dir: str, *, guard=None,
               step: Optional[int] = None) -> List[Request]:
        """Finish a preempted wave from its snapshot (LATEST by default).

        Reconstructs the Request objects, scheduler queues, KV cache, and
        RNG streams saved by ``_save_snapshot`` and re-enters ``generate``
        mid-wave; remaining token streams are bit-identical to the
        uninterrupted run (each slot key advanced once per decode tick it
        actually sat in, on either side of the cut). The engine must be
        configured identically to the one that snapshotted — a fingerprint
        mismatch raises ``ValueError`` rather than corrupting streams.
        Returns the reconstructed (completed) request list."""
        arrays, manifest = ckpt_io.restore_flat(snapshot_dir, step)
        meta = manifest["meta"]
        if meta.get("kind") != "elastic-engine-snapshot":
            raise ValueError(
                f"{snapshot_dir} holds {meta.get('kind')!r}, not an "
                "elastic-engine-snapshot")
        fp_saved = meta["fingerprint"]
        fp_now = self._snapshot_fingerprint()
        if fp_saved != fp_now:
            diff = {k: {"snapshot": fp_saved.get(k), "engine": fp_now.get(k)}
                    for k in sorted(set(fp_saved) | set(fp_now))
                    if fp_saved.get(k) != fp_now.get(k)}
            raise ValueError(
                "snapshot/engine fingerprint mismatch — resume requires an "
                f"identically configured engine; differs on: {diff}")
        tmpl_leaves, treedef = jax.tree_util.tree_flatten(
            jax.eval_shape(lambda: self._init_cache(self.slots)))
        cache = jax.tree_util.tree_unflatten(treedef, [
            jnp.asarray(arrays[f"cache_{n:04d}"]).astype(t.dtype)
            for n, t in enumerate(tmpl_leaves)])
        if self.mesh is not None:
            cache = jax.device_put(cache, self._cache_shardings)
        self._key = jnp.asarray(arrays["engine_key"])
        self._slot_keys = jnp.asarray(arrays["slot_keys"])
        if "slot_temp" in arrays:
            self._slot_temp = np.asarray(arrays["slot_temp"],
                                         np.float32).copy()
            self._slot_topp = np.asarray(arrays["slot_topp"],
                                         np.float32).copy()
        by_rid: Dict[int, Request] = {}
        requests: List[Request] = []
        for rd in meta["requests"]:
            r = Request(rid=rd["rid"], prompt=arrays[f"prompt_{rd['rid']}"],
                        max_new=rd["max_new"])
            r.out_tokens = [int(t) for t in arrays[f"out_{rd['rid']}"]]
            r.status = RequestStatus(rd["status"])
            r.error = rd["error"]
            r.fmt_used = rd["fmt_used"]
            r.ttft_s = rd["ttft_s"]
            r.deadline_s = rd["deadline_s"]
            r.done = rd["done"]
            r.cancel_requested = rd["cancel_requested"]
            sd = rd.get("slo")
            r.slo = SLOClass.from_dict(sd) if sd is not None else None
            r.tenant = rd.get("tenant")
            r.arrival_tick = int(rd.get("arrival_tick", 0))
            r.arrival_s = rd.get("arrival_s")
            r.admitted_tick = rd.get("admitted_tick")
            r.temperature = rd.get("temperature")
            r.top_p = rd.get("top_p")
            by_rid[r.rid] = r
            requests.append(r)
        c = meta["counters"]
        self._ticks = c["ticks"]
        self._tokens_out = c["tokens_out"]
        self._kv_pages_alloc = c["kv_pages_alloc"]
        self._kv_pages_freed = c["kv_pages_freed"]
        self._kv_pages_hwm = c["kv_pages_hwm"]
        self._faults_detected = c["faults_detected"]
        self._fmt_escalations = c["fmt_escalations"]
        self._ticks_replayed = c["ticks_replayed"]
        self._admission_requeues = c["admission_requeues"]
        self._attn_tokens_read = c["attn_tokens_read"]
        self._spec_ticks = c.get("spec_ticks", 0)
        self._spec_accepted = c.get("spec_accepted", 0)
        self._spec_rejected = c.get("spec_rejected", 0)
        self._spec_aborts = c.get("spec_aborts", 0)
        self._status_counts = dict(c["status_counts"])
        self._failures = list(c["failures"])
        self._escalation_events = list(c["escalation_events"])
        self.policy.quarantined |= set(meta["quarantined"])
        self._resumes += 1
        state = dict(
            pending=[by_rid[rid] for rid in meta["pending"]],
            active=[by_rid[rid] if rid is not None else None
                    for rid in meta["active"]],
            slot_len=[int(v) for v in meta["slot_len"]],
            cache=cache,
            cache_len=jnp.asarray(arrays["cache_len"]),
            tokens=jnp.asarray(arrays["tokens"]),
            pinned=meta["pinned"],
            filling=(by_rid[meta["filling"]]
                     if meta["filling"] is not None else None),
            fill_slot=meta["fill_slot"],
            fill_cursor=meta["fill_cursor"],
            wait_pages=meta["wait_pages"],
            free_pages=list(meta["free_pages"]),
            bt=(np.asarray(arrays["bt"]).copy()
                if "bt" in arrays else None),
            elapsed_s=meta["elapsed_s"],
            tick_no=meta["tick_no"])
        return self.generate(requests, greedy=meta["greedy"],
                             fmt_override=meta["fmt_override"],
                             guard=guard, snapshot_dir=snapshot_dir,
                             _state=state)

    # ---- introspection ----------------------------------------------------
    @property
    def stats(self):
        def containers(tree):
            kinds = {type(l).__name__
                     for l in jax.tree_util.tree_leaves(
                         tree, is_leaf=lambda x: isinstance(
                             x, (MXTensor, PackedInt4Leaf)))
                     if isinstance(l, (MXTensor, PackedInt4Leaf))}
            return sorted(kinds) or ["dense"]

        return {
            "formats_cached": sorted(self._weights),
            "containers": {f: containers(t)
                           for f, t in self._weights.items()},
            "weight_bytes": {f: weight_stream_bytes(t)
                             for f, t in self._weights.items()},
            "weight_bytes_per_chip": {f: weight_stream_bytes_local(t)
                                      for f, t in self._weights.items()},
            "mesh": self._mesh_str(),
            "fmt_swaps": self._fmt_swaps,
            "ticks": self._ticks,
            "tokens_out": self._tokens_out,
            "current": self.current_fmt,
            "fused": self.fused,
            "prefill_traces": self._prefill_traces,
            "prefill_chunk": self.prefill_chunk,
            "admission_requeues": self._admission_requeues,
            "kv_layout": self.kv_layout,
            "kv_cache_bytes": self._kv_cache_bytes,
            "kv_bytes_per_slot": self._kv_cache_bytes // self.slots,
            "kv_page_size": self.kv_page_size,
            "kv_total_pages": self._kv_total_pages,
            "kv_pages_alloc": self._kv_pages_alloc,
            "kv_pages_freed": self._kv_pages_freed,
            "kv_pages_hwm": self._kv_pages_hwm,
            "speculative": (dataclasses.asdict(self.speculative)
                            if self.speculative is not None else None),
            "spec_ticks": self._spec_ticks,
            "spec_accepted": self._spec_accepted,
            "spec_rejected": self._spec_rejected,
            "spec_aborts": self._spec_aborts,
            "spec_acceptance_rate": (
                self._spec_accepted
                / (self._spec_accepted + self._spec_rejected)
                if self._spec_accepted + self._spec_rejected else None),
            "logit_guard": self.logit_guard,
            "faults_detected": self._faults_detected,
            "fmt_escalations": self._fmt_escalations,
            "escalation_events": list(self._escalation_events),
            "ticks_replayed": self._ticks_replayed,
            "request_statuses": dict(self._status_counts),
            "failures": list(self._failures),
            "snapshots_saved": self._snapshots_saved,
            "resumes": self._resumes,
            "quarantined_formats": sorted(self.policy.quarantined),
            "attn_impl": self.attn_impl,
            "attn_tokens_read": self._attn_tokens_read,
            "attn_read_bytes": self._attn_tokens_read
            * self._attn_token_bytes,
            "admission_order": self.admission_order,
            "cost_model": (self.policy.cost.snapshot()
                           if self.policy.cost is not None else None),
        }
