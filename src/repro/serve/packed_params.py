"""Packed-MX serving parameters: dequantize-on-load inside the jitted step.

The elastic-inference performance claim: decode is HBM-bound on weight reads,
so serving from MX codes (int8, or nibble-packed int4) cuts the memory
roofline term by 2x/4x vs bf16 dense weights. These containers keep the
*packed* representation as the on-device params pytree; `as_dense` runs
inside the jitted serve step, so XLA's HBM traffic is the packed bytes and
the dequant fuses into the consuming matmuls (on TPU the Pallas
``mx_matmul`` kernel implements the same contract explicitly).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.anchor import AnchorModel
from repro.core.formats import get_format
from repro.core.mx import MXTensor, decode_elements, dequantize
from repro.core.packed import pack_int4_jnp, unpack_int4_jnp
from repro.core.qat import QATConfig


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("packed", "scale_exp"),
                   meta_fields=("shape", "block_axis", "fmt_name"))
@dataclasses.dataclass
class PackedInt4Leaf:
    packed: jax.Array            # uint8, block axis moved last, len/2
    scale_exp: jax.Array
    shape: tuple
    block_axis: int
    fmt_name: str


def pack_leaf_int4(t: MXTensor) -> PackedInt4Leaf:
    assert t.fmt.kind == "int" and t.fmt.bits == 4
    moved = jnp.moveaxis(t.codes, t.block_axis, -1)
    return PackedInt4Leaf(packed=pack_int4_jnp(moved),
                          scale_exp=t.scale_exp,
                          shape=tuple(t.codes.shape),
                          block_axis=t.block_axis,
                          fmt_name=t.fmt.name)


def unpack_leaf_int4(p: PackedInt4Leaf, block_size: int,
                     dtype=jnp.bfloat16) -> jax.Array:
    codes = unpack_int4_jnp(p.packed)
    codes = jnp.moveaxis(codes, -1, p.block_axis)
    t = MXTensor(codes=codes, scale_exp=p.scale_exp,
                 fmt=get_format(p.fmt_name, block_size),
                 block_axis=p.block_axis)
    return dequantize(t, dtype=dtype)


def anchor_block_size(anchor: AnchorModel) -> int:
    """The block size the anchor was actually quantized at."""
    for t in anchor.quantized.values():
        return t.fmt.block_size
    return get_format(anchor.fmt_name).block_size


def make_packed_params(anchor: AnchorModel, template, *,
                       target_bits: int = 8, target_fmt: str | None = None,
                       dtype=jnp.bfloat16):
    """Params pytree whose quantized leaves are packed MX containers.

    ``target_fmt`` names any same-kind format at or below the anchor's
    precision: the anchor is Slice-and-Scaled to it (packed domain, no FP32
    round-trip) and the result kept as MXTensor leaves — except 4-bit MXINT,
    which is additionally nibble-packed (``PackedInt4Leaf``, 2 codes/byte).
    Legacy ``target_bits`` (8 = anchor as-is, 4 = mxint4) is honored when
    ``target_fmt`` is None.
    """
    from repro.core.anchor import convert
    bs = anchor_block_size(anchor)
    if target_fmt is None:
        target_fmt = anchor.fmt_name if target_bits == 8 else "mxint4"
    fmt_t = get_format(target_fmt, bs)
    model = anchor if fmt_t.name == anchor.fmt_name \
        else convert(anchor, fmt_t)
    pack4 = fmt_t.kind == "int" and fmt_t.bits == 4

    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for pth, leaf in leaves:
        k = jax.tree_util.keystr(pth)
        if k in model.quantized:
            t = model.quantized[k]
            out.append(pack_leaf_int4(t) if pack4 else t)
        else:
            w = model.raw[k]
            out.append(w.astype(dtype)
                       if jnp.issubdtype(w.dtype, jnp.floating) else w)
    return jax.tree_util.tree_unflatten(treedef, out)


def densify_params(packed_params, block_size: int = 32,
                   dtype=jnp.bfloat16):
    """Inside-jit: packed leaves -> dense weights (fuses into consumers)."""
    def one(leaf):
        if isinstance(leaf, MXTensor):
            return dequantize(leaf, dtype=dtype)
        if isinstance(leaf, PackedInt4Leaf):
            return unpack_leaf_int4(leaf, block_size, dtype)
        return leaf
    return jax.tree_util.tree_map(
        one, packed_params,
        is_leaf=lambda x: isinstance(x, (MXTensor, PackedInt4Leaf)))


def packed_param_shardings(packed_abstract, axes_tree, mesh, rules=None):
    """NamedShardings for a packed-params pytree.

    Codes/packed arrays shard with the dense weight's logical axes (the
    packed dim reuses the block axis' mapping when divisibility allows);
    scale tensors follow the moved-last layout; raw leaves use their axes.
    """
    from jax.sharding import NamedSharding
    from repro.sharding.rules import spec_for_axes

    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)

    flat_a = {jax.tree_util.keystr(p): a for p, a in
              jax.tree_util.tree_flatten_with_path(
                  axes_tree, is_leaf=is_ax)[0]}

    def container(path_str, leaf):
        axes = flat_a[path_str]
        if isinstance(leaf, MXTensor):
            ax = leaf.block_axis
            moved = tuple(a for i, a in enumerate(axes) if i != ax) + \
                (axes[ax],)
            return MXTensor(
                codes=NamedSharding(mesh, spec_for_axes(
                    leaf.codes.shape, axes, mesh, rules)),
                scale_exp=NamedSharding(mesh, spec_for_axes(
                    leaf.scale_exp.shape, moved, mesh, rules)),
                fmt=leaf.fmt, block_axis=leaf.block_axis)
        if isinstance(leaf, PackedInt4Leaf):
            ax = leaf.block_axis
            moved = tuple(a for i, a in enumerate(leaf.shape) if i != ax)
            moved_axes = tuple(a for i, a in enumerate(axes) if i != ax) + \
                (axes[ax],)
            return PackedInt4Leaf(
                packed=NamedSharding(mesh, spec_for_axes(
                    leaf.packed.shape, moved_axes, mesh, rules)),
                scale_exp=NamedSharding(mesh, spec_for_axes(
                    leaf.scale_exp.shape, moved_axes, mesh, rules)),
                shape=leaf.shape, block_axis=ax, fmt_name=leaf.fmt_name)
        return NamedSharding(mesh, spec_for_axes(leaf.shape, axes, mesh,
                                                 rules))

    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        packed_abstract,
        is_leaf=lambda x: isinstance(x, (MXTensor, PackedInt4Leaf)))
    return jax.tree_util.tree_unflatten(
        treedef, [container(jax.tree_util.keystr(p), l)
                  for p, l in leaves])


def make_packed_fn(api, fn, block_size: int = 32):
    """Wrap a ``fn(params, *rest)`` entry point to take packed params.

    Densification runs *inside* the (to-be-jitted) call, so the resident /
    HBM-streamed weights are the packed bytes and the dequant fuses into the
    consuming matmuls.
    """
    def wrapped(packed_params, *rest):
        params = densify_params(packed_params, block_size,
                                api.cfg.compute_dtype)
        return fn(params, *rest)
    return wrapped


def make_packed_serve_step(api, block_size: int = 32):
    """serve_step over packed params (the roofline-optimized decode path)."""
    return make_packed_fn(api, api.serve_step, block_size)


def make_packed_prefill_slot(api, block_size: int = 32):
    """Single-slot prefill-insert over packed params (see ModelApi)."""
    return make_packed_fn(api, api.prefill_slot, block_size)


def weight_stream_bytes(params) -> int:
    """Device bytes one decode tick must stream for the weight pytree.

    For packed trees this counts codes + scales at their stored width (uint8
    nibble-pairs for PackedInt4Leaf), i.e. the roofline weight-read term.
    """
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree_util.tree_leaves(params))
