"""Packed-MX serving parameters: packed leaves all the way to the GEMM.

The elastic-inference performance claim: decode is HBM-bound on weight reads,
so serving from MX codes (int8, or nibble-packed int4) cuts the memory
roofline term by 2x/4x vs bf16 dense weights. These containers keep the
*packed* representation as the on-device params pytree, and two serving
contracts realize the claim:

  fused (default on TPU)  — ``make_packed_serve_step(api, fused=True)``
    passes the packed tree straight into the model; every projection routes
    its leaf through ``repro.kernels.dispatch.qmatmul``, the fused Pallas
    dequant-GEMM (interpret-mode off TPU), so the only weight HBM traffic is
    the packed codes + scales streamed tile-by-tile into VMEM.

  densify-inside-jit      — the XLA fallback: leaves are dequantized inside
    the jitted step and XLA fuses the dequant into the consuming matmuls.
    Numerically identical (same codes); the reference for parity tests.

MXINT4 leaves use the split-N nibble layout (``PackedInt4Leaf`` with
``layout="splitn"``): byte column j holds output column j in the low nibble
and column j + N/2 in the high nibble, which is exactly what
``mx_matmul_int4_pallas`` streams.

The layout conventions these containers rely on (scan-stale metadata,
moved-last scales, split-N vs split-K) are documented in
docs/serving_internals.md.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.anchor import AnchorModel
from repro.core.formats import get_format
from repro.core.mx import MXTensor, decode_elements, dequantize
from repro.core.packed import (pack_int4_jnp, pack_int4_splitn_jnp,
                               unpack_int4_jnp, unpack_int4_splitn_jnp)
from repro.core.qat import QATConfig


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("packed", "scale_exp"),
                   meta_fields=("shape", "block_axis", "fmt_name", "layout"))
@dataclasses.dataclass
class PackedInt4Leaf:
    packed: jax.Array            # uint8 nibble pairs, codes.size / 2
    scale_exp: jax.Array
    shape: tuple                 # original codes shape
    block_axis: int
    fmt_name: str
    # "splitn": codes shape with the last (output) axis halved; byte col j =
    #   output cols (j, j + N/2) — the fused int4 GEMM kernel's layout.
    # "splitk": legacy — block axis moved last, adjacent nibble pairs along
    #   it; densify-only (no fused kernel reads it).
    layout: str = "splitn"


def pack_leaf_int4(t: MXTensor, layout: str = "splitn") -> PackedInt4Leaf:
    assert t.fmt.kind == "int" and t.fmt.bits == 4
    # split-N needs the last axis to be the GEMM output dim (block axis is
    # the contraction) and even; otherwise fall back to the split-K layout.
    if layout == "splitn" and (
            t.block_axis % t.codes.ndim == t.codes.ndim - 1
            or t.codes.shape[-1] % 2 != 0):
        layout = "splitk"
    if layout == "splitn":
        packed = pack_int4_splitn_jnp(t.codes)
    else:
        packed = pack_int4_jnp(jnp.moveaxis(t.codes, t.block_axis, -1))
    return PackedInt4Leaf(packed=packed,
                          scale_exp=t.scale_exp,
                          shape=tuple(t.codes.shape),
                          block_axis=t.block_axis,
                          fmt_name=t.fmt.name,
                          layout=layout)


def leaf_block_size(p: PackedInt4Leaf) -> int:
    """The block size the leaf was actually packed at, from its shapes.

    K sits at ndim-2 for split-N (last dim is N/2) and, nibble-paired, at
    the last dim for split-K; scale_exp's last dim is K/bs either way. Never
    trust the format registry default here — anchors quantize at arbitrary
    block sizes.
    """
    k = p.packed.shape[-2] if p.layout == "splitn" \
        else p.packed.shape[-1] * 2
    return k // p.scale_exp.shape[-1]


def leaf_as_mx(p: PackedInt4Leaf, block_size: Optional[int] = None,
               block_axis: Optional[int] = None) -> MXTensor:
    """Unpack a PackedInt4Leaf back to an MXTensor view (int8 codes).

    ``block_axis`` overrides the stored metadata — leaves sliced out of a
    scan keep stale static axes; the serving convention is ndim-2.
    ``block_size=None`` derives it from the leaf's own shapes.
    """
    ax = p.block_axis if block_axis is None else block_axis
    bs = leaf_block_size(p) if block_size is None else block_size
    if p.layout == "splitn":
        codes = unpack_int4_splitn_jnp(p.packed)
    else:
        codes = jnp.moveaxis(unpack_int4_jnp(p.packed), -1, ax)
    return MXTensor(codes=codes, scale_exp=p.scale_exp,
                    fmt=get_format(p.fmt_name, bs), block_axis=ax)


def unpack_leaf_int4(p: PackedInt4Leaf, block_size: int,
                     dtype=jnp.bfloat16) -> jax.Array:
    return dequantize(leaf_as_mx(p, block_size), dtype=dtype)


def densify_leaf(leaf, block_size: Optional[int], dtype,
                 serving_axis: bool = False) -> jax.Array:
    """One packed container -> dense weight; non-containers pass through.

    ``serving_axis=True`` re-derives the contraction axis as ndim-2 (the
    serving convention — leaves sliced out of a scan keep stale static
    ``block_axis``/``shape`` metadata). ``block_size=None`` derives the int4
    block size from the leaf's own shapes. This is THE densify
    implementation; both the qmatmul fallback and ``QuantCtx.dense`` route
    here so the convention can't diverge between them.
    """
    if isinstance(leaf, MXTensor):
        ax = max(leaf.codes.ndim - 2, 0) if serving_axis else leaf.block_axis
        t = MXTensor(codes=leaf.codes, scale_exp=leaf.scale_exp,
                     fmt=leaf.fmt, block_axis=ax)
        return dequantize(t, dtype=dtype)
    if isinstance(leaf, PackedInt4Leaf):
        ax = max(leaf.packed.ndim - 2, 0) if serving_axis else None
        return dequantize(leaf_as_mx(leaf, block_size, block_axis=ax),
                          dtype=dtype)
    return leaf


def anchor_block_size(anchor: AnchorModel) -> int:
    """The block size the anchor was actually quantized at."""
    for t in anchor.quantized.values():
        return t.fmt.block_size
    return get_format(anchor.fmt_name).block_size


def make_packed_params(anchor: AnchorModel, template, *,
                       target_bits: int = 8, target_fmt: str | None = None,
                       dtype=jnp.bfloat16):
    """Params pytree whose quantized leaves are packed MX containers.

    ``target_fmt`` names any same-kind format at or below the anchor's
    precision: the anchor is Slice-and-Scaled to it (packed domain, no FP32
    round-trip) and the result kept as MXTensor leaves — except 4-bit MXINT,
    which is additionally nibble-packed (``PackedInt4Leaf``, 2 codes/byte).
    Legacy ``target_bits`` (8 = anchor as-is, 4 = mxint4) is honored when
    ``target_fmt`` is None.
    """
    from repro.core.anchor import convert
    bs = anchor_block_size(anchor)
    if target_fmt is None:
        target_fmt = anchor.fmt_name if target_bits == 8 else "mxint4"
    fmt_t = get_format(target_fmt, bs)
    model = anchor if fmt_t.name == anchor.fmt_name \
        else convert(anchor, fmt_t)
    pack4 = fmt_t.kind == "int" and fmt_t.bits == 4

    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for pth, leaf in leaves:
        k = jax.tree_util.keystr(pth)
        if k in model.quantized:
            t = model.quantized[k]
            out.append(pack_leaf_int4(t) if pack4 else t)
        else:
            w = model.raw[k]
            out.append(w.astype(dtype)
                       if jnp.issubdtype(w.dtype, jnp.floating) else w)
    return jax.tree_util.tree_unflatten(treedef, out)


def densify_params(packed_params, block_size: int = 32,
                   dtype=jnp.bfloat16):
    """Inside-jit: packed leaves -> dense weights (fuses into consumers)."""
    return jax.tree_util.tree_map(
        lambda leaf: densify_leaf(leaf, block_size, dtype),
        packed_params,
        is_leaf=lambda x: isinstance(x, (MXTensor, PackedInt4Leaf)))


def repack_splitn_for_tp(packed_params, shardings, tp: int):
    """Re-nibble split-N int4 leaves whose output (N) axis is sharded.

    Split-N byte column ``j`` pairs output columns ``(j, j + N/2)`` — a
    GLOBAL interleave. Contiguously sharding the packed array hands each
    shard bytes whose nibbles decode to a permuted, non-contiguous column
    set, while the row-parallel consumer downstream (wo / w_down) shards
    its contraction rows contiguously — half the per-head / per-ff-block
    contributions would pair wrong under ``shard_map``. Repack so each
    shard's contiguous slice is a self-contained split-N layout of its own
    ``N/tp`` columns: the local unpack then yields exactly the columns the
    local step function expects, and the fused int4 kernel still reads a
    valid split-N tile (its dims come from the local shapes).

    Column-sharded leaves are detected from ``shardings`` (the tree
    ``packed_param_shardings`` built): a ``PackedInt4Leaf`` whose packed
    spec carries a mesh axis on the last dim. Split-K leaves and k-sharded
    split-N leaves (row-parallel) slice cleanly and pass through.
    """
    def fix(leaf, shd):
        if not (isinstance(leaf, PackedInt4Leaf) and leaf.layout == "splitn"
                and tp > 1):
            return leaf
        spec = shd.packed.spec
        last = spec[-1] if len(spec) == leaf.packed.ndim else None
        if last is None:
            return leaf
        # shard count along the byte-column axis — size-1 mesh axes (e.g.
        # 'data' on a (1, tp) serving mesh) never split it, so standard
        # split-N nibbling is already correct for those leaves.
        mesh_shape = shd.packed.mesh.shape
        n_shards = 1
        for nm in (last if isinstance(last, tuple) else (last,)):
            n_shards *= int(mesh_shape[nm])
        if n_shards <= 1:
            return leaf
        codes = unpack_int4_splitn_jnp(leaf.packed)
        n = codes.shape[-1]
        if n % (2 * n_shards):
            raise ValueError(
                f"cannot repack split-N leaf with N={n} over "
                f"{n_shards} shards")
        n_loc = n // n_shards
        packed = jnp.concatenate(
            [pack_int4_splitn_jnp(codes[..., s * n_loc:(s + 1) * n_loc])
             for s in range(n_shards)], axis=-1)
        return dataclasses.replace(leaf, packed=packed)

    is_c = lambda x: isinstance(x, (MXTensor, PackedInt4Leaf))
    return jax.tree_util.tree_map(fix, packed_params, shardings,
                                  is_leaf=is_c)


def packed_param_shardings(packed_abstract, axes_tree, mesh, rules=None):
    """NamedShardings for a packed-params pytree.

    Codes/packed arrays shard with the dense weight's logical axes (the
    packed dim reuses the block axis' mapping when divisibility allows);
    scale tensors follow the moved-last layout; raw leaves use their axes.

    These placements are what the tensor-parallel serving path
    (``ElasticEngine(mesh=...)``) feeds to ``jax.device_put`` before
    wrapping the step functions in ``shard_map`` — see
    docs/serving_internals.md §11 "Tensor-parallel serving".
    """
    from jax.sharding import NamedSharding
    from repro.sharding.rules import spec_for_axes

    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)

    flat_a = {jax.tree_util.keystr(p): a for p, a in
              jax.tree_util.tree_flatten_with_path(
                  axes_tree, is_leaf=is_ax)[0]}

    def container(path_str, leaf):
        axes = flat_a[path_str]
        if isinstance(leaf, MXTensor):
            ax = leaf.block_axis
            moved = tuple(a for i, a in enumerate(axes) if i != ax) + \
                (axes[ax],)
            return MXTensor(
                codes=NamedSharding(mesh, spec_for_axes(
                    leaf.codes.shape, axes, mesh, rules)),
                scale_exp=NamedSharding(mesh, spec_for_axes(
                    leaf.scale_exp.shape, moved, mesh, rules)),
                fmt=leaf.fmt, block_axis=leaf.block_axis)
        if isinstance(leaf, PackedInt4Leaf):
            ax = leaf.block_axis
            moved_axes = tuple(a for i, a in enumerate(axes) if i != ax) + \
                (axes[ax],)
            # split-N keeps the dense axis order (last dim halved);
            # split-K moves the block axis last (nibble-paired).
            packed_axes = axes if leaf.layout == "splitn" else moved_axes
            return PackedInt4Leaf(
                packed=NamedSharding(mesh, spec_for_axes(
                    leaf.packed.shape, packed_axes, mesh, rules)),
                scale_exp=NamedSharding(mesh, spec_for_axes(
                    leaf.scale_exp.shape, moved_axes, mesh, rules)),
                shape=leaf.shape, block_axis=ax, fmt_name=leaf.fmt_name,
                layout=leaf.layout)
        return NamedSharding(mesh, spec_for_axes(leaf.shape, axes, mesh,
                                                 rules))

    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        packed_abstract,
        is_leaf=lambda x: isinstance(x, (MXTensor, PackedInt4Leaf)))
    return jax.tree_util.tree_unflatten(
        treedef, [container(jax.tree_util.keystr(p), l)
                  for p, l in leaves])


def make_packed_fn(api, fn, block_size: int = 32):
    """Wrap a ``fn(params, *rest)`` entry point to take packed params.

    Densification runs *inside* the (to-be-jitted) call, so the resident /
    HBM-streamed weights are the packed bytes and the dequant fuses into the
    consuming matmuls. This is the XLA fallback contract; the fused contract
    (``fused=True`` below) skips densification entirely.
    """
    def wrapped(packed_params, *rest):
        params = densify_params(packed_params, block_size,
                                api.cfg.compute_dtype)
        return fn(params, *rest)
    return wrapped


def _fused_api(api, block_size: int, attn_impl: str = "gather"):
    """A ModelApi clone whose serving entry points run packed leaves through
    the fused Pallas dequant-GEMM dispatch (``kernels.dispatch.qmatmul``),
    with the paged decode-attention path (``attn_impl``) baked in."""
    if api.with_qmm is None:
        raise ValueError(
            f"model family {api.cfg.family!r} has no qmm hook; use the "
            "densify path (fused=False)")
    from repro.kernels.dispatch import make_qmm
    qmm = make_qmm(block_size=block_size, mode="pallas")
    if api.with_serving is not None:
        return api.with_serving(qmm=qmm, attn_impl=attn_impl)
    if attn_impl != "gather":
        raise ValueError(
            f"model family {api.cfg.family!r} cannot rebuild its serving "
            f"entry points with attn_impl={attn_impl!r} (no with_serving)")
    return api.with_qmm(qmm)


def _attn_api(api, attn_impl: str):
    """``api`` rebuilt (if needed) so serve_step uses ``attn_impl``."""
    if api.attn_impl == attn_impl:
        return api
    if api.with_serving is None:
        raise ValueError(
            f"model family {api.cfg.family!r} cannot rebuild its serving "
            f"entry points with attn_impl={attn_impl!r} (no with_serving)")
    return api.with_serving(attn_impl=attn_impl)


def make_packed_serve_step(api, block_size: int = 32, *,
                           fused: bool = False, attn_impl: str = "gather"):
    """serve_step over packed params (the roofline-optimized decode path).

    ``fused=True`` returns a step where each projection calls the Pallas
    dequant-GEMM on its packed leaf (interpret-mode off TPU); ``fused=False``
    keeps the XLA densify-inside-jit contract. Both take the same packed
    pytree and produce the same logits (same codes). ``attn_impl`` picks the
    paged decode-attention read path — the gather-free block-table kernel
    (``"paged_kernel"``) vs gather + masked softmax (``"gather"``) — and is
    orthogonal to the weight contract: any (fused, attn_impl) pairing is a
    valid serving configuration with identical token streams.
    """
    if fused:
        return _fused_api(api, block_size, attn_impl).serve_step
    api = _attn_api(api, attn_impl)
    return make_packed_fn(api, api.serve_step, block_size)


def make_packed_mixed_step(api, block_size: int = 32, *,
                           fused: bool = False, attn_impl: str = "gather"):
    """Unified mixed prefill+decode tick over packed params.

    ``(packed_params, batch{tokens (B,C), q_len (B,)}, cache, cache_len)
    -> (logits (B,V), cache)`` — the single-executable scheduler tick
    subsuming serve_step + prefill_chunk (``ModelApi.mixed_step``): decode
    rows carry 1 real token, the mid-prefill row its chunk, each at its own
    ``cache_len`` cursor. Contracts mirror ``make_packed_serve_step``:
    fused Pallas dequant-GEMM vs XLA densify-inside-jit on the weight side,
    and ``attn_impl`` picking the ragged multi-query paged read path — the
    gather-free MQ block-table kernel (``"paged_kernel"``) vs gather +
    masked softmax (``"gather"``). Any (fused, attn_impl) pairing yields
    identical token streams.
    """
    if fused:
        return _fused_api(api, block_size, attn_impl).mixed_step
    api = _attn_api(api, attn_impl)
    return make_packed_fn(api, api.mixed_step, block_size)


def make_packed_verify_step(api, block_size: int = 32, *,
                            fused: bool = False, attn_impl: str = "gather"):
    """Speculative verify tick over packed params.

    ``(packed_params, batch{tokens (B,C), q_len (B,)}, cache, cache_len)
    -> (logits (B,C,V), cache)`` — ``ModelApi.verify_step``, the
    all-positions sibling of ``mixed_step``: one executable scores a
    k-token draft burst per decode row under the verify format so the
    engine can accept the longest greedy-matching prefix and rewind the
    rest (docs/serving_internals.md §9 "Speculative decoding"). Weight and
    attention contracts mirror ``make_packed_mixed_step`` — fused Pallas
    dequant-GEMM vs XLA densify-inside-jit, and the ragged multi-query
    paged read path (``"paged_kernel"`` | ``"gather"``). Any
    (fused, attn_impl) pairing yields identical token streams.
    """
    if fused:
        return _fused_api(api, block_size, attn_impl).verify_step
    api = _attn_api(api, attn_impl)
    return make_packed_fn(api, api.verify_step, block_size)


def make_packed_prefill_slot(api, block_size: int = 32, *,
                             fused: bool = False):
    """Single-slot prefill-insert over packed params (see ModelApi).

    This is the *monolithic* admission path: the whole prompt in one call.
    The chunked counterpart is ``make_packed_prefill_chunk`` below; the
    engine's admission state machine that drives both is documented in
    docs/serving_internals.md ("Admission & scheduling").
    """
    if fused:
        return _fused_api(api, block_size).prefill_slot
    return make_packed_fn(api, api.prefill_slot, block_size)


def make_packed_prefill_chunk(api, block_size: int = 32, *,
                              fused: bool = False):
    """Single-slot *chunked* prefill over packed params.

    ``(packed_params, batch{tokens (1,C), lengths}, cache, slot, start_pos)
    -> (logits (V,), cache, new_len)`` — one prompt chunk at cursor
    ``start_pos``. The engine calls it once per tick so a long admission
    never stalls running slots for more than one chunk; it compiles once
    per chunk *bucket* (C is the fixed chunk size, or a pow2 bucket of the
    final remainder), not once per cursor — ``start_pos`` is traced.
    Contracts mirror ``make_packed_prefill_slot``: fused Pallas dequant-GEMM
    vs XLA densify-inside-jit, same packed tree, same logits.
    """
    if fused:
        return _fused_api(api, block_size).prefill_chunk_slot
    return make_packed_fn(api, api.prefill_chunk_slot, block_size)


def weight_stream_bytes(params) -> int:
    """Device bytes one decode tick must stream for the weight pytree.

    For packed trees this counts codes + scales at their stored width (uint8
    nibble-pairs for PackedInt4Leaf), i.e. the roofline weight-read term.
    """
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree_util.tree_leaves(params))


def weight_stream_bytes_local(params) -> int:
    """Per-chip weight-stream bytes for a (possibly sharded) weight pytree.

    Uses each leaf's actual sharding to size the LOCAL shard — on a
    ``(1, n_model)`` mesh this is ~``weight_stream_bytes / n_model`` (exactly,
    up to replicated bias/norm leaves), which is the number the per-chip
    roofline cost model must be seeded with. Falls back to the global size
    for uncommitted/unsharded leaves.
    """
    total = 0
    for leaf in jax.tree_util.tree_leaves(params):
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None:
            shape = sharding.shard_shape(leaf.shape)
            n = 1
            for d in shape:
                n *= d
        else:
            n = leaf.size
        total += n * leaf.dtype.itemsize
    return total
