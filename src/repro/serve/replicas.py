"""Data-parallel serving replicas: one ``ElasticEngine`` per device slice.

``ElasticEngine(mesh=...)`` is tensor parallelism — ONE logical engine whose
weights, KV pools, and step functions are sharded over a mesh's ``model``
axis, with token streams bit-identical to the single-device engine
(docs/serving_internals.md §11). Data parallelism is the other axis:
independent engines over disjoint device groups, each serving a disjoint
slice of the request stream. The two compose here — a ``ReplicaSet`` of
``n_replicas`` engines, each on its own ``(1, tp)`` mesh.

Requests partition by ``rid % n_replicas``: deterministic, stateless, and
stable across snapshot/resume (a request's home replica is a pure function
of its rid, so a resumed fleet re-derives the same partition). Each
replica's wave is a plain single-engine wave — streams are bit-identical to
running that replica's requests alone on one engine, which is this module's
tested contract (tests/test_mesh_serving.py).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.serve.engine import ElasticEngine, Request


def replica_meshes(n_replicas: int, tp: int = 1, devices=None):
    """Carve ``devices`` (default: all of ``jax.devices()``) into
    ``n_replicas`` disjoint ``(1, tp)`` meshes with axes ``("data",
    "model")``. ``tp == 1`` still returns meshes — a uniform code path —
    but callers may pass ``mesh=None`` per engine instead for the plain
    single-device build."""
    import jax
    from jax.sharding import Mesh
    if devices is None:
        devices = jax.devices()
    need = n_replicas * tp
    if len(devices) < need:
        raise ValueError(
            f"{n_replicas} replica(s) x tp={tp} needs {need} device(s); "
            f"only {len(devices)} available")
    return [Mesh(np.array(devices[i * tp:(i + 1) * tp]).reshape(1, tp),
                 ("data", "model"))
            for i in range(n_replicas)]


class ReplicaSet:
    """``n_replicas`` independent engines serving a partitioned stream.

    Every engine is built with identical configuration (same anchor, same
    knobs) so any request produces the same tokens regardless of which
    replica it lands on; the partition only decides WHERE, never WHAT.
    """

    def __init__(self, api, anchor, *, n_replicas: int, tp: int = 1,
                 devices=None, **engine_kwargs):
        if n_replicas < 1:
            raise ValueError(f"n_replicas ({n_replicas}) must be >= 1")
        if "mesh" in engine_kwargs:
            raise ValueError(
                "pass tp= instead of mesh=; ReplicaSet builds one "
                "(1, tp) mesh per replica")
        if tp > 1:
            meshes = replica_meshes(n_replicas, tp, devices)
        else:
            meshes = [None] * n_replicas
        self.n_replicas = n_replicas
        self.tp = tp
        self.engines: List[ElasticEngine] = [
            ElasticEngine(api, anchor, mesh=m, **engine_kwargs)
            for m in meshes]

    def home(self, rid: int) -> int:
        """The replica index serving request ``rid``."""
        return rid % self.n_replicas

    def partition(self, requests: List[Request]) -> List[List[Request]]:
        parts: List[List[Request]] = [[] for _ in range(self.n_replicas)]
        for r in requests:
            parts[self.home(r.rid)].append(r)
        return parts

    def generate(self, requests: List[Request], **kw) -> List[Request]:
        """Serve ``requests`` across the replicas; returns them all (each
        mutated in place by its home engine, original order preserved)."""
        for part, eng in zip(self.partition(requests), self.engines):
            if part:
                eng.generate(part, **kw)
        return requests

    @property
    def stats(self) -> Dict:
        per = [e.stats for e in self.engines]
        return {
            "n_replicas": self.n_replicas,
            "tp": self.tp,
            "tokens_out": sum(s["tokens_out"] for s in per),
            "ticks": sum(s["ticks"] for s in per),
            "replicas": per,
        }
