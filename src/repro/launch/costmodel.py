"""Analytic per-(arch x shape x mesh) cost model for the roofline analysis.

Why analytic: XLA:CPU ``cost_analysis()`` counts a ``while`` body ONCE, not
times its trip count (verified empirically — see EXPERIMENTS.md §Roofline
methodology), and every stack here is a scan-over-layers with scans inside.
So FLOPs/bytes/collective-bytes are derived from the model algebra — exact
for matmul-dominated transformers — and *validated* against compiled HLO
counts on small unrolled configs (tests/test_costmodel.py). The dry-run
still provides compile success, memory analysis, and the structural list of
collectives; this module provides the magnitudes.

Conventions:
  - FLOPs count multiply+add as 2 (XLA convention).
  - Backward matmul cost = 2x forward (dgrad + wgrad); full remat adds one
    extra forward: train factor = 2 (fwd) + 4 (bwd) + 2 (remat) = 8x the
    per-matmul MACs... expressed as ``TRAIN_MM_FACTOR * fwd_flops`` with
    fwd counted once.
  - Flash attention computes masked full blocks: causal costs the full
    S x S_kv rectangle (honest about the implementation; the banded SWA path
    costs S x min(S, W + chunk)).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.shapes import ShapeSpec, decode_cache_len
from repro.models.common import ModelConfig

TRAIN_MM_FACTOR = 8.0     # fwd + bwd(2x) + remat refwd
FWD_ONLY = 2.0            # fwd matmul flops = 2 * MACs; factor on MACs
ACT_BYTES_PER_LAYER_CONST = 14   # resid/norm/qkv/attnout/mlp traffic, bf16


@dataclasses.dataclass(frozen=True)
class MeshDesc:
    pod: int = 1
    data: int = 16
    model: int = 16

    @property
    def chips(self) -> int:
        return self.pod * self.data * self.model

    @property
    def dp(self) -> int:
        return self.pod * self.data


# =============================================================================
# Parameter counting
# =============================================================================
def layer_param_macs(cfg: ModelConfig, j: int) -> Dict[str, float]:
    """MAC-relevant weight sizes (= params in matmuls) for in-group layer j."""
    d, hd = cfg.d_model, cfg.hd
    out: Dict[str, float] = {}
    from repro.models.transformer import ffn_kind, mixer_kind
    mk, fk = mixer_kind(cfg, j), ffn_kind(cfg, j)
    if mk == "attn":
        out["attn"] = d * (cfg.n_heads * hd) * 2 + \
            d * (cfg.n_kv_heads * hd) * 2
    elif mk == "mamba":
        di, n, dtr = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.dt_rank
        out["mamba"] = d * 2 * di + di * (dtr + 2 * n) + dtr * di + di * d
    else:
        out["rwkv_time"] = 5 * d * d + 2 * d * 64
    if fk == "mlp":
        out["mlp"] = (3 if cfg.act == "swiglu" else 2) * d * cfg.d_ff
    elif fk == "moe":
        out["router"] = d * cfg.moe_experts
        out["moe_active"] = cfg.moe_topk * 3 * d * cfg.d_ff
        out["moe_total"] = cfg.moe_experts * 3 * d * cfg.d_ff
    else:
        out["rwkv_channel"] = 2 * d * cfg.d_ff + d * d
    return out


def stack_macs_per_token(cfg: ModelConfig, active: bool = True) -> float:
    """Sum of matmul MACs per token across the whole stack."""
    total = 0.0
    per_group = 0.0
    for j in range(cfg.scan_group):
        lp = layer_param_macs(cfg, j)
        for k, v in lp.items():
            if k == "moe_total":
                continue
            if k == "moe_active" and not active:
                continue
            per_group += v
    total = per_group * cfg.n_groups
    if cfg.family == "encdec":
        # decoder layers add cross-attn; encoder counted separately in callers
        total += cfg.n_layers * (cfg.d_model * cfg.n_heads * cfg.hd
                                 + 2 * cfg.d_model * cfg.n_kv_heads * cfg.hd)
    return total


def total_params(cfg: ModelConfig) -> float:
    """All weights (incl. every expert) + embeddings."""
    per_group = 0.0
    for j in range(cfg.scan_group):
        for k, v in layer_param_macs(cfg, j).items():
            if k == "moe_active":
                continue
            per_group += v
    stack = per_group * cfg.n_groups
    if cfg.family == "encdec":
        enc = cfg.enc_layers * (
            2 * cfg.d_model * cfg.n_heads * cfg.hd
            + 2 * cfg.d_model * cfg.n_kv_heads * cfg.hd
            + 2 * cfg.d_model * cfg.d_ff)
        cross = cfg.n_layers * (cfg.d_model * cfg.n_heads * cfg.hd * 2
                                + 2 * cfg.d_model * cfg.n_kv_heads * cfg.hd)
        stack += enc + cross
    embed = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return stack + embed


def active_params(cfg: ModelConfig) -> float:
    per_group = 0.0
    for j in range(cfg.scan_group):
        for k, v in layer_param_macs(cfg, j).items():
            if k == "moe_total":
                continue
            per_group += v
    stack = per_group * cfg.n_groups
    embed = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return stack + embed


# =============================================================================
# Attention / mixer extra flops (beyond weight matmuls)
# =============================================================================
def _attn_layers(cfg: ModelConfig) -> int:
    return sum(1 for j in range(cfg.scan_group)
               if cfg.is_attn_layer(j)) * cfg.n_groups \
        if cfg.family != "ssm" else 0


def attn_score_macs(cfg: ModelConfig, sq: int, skv: int, batch: int) -> float:
    """scores + pv MACs for one pass over all attention layers."""
    if cfg.family == "ssm":
        return 0.0
    if cfg.sliding_window is not None and skv > cfg.sliding_window:
        skv_eff = min(skv, cfg.sliding_window + min(cfg.seq_chunk, sq))
    else:
        skv_eff = skv
    per_layer = 2.0 * batch * cfg.n_heads * sq * skv_eff * cfg.hd
    return per_layer * _attn_layers(cfg)


def mixer_state_macs(cfg: ModelConfig, s: int, batch: int) -> float:
    """mamba scan / rwkv wkv extra MACs for one pass."""
    total = 0.0
    if cfg.family in ("hybrid",):
        n_mamba = (cfg.scan_group - sum(
            1 for j in range(cfg.scan_group) if cfg.is_attn_layer(j))) \
            * cfg.n_groups
        di, n = cfg.mamba_d_inner, cfg.mamba_d_state
        total += 5.0 * batch * s * di * n * n_mamba
    if cfg.family == "ssm":
        hd = cfg.rwkv_head_dim
        c = 64  # WKV_CHUNK
        per_tok = cfg.d_model * (4 * hd + 3 * c)
        total += batch * s * per_tok * cfg.n_layers
    return total


# =============================================================================
# Entry-point FLOPs
# =============================================================================
def flops_train(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, float]:
    b, s = shape.global_batch, shape.seq_len
    tokens = b * s
    mm = stack_macs_per_token(cfg, active=True) * tokens
    if cfg.family == "encdec":
        se = s // max(cfg.audio_downsample, 1)
        enc_mm = cfg.enc_layers * (
            2 * cfg.d_model * cfg.n_heads * cfg.hd
            + 2 * cfg.d_model * cfg.n_kv_heads * cfg.hd
            + 2 * cfg.d_model * cfg.d_ff) * b * se
        mm += enc_mm
        attn = attn_score_macs(cfg, s, s, b) \
            + attn_score_macs(cfg, se, se, b) \
            + 2.0 * b * cfg.n_heads * s * se * cfg.hd * cfg.n_layers
    elif cfg.family == "vlm":
        s_tot = s + cfg.vision_tokens
        mm = stack_macs_per_token(cfg) * b * s_tot
        attn = attn_score_macs(cfg, s_tot, s_tot, b)
    else:
        attn = attn_score_macs(cfg, s, s, b)
    head = cfg.d_model * cfg.vocab * tokens
    mixer = mixer_state_macs(cfg, s, b)
    fwd2 = FWD_ONLY * (mm + attn + head + mixer)      # flops of one forward
    total = TRAIN_MM_FACTOR / FWD_ONLY * fwd2
    qat_overhead = 10.0 * active_params(cfg) * len(
        ("mxint2", "mxint4", "mxint6", "mxint8")) / 4.0   # fake-quant pass
    model_flops = 6.0 * active_params(cfg) * tokens
    return {"total": total + qat_overhead, "forward": fwd2,
            "model_flops": model_flops}


def flops_prefill(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, float]:
    b, s = shape.global_batch, shape.seq_len
    tokens = b * s
    mm = stack_macs_per_token(cfg) * tokens
    if cfg.family == "vlm":
        s_tot = s + cfg.vision_tokens
        mm = stack_macs_per_token(cfg) * b * s_tot
        attn = attn_score_macs(cfg, s_tot, s_tot, b)
    elif cfg.family == "encdec":
        se = s // max(cfg.audio_downsample, 1)
        mm += cfg.enc_layers * (2 * cfg.d_model * cfg.n_heads * cfg.hd
                                + 2 * cfg.d_model * cfg.n_kv_heads * cfg.hd
                                + 2 * cfg.d_model * cfg.d_ff) * b * se
        attn = attn_score_macs(cfg, s, s, b) + attn_score_macs(cfg, se, se, b)\
            + 2.0 * b * cfg.n_heads * s * se * cfg.hd * cfg.n_layers
    else:
        attn = attn_score_macs(cfg, s, s, b)
    head = cfg.d_model * cfg.vocab * b            # last position only
    mixer = mixer_state_macs(cfg, s, b)
    total = FWD_ONLY * (mm + attn + head + mixer)
    return {"total": total,
            "model_flops": 2.0 * active_params(cfg) * tokens}


def flops_decode(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, float]:
    b = shape.global_batch
    cache = decode_cache_len(cfg, shape)
    mm = stack_macs_per_token(cfg) * b            # 1 token
    attn = attn_score_macs(cfg, 1, cache, b)
    head = cfg.d_model * cfg.vocab * b
    mixer = mixer_state_macs(cfg, 1, b)
    total = FWD_ONLY * (mm + attn + head + mixer)
    return {"total": total,
            "model_flops": 2.0 * active_params(cfg) * b}


# =============================================================================
# HBM bytes per device
# =============================================================================
def hbm_train(cfg: ModelConfig, shape: ShapeSpec, mesh: MeshDesc) -> float:
    p_local = total_params(cfg) / mesh.chips
    # f32 master read + fake-quant write/read (bf16) + grad write (f32) +
    # AdamW m/v read+write (f32 or bf16; assume f32) + remat weight re-read
    param_traffic = p_local * (4 + 2 + 2 + 4 + 16 + 2)
    tokens_local = shape.global_batch * shape.seq_len / mesh.dp
    d_model_local = cfg.d_model    # activations replicated over model axis
    act = tokens_local * d_model_local * cfg.n_layers * \
        ACT_BYTES_PER_LAYER_CONST * 2   # fwd+bwd
    return param_traffic + act


def hbm_prefill(cfg: ModelConfig, shape: ShapeSpec, mesh: MeshDesc) -> float:
    p_local = total_params(cfg) * 2 / mesh.chips     # bf16 serve weights
    tokens_local = shape.global_batch * shape.seq_len / mesh.dp
    act = tokens_local * cfg.d_model * cfg.n_layers * ACT_BYTES_PER_LAYER_CONST
    return p_local + act


def hbm_decode(cfg: ModelConfig, shape: ShapeSpec, mesh: MeshDesc,
               weight_bits: int = 16, weight_stationary: bool = False) -> float:
    """Decode is weight + KV-cache bound: every step reads all local weights
    + this batch's cache shard.

    FSDP layout shards weight reads over all chips (cheap HBM, collective
    psums per layer); weight-stationary replicates over (pod, data) so each
    chip reads its full model-shard (bits/8 x p / model) but psums vanish.
    """
    if weight_stationary:
        p_local = active_params(cfg) * weight_bits / 8 / mesh.model
    else:
        p_local = active_params(cfg) * weight_bits / 8 / mesh.chips
    cache = decode_cache_len(cfg, shape)
    b_local = max(shape.global_batch / mesh.dp, 1)
    kv = 2 * _attn_layers(cfg) * cfg.n_kv_heads * cfg.hd * cache * 2 \
        * b_local / mesh.model
    state = 0.0
    if cfg.family == "ssm":
        hh = cfg.d_model // cfg.rwkv_head_dim
        state = cfg.n_layers * hh * cfg.rwkv_head_dim ** 2 * 4 * b_local * 2
    if cfg.family == "hybrid":
        n_mamba = cfg.n_layers - _attn_layers(cfg)
        state = n_mamba * cfg.mamba_d_inner * cfg.mamba_d_state * 4 \
            * b_local * 2 / mesh.model
    return p_local + kv + state


# =============================================================================
# Serving-engine roofline terms (the measured-cost-model seed)
# =============================================================================
# These are the analytic counterparts of the byte counters the packed-weight
# serving engine actually measures — ``serve.packed_params.weight_stream_bytes``
# over the cached tree and ``ElasticEngine.stats()["attn_read_bytes"]`` — and
# they are a *tested contract*: tests/test_costmodel.py asserts they agree
# with a real engine run within a stated tolerance, per format x {dense,
# paged}. ``serve.slo.CostModel.from_roofline`` seeds its per-format terms
# from them, then calibrates online from observed tick timings.

def serve_weight_stream_bytes(cfg: ModelConfig, fmt_name: str,
                              block_size: int = 32) -> float:
    """Bytes one decode tick streams for the packed serving tree at
    ``fmt_name`` (codes + E8M0 scales for the quantized stack, raw leaves
    at ``cfg.compute_dtype``; the ``"bf16"`` pseudo-format is the dense
    tree). Mirrors ``make_packed_params``'s packing rules: every ndim>=2
    stack matmul weight is quantized, embeddings and norm vectors stay raw
    (norm vectors are dropped here — they are O(d_model) noise)."""
    import jax.numpy as jnp
    item = jnp.dtype(cfg.compute_dtype).itemsize
    embed = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    stack = total_params(cfg) - embed
    if fmt_name == "bf16":
        return (stack + embed) * item
    from repro.core.formats import get_format
    fmt = get_format(fmt_name, block_size)
    code_bytes = 0.5 if (fmt.kind == "int" and fmt.bits == 4) else 1.0
    return stack * (code_bytes + 1.0 / block_size) + embed * item


def serve_attn_read_span(cfg: ModelConfig, max_len: int,
                         kv_layout: str = "dense",
                         kv_page_size: int = 16) -> int:
    """KV tokens one gather-path decode read spans per batch row: the whole
    logical view — ``max_len`` (+ vision prefix) for the dense layout, the
    block table's page span for the paged layout. The gather-free kernel
    reads only ``ceil(cache_len/page)`` pages of it; the engine accounts
    that difference per tick, this term is the layout's upper bound."""
    logical = max_len + cfg.vision_tokens
    if kv_layout == "paged":
        return -(-logical // kv_page_size) * kv_page_size
    return logical


def serve_attn_bytes_per_row(cfg: ModelConfig, span_tokens: int) -> float:
    """HBM bytes one decode row's attention reads per tick when its read
    spans ``span_tokens`` KV positions: K+V at ``cfg.compute_dtype`` across
    every attention layer. The analytic twin of the engine's
    ``attn_read_bytes`` accounting (same per-token multiplier)."""
    import jax.numpy as jnp
    item = jnp.dtype(cfg.compute_dtype).itemsize
    return float(span_tokens) * _attn_layers(cfg) * 2 \
        * cfg.n_kv_heads * cfg.hd * item


def serve_roofline_terms(cfg: ModelConfig, formats,
                         *, max_len: int, kv_layout: str = "dense",
                         kv_page_size: int = 16, block_size: int = 32,
                         n_model: int = 1) -> Dict[str, Dict[str, float]]:
    """Per-format decode roofline terms for the serving cost model:
    ``{fmt: {"weight_bytes": <per tick>, "attn_bytes_per_row": <per row per
    tick>}}``. The weight read happens once per tick regardless of batch
    occupancy (one fused step streams the whole tree); the attention read
    scales with live rows.

    ``n_model``: tensor-parallel shards. The roofline is PER CHIP — a
    meshed engine streams only its weight shard and its kv-head slice of
    every token read, so both terms divide by the mesh's 'model' axis size
    (the single-chip ``HBM_BW`` the cost model divides by stays a per-chip
    number either way). Replicated leaves (norms, biases) are O(d_model)
    noise at this granularity, same as the unsharded approximation.
    """
    if n_model < 1:
        raise ValueError(f"n_model ({n_model}) must be >= 1")
    span = serve_attn_read_span(cfg, max_len, kv_layout, kv_page_size)
    attn = serve_attn_bytes_per_row(cfg, span) / n_model
    return {f: {"weight_bytes":
                serve_weight_stream_bytes(cfg, f, block_size) / n_model,
                "attn_bytes_per_row": attn}
            for f in formats}


# =============================================================================
# Collective bytes per device
# =============================================================================
def collectives_train(cfg: ModelConfig, shape: ShapeSpec,
                      mesh: MeshDesc) -> Dict[str, float]:
    """Per-device cross-chip traffic per train step (ring estimates)."""
    p = total_params(cfg)
    # FSDP: all-gather bf16 weights fwd + remat-fwd + bwd, reduce-scatter f32
    fsdp_shards = mesh.dp
    ag = 3 * (p / mesh.model) * 2 * (fsdp_shards - 1) / fsdp_shards
    rs = (p / mesh.model) * 4 * (fsdp_shards - 1) / fsdp_shards
    # TP: all-reduce activations, 2 row-parallel matmuls/layer, fwd+bwd+remat
    tokens_local = shape.global_batch * shape.seq_len / mesh.dp
    tp_ar = 2 * cfg.n_layers * tokens_local * cfg.d_model * 2 * 3 \
        * 2 * (mesh.model - 1) / mesh.model
    # vocab-parallel CE: lse/max all-reduce + dgrad all-reduce
    ce = tokens_local * (8 + cfg.d_model * 4) * 2 * (mesh.model - 1) \
        / mesh.model
    # MoE all-to-all (EP policy only: experts divide model axis)
    a2a = 0.0
    if cfg.moe_experts and cfg.moe_experts % mesh.model == 0:
        n_moe = sum(1 for j in range(cfg.scan_group)
                    if cfg.is_moe_layer(j)) * cfg.n_groups
        a2a = 3 * n_moe * tokens_local * cfg.moe_topk * cfg.d_model * 2
    return {"all_gather": ag, "reduce_scatter": rs, "tp_allreduce": tp_ar,
            "ce": ce, "all_to_all": a2a,
            "total": ag + rs + tp_ar + ce + a2a}


def collectives_decode(cfg: ModelConfig, shape: ShapeSpec,
                       mesh: MeshDesc, weight_stationary: bool = False,
                       weight_bits: int = 16) -> Dict[str, float]:
    b_local = max(shape.global_batch / mesh.dp, 1)
    # TP all-reduce of per-token activations, 2/layer
    tp_ar = 2 * cfg.n_layers * b_local * cfg.d_model * 2 \
        * 2 * (mesh.model - 1) / mesh.model
    # attention over seq-sharded cache: psum of (b, H, hd) partials + stats
    attn_ar = _attn_layers(cfg) * b_local * (cfg.n_heads * cfg.hd * 4 + 8) \
        * 2 * (mesh.model - 1) / mesh.model
    logits = b_local * cfg.vocab * 4 / mesh.model * 2
    # FSDP-layout serving: GSPMD keeps weights sharded over `data` and psums
    # per-layer partial activations across it (observed in post-cache-fix
    # HLO; pre-fix it gathered the full bf16 weights instead). The
    # weight-stationary layout eliminates the fsdp-axis traffic entirely.
    fsdp_ar = 0.0
    if not weight_stationary and mesh.dp > 1:
        per_layer_acts = b_local * cfg.d_model * 4        # f32 partials
        matmuls_per_layer = 4 if cfg.moe_experts else 3
        fsdp_ar = cfg.n_layers * matmuls_per_layer * per_layer_acts \
            * 2 * (mesh.dp - 1) / mesh.dp
        # MoE expert-operand gathers (dispatch spans the fsdp axis)
        if cfg.moe_experts:
            cap = max(1, int(cfg.capacity_factor * cfg.moe_topk
                             / cfg.moe_experts))
            fsdp_ar += cfg.n_layers * cfg.moe_experts * b_local * cap \
                * cfg.d_model * 4
    return {"tp_allreduce": tp_ar, "attn_psum": attn_ar, "logits": logits,
            "fsdp_allreduce": fsdp_ar,
            "total": tp_ar + attn_ar + logits + fsdp_ar}


def collectives_prefill(cfg: ModelConfig, shape: ShapeSpec,
                        mesh: MeshDesc) -> Dict[str, float]:
    tokens_local = shape.global_batch * shape.seq_len / mesh.dp
    tp_ar = 2 * cfg.n_layers * tokens_local * cfg.d_model * 2 \
        * 2 * (mesh.model - 1) / mesh.model
    wgt_ag = (total_params(cfg) / mesh.model) * 2 \
        * (mesh.dp - 1) / mesh.dp
    return {"tp_allreduce": tp_ar, "weight_allgather": wgt_ag,
            "total": tp_ar + wgt_ag}


# =============================================================================
# Roofline terms
# =============================================================================
def roofline(cfg: ModelConfig, shape: ShapeSpec, mesh: MeshDesc,
             weight_bits_decode: int = 16,
             weight_stationary: bool = False) -> Dict[str, float]:
    from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
    if shape.kind == "train":
        fl = flops_train(cfg, shape)
        hbm = hbm_train(cfg, shape, mesh)
        coll = collectives_train(cfg, shape, mesh)
    elif shape.kind == "prefill":
        fl = flops_prefill(cfg, shape)
        hbm = hbm_prefill(cfg, shape, mesh)
        coll = collectives_prefill(cfg, shape, mesh)
    else:
        fl = flops_decode(cfg, shape)
        hbm = hbm_decode(cfg, shape, mesh, weight_bits_decode,
                         weight_stationary=weight_stationary)
        coll = collectives_decode(cfg, shape, mesh,
                                  weight_stationary=weight_stationary,
                                  weight_bits=weight_bits_decode)
    t_comp = fl["total"] / mesh.chips / PEAK_FLOPS_BF16
    t_mem = hbm / HBM_BW
    t_coll = coll["total"] / ICI_BW
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    bound = max(t_comp, t_mem, t_coll)
    return {
        "flops_global": fl["total"],
        "model_flops": fl.get("model_flops", 0.0),
        "useful_ratio": fl.get("model_flops", 0.0) / max(fl["total"], 1.0),
        "hbm_bytes_per_dev": hbm,
        "coll_bytes_per_dev": coll["total"],
        "coll_breakdown": coll,
        "t_compute": t_comp, "t_memory": t_mem, "t_collective": t_coll,
        "dominant": dominant,
        "roofline_fraction": t_comp / bound if bound > 0 else 0.0,
        "step_time_lower_bound": bound,
    }
