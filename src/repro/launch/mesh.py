"""Production mesh definitions (TPU v5e numbers; CPU placeholders in dry-run).

``make_production_mesh`` is a function (never a module-level constant) so that
importing this module does not touch jax device state.
"""
from __future__ import annotations

import jax

# v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over however many local devices exist (tests)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def parse_mesh(spec: str):
    """``"DxM"`` -> ``(n_data, n_model)`` — the CLI mesh-shape syntax used
    by the serving bench (``--mesh 1x2``)."""
    parts = spec.lower().split("x")
    if len(parts) != 2:
        raise ValueError(f"mesh spec {spec!r} is not of the form 'DxM'")
    try:
        n_data, n_model = int(parts[0]), int(parts[1])
    except ValueError:
        raise ValueError(f"mesh spec {spec!r} is not of the form 'DxM'")
    if n_data < 1 or n_model < 1:
        raise ValueError(f"mesh spec {spec!r} must have positive axes")
    return n_data, n_model
