"""Version shims for JAX API drift in the launch/analysis tooling.

``jax.stages.Compiled.cost_analysis()`` historically returned a single dict;
current JAX returns a *list* of per-computation dicts (usually length 1).
``compiled_cost`` normalizes both to one flat dict so callers can keep doing
``cost.get("flops", 0.0)``.
"""
from __future__ import annotations

from typing import Any, Dict


def compiled_cost(compiled) -> Dict[str, Any]:
    """Normalized ``cost_analysis()`` of a ``jax.stages.Compiled``.

    Returns {} when the backend reports nothing. When the analysis is a list
    of per-computation dicts, numeric entries are summed across computations
    (the main module dominates; summing keeps totals right if XLA ever splits
    the module).
    """
    cost = compiled.cost_analysis()
    if not cost:
        return {}
    if isinstance(cost, dict):
        return dict(cost)
    merged: Dict[str, Any] = {}
    for comp in cost:
        for k, v in (comp or {}).items():
            if isinstance(v, (int, float)) and isinstance(
                    merged.get(k, 0.0), (int, float)):
                merged[k] = merged.get(k, 0.0) + v
            else:
                merged.setdefault(k, v)
    return merged
