"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This file proves the distribution config is coherent without hardware:
``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` must succeed on the
single-pod 16x16 mesh and the 2x16x16 multi-pod mesh for every assigned
(architecture x input shape) pair, and its memory/cost analyses feed the
roofline (EXPERIMENTS.md). Results are written incrementally to JSON so the
sweep is resumable cell-by-cell.
"""
# The VERY FIRST lines, before any other import: 512 placeholder devices.
# Never clobber flags the caller already set (CI exports its own XLA_FLAGS
# for CPU-mesh tests), and skip entirely when a host-device-count flag is
# already present — the caller's device count wins.
import os

HOST_DEVICE_COUNT_FLAG = "--xla_force_host_platform_device_count"


def _merged_xla_flags(existing: str, n: int = 512):
    """XLA_FLAGS value with ``--xla_force_host_platform_device_count=n``
    appended to ``existing``, or None when ``existing`` already pins a host
    device count (setting it twice would silently override the caller's)."""
    if HOST_DEVICE_COUNT_FLAG in existing:
        return None
    return f"{existing} {HOST_DEVICE_COUNT_FLAG}={n}".strip()


_flags = _merged_xla_flags(os.environ.get("XLA_FLAGS", ""))
if _flags is not None:
    os.environ["XLA_FLAGS"] = _flags
del _flags

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from repro.configs import (SHAPES, applicable, decode_cache_len,  # noqa: E402
                           get_config, list_archs)
from repro.core.formats import TRAIN_FORMATS_MXINT  # noqa: E402
from repro.core.qat import QATConfig                # noqa: E402
from repro.launch._compat import compiled_cost      # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import get_model                  # noqa: E402
from repro.optim.adamw import AdamWConfig, init_opt_state  # noqa: E402
from repro.sharding.rules import (DEFAULT_RULES, LogicalRules,  # noqa: E402
                                  param_shardings, spec_for_axes, use_rules)
from repro.train.state import TrainState, build_train_step  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+(?:\(?)([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum per-device result bytes of every collective in optimized HLO.

    Per-chip traffic factors (ring algorithms on N shards):
      all-gather: result bytes (each chip receives the full result),
      all-reduce: 2x operand, reduce-scatter: operand, all-to-all: operand,
      collective-permute: operand.
    """
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    for m in _COLL_RE.finditer(hlo_text):
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[kind] += n * _DTYPE_BYTES[dt]
    factors = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}
    out["total_weighted"] = sum(out[k] * factors[k] for k in factors)
    return out


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg, shape, kind: str):
    b, s = shape.global_batch, shape.seq_len
    if kind == "train":
        batch = {"tokens": _sds((b, s), jnp.int32),
                 "labels": _sds((b, s), jnp.int32)}
    elif kind == "prefill":
        batch = {"tokens": _sds((b, s), jnp.int32)}
    else:
        batch = {"tokens": _sds((b, 1), jnp.int32)}
    if cfg.family == "vlm" and kind != "decode":
        batch["vision_embeds"] = _sds((b, cfg.vision_tokens, cfg.d_model),
                                      jnp.float32)
    if cfg.family == "encdec" and kind != "decode":
        batch["frame_embeds"] = _sds(
            (b, max(1, s // max(cfg.audio_downsample, 1)), cfg.d_model),
            jnp.float32)
    return batch


def batch_sharding(batch, mesh):
    def one(sds):
        axes = ("batch",) + (None,) * (len(sds.shape) - 1)
        return NamedSharding(mesh, spec_for_axes(sds.shape, axes, mesh))
    return jax.tree_util.tree_map(one, batch)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               variant: str = "baseline",
               rules_override: Optional[dict] = None):
    """Lower+compile one cell; returns the result record.

    Variants (the §Perf ladder):
      baseline     — as-shipped defaults (flash-VJP on, local-group MoE)
      novjp        — flash attention without the custom VJP (the original
                     implementation; records the O(S^2)-residual memory)
      sp           — + sequence-parallel residual stream saves
      sp_mb4       — sp + 4-way microbatched gradient accumulation
    """
    import dataclasses as _dc
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not applicable(cfg, shape):
        return {"status": "skipped", "reason": "full-attention arch at 500k"}
    variant_label = variant
    microbatch = 1
    if variant == "novjp":
        cfg = _dc.replace(cfg, flash_vjp=False)
    elif variant == "sp":
        cfg = _dc.replace(cfg, seq_sharding=True)
    elif variant == "sp_mb4":
        cfg = _dc.replace(cfg, seq_sharding=True)
        microbatch = 4
    elif variant == "inner":
        cfg = _dc.replace(cfg, remat_inner=True)
    elif variant == "inner_mb4":
        cfg = _dc.replace(cfg, remat_inner=True)
        microbatch = 4
    elif variant == "inner_mb8":
        cfg = _dc.replace(cfg, remat_inner=True)
        microbatch = 8
    if variant.endswith("tp") or variant.endswith("scan"):
        # weight-stationary serving: weights replicate over (pod, data) and
        # stay TP-sharded over model — no per-step weight all-gather. Packed
        # MX weights (w8/w4) are what make the biggest models *fit* this
        # layout (bf16 replicated doesn't for 141B+); the *scan variants
        # additionally dequantize per layer inside the scan, so no resident
        # bf16 weight copy exists either.
        rules_override = dict(rules_override or {})
        rules_override["fsdp"] = ()
        variant_bits = {"w16tp": None, "w8tp": "w8", "w4tp": "w4"}
        variant = variant_bits.get(variant, variant) or "baseline_tp"

    mesh = make_production_mesh(multi_pod=multi_pod)
    table = dict(DEFAULT_RULES)
    if rules_override:
        table.update(rules_override)
    rules = LogicalRules(table)

    qat = QATConfig(formats=TRAIN_FORMATS_MXINT, block_size=32)
    api = get_model(cfg, qat)
    t0 = time.time()

    with use_rules(mesh, rules):
        params_s = jax.eval_shape(api.init_params, jax.random.PRNGKey(0))
        p_shard = param_shardings(api.param_axes(), params_s, mesh, rules)
        scalar = NamedSharding(mesh, P())

        if shape.kind == "train":
            moment_dtype = jnp.bfloat16 if "jamba" in arch else jnp.float32
            opt_cfg = AdamWConfig(moment_dtype=moment_dtype)
            opt_s = jax.eval_shape(
                lambda p: init_opt_state(p, opt_cfg), params_s)
            opt_shard = {"step": scalar, "m": p_shard, "v": p_shard}
            state_s = TrainState(params_s, opt_s, _sds((), jnp.int32))
            state_shard = TrainState(p_shard, opt_shard, scalar)
            batch = batch_specs(cfg, shape, "train")
            step_fn = build_train_step(api, opt_cfg, microbatch=microbatch)
            jitted = jax.jit(step_fn,
                             in_shardings=(state_shard,
                                           batch_sharding(batch, mesh),
                                           scalar),
                             out_shardings=(state_shard, scalar),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_s, batch, _sds((), jnp.int32))
        else:
            # serving: bf16 dense params
            params_bf16 = jax.tree_util.tree_map(
                lambda sds: _sds(sds.shape, jnp.bfloat16)
                if jnp.issubdtype(sds.dtype, jnp.floating) else sds, params_s)
            b = shape.global_batch
            if shape.kind == "prefill":
                cache_len_alloc = shape.seq_len
                cache_s = jax.eval_shape(
                    lambda: api.init_cache(b, cache_len_alloc))
                c_shard = param_shardings(api.cache_axes(), cache_s, mesh,
                                          rules)
                batch = batch_specs(cfg, shape, "prefill")
                jitted = jax.jit(api.prefill,
                                 in_shardings=(p_shard,
                                               batch_sharding(batch, mesh),
                                               c_shard),
                                 out_shardings=(scalar, c_shard, scalar))
                lowered = jitted.lower(params_bf16, batch, cache_s)
            else:
                # round the cache allocation up to a model-axis-shardable
                # length: a non-divisible kv_seq dim silently drops the
                # sequence sharding and GSPMD then head-gathers the cache
                # in f32 (found via dry-run HLO; see EXPERIMENTS.md §Perf)
                cache_len_alloc = decode_cache_len(cfg, shape) + 1
                cache_len_alloc = -(-cache_len_alloc // 128) * 128
                cache_s = jax.eval_shape(
                    lambda: api.init_cache(b, cache_len_alloc))
                c_shard = param_shardings(api.cache_axes(), cache_s, mesh,
                                          rules)
                batch = batch_specs(cfg, shape, "decode")
                len_s = _sds((b,), jnp.int32)
                if variant in ("w8", "w4", "w8scan", "w4scan"):
                    # packed-MX serving weights (the paper's deployment
                    # artifact): int8 anchor codes, or SS->int4 nibble-packed
                    from repro.core.anchor import make_anchor
                    from repro.core.formats import get_format
                    from repro.serve.packed_params import (
                        make_packed_params, make_packed_serve_step,
                        packed_param_shardings)
                    bits = 8 if variant.startswith("w8") else 4
                    anchor_fmt = get_format("mxint8", qat.block_size)
                    packed_s = jax.eval_shape(
                        lambda p: make_packed_params(
                            make_anchor(p, qat, anchor_fmt), p,
                            target_bits=bits),
                        params_s)
                    pk_shard = packed_param_shardings(
                        packed_s, api.param_axes(), mesh, rules)
                    if variant.endswith("scan"):
                        # packed weights flow INTO the layer scan; dense()
                        # dequantizes per layer (Pallas-GEMM contract at the
                        # XLA level) — no resident bf16 weight copy.
                        step = api.serve_step
                    else:
                        step = make_packed_serve_step(api, qat.block_size)
                    jitted = jax.jit(
                        step,
                        in_shardings=(pk_shard, batch_sharding(batch, mesh),
                                      c_shard, scalar),
                        out_shardings=(scalar, c_shard))
                    lowered = jitted.lower(packed_s, batch, cache_s, len_s)
                else:
                    jitted = jax.jit(
                        api.serve_step,
                        in_shardings=(p_shard, batch_sharding(batch, mesh),
                                      c_shard, scalar),
                        out_shardings=(scalar, c_shard))
                    lowered = jitted.lower(params_bf16, batch, cache_s,
                                           len_s)

        compiled = lowered.compile()

    cost = compiled_cost(compiled)
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo)
    rec = {
        "status": "ok",
        "arch": arch, "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "variant": variant_label,
        "compile_s": round(time.time() - t0, 1),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
        "memory": {
            k: int(getattr(mem, k, 0)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes",
             "alias_size_in_bytes")
        },
        "n_devices": int(np.prod(mesh.devices.shape)),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="out/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_tag = "pod2x16x16" if mp else "16x16"
                path = os.path.join(
                    args.out,
                    f"{arch}__{shape}__{mesh_tag}__{args.variant}.json")
                if os.path.exists(path) and not args.force:
                    print(f"skip (exists): {path}")
                    continue
                print(f"=== {arch} x {shape} x {mesh_tag} ===", flush=True)
                try:
                    rec = lower_cell(arch, shape, mp, variant=args.variant)
                except Exception as e:  # record failures — they are bugs
                    rec = {"status": "error", "arch": arch, "shape": shape,
                           "mesh": mesh_tag, "variant": args.variant,
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(json.dumps({k: v for k, v in rec.items()
                                  if k != "trace"})[:600], flush=True)


if __name__ == "__main__":
    main()
