"""Serving launcher: load (or synthesize) an anchor checkpoint and serve
batched requests with elastic precision selection."""
from __future__ import annotations

import argparse
import os

import jax
import numpy as np

from repro.checkpoint.anchor_ckpt import load_anchor, save_anchor
from repro.configs import get_config, get_reduced, list_archs
from repro.core import get_format, make_anchor
from repro.core.qat import QATConfig
from repro.models import get_model
from repro.serve.engine import ElasticEngine, Request
from repro.serve.policy import FormatPolicy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--anchor-ckpt", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--fmt", default=None,
                    help="pin a format instead of the load policy")
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    qat = QATConfig(formats=("mxint4", "mxint8"), anchor="mxint8",
                    block_size=32)

    if args.anchor_ckpt and os.path.isdir(args.anchor_ckpt):
        anchor = load_anchor(args.anchor_ckpt)
        print(f"loaded anchor checkpoint {args.anchor_ckpt} "
              f"({anchor.fmt_name})")
    else:
        anchor = make_anchor(params, qat, get_format("mxint8", 32))
        if args.anchor_ckpt:
            n = save_anchor(args.anchor_ckpt, anchor)
            print(f"wrote anchor checkpoint ({n / 1e6:.1f} MB)")

    eng = ElasticEngine(api, anchor, batch_slots=args.slots, max_len=96,
                        policy=FormatPolicy(anchor="mxint8"),
                        param_template=params)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, 8).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    eng.generate(reqs, fmt_override=args.fmt)
    for r in reqs[:4]:
        print(f"req {r.rid}: fmt={r.fmt_used} out={r.out_tokens}")
    print("engine:", eng.stats)


if __name__ == "__main__":
    main()
