"""Production training launcher: ``--arch <id> --shape train_4k`` etc.

On this CPU container it runs reduced configs for real; on a TPU fleet the
same entry point builds the sharded step over the production mesh (the
dry-run proves those lower+compile). Auto-resumes from the latest checkpoint.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced, list_archs
from repro.core.formats import TRAIN_FORMATS_MXFP, TRAIN_FORMATS_MXINT
from repro.core.qat import QATConfig
from repro.data.pipeline import DataConfig, LMDataset
from repro.models import get_model
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--formats", default="mxint",
                    choices=["mxint", "mxfp", "none"])
    ap.add_argument("--schedule", default="multiformat")
    ap.add_argument("--anchor", default=None,
                    help="anchor format for §3.5 training (e.g. mxint8)")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--moment-dtype", default="f32", choices=["f32", "bf16"])
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    fmts = {"mxint": TRAIN_FORMATS_MXINT, "mxfp": TRAIN_FORMATS_MXFP,
            "none": ()}[args.formats]
    qat = QATConfig(formats=fmts, anchor=args.anchor, block_size=32) \
        if fmts else None
    api = get_model(cfg, qat)
    data = LMDataset(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                global_batch=args.batch))
    opt = AdamWConfig(lr=args.lr,
                      moment_dtype=jnp.bfloat16
                      if args.moment_dtype == "bf16" else jnp.float32)
    out = run_training(
        api, data, opt,
        LoopConfig(total_steps=args.steps,
                   schedule=args.schedule if fmts else "fp",
                   ckpt_dir=args.ckpt),
        on_step=lambda s, m: print(
            f"step {s} fmt={m['fmt_idx']} loss={m['loss']:.4f}")
        if s % 10 == 0 else None)
    h = out["history"]
    print(f"finished at step {out['last_step']}; "
          f"loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f}" if h else "noop")


if __name__ == "__main__":
    main()
