"""TrainState pytree + construction of sharded train/serve step functions."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.transformer import ModelApi
from repro.optim.adamw import (AdamWConfig, adamw_update, init_opt_state)
from repro.sharding.rules import (param_shardings, spec_for_axes, use_rules)


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: jax.Array

    def as_tuple(self):
        return (self.params, self.opt, self.step)


def abstract_params(api: ModelApi, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    return jax.eval_shape(api.init_params, key)


def state_shardings(api: ModelApi, mesh: Mesh):
    """NamedShardings for params + AdamW moments (moments follow params)."""
    shapes = abstract_params(api)
    p_shard = param_shardings(api.param_axes(), shapes, mesh)
    opt_shard = {
        "step": NamedSharding(mesh, P()),
        "m": p_shard,
        "v": p_shard,
    }
    return p_shard, opt_shard


def batch_shardings(batch_shapes: Dict, mesh: Mesh):
    def one(s):
        axes = ("batch",) + (None,) * (len(s.shape) - 1)
        return NamedSharding(mesh, spec_for_axes(s.shape, axes, mesh))
    return jax.tree_util.tree_map(one, batch_shapes)


def build_train_step(api: ModelApi, opt_cfg: AdamWConfig,
                     lr_schedule: Optional[Callable] = None,
                     microbatch: int = 1):
    """(state, batch, fmt_idx) -> (state, metrics). Grad-accumulates over
    `microbatch` slices of the batch when > 1 (activation-memory relief)."""

    def loss_fn(params, batch, fmt_idx):
        loss, aux = api.train_loss(params, batch, fmt_idx)
        return loss, aux

    def train_step(state: TrainState, batch, fmt_idx):
        params, opt, step = state.params, state.opt, state.step
        if microbatch <= 1:
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, fmt_idx)
        else:
            def slice_mb(i, t):
                mb = t.shape[0] // microbatch
                return jax.lax.dynamic_slice_in_dim(t, i * mb, mb, axis=0)

            def acc_body(carry, i):
                gsum, lsum = carry
                mb = jax.tree_util.tree_map(
                    functools.partial(slice_mb, i), batch)
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb, fmt_idx)
                gsum = jax.tree_util.tree_map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, lsum), _ = jax.lax.scan(
                acc_body, (zeros, jnp.zeros((), jnp.float32)),
                jnp.arange(microbatch))
            grads = jax.tree_util.tree_map(lambda g: g / microbatch, grads)
            loss = lsum / microbatch
            aux = {}

        lr_scale = lr_schedule(step) if lr_schedule else 1.0
        new_params, new_opt, om = adamw_update(params, grads, opt, opt_cfg,
                                               lr_scale)
        metrics = {"loss": loss, **om}
        return TrainState(new_params, new_opt, step + 1), metrics

    return train_step


def make_sharded_train_step(api: ModelApi, mesh: Mesh, opt_cfg: AdamWConfig,
                            batch_shapes: Dict, lr_schedule=None,
                            microbatch: int = 1, donate: bool = True):
    """jit the train step with explicit in/out shardings on `mesh`."""
    p_shard, opt_shard = state_shardings(api, mesh)
    b_shard = batch_shardings(batch_shapes, mesh)
    scalar = NamedSharding(mesh, P())
    state_shard = TrainState(params=p_shard, opt=opt_shard, step=scalar)
    step_fn = build_train_step(api, opt_cfg, lr_schedule, microbatch)

    jitted = jax.jit(
        step_fn,
        in_shardings=(state_shard, b_shard, scalar),
        out_shardings=(state_shard, NamedSharding(mesh, P())),
        donate_argnums=(0,) if donate else (),
    )
    return jitted, state_shard


jax.tree_util.register_dataclass(TrainState,
                                 data_fields=("params", "opt", "step"),
                                 meta_fields=())
