from repro.train.state import (TrainState, build_train_step,
                               make_sharded_train_step, state_shardings)
from repro.train.loop import LoopConfig, make_schedule, run_training
from repro.train import compression
