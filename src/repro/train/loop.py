"""The training loop: MF-QAT schedules + fault tolerance + checkpointing.

Implements the paper's protocol end-to-end:
  - multi-format QAT: sequential increasing-bit schedule (2→4→6→8), one
    epoch per format (or interleaved within one epoch for large models),
  - single-format QAT / full-precision FT baselines (same loop, different
    schedule arrays),
  - anchor-storage training (§3.5) via QATConfig.anchor,
and the production-run machinery: auto-resume from LATEST, preemption-safe
checkpointing, watchdog, straggler monitor, deterministic step->batch
mapping (restart reproduces the exact batch sequence).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.core.qat import (QATConfig, fp_schedule, interleaved_schedule,
                            sequential_schedule, single_format_schedule)
from repro.data.pipeline import DataConfig, LMDataset
from repro.models.transformer import ModelApi
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.runtime.fault import (PreemptionGuard, StragglerMonitor, Watchdog)
from repro.train.state import TrainState, build_train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    schedule: str = "multiformat"   # multiformat | interleaved | fp |
    #                                 single:<pos>
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_n: int = 3
    watchdog_timeout_s: float = 600.0
    log_every: int = 10


def make_schedule(kind: str, n_formats: int, total_steps: int) -> np.ndarray:
    if kind == "multiformat":
        per = max(1, total_steps // max(n_formats, 1))
        sched = sequential_schedule(n_formats, per)
        if len(sched) < total_steps:
            sched = np.concatenate([
                sched, np.full(total_steps - len(sched), n_formats - 1,
                               np.int32)])
        return sched[:total_steps]
    if kind == "interleaved":
        return interleaved_schedule(n_formats, total_steps)
    if kind == "fp":
        return fp_schedule(total_steps, n_formats)
    if kind.startswith("single:"):
        return single_format_schedule(int(kind.split(":")[1]), total_steps)
    raise ValueError(kind)


def run_training(api: ModelApi, data: LMDataset, opt_cfg: AdamWConfig,
                 loop: LoopConfig, *, step_fn=None, seed: int = 0,
                 on_step: Optional[Callable] = None) -> Dict:
    """Single-host training driver (the pjit'd multi-host variant passes a
    sharded `step_fn` built by train.state.make_sharded_train_step)."""
    n_formats = len(api.qat.formats) if api.qat else 0
    schedule = make_schedule(loop.schedule, n_formats, loop.total_steps)

    if step_fn is None:
        step_fn = jax.jit(build_train_step(api, opt_cfg))

    # ---- init or resume --------------------------------------------------
    start_step = 0
    if loop.ckpt_dir and ckpt_io.latest_step(loop.ckpt_dir) is not None:
        template = TrainState(
            params=jax.eval_shape(api.init_params, jax.random.PRNGKey(seed)),
            opt=jax.eval_shape(
                lambda p: init_opt_state(p, opt_cfg),
                jax.eval_shape(api.init_params, jax.random.PRNGKey(seed))),
            step=jax.ShapeDtypeStruct((), jnp.int32))
        state, manifest = ckpt_io.restore(loop.ckpt_dir, template)
        state = TrainState(*map(
            lambda t: jax.tree_util.tree_map(jnp.asarray, t),
            state.as_tuple()))
        start_step = int(manifest["step"])
    else:
        params = api.init_params(jax.random.PRNGKey(seed))
        state = TrainState(params=params,
                           opt=init_opt_state(params, opt_cfg),
                           step=jnp.zeros((), jnp.int32))

    monitor = StragglerMonitor()
    history: List[Dict] = []
    watchdog = Watchdog(loop.watchdog_timeout_s).start()

    with PreemptionGuard() as guard:
        for step in range(start_step, loop.total_steps):
            t0 = time.time()
            batch = jax.tree_util.tree_map(jnp.asarray, data.batch_at(step))
            fmt_idx = jnp.int32(schedule[step])
            state, metrics = step_fn(state, batch, fmt_idx)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            watchdog.heartbeat()
            monitor.record(step, dt)
            metrics.update(step=step, sec=dt, fmt_idx=int(schedule[step]))
            history.append(metrics)
            if on_step:
                on_step(step, metrics)

            should_ckpt = loop.ckpt_dir and (
                (step + 1) % loop.ckpt_every == 0 or guard.preempted
                or step + 1 == loop.total_steps)
            if should_ckpt:
                ckpt_io.save(loop.ckpt_dir, step + 1, state,
                             extra_meta={"schedule": loop.schedule},
                             keep_n=loop.keep_n)
            if guard.preempted:
                break
    watchdog.stop()
    return {"state": state, "history": history,
            "stragglers": monitor.events,
            "preempted": guard.preempted,
            "last_step": history[-1]["step"] + 1 if history else start_step}
