"""MX gradient compression with error feedback for cross-pod data parallelism.

The paper's machinery applied to the distributed layer: cross-pod gradient
reduction is the dominant collective at multi-pod scale (slow inter-pod
links). We quantize pod-local gradients to MXINT8 blocks (+E8M0 scales),
all-gather the *packed* representation across the pod axis (4x fewer bytes
than an f32 psum ring), dequantize and sum locally, and keep the quantization
residual as error feedback so the compression bias vanishes over steps
(EF-SGD style).

Composition rule: with compression ON, params/optimizer shard FSDP over
`data` only and replicate across `pod` — pod-local grads exist, the
compressed all-gather is the only cross-pod traffic. (Without compression,
fsdp spans (pod, data) and GSPMD emits f32 reduce-scatters across pods.)
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.formats import MXFormat, get_format
from repro.core.mx import MXTensor, dequantize, quantize

try:                                       # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map
except ImportError:                        # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

PAD = 128   # flatten-pad multiple (>= block size, lane aligned)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True, **kw):
    """Version-stable ``shard_map`` for wiring ``compressed_pod_allreduce``.

    Newer JAX spells the replication check ``check_vma``; the experimental
    API spells it ``check_rep``. Callers use the new spelling.
    """
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check_vma, **kw)
    except TypeError:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma, **kw)


def _flatten_pad(g: jax.Array, bs: int) -> Tuple[jax.Array, int]:
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % max(bs, PAD)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(1, -1), n


def ef_compress_leaf(g: jax.Array, err: jax.Array, fmt: MXFormat):
    """(grad, error_state) -> (MXTensor, new_error_state).

    err has g's shape; the quantization residual accumulates there.
    """
    corrected = g.astype(jnp.float32) + err.astype(jnp.float32)
    flat, n = _flatten_pad(corrected, fmt.block_size)
    t = quantize(flat, fmt, axis=-1)
    deq = dequantize(t).reshape(-1)[:n].reshape(g.shape)
    new_err = corrected - deq
    return t, new_err.astype(err.dtype)


def ef_decompress_sum(gathered_codes, gathered_scales, fmt: MXFormat,
                      shape, n: int):
    """Sum dequantized per-pod contributions: codes (npod, 1, L)."""
    t = MXTensor(codes=gathered_codes, scale_exp=gathered_scales,
                 fmt=fmt, block_axis=gathered_codes.ndim - 1)
    deq = dequantize(t)                     # (npod, 1, L)
    s = jnp.sum(deq, axis=0).reshape(-1)[:n].reshape(shape)
    return s


def init_error_state(grads_or_params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), grads_or_params)


def compressed_pod_allreduce(grads, err_state, fmt_name: str = "mxint8",
                             axis_name: str = "pod", mean: bool = True):
    """Inside shard_map(manual over `axis_name`): EF-compress + all-gather +
    local dequant-sum. Returns (reduced_grads, new_err_state)."""
    fmt = get_format(fmt_name)
    npod = jax.lax.psum(1, axis_name)

    def one(g, err):
        t, new_err = ef_compress_leaf(g, err, fmt)
        codes = jax.lax.all_gather(t.codes, axis_name)        # (npod, 1, L)
        scales = jax.lax.all_gather(t.scale_exp, axis_name)
        flatn = g.size
        s = ef_decompress_sum(codes, scales, fmt, g.shape, flatn)
        if mean:
            s = s / npod
        return s.astype(g.dtype), new_err

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    red = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_err = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    return red, new_err


def compressed_bytes(params, fmt_name: str = "mxint8") -> int:
    """Cross-pod bytes per step with compression (vs 4 bytes/param f32)."""
    fmt = get_format(fmt_name)
    total = 0
    for p in jax.tree_util.tree_leaves(params):
        n = p.size
        npad = n + ((-n) % max(fmt.block_size, PAD))
        total += npad * fmt.bits // 8 + npad // fmt.block_size
    return total
