"""Public jit'd wrappers for the MX Pallas kernels.

On CPU (this container) the kernels run in ``interpret=True`` mode — the
kernel body executes exactly as written, which is how we validate TPU-target
code here. On TPU the same calls lower to Mosaic.

All wrappers accept arbitrary leading dims and an arbitrary block axis; they
canonicalize to a 2D (rows, block-cols) view before tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.formats import MXFormat
from repro.core.mx import MXTensor
from repro.kernels import fake_quant as _fq
from repro.kernels import mx_matmul as _mm
from repro.kernels import mx_quantize as _mq
from repro.kernels import ss_convert as _ss


def _use_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() == "cpu"


def _pick_tile(n: int, target: int, multiple: int) -> int:
    """Largest divisor of n that is <= target and a multiple of `multiple`."""
    best = multiple
    t = multiple
    while t <= min(n, target):
        if n % t == 0:
            best = t
        t += multiple
    return best


def _as2d(v: jax.Array, axis: int):
    """Move `axis` last and flatten the rest -> (R, C); returns unflatteners.

    ``restore`` undoes the move (for element codes); ``restore_blocked``
    keeps the moved-last ("blocked") layout that MXTensor uses for scales.
    """
    axis = axis % v.ndim
    moved = jnp.moveaxis(v, axis, -1)
    lead = moved.shape[:-1]
    c = moved.shape[-1]
    r = 1
    for d in lead:
        r *= int(d)
    flat = moved.reshape(r, c)

    def restore(x):
        return jnp.moveaxis(x.reshape(*lead, c), -1, axis)

    def restore_blocked(x, last_dim):
        return x.reshape(*lead, last_dim)

    return flat, restore, restore_blocked


def _tiles(r: int, c: int, bs: int):
    tm = _pick_tile(r, 256, 8) if r % 8 == 0 else _pick_tile(r, 256, 1)
    tc = _pick_tile(c, 512, bs)
    return tm, tc


# =============================================================================
@functools.partial(jax.jit, static_argnames=("fmt", "axis", "interpret"))
def mx_quantize(v: jax.Array, fmt: MXFormat, axis: int = -1,
                interpret: bool | None = None) -> MXTensor:
    """Pallas-backed MX quantization -> MXTensor (same API as core.quantize)."""
    interp = _use_interpret(interpret)
    flat, restore, restore_blocked = _as2d(v, axis)
    r, c = flat.shape
    tm, tc = _tiles(r, c, fmt.block_size)
    codes, scales = _mq.mx_quantize_pallas(flat, fmt, tm=tm, tc=tc,
                                           interpret=interp)
    return MXTensor(codes=restore(codes),
                    scale_exp=restore_blocked(scales, c // fmt.block_size),
                    fmt=fmt, block_axis=axis % v.ndim)


@functools.partial(jax.jit, static_argnames=("fmt", "axis", "interpret"))
def fake_quant(v: jax.Array, fmt: MXFormat, axis: int = -1,
               interpret: bool | None = None) -> jax.Array:
    """Pallas-backed fused quant-dequant (QAT forward weight)."""
    interp = _use_interpret(interpret)
    flat, restore, _ = _as2d(v, axis)
    r, c = flat.shape
    tm, tc = _tiles(r, c, fmt.block_size)
    out = _fq.fake_quant_pallas(flat, fmt, tm=tm, tc=tc, interpret=interp)
    return restore(out)


@functools.partial(jax.jit, static_argnames=("low", "interpret"))
def ss_convert(t: MXTensor, low: MXFormat,
               interpret: bool | None = None) -> MXTensor:
    """Pallas-backed Slice-and-Scale on packed representations."""
    interp = _use_interpret(interpret)
    high = t.fmt
    flat_c, restore_c, _ = _as2d(t.codes, t.block_axis)
    # scale_exp is already in blocked (moved-last) layout
    s_shape = t.scale_exp.shape
    flat_s = t.scale_exp.reshape(-1, s_shape[-1])
    r, c = flat_c.shape
    tm, tc = _tiles(r, c, high.block_size)
    codes, scales = _ss.ss_convert_pallas(flat_c, flat_s, high, low,
                                          tm=tm, tc=tc, interpret=interp)
    return MXTensor(codes=restore_c(codes), scale_exp=scales.reshape(s_shape),
                    fmt=low, block_axis=t.block_axis)


@functools.partial(jax.jit,
                   static_argnames=("fmt", "interpret", "tm", "tn", "tk"))
def mx_matmul(x: jax.Array, codes: jax.Array, scale_exp: jax.Array,
              fmt: MXFormat, interpret: bool | None = None,
              tm: int | None = None, tn: int | None = None,
              tk: int | None = None) -> jax.Array:
    """x (M,K) @ MX-packed W (K,N): dequant-fused GEMM."""
    interp = _use_interpret(interpret)
    m, k = x.shape
    n = codes.shape[1]
    tm = tm or _pick_tile(m, 256, 8)
    tn = tn or _pick_tile(n, 256, 128 if n % 128 == 0 else 8)
    tk = tk or _pick_tile(k, 512, fmt.block_size)
    return _mm.mx_matmul_pallas(x, codes, scale_exp, fmt,
                                tm=tm, tn=tn, tk=tk, interpret=interp)


@functools.partial(jax.jit,
                   static_argnames=("fmt", "interpret", "tm", "tn", "tk"))
def mx_matmul_int4(x: jax.Array, packed: jax.Array, scale_exp: jax.Array,
                   fmt: MXFormat, interpret: bool | None = None,
                   tm: int | None = None, tn: int | None = None,
                   tk: int | None = None) -> jax.Array:
    """x (M,K) @ int4-split-N-packed W (K,N/2): half the weight HBM bytes."""
    interp = _use_interpret(interpret)
    m, k = x.shape
    half_n = packed.shape[1]
    tm = tm or _pick_tile(m, 256, 8)
    tn = tn or _pick_tile(half_n, 256, 128 if half_n % 128 == 0 else 8)
    tk = tk or _pick_tile(k, 512, fmt.block_size)
    return _mm.mx_matmul_int4_pallas(x, packed, scale_exp, fmt,
                                     tm=tm, tn=tn, tk=tk, interpret=interp)


pack_int4_splitn = _mm.pack_int4_splitn


def to_weight_layout(t: MXTensor):
    """Core MXTensor (2D, blocks along axis 0 = K) -> kernel weight layout.

    Returns (codes (K, N), scale_exp (K/bs, N)). Core stores scales in the
    blocked (moved-last) layout (N, K/bs); the GEMM kernel tiles scales
    alongside the weight, so it wants them K-major.
    """
    assert t.codes.ndim == 2 and t.block_axis == 0, (t.codes.shape,
                                                     t.block_axis)
    return t.codes, t.scale_exp.T
