"""Shared in-kernel numerics for the MX Pallas kernels.

Everything here is elementwise / small-reduction VPU math that lowers on TPU:
bit ops on int32 lanes, float<->int bitcasts, and exact power-of-two
construction by assembling f32 exponent bits (no transcendental exp2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import MXFormat


def pow2i(e: jax.Array) -> jax.Array:
    """Exact 2^e for integer e in [-126, 127], by building f32 exponent bits.

    e < -126 saturates to 2^-126 (f32 normal min). MX scale exponents of -127
    only occur for all-zero blocks, whose elements are 0 anyway.
    """
    e = jnp.clip(e.astype(jnp.int32), -126, 127)
    bits = (e + 127) << 23
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def f32_exponent(a: jax.Array) -> jax.Array:
    """floor(log2(a)) for positive normal f32 a, from the exponent bits."""
    bits = jax.lax.bitcast_convert_type(a.astype(jnp.float32), jnp.int32)
    return ((bits >> 23) & 0xFF) - 127


def decode_fp_arith(codes: jax.Array, fmt: MXFormat) -> jax.Array:
    """MXFP uint8 bit patterns -> f32 values (arithmetic, no LUT).

    Valid codes only (the E4M3 NaN pattern is never produced by our
    quantizers; it decodes here as 480, and to NaN in the core LUT).
    """
    c = codes.astype(jnp.int32)
    s = (c >> (fmt.bits - 1)) & 1
    e = (c >> fmt.mbits) & ((1 << fmt.ebits) - 1)
    m = c & ((1 << fmt.mbits) - 1)
    mf = m.astype(jnp.float32) * (2.0 ** -fmt.mbits)
    normal = e > 0
    mag = jnp.where(normal,
                    (1.0 + mf) * pow2i(e - fmt.fp_bias),
                    mf * (2.0 ** fmt.emin))
    return jnp.where(s == 1, -mag, mag)


def quantize_fp_value_arith(y: jax.Array, fmt: MXFormat) -> jax.Array:
    """Round f32 -> nearest MXFP(η,μ) value, saturating (kernel-safe)."""
    a = jnp.abs(y)
    expo = jnp.maximum(f32_exponent(jnp.where(a > 0, a, 1.0)), fmt.emin)
    quantum = pow2i(expo - fmt.mbits)
    q = jnp.round(y / quantum) * quantum
    q = jnp.clip(q, -fmt.fp_max, fmt.fp_max)
    return jnp.where(a > 0, q, jnp.zeros_like(q))


def encode_fp_arith(q: jax.Array, fmt: MXFormat) -> jax.Array:
    """Exactly-representable MXFP values -> uint8 bit patterns (kernel-safe)."""
    qbits = jax.lax.bitcast_convert_type(q.astype(jnp.float32), jnp.int32)
    s = (qbits >> 31) & 1                      # preserves the sign of -0.0
    a = jnp.abs(q)
    expo = f32_exponent(jnp.where(a > 0, a, 1.0))
    is_sub = (expo < fmt.emin) | (a <= 0)
    mant_n = jnp.round((a * pow2i(-expo) - 1.0) * (1 << fmt.mbits))
    mant_s = jnp.round(a * pow2i(jnp.full_like(expo, fmt.mbits - fmt.emin)))
    e_field = jnp.where(is_sub, 0, expo + fmt.fp_bias).astype(jnp.int32)
    mant = jnp.where(is_sub, mant_s, mant_n).astype(jnp.int32)
    code = (s << (fmt.bits - 1)) | (e_field << fmt.mbits) | mant
    return code.astype(jnp.uint8)


def quantize_block_tile(v: jax.Array, fmt: MXFormat):
    """Quantize a (TM, TC) f32 tile; blocks of fmt.block_size along axis 1.

    Returns (codes int8/uint8 (TM, TC), scale_exp int8 (TM, TC//bs)).
    """
    bs = fmt.block_size
    tm, tc = v.shape
    vb = v.reshape(tm, tc // bs, bs)
    bmax = jnp.max(jnp.abs(vb), axis=-1)
    se = jnp.where(bmax > 0,
                   f32_exponent(jnp.where(bmax > 0, bmax, 1.0)),
                   -127 + fmt.emax) - fmt.emax
    se = jnp.clip(se, -127, 127)
    y = vb * pow2i(-se)[:, :, None]
    if fmt.kind == "int":
        maxq = float(fmt.int_maxq)
        codes = jnp.clip(jnp.round(y), -maxq, maxq).astype(jnp.int8)
    else:
        codes = encode_fp_arith(quantize_fp_value_arith(y, fmt), fmt)
    return codes.reshape(tm, tc), se.astype(jnp.int8)


def dequantize_block_tile(codes: jax.Array, scale_exp: jax.Array,
                          fmt: MXFormat) -> jax.Array:
    """Inverse of quantize_block_tile -> f32 (TM, TC)."""
    bs = fmt.block_size
    tm, tc = codes.shape
    if fmt.kind == "int":
        vals = codes.astype(jnp.float32)
    else:
        vals = decode_fp_arith(codes, fmt)
    scale = pow2i(scale_exp.astype(jnp.int32))
    vb = vals.reshape(tm, tc // bs, bs) * scale[:, :, None]
    return vb.reshape(tm, tc)
