"""Pallas TPU kernel: Slice-and-Scale format conversion on packed MX data.

SSMXINT (paper Eq. 4) is a pure-integer right-shift with round-to-nearest-even
on int8 lanes plus a scalar bump of the E8M0 scale — the kernel never touches
FP32 master weights, which is the point of the paper's deployment pipeline.
SSMXFP (Eq. 6) decodes elements arithmetically, divides by 2^Δe, re-rounds
into the narrower element format, and re-encodes — all elementwise VPU math.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import MXFormat, SCALE_EXP_MAX, SCALE_EXP_MIN, delta_e
from repro.kernels.common import (decode_fp_arith, encode_fp_arith,
                                  pow2i, quantize_fp_value_arith)


def _rshift_rne_i32(p, de: int):
    if de == 0:
        return p
    q = p >> de
    r = p - (q << de)
    half = 1 << (de - 1)
    return q + ((r > half) | ((r == half) & ((q & 1) == 1))).astype(p.dtype)


def _kernel(codes_ref, scales_ref, out_codes_ref, out_scales_ref, *,
            high: MXFormat, low: MXFormat):
    de = delta_e(high, low)
    if high.kind == "int":
        p = codes_ref[...].astype(jnp.int32)
        q = _rshift_rne_i32(p, de)
        maxq = low.int_maxq
        out_codes_ref[...] = jnp.clip(q, -maxq, maxq).astype(jnp.int8)
    else:
        vals = decode_fp_arith(codes_ref[...], high)
        y = vals * pow2i(jnp.full((), -de, jnp.int32))
        out_codes_ref[...] = encode_fp_arith(
            quantize_fp_value_arith(y, low), low)
    se = scales_ref[...].astype(jnp.int32) + de
    out_scales_ref[...] = jnp.clip(se, SCALE_EXP_MIN, SCALE_EXP_MAX) \
        .astype(jnp.int8)


def ss_convert_pallas(codes: jax.Array, scale_exp: jax.Array,
                      high: MXFormat, low: MXFormat, *, tm: int, tc: int,
                      interpret: bool = False):
    """(codes (R,C), scales (R,C/bs)) in `high` -> same shapes in `low`."""
    r, c = codes.shape
    bs = high.block_size
    assert c % tc == 0 and r % tm == 0 and tc % bs == 0
    out_dtype = jnp.int8 if low.kind == "int" else jnp.uint8
    grid = (r // tm, c // tc)
    return pl.pallas_call(
        functools.partial(_kernel, high=high, low=low),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tc), lambda i, j: (i, j)),
            pl.BlockSpec((tm, tc // bs), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((tm, tc), lambda i, j: (i, j)),
            pl.BlockSpec((tm, tc // bs), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, c), out_dtype),
            jax.ShapeDtypeStruct((r, c // bs), jnp.int8),
        ],
        interpret=interpret,
    )(codes, scale_exp)
