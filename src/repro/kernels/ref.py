"""Pure-jnp oracles for every Pallas kernel in this package.

These delegate to ``repro.core`` (which is itself pure jnp) so the kernels are
validated against the exact semantics the rest of the framework uses.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.formats import MXFormat
from repro.core import mx as _mx
from repro.core import slice_scale as _ss


def ref_mx_quantize(v, fmt: MXFormat, axis: int = -1):
    """-> (codes, scale_exp) exactly as core.mx.quantize."""
    t = _mx.quantize(v, fmt, axis=axis)
    return t.codes, t.scale_exp


def ref_fake_quant(v, fmt: MXFormat, axis: int = -1):
    """-> dequantize(quantize(v)) values (the QAT forward weight)."""
    return _mx.quantize_dequantize(v, fmt, axis=axis)


def ref_ss_convert(codes, scale_exp, high: MXFormat, low: MXFormat,
                   block_axis: int = -1):
    """-> (codes_low, scale_exp_low) via core slice-and-scale."""
    t = _mx.MXTensor(codes=codes, scale_exp=scale_exp, fmt=high,
                     block_axis=block_axis % codes.ndim)
    out = _ss.slice_and_scale(t, low)
    return out.codes, out.scale_exp


def ref_mx_matmul(x, codes, scale_exp, fmt: MXFormat, out_dtype=jnp.float32):
    """x (M,K) @ dequant(codes (K,N), scale_exp (K/bs, N)) -> (M,N).

    Weight blocks run along K (the contraction axis), per OCP MX dot-product
    semantics. Scales use the kernel layout: K-major, (K/bs, N).
    """
    vals = _mx.decode_elements(codes, fmt, jnp.float32)
    scale = jnp.exp2(scale_exp.astype(jnp.float32))
    w = vals * jnp.repeat(scale, fmt.block_size, axis=0)
    return jnp.dot(x.astype(jnp.float32), w,
                   preferred_element_type=jnp.float32).astype(out_dtype)


def ref_mx_matmul_int4_packed(x, packed, scale_exp, fmt: MXFormat,
                              out_dtype=jnp.float32):
    """Split-N int4-packed weights: packed (K, N/2) uint8.

    Column j of `packed` holds code column j in the low nibble and column
    j + N/2 in the high nibble (no lane interleaving on TPU).
    """
    lo = (packed & 0xF).astype(jnp.int32)
    hi = ((packed >> 4) & 0xF).astype(jnp.int32)
    lo = (lo ^ 8) - 8
    hi = (hi ^ 8) - 8
    codes = jnp.concatenate([lo, hi], axis=1).astype(jnp.int8)
    return ref_mx_matmul(x, codes, scale_exp, fmt, out_dtype)
