"""repro.kernels — TPU Pallas kernels for the MX hot spots.

Kernels (pl.pallas_call + explicit BlockSpec VMEM tiling):
  mx_quantize  — block-max + shared-exponent + element cast, fused
  fake_quant   — QAT forward quant-dequant in one VMEM pass
  ss_convert   — Slice-and-Scale on packed codes (int shift-RNE / fp requant)
  mx_matmul    — dequant-fused GEMM over packed MX weights (+ int4-packed)
  paged_attention — gather-free paged decode attention over the block table

``ops`` holds the jit'd public wrappers (interpret=True on CPU), ``ref`` the
pure-jnp oracles every kernel is tested against, and ``dispatch`` the
serving-path entry point: ``qmatmul(x, leaf)`` routes packed weight
containers (MXTensor / split-N PackedInt4Leaf) into the fused dequant-GEMM
with shape padding, tile selection, and an XLA densify fallback.
``paged_attention.paged_decode_attention`` is the analogous shim for the
decode-attention side: block-table kernel vs gather fallback, with its own
trace-time path counters.
"""
from repro.kernels import dispatch, ops, paged_attention, ref  # noqa: F401
from repro.kernels.dispatch import qmatmul  # noqa: F401
