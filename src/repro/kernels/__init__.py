"""repro.kernels — TPU Pallas kernels for the MX hot spots.

Kernels (pl.pallas_call + explicit BlockSpec VMEM tiling):
  mx_quantize  — block-max + shared-exponent + element cast, fused
  fake_quant   — QAT forward quant-dequant in one VMEM pass
  ss_convert   — Slice-and-Scale on packed codes (int shift-RNE / fp requant)
  mx_matmul    — dequant-fused GEMM over packed MX weights (+ int4-packed)

``ops`` holds the jit'd public wrappers (interpret=True on CPU), ``ref`` the
pure-jnp oracles every kernel is tested against.
"""
from repro.kernels import ops, ref  # noqa: F401
