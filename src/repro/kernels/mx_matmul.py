"""Pallas TPU kernel: dequant-fused GEMM over packed MX weights.

The elastic-inference hot loop: activations stay bf16, weights stream from
HBM as int8/uint8 element codes (or int4 nibble-packed) plus E8M0 scales.
Each grid step loads a (TK, TN) weight tile into VMEM, dequantizes on the VPU,
and feeds the MXU with a (TM, TK) x (TK, TN) bf16 matmul accumulated in f32.

HBM traffic per weight tile is bits/16 of the bf16 equivalent — this is where
MX serving wins, since decode-mode GEMMs are memory-bound.

Layouts:
  - unpacked: codes (K, N), scales (K/bs, N); MX blocks along K (contraction).
  - int4 split-N packed: packed (K, N/2) uint8 where column j carries output
    column j in the low nibble and column j + N/2 in the high nibble. Output
    tiles never straddle the halves, so the nibble choice is a scalar per
    grid step (no lane interleaving).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import MXFormat
from repro.kernels.common import decode_fp_arith, pow2i


def _dequant_tile(codes, scales, fmt: MXFormat):
    """codes (TK, TN), scales (TK/bs, TN) -> w (TK, TN) f32. Blocks along K."""
    tk, tn = codes.shape
    bs = fmt.block_size
    if fmt.kind == "int":
        vals = codes.astype(jnp.float32)
    else:
        vals = decode_fp_arith(codes, fmt)
    scale = pow2i(scales.astype(jnp.int32))          # (TK/bs, TN)
    scale_full = jnp.repeat(scale, bs, axis=0)       # (TK, TN)
    del tk, tn
    return vals * scale_full


def _mm_kernel(x_ref, codes_ref, scales_ref, out_ref, *, fmt: MXFormat):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = _dequant_tile(codes_ref[...], scales_ref[...], fmt)
    x = x_ref[...].astype(jnp.float32)
    out_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


def mx_matmul_pallas(x: jax.Array, codes: jax.Array, scale_exp: jax.Array,
                     fmt: MXFormat, *, tm: int, tn: int, tk: int,
                     interpret: bool = False) -> jax.Array:
    """x (M, K) @ dequant(codes (K, N), scales (K/bs, N)) -> (M, N) f32."""
    m, k = x.shape
    k2, n = codes.shape
    bs = fmt.block_size
    assert k == k2 and m % tm == 0 and n % tn == 0 and k % tk == 0
    assert tk % bs == 0
    grid = (m // tm, n // tn, k // tk)
    return pl.pallas_call(
        functools.partial(_mm_kernel, fmt=fmt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((tk // bs, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, codes, scale_exp)


# =============================================================================
# int4 split-N packed variant
# =============================================================================
def pack_int4_splitn(codes: jax.Array) -> jax.Array:
    """int8 codes (K, N) -> uint8 packed (K, N/2), split-N layout.

    Thin 2D shim over the one true implementation in ``core.packed`` (the
    serving trees pack through it too — one byte layout, one source).
    """
    from repro.core.packed import pack_int4_splitn_jnp
    assert codes.ndim == 2 and codes.shape[1] % 2 == 0
    return pack_int4_splitn_jnp(codes)


def _mm4_kernel(x_ref, packed_ref, scales_ref, out_ref, *,
                fmt: MXFormat, half_blocks: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    j = pl.program_id(1)
    p = packed_ref[...].astype(jnp.int32)
    lo = ((p & 0xF) ^ 8) - 8
    hi = (((p >> 4) & 0xF) ^ 8) - 8
    codes = jnp.where(j < half_blocks, lo, hi)
    w = _dequant_tile(codes, scales_ref[...], fmt)
    x = x_ref[...].astype(jnp.float32)
    out_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


def mx_matmul_int4_pallas(x: jax.Array, packed: jax.Array,
                          scale_exp: jax.Array, fmt: MXFormat, *,
                          tm: int, tn: int, tk: int,
                          interpret: bool = False) -> jax.Array:
    """x (M, K) @ dequant(int4-packed (K, N/2), scales (K/bs, N)) -> (M, N)."""
    m, k = x.shape
    k2, half_n = packed.shape
    n = half_n * 2
    bs = fmt.block_size
    assert fmt.kind == "int" and fmt.bits == 4
    assert k == k2 and m % tm == 0 and k % tk == 0 and tk % bs == 0
    assert half_n % tn == 0, "tile must not straddle the packed halves"
    half_blocks = half_n // tn
    grid = (m // tm, n // tn, k // tk)

    def packed_idx(i, j, kk):
        return (kk, jnp.where(j < half_blocks, j, j - half_blocks))

    return pl.pallas_call(
        functools.partial(_mm4_kernel, fmt=fmt, half_blocks=half_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), packed_idx),
            pl.BlockSpec((tk // bs, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, packed, scale_exp)
