"""Pallas TPU kernel: gather-free paged decode attention over the block table.

``paged_gather`` (models/layers.py) made paged serving *correct* by
materializing each slot's logical KV view — (B, max_pages*page_size, Hkv, D)
per layer per tick — before the masked softmax, so attention-side HBM
traffic and scratch footprint still scaled with ``max_len`` rather than live
tokens. This kernel is the PagedAttention move (Kwon et al., SOSP 2023): it
consumes the page pools and the per-slot block table *directly*.

Grid = (slot, logical KV block). Each step translates logical block ``j`` →
physical page via the scalar-prefetched block table (the index map picks the
page, so only the pages a slot actually occupies are ever DMA'd into VMEM)
and folds one page into a flash-style running (max, sum-exp, acc) partial
softmax held in VMEM scratch. Steps past the live frontier revisit the last
live page — Pallas skips the DMA when the block index repeats — so per-slot
KV reads are ``ceil(cache_len/page_size)`` pages, not ``max_pages``.

Masking is IN-KERNEL and total: a position contributes iff
``pos < cache_len`` (and, with a sliding window, ``pos >= cache_len - W``).
Scores at dead positions are forced to -inf *before* the running max,
probabilities are re-zeroed after the exp, and V rows are zeroed before the
PV product — so garbage beyond the write frontier, scratch-page-0 contents,
and unallocated pages never enter the reduction, **even when they hold NaN
or ±1e9** (0 * NaN = NaN, which is why masking only the scores is not
enough; the adversarial poison tests in tests/test_paged_attention_kernel.py
hold this line). ``cache_len == 0`` rows produce exact zeros (the dense
reference NaNs there — no valid key exists; the engine never emits it since
decode always appends before attending).

GQA (``Hkv != H``) runs natively: queries fold to (Hkv, G, D) and every
reduction stays per-kv-head, matching ``decode_attention``.

The multi-query variant (``paged_attention_pallas_mq``) generalizes the
grid to (slot, q block, logical KV block) for the unified mixed
prefill+decode tick: each row carries a ragged span of ``q_len`` queries at
cursor ``q_offset`` (decode rows 1, the mid-prefill row a whole chunk), the
causal mask is per query lane (``pos <= q_offset + i``), and the same
clamped block-table walk bounds DMA to the pages each q block's live lanes
can see (``pages_read_mq``). It subsumes the single-query kernel
(``q_len == 1`` rows cost and compute identically) and retires the
gather-based chunked-prefill read path on TPU.

Dispatch (mirroring kernels/dispatch.py): ``paged_decode_attention`` is the
serving entry point. Mode "pallas" runs this kernel — Mosaic on TPU,
interpret-mode elsewhere (the test/CI correctness path); mode "fallback"
keeps the original gather + ``decode_attention`` pair; "auto" picks
"pallas" on TPU. Trace-time ``stats()`` counters let benchmarks and the
``kernels_bench.py --smoke`` CI gate assert which path is live.

Layout/placement conventions are documented in docs/serving_internals.md §5.

Tensor parallelism: every dimension here — Hkv, page size, page count — is
derived from the INPUT shapes, never from a model config, so under the
head-sharded serving mesh (docs §11) the kernels run unchanged on each
shard's local slice of the pools (kv-head axis split across chips) with the
REPLICATED block table and its global page ids. The grid covers local pages
only; no collective appears at this layer (attention is exactly per-kv-head
parallel — the psum lives in the wo projection above).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# ---------------------------------------------------------------------------
# Mode resolution + trace-time accounting (kernels/dispatch.py conventions)
# ---------------------------------------------------------------------------
MODES = ("auto", "pallas", "fallback")

_stats: Dict[str, int] = {"pallas": 0, "fallback": 0,
                          "pallas_mq": 0, "fallback_mq": 0}


def stats() -> Dict[str, int]:
    """Trace-time counts of which paged-attention path was dispatched."""
    return dict(_stats)


def reset_stats() -> None:
    for k in _stats:
        _stats[k] = 0


def default_mode() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "fallback"


def resolve_mode(mode: Optional[str]) -> str:
    if mode is None or mode == "auto":
        return default_mode()
    if mode not in ("pallas", "fallback"):
        raise ValueError(
            f"unknown paged-attention mode {mode!r}; one of {MODES}")
    return mode


def _interpret() -> bool:
    # Mosaic only lowers on TPU; everywhere else the kernel body runs in the
    # Pallas interpreter (exactly as written — the CI correctness contract).
    return jax.default_backend() != "tpu"


def pages_read(length: int, page_size: int,
               window: Optional[int] = None) -> int:
    """Distinct pages one slot's block-table walk DMAs for ``length`` live
    tokens — THE host-side mirror of ``kv_index``'s clamp arithmetic below
    (the engine's attention-read accounting must use this, never reimplement
    it, so the metric stays definitionally consistent with the kernel).
    Zero-length rows still fetch the clamped page 0 once."""
    pages = max(-(-length // page_size), 1)
    if window is not None:
        pages -= min(max((length - window) // page_size, 0), pages - 1)
    return pages


def pages_read_mq(q_offset: int, q_len: int, page_size: int,
                  window: Optional[int] = None) -> int:
    """Distinct pages the multi-query walk DMAs for one row whose ``q_len``
    queries sit at positions ``q_offset .. q_offset + q_len - 1`` — the
    host-side mirror of the MQ ``kv_index`` clamp below (single q block).
    The highest query attends up to ``q_offset + q_len`` positions; the
    lowest query's window lower-bounds the walk. ``q_len == 1`` collapses
    to ``pages_read(q_offset + 1, ...)`` — decode rows in a mixed batch
    cost exactly what they cost in the single-query kernel."""
    last = max(-(-(q_offset + q_len) // page_size) - 1, 0)
    first = 0
    if window is not None:
        first = min(max((q_offset + 1 - window) // page_size, 0), last)
    return last - first + 1


# ---------------------------------------------------------------------------
# The kernel
# ---------------------------------------------------------------------------
def _paged_attn_kernel(bt_ref, cl_ref, q_ref, k_ref, v_ref, o_ref,
                       m_ref, l_ref, acc_ref, *,
                       page_size: int, window: Optional[int],
                       hkv: int, g: int):
    """One (slot, logical-block) grid step of the flash partial softmax.

    ``bt_ref``/``cl_ref`` are the scalar-prefetched block table and
    cache_len (also consumed by the index maps); ``k_ref``/``v_ref`` hold
    ONE physical page each — the page this slot's block ``j`` maps to.
    Scratch (m, l, acc) persists across the j-minor grid walk of a slot.
    """
    b = pl.program_id(0)
    j = pl.program_id(1)
    mp = pl.num_programs(1)
    d = q_ref.shape[-1]
    scale = 1.0 / (d ** 0.5)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cache_len = cl_ref[b]
    pos = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)[0]
    valid = pos < cache_len
    if window is not None:
        valid &= pos >= cache_len - window

    # Skip pages with no live position: keeps the running max finite (a
    # wholly-masked page would be all -inf and poison the carry with
    # exp(-inf - -inf) = NaN) and skips the FLOPs past the frontier.
    @pl.when(jnp.any(valid))
    def _accumulate():
        q = q_ref[0].astype(jnp.float32).reshape(hkv, g, d)
        k = k_ref[0].astype(jnp.float32)             # (ps, Hkv, D)
        s = jnp.einsum("kgd,tkd->kgt", q, k) * scale
        # Mask BEFORE the max — dead positions may hold NaN (poisoned /
        # recycled pages) and NaN propagates through jnp.maximum.
        s = jnp.where(valid[None, None, :], s, -jnp.inf)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        # p is already 0 at dead positions (exp(-inf)) but 0 * NaN = NaN in
        # the PV product, so the V rows are zeroed too — this pair is what
        # the NaN-poison tests pin down.
        p = jnp.where(valid[None, None, :], p, 0.0)
        v = jnp.where(valid[:, None, None],
                      v_ref[0].astype(jnp.float32), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + \
            jnp.einsum("kgt,tkd->kgd", p, v)
        m_ref[...] = m_new

    @pl.when(j == mp - 1)
    def _finalize():
        l = l_ref[...]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)[..., None]
        out = jnp.where(l[..., None] > 0, out, 0.0)   # cache_len==0 -> zeros
        o_ref[0] = out.reshape(hkv * g, d).astype(o_ref.dtype)


def paged_attention_pallas(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_table: jax.Array,
                           cache_len: jax.Array, *,
                           window: Optional[int] = None,
                           interpret: bool = False) -> jax.Array:
    """Single-token attention straight off the page pool: (B, H, D) f32.

    q (B, H, D); k_pages/v_pages (P, page_size, Hkv, D) — ONE layer's pool;
    block_table (B, max_pages) int32 physical page ids (0 = unmapped /
    scratch); cache_len (B,) int32 live lengths (may be traced). The block
    table and cache_len ride as scalar-prefetch operands so the KV index
    maps can translate logical block → physical page before each DMA.
    """
    b, h, d = q.shape
    ps = k_pages.shape[1]
    hkv = k_pages.shape[2]
    mp = block_table.shape[1]
    assert h % hkv == 0, (h, hkv)
    g = h // hkv

    def kv_index(bi, j, bt, cl):
        # Clamp the walk to the live block range: steps outside it revisit
        # the nearest live page, and Pallas elides the DMA when the index
        # repeats — the bytes-read term drops from max_pages to
        # ceil(cache_len/ps) pages (to the ~window/ps in-window pages when
        # sliding; blocks below the window hold no valid position, their
        # compute is @pl.when-skipped, so revisiting the first in-window
        # page is safe).
        last = jnp.maximum(pl.cdiv(cl[bi], ps) - 1, 0)
        jc = jnp.minimum(j, last)
        if window is not None:
            first = jnp.clip((cl[bi] - window) // ps, 0, last)
            jc = jnp.maximum(jc, first)
        return (bt[bi, jc], 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, mp),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda bi, j, bt, cl: (bi, 0, 0)),
            pl.BlockSpec((1, ps, hkv, d), kv_index),
            pl.BlockSpec((1, ps, hkv, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda bi, j, bt, cl: (bi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hkv, g), jnp.float32),       # running max
            pltpu.VMEM((hkv, g), jnp.float32),       # running sum-exp
            pltpu.VMEM((hkv, g, d), jnp.float32),    # running PV acc
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_attn_kernel, page_size=ps, window=window,
                          hkv=hkv, g=g),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), jnp.float32),
        interpret=interpret,
    )(block_table, cache_len, q, k_pages, v_pages)


# ---------------------------------------------------------------------------
# Multi-query extension: ragged rows of a mixed prefill+decode batch
# ---------------------------------------------------------------------------
def _paged_attn_mq_kernel(bt_ref, qo_ref, ql_ref, q_ref, k_ref, v_ref, o_ref,
                          m_ref, l_ref, acc_ref, *,
                          page_size: int, window: Optional[int],
                          hkv: int, g: int, tq: int):
    """One (slot, q-block, logical-KV-block) grid step.

    The q_len==1 kernel above with a query axis: each slot carries ``tq``
    query lanes per q block; lane ``i`` of block ``qi`` sits at logical
    position ``q_offset + qi*tq + i`` and is live iff ``qi*tq + i < q_len``.
    Scratch persists across the j-minor KV walk of one (slot, q block);
    masking stays total (scores -inf'd before the max, p re-zeroed after the
    exp, V rows zeroed) so poisoned pages and the garbage under dead query
    lanes never reach the reduction. Unlike the single-query kernel, a page
    the walk visits can be live for some lanes and dead for others, so the
    running max is per-lane and the carry ``alpha`` needs the
    ``m == -inf`` guard (exp(-inf - -inf) would NaN a lane that has not
    seen a valid position yet).
    """
    b = pl.program_id(0)
    qi = pl.program_id(1)
    j = pl.program_id(2)
    mp = pl.num_programs(2)
    d = q_ref.shape[-1]
    scale = 1.0 / (d ** 0.5)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_offset = qo_ref[b]
    q_len = ql_ref[b]
    live = q_offset + q_len                 # KV frontier after this tick
    pos = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)[0]
    qidx = qi * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, 1), 0)[:, 0]
    qpos = q_offset + qidx
    # (tq, ps): causal self-inclusive, clipped at the frontier, windowed,
    # and dead for pad lanes past q_len.
    mask = (pos[None, :] <= qpos[:, None]) & (pos[None, :] < live)
    mask &= (qidx < q_len)[:, None]
    if window is not None:
        mask &= qpos[:, None] - pos[None, :] < window
    vvalid = pos < live

    @pl.when(jnp.any(mask))
    def _accumulate():
        q = q_ref[0].astype(jnp.float32).reshape(tq, hkv, g, d)
        k = k_ref[0].astype(jnp.float32)             # (ps, Hkv, D)
        s = jnp.einsum("qkgd,tkd->kgqt", q, k) * scale
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_prev = m_ref[...]                          # (Hkv, G, tq)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(jnp.where(m_new > -jnp.inf, m_prev - m_new, 0.0))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        v = jnp.where(vvalid[:, None, None],
                      v_ref[0].astype(jnp.float32), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + \
            jnp.einsum("kgqt,tkd->kgqd", p, v)
        m_ref[...] = m_new

    @pl.when(j == mp - 1)
    def _finalize():
        l = l_ref[...]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)[..., None]
        out = jnp.where(l[..., None] > 0, out, 0.0)   # dead lanes -> zeros
        o_ref[0] = out.transpose(2, 0, 1, 3).reshape(
            tq, hkv * g, d).astype(o_ref.dtype)


def paged_attention_pallas_mq(q: jax.Array, k_pages: jax.Array,
                              v_pages: jax.Array, block_table: jax.Array,
                              q_offset: jax.Array, q_len: jax.Array, *,
                              window: Optional[int] = None,
                              tq: Optional[int] = None,
                              interpret: bool = False) -> jax.Array:
    """Ragged multi-query attention off the page pool: (B, C, H, D) f32.

    q (B, C, H, D) — row b's query ``i`` sits at logical position
    ``q_offset[b] + i`` and is live iff ``i < q_len[b]`` (decode rows carry
    C-1 dead pad lanes; the mid-prefill row is mostly live). The pool must
    already hold each row's new K/V at those positions. ``tq`` is the q
    block size (defaults to C — one block; must divide C); the KV walk per
    (row, q block) is clamped to the pages that block's live queries can
    see, so DMA cost follows ``pages_read_mq``, and dead q blocks collapse
    to one elided page. Dead lanes output exact zeros.
    """
    b, c, h, d = q.shape
    ps = k_pages.shape[1]
    hkv = k_pages.shape[2]
    mp = block_table.shape[1]
    assert h % hkv == 0, (h, hkv)
    g = h // hkv
    tq = c if tq is None else tq
    assert c % tq == 0, (c, tq)
    nq = c // tq

    def kv_index(bi, qi, j, bt, qo, ql):
        # Clamp the walk to [first in-window page of the block's lowest
        # query, last page its highest LIVE query can see]; out-of-range
        # steps revisit a live page and Pallas elides the repeat DMA.
        hi = qo[bi] + jnp.minimum((qi + 1) * tq, ql[bi])
        last = jnp.maximum(pl.cdiv(hi, ps) - 1, 0)
        jc = jnp.minimum(j, last)
        if window is not None:
            first = jnp.clip((qo[bi] + qi * tq + 1 - window) // ps, 0, last)
            jc = jnp.maximum(jc, first)
        return (bt[bi, jc], 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, nq, mp),
        in_specs=[
            pl.BlockSpec((1, tq, h, d),
                         lambda bi, qi, j, bt, qo, ql: (bi, qi, 0, 0)),
            pl.BlockSpec((1, ps, hkv, d), kv_index),
            pl.BlockSpec((1, ps, hkv, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, tq, h, d),
                               lambda bi, qi, j, bt, qo, ql: (bi, qi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hkv, g, tq), jnp.float32),     # running max
            pltpu.VMEM((hkv, g, tq), jnp.float32),     # running sum-exp
            pltpu.VMEM((hkv, g, tq, d), jnp.float32),  # running PV acc
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_attn_mq_kernel, page_size=ps, window=window,
                          hkv=hkv, g=g, tq=tq),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, c, h, d), jnp.float32),
        interpret=interpret,
    )(block_table, q_offset, q_len, q, k_pages, v_pages)


# ---------------------------------------------------------------------------
# Serving dispatch shim
# ---------------------------------------------------------------------------
def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_table: jax.Array,
                           cache_len: jax.Array, *,
                           window: Optional[int] = None,
                           mode: Optional[str] = None) -> jax.Array:
    """Paged decode attention: q (B, 1, H, D) over the page pool -> same.

    The paged counterpart of ``decode_attention`` and the entry point
    ``attention_block``'s paged-decode branch routes through. ``mode``:

      "pallas"    the gather-free kernel above (Mosaic on TPU, interpret
                  elsewhere — the test path). ``attn_impl="paged_kernel"``.
      "fallback"  ``paged_gather`` + masked ``decode_attention`` — the
                  original materialize-then-attend pair, kept selectable for
                  comparison. ``attn_impl="gather"``.
      "auto"/None "pallas" on TPU, "fallback" elsewhere.

    ``cache_len`` must already include this tick's appended token (callers
    pass ``cache_len + 1``, exactly as for ``decode_attention``).
    """
    if resolve_mode(mode) == "pallas":
        _stats["pallas"] += 1
        out = paged_attention_pallas(q[:, 0], k_pages, v_pages, block_table,
                                     cache_len, window=window,
                                     interpret=_interpret())
        return out[:, None].astype(q.dtype)
    _stats["fallback"] += 1
    from repro.models.layers import decode_attention, paged_gather
    return decode_attention(q, paged_gather(k_pages, block_table),
                            paged_gather(v_pages, block_table),
                            cache_len, window=window)


def paged_mixed_attention(q: jax.Array, k_pages: jax.Array,
                          v_pages: jax.Array, block_table: jax.Array,
                          q_offset: jax.Array, q_len: jax.Array, *,
                          window: Optional[int] = None,
                          mode: Optional[str] = None,
                          tq: Optional[int] = None) -> jax.Array:
    """Mixed-batch attention: ragged q (B, C, H, D) over the page pool.

    The multi-query counterpart of ``paged_decode_attention`` and the entry
    point ``attention_block``'s mixed branch routes through — one call
    serves the whole unified tick: decode rows at ``q_len == 1``, the
    mid-prefill row at its chunk width, pad lanes dead. ``mode``:

      "pallas"    the gather-free MQ kernel above (Mosaic on TPU, interpret
                  elsewhere). ``attn_impl="paged_kernel"`` — this retires
                  the gather-based chunked-prefill read path on TPU.
      "fallback"  ``paged_gather`` + masked ``mixed_attention`` — the
                  materialize-then-attend pair. ``attn_impl="gather"``.
      "auto"/None "pallas" on TPU, "fallback" elsewhere.

    The pool must already hold each row's new K/V (callers write through
    ``paged_mixed_update`` first); dead lanes output exact zeros on both
    paths.
    """
    if resolve_mode(mode) == "pallas":
        _stats["pallas_mq"] += 1
        out = paged_attention_pallas_mq(q, k_pages, v_pages, block_table,
                                        q_offset, q_len, window=window,
                                        tq=tq, interpret=_interpret())
        return out.astype(q.dtype)
    _stats["fallback_mq"] += 1
    from repro.models.layers import mixed_attention, paged_gather
    return mixed_attention(q, paged_gather(k_pages, block_table),
                           paged_gather(v_pages, block_table),
                           q_offset, q_len, window=window)
