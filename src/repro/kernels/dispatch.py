"""Quantized-matmul dispatch: packed MX weight leaves straight to the MXU.

``qmatmul(x, leaf)`` is the serving hot loop's GEMM entry point. It accepts
the packed containers the weight caches hold — ``MXTensor`` (int8/uint8
element codes + E8M0 scales) and split-N ``PackedInt4Leaf`` (nibble pairs) —
and routes them to the fused Pallas dequant-GEMM kernels in ``mx_matmul.py``
without ever materializing a dense weight in HBM:

  mode "pallas"   ``mx_matmul_pallas`` / ``mx_matmul_int4_pallas``; on TPU
                  these lower to Mosaic, elsewhere they run interpret-mode
                  (the test/CI correctness path).
  mode "densify"  XLA fallback: dequantize the leaf at its point of use and
                  issue a plain dot (XLA fuses the dequant into the GEMM).
  mode "auto"     "pallas" on TPU, "densify" elsewhere.

The wrapper owns everything the raw kernels refuse to deal with: arbitrary
``(M, K, N)`` via zero padding to tile multiples (zero codes dequantize to
exactly 0 in every MX format, so padding never perturbs the result), the
int4 kernel's ``half_n % tn == 0`` constraint (both packed halves are padded
and the two output column ranges re-spliced), and tile-size selection — a
static table refined by autotuned entries registered per ``(shape, fmt)``
from ``benchmarks/kernels_bench.py``.

Fallback conditions (leaf not 2D after scan slicing, legacy split-K int4
layout, non-even shapes) silently take the densify path; ``stats()`` counts
which path each traced call took so benchmarks and CI can assert the fused
kernels are actually live.

Layout conventions this layer depends on — scan-stale leaf metadata
(contraction dim re-derived as ndim-2), moved-last ``(N, K/bs)`` scales
(the kernels consume the transpose), split-N vs split-K nibble packing —
are documented in docs/serving_internals.md §§1-3.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.formats import MXFormat, get_format
from repro.core.mx import MXTensor
from repro.kernels import mx_matmul as _mm

# ---------------------------------------------------------------------------
# Mode resolution + trace-time accounting
# ---------------------------------------------------------------------------
MODES = ("auto", "pallas", "densify")

_stats: Dict[str, int] = {"pallas": 0, "pallas_int4": 0, "densify": 0}


def stats() -> Dict[str, int]:
    """Trace-time counts of which execution path qmatmul dispatched to."""
    return dict(_stats)


def reset_stats() -> None:
    for k in _stats:
        _stats[k] = 0


def default_mode() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "densify"


def resolve_mode(mode: Optional[str]) -> str:
    if mode is None or mode == "auto":
        return default_mode()
    if mode not in ("pallas", "densify"):
        raise ValueError(f"unknown qmatmul mode {mode!r}; one of {MODES}")
    return mode


def _interpret() -> bool:
    # Mosaic only lowers on TPU; everywhere else the kernel body runs in the
    # Pallas interpreter (exactly as written — the CI correctness contract).
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Tile selection: static table + autotune-registered cache
# ---------------------------------------------------------------------------
# (m, k, n, block_size, fmt_name, kind) -> (tm, tn, tk); kind is "mx" or
# "int4" (for "int4" the tn entry tiles half_n = n // 2, matching the kernel
# grid). The key is EXACTLY the shapes the kernel is traced with — under
# shard_map these are the per-shard LOCAL dims (a tensor-parallel projection
# sees n / n_model, or k / n_model for row-parallel), so autotune for a
# meshed engine must register the local shapes, and entries tuned at global
# shapes simply miss (heuristic fallback) instead of mis-tiling the shard.
# block_size is part of the key: a tk tuned for one block size need not
# divide another's scale blocking (kp // bs would truncate — silently wrong
# scales), so entries never apply across block sizes.
_TILE_CACHE: Dict[Tuple[int, int, int, int, str, str],
                  Tuple[int, int, int]] = {}

# Hard ceilings keeping one (TM,TK)+(TK,TN) operand pair comfortably in VMEM.
_TM_CAP, _TN_CAP, _TK_CAP = 128, 256, 512


def register_tiles(m: int, k: int, n: int, fmt_name: str,
                   tiles: Tuple[int, int, int], kind: str = "mx",
                   block_size: int = 32) -> None:
    """Pin (tm, tn, tk) for an exact (M, K, N, fmt@block_size) — autotune
    results land here (``benchmarks/kernels_bench.py::autotune_qmatmul``).
    (M, K, N) are the shapes the kernel is traced with: per-shard local
    dims under a mesh, global dims on one device."""
    _TILE_CACHE[(m, k, n, block_size, fmt_name, kind)] = tuple(tiles)


def tile_cache() -> Dict:
    return dict(_TILE_CACHE)


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def _best_tile(dim: int, unit: int, cap: int) -> int:
    """Largest multiple of ``unit`` <= cap that minimizes padded size."""
    best, best_pad = unit, _round_up(max(dim, 1), unit)
    t = unit
    while t <= cap:
        pad = _round_up(max(dim, 1), t)
        if pad < best_pad or (pad == best_pad and t > best):
            best, best_pad = t, pad
        t += unit
    return best


def select_tiles(m: int, k: int, n: int, fmt: MXFormat,
                 kind: str = "mx") -> Tuple[int, int, int]:
    """(tm, tn, tk) for an (M, K, N) qmatmul at ``fmt``.

    Autotuned entries win; otherwise tiles are picked to minimize zero
    padding subject to VMEM-friendly caps — sublane multiples of 8 for M,
    lane-dim multiples of 8 (128 when it divides) for N, block-size
    multiples for K so scales tile alongside the weight.

    ``(m, k, n)`` are whatever shapes this trace actually sees — per-shard
    local dims inside shard_map — and the lookup keys on them plus
    ``fmt.block_size``, so a cached entry can never pick tiles that don't
    divide the shapes (or scale blocking) of the call at hand. A registered
    entry that nonetheless violates the kernel's alignment rules (stale
    hand-registration) is ignored, not applied.
    """
    bs = fmt.block_size
    key = (m, k, n, bs, fmt.name, kind)
    if key in _TILE_CACHE:
        tm, tn, tk = _TILE_CACHE[key]
        if tm % 8 == 0 and tn > 0 and tk % bs == 0:
            return tm, tn, tk
    n_eff = n // 2 if kind == "int4" else n
    tm = _best_tile(m, 8, _TM_CAP)
    tn = 128 if n_eff % 128 == 0 else _best_tile(n_eff, 8, _TN_CAP)
    tk = _best_tile(k, bs, max(bs, (_TK_CAP // bs) * bs))
    return tm, tn, tk


# ---------------------------------------------------------------------------
# Padded kernel wrappers
# ---------------------------------------------------------------------------
def _pad_to(a: jax.Array, axis: int, size: int) -> jax.Array:
    pad = size - a.shape[axis]
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def qmatmul_mx(x2: jax.Array, codes: jax.Array, scales_kn: jax.Array,
               fmt: MXFormat, *, tiles: Optional[Tuple] = None) -> jax.Array:
    """x2 (M, K) @ dequant(codes (K, N), scales (K/bs, N)) -> (M, N) f32.

    Pads every dim to the selected tile multiples (zero codes contribute
    exactly 0) and slices the result back — arbitrary shapes welcome.
    """
    m, k = x2.shape
    n = codes.shape[1]
    bs = fmt.block_size
    tm, tn, tk = tiles or select_tiles(m, k, n, fmt, kind="mx")
    mp, kp, np_ = _round_up(m, tm), _round_up(k, tk), _round_up(n, tn)
    x2 = _pad_to(_pad_to(x2, 0, mp), 1, kp)
    codes = _pad_to(_pad_to(codes, 0, kp), 1, np_)
    scales = _pad_to(_pad_to(scales_kn, 0, kp // bs), 1, np_)
    _stats["pallas"] += 1
    out = _mm.mx_matmul_pallas(x2, codes, scales, fmt, tm=tm, tn=tn, tk=tk,
                               interpret=_interpret())
    return out[:m, :n]


def qmatmul_int4(x2: jax.Array, packed: jax.Array, scales_kn: jax.Array,
                 fmt: MXFormat, *, tiles: Optional[Tuple] = None) -> jax.Array:
    """x2 (M, K) @ dequant(split-N int4 (K, N/2), scales (K/bs, N)) -> (M, N).

    The raw kernel requires ``half_n % tn == 0``; here both nibble halves are
    zero-padded to the tile multiple (scales split and re-packed to match the
    padded column layout) and the two true output ranges re-spliced, so odd
    tile-unfriendly N just works.
    """
    m, k = x2.shape
    half_n = packed.shape[1]
    n = half_n * 2
    bs = fmt.block_size
    tm, tn, tk = tiles or select_tiles(m, k, n, fmt, kind="int4")
    mp, kp = _round_up(m, tm), _round_up(k, tk)
    hp = _round_up(half_n, tn)
    x2 = _pad_to(_pad_to(x2, 0, mp), 1, kp)
    packed = _pad_to(_pad_to(packed, 0, kp), 1, hp)
    scales = jnp.concatenate([_pad_to(scales_kn[:, :half_n], 1, hp),
                              _pad_to(scales_kn[:, half_n:], 1, hp)], axis=1)
    scales = _pad_to(scales, 0, kp // bs)
    _stats["pallas_int4"] += 1
    out = _mm.mx_matmul_int4_pallas(x2, packed, scales, fmt,
                                    tm=tm, tn=tn, tk=tk,
                                    interpret=_interpret())
    return jnp.concatenate([out[:m, :half_n], out[:m, hp:hp + half_n]],
                           axis=1)


# ---------------------------------------------------------------------------
# Leaf-level dispatch
# ---------------------------------------------------------------------------
def _check_serving_layout(leaf) -> None:
    """Reject 2D MXTensor leaves whose scales aren't in the serving layout.

    The contract is codes (K, N) with scale_exp (N, K/bs) — what
    ``quantize(w, fmt, axis=0)`` and scan-sliced serving trees produce. A
    leaf quantized along the wrong axis has scale_exp (K, N/bs), which for
    non-square weights is caught here LOUDLY (both the fused kernel and the
    serving-axis densify fallback would silently misread it). Square K == N
    is inherently shape-ambiguous; callers own the convention there.
    """
    if isinstance(leaf, MXTensor) and leaf.codes.ndim == 2:
        k, n = leaf.codes.shape
        bs = leaf.fmt.block_size
        want = (n, k // bs)
        if k % bs == 0 and tuple(leaf.scale_exp.shape) != want:
            raise ValueError(
                f"MXTensor leaf violates the serving layout: codes "
                f"{(k, n)} expect scale_exp {want}, got "
                f"{tuple(leaf.scale_exp.shape)} — was it quantized along "
                "the wrong axis?")


def _fused_supported(leaf) -> bool:
    from repro.serve.packed_params import PackedInt4Leaf
    if isinstance(leaf, MXTensor):
        return leaf.codes.ndim == 2 and leaf.codes.shape[0] % \
            leaf.fmt.block_size == 0
    if isinstance(leaf, PackedInt4Leaf):
        # legacy split-K nibble layout has no fused kernel — densify it
        return leaf.layout == "splitn" and leaf.packed.ndim == 2
    return False


def qmatmul(x: jax.Array, leaf, *, mode: Optional[str] = None,
            block_size: int = 32, tiles: Optional[Tuple] = None,
            out_dtype=None) -> jax.Array:
    """y = x @ dequant(leaf), never materializing the dense weight in HBM.

    x (..., K); leaf is an MXTensor with codes (K, N) / scales (N, K/bs)
    (the serving convention: contraction dim = ndim-2, scales in the
    moved-last blocked layout) or a split-N PackedInt4Leaf with packed
    (K, N/2). Block sizes are carried by the leaves themselves
    (``block_size`` is kept for API stability only). Returns (..., N) in
    ``out_dtype`` (default: x.dtype).
    """
    out_dtype = out_dtype or x.dtype
    _check_serving_layout(leaf)
    use_pallas = resolve_mode(mode) == "pallas" and _fused_supported(leaf)
    if not use_pallas:
        from repro.serve.packed_params import densify_leaf
        _stats["densify"] += 1
        w = densify_leaf(leaf, None, out_dtype, serving_axis=True)
        return jax.lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())),
                                   preferred_element_type=out_dtype)

    from repro.serve.packed_params import PackedInt4Leaf, leaf_block_size
    k = x.shape[-1]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k)
    if isinstance(leaf, MXTensor):
        fmt = leaf.fmt
        out = qmatmul_mx(x2, leaf.codes, leaf.scale_exp.T, fmt, tiles=tiles)
    else:
        assert isinstance(leaf, PackedInt4Leaf)
        # block size from the leaf's own shapes, not the registry default
        fmt = get_format(leaf.fmt_name, leaf_block_size(leaf))
        out = qmatmul_int4(x2, leaf.packed, leaf.scale_exp.T, fmt,
                           tiles=tiles)
    return out.reshape(*lead, out.shape[-1]).astype(out_dtype)


def make_qmm(block_size: int = 32, mode: Optional[str] = None) -> Callable:
    """A ``QuantCtx.qmm`` hook: (x, leaf, name) -> y at a fixed mode.

    The mode is resolved once, at construction — engines build one jitted
    executable per hook, so the fused/densify choice is baked into the trace
    (no stale-jit-cache hazards from flipping a global).
    """
    resolved = resolve_mode(mode)

    def qmm(x, leaf, name=None):
        del name
        return qmatmul(x, leaf, mode=resolved, block_size=block_size)

    return qmm
