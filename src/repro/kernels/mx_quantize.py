"""Pallas TPU kernel: block-wise MX quantization (codes + E8M0 scales).

Tiling: grid over (rows/TM, cols/TC); each step loads a (TM, TC) f32 tile
HBM->VMEM, computes per-32(block)-column max, assembles the shared exponent,
casts elements, and writes int8 codes + int8 scales. TC is a multiple of the
scaling block size and of 128 (lane width) so the MXU/VPU see aligned tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import MXFormat
from repro.kernels.common import quantize_block_tile


def _kernel(v_ref, codes_ref, scales_ref, *, fmt: MXFormat):
    codes, scales = quantize_block_tile(v_ref[...].astype(jnp.float32), fmt)
    codes_ref[...] = codes.astype(codes_ref.dtype)
    scales_ref[...] = scales


def mx_quantize_pallas(v: jax.Array, fmt: MXFormat, *, tm: int, tc: int,
                       interpret: bool = False):
    """v (R, C) f32/bf16 -> (codes (R, C), scale_exp (R, C/bs)) int8."""
    r, c = v.shape
    bs = fmt.block_size
    assert c % tc == 0 and r % tm == 0 and tc % bs == 0, (r, c, tm, tc, bs)
    code_dtype = jnp.int8 if fmt.kind == "int" else jnp.uint8
    grid = (r // tm, c // tc)
    return pl.pallas_call(
        functools.partial(_kernel, fmt=fmt),
        grid=grid,
        in_specs=[pl.BlockSpec((tm, tc), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((tm, tc), lambda i, j: (i, j)),
            pl.BlockSpec((tm, tc // bs), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, c), code_dtype),
            jax.ShapeDtypeStruct((r, c // bs), jnp.int8),
        ],
        interpret=interpret,
    )(v)
