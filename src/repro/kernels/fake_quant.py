"""Pallas TPU kernel: fused QAT fake-quantization (quantize -> dequantize).

The QAT forward path runs this on every quantized weight every step; fusing
the block-max, cast, and rescale into one VMEM pass avoids materializing
codes/scales in HBM (3 HBM round-trips -> 1 read + 1 write).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import MXFormat
from repro.kernels.common import dequantize_block_tile, quantize_block_tile


def _kernel(v_ref, out_ref, *, fmt: MXFormat):
    v = v_ref[...].astype(jnp.float32)
    codes, scales = quantize_block_tile(v, fmt)
    out_ref[...] = dequantize_block_tile(codes, scales, fmt).astype(out_ref.dtype)


def fake_quant_pallas(v: jax.Array, fmt: MXFormat, *, tm: int, tc: int,
                      interpret: bool = False) -> jax.Array:
    """v (R, C) -> fake-quantized values, same shape/dtype."""
    r, c = v.shape
    assert c % tc == 0 and r % tm == 0 and tc % fmt.block_size == 0
    grid = (r // tm, c // tc)
    return pl.pallas_call(
        functools.partial(_kernel, fmt=fmt),
        grid=grid,
        in_specs=[pl.BlockSpec((tm, tc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tm, tc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), v.dtype),
        interpret=interpret,
    )(v)
