"""rwkv6-7b — exact assigned config (see repo prompt; [source] in DESIGN.md)."""
from repro.models.common import ModelConfig
import dataclasses

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,
    d_ff=14336, vocab=65536,
    rwkv_head_dim=64,
)


def reduced() -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    return _reduce(CONFIG)


from repro.configs._reduce import _reduce  # noqa: E402
