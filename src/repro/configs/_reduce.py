"""Reduced same-family configs for CPU smoke tests."""
import dataclasses

from repro.models.common import ModelConfig
import jax.numpy as jnp


def _reduce(cfg: ModelConfig) -> ModelConfig:
    upd = dict(
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        vocab=512, compute_dtype=jnp.float32, seq_chunk=64,
    )
    if cfg.family == "moe":
        upd.update(moe_experts=4, n_layers=2)
    elif cfg.family == "hybrid":
        upd.update(moe_experts=4, moe_every=2, moe_offset=1,
                   attn_every=4, attn_offset=2, scan_group=4, n_layers=4,
                   mamba_d_state=4)
    elif cfg.family == "ssm":
        upd.update(n_layers=2, n_kv_heads=4, rwkv_head_dim=16)
    elif cfg.family == "encdec":
        upd.update(n_layers=2, enc_layers=2, n_kv_heads=4)
    elif cfg.family == "vlm":
        upd.update(n_layers=2, vision_tokens=24)
    else:
        upd.update(n_layers=2)
    if cfg.sliding_window is not None:
        upd["sliding_window"] = 32
    return dataclasses.replace(cfg, **upd)
