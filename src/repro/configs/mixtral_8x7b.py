"""mixtral-8x7b — exact assigned config (see repo prompt; [source] in DESIGN.md)."""
from repro.models.common import ModelConfig
import dataclasses

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000,
    moe_experts=8, moe_topk=2, sliding_window=4096,
    rope_theta=1e6,
)


def reduced() -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    return _reduce(CONFIG)


from repro.configs._reduce import _reduce  # noqa: E402
