"""starcoder2-3b — exact assigned config (see repo prompt; [source] in DESIGN.md)."""
from repro.models.common import ModelConfig
import dataclasses

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12288, vocab=49152,
    act="gelu", qkv_bias=True, mlp_bias=True, rope_theta=1e5,
)


def reduced() -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    return _reduce(CONFIG)


from repro.configs._reduce import _reduce  # noqa: E402
