"""Assigned input-shape set (same 4 shapes for every LM arch).

``train_*`` lowers train_step; ``prefill_*`` lowers the prefill path;
``decode_*`` / ``long_*`` lower serve_step (one new token against a KV cache
of seq_len). ``long_500k`` requires sub-quadratic attention: it runs for
SSM / hybrid / sliding-window archs and is skipped for pure full-attention
archs (DESIGN.md §Arch-applicability).
"""
import dataclasses
from typing import Optional

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def subquadratic(cfg: ModelConfig) -> bool:
    return (cfg.family in ("ssm", "hybrid")
            or cfg.sliding_window is not None)


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    if shape.name == "long_500k" and not subquadratic(cfg):
        return False
    return True


def decode_cache_len(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """KV cache allocation for decode cells: SWA caches are window-bounded."""
    if cfg.sliding_window is not None:
        return min(shape.seq_len, cfg.sliding_window)
    return shape.seq_len
