"""llava-next-mistral-7b — exact assigned config (see repo prompt; [source] in DESIGN.md)."""
from repro.models.common import ModelConfig
import dataclasses

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000,
    vision_tokens=2880,  # anyres: 5 tiles x 576 patch embeds (stub frontend)
    rope_theta=1e6,
)


def reduced() -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    return _reduce(CONFIG)


from repro.configs._reduce import _reduce  # noqa: E402
