"""qwen3-4b — exact assigned config (see repo prompt; [source] in DESIGN.md)."""
from repro.models.common import ModelConfig
import dataclasses

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=9728, vocab=151936,
    head_dim=128, qk_norm=True, rope_theta=1e6,
)


def reduced() -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    return _reduce(CONFIG)


from repro.configs._reduce import _reduce  # noqa: E402
