"""Per-architecture configs (assigned pool) + shape specs.

Select with ``--arch <id>`` in the launchers; ``get_config(id)`` here.
"""
import importlib
from typing import Dict, List

from repro.models.common import ModelConfig
from repro.configs.shapes import (SHAPES, ShapeSpec, applicable,
                                  decode_cache_len, subquadratic)

_MODULES = {
    "mixtral-8x22b": "mixtral_8x22b",
    "mixtral-8x7b": "mixtral_8x7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "qwen3-4b": "qwen3_4b",
    "qwen2-72b": "qwen2_72b",
    "smollm-135m": "smollm_135m",
    "starcoder2-3b": "starcoder2_3b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "rwkv6-7b": "rwkv6_7b",
}


def list_archs() -> List[str]:
    return sorted(_MODULES)


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _mod(name).CONFIG


def get_reduced(name: str) -> ModelConfig:
    return _mod(name).reduced()
