"""jamba-1.5-large-398b — exact assigned config (see repo prompt; [source] in DESIGN.md)."""
from repro.models.common import ModelConfig
import dataclasses

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab=65536,
    moe_experts=16, moe_topk=2, moe_every=2, moe_offset=1,
    attn_every=8, attn_offset=4, scan_group=8,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
)


def reduced() -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    return _reduce(CONFIG)


from repro.configs._reduce import _reduce  # noqa: E402
