"""seamless-m4t-large-v2 — exact assigned config (see repo prompt; [source] in DESIGN.md)."""
from repro.models.common import ModelConfig
import dataclasses

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206,
    act="gelu", enc_layers=24, audio_downsample=4,
)


def reduced() -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    return _reduce(CONFIG)


from repro.configs._reduce import _reduce  # noqa: E402
