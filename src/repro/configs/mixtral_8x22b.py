"""mixtral-8x22b — exact assigned config (see repo prompt; [source] in DESIGN.md)."""
from repro.models.common import ModelConfig
import dataclasses

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=32768,
    moe_experts=8, moe_topk=2, sliding_window=4096,
    rope_theta=1e6,
)


def reduced() -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    return _reduce(CONFIG)


from repro.configs._reduce import _reduce  # noqa: E402
