"""qwen2-72b — exact assigned config (see repo prompt; [source] in DESIGN.md)."""
from repro.models.common import ModelConfig
import dataclasses

CONFIG = ModelConfig(
    name="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab=152064,
    qkv_bias=True, rope_theta=1e6,
)


def reduced() -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    return _reduce(CONFIG)


from repro.configs._reduce import _reduce  # noqa: E402
