"""smollm-135m — exact assigned config (see repo prompt; [source] in DESIGN.md)."""
from repro.models.common import ModelConfig
import dataclasses

CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
    d_ff=1536, vocab=49152,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    return _reduce(CONFIG)


from repro.configs._reduce import _reduce  # noqa: E402
