"""Mamba-1 selective-SSM block (Jamba's dominant mixer).

Chunked selective scan: Δ/B/C projections are computed for the full sequence
(small tensors), but the (B, S, d_inner, d_state) discretized operands are
only materialized one chunk at a time inside a lax.scan with an associative
scan within the chunk — the TPU-friendly analogue of the fused CUDA kernel's
SRAM blocking (HBM never sees the expanded state).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, QuantCtx, trunc_normal

SCAN_CHUNK = 256


def init_mamba_params(key, cfg: ModelConfig):
    d, di = cfg.d_model, cfg.mamba_d_inner
    n, kc, dtr = cfg.mamba_d_state, cfg.mamba_d_conv, cfg.dt_rank
    ks = jax.random.split(key, 8)
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": trunc_normal(ks[0], (d, 2 * di)),
        "conv_w": trunc_normal(ks[1], (kc, di), std=0.1),
        "conv_b": jnp.zeros((di,)),
        "x_proj": trunc_normal(ks[2], (di, dtr + 2 * n)),
        "dt_w": trunc_normal(ks[3], (dtr, di)),
        "dt_bias": jnp.full((di,), -4.6),     # softplus^-1(0.01)
        "A_log": jnp.log(a),
        "D": jnp.ones((di,)),
        "out_proj": trunc_normal(ks[4], (di, d), std=0.02 / cfg.n_layers ** 0.5),
    }


def mamba_param_axes(cfg: ModelConfig):
    return {
        "in_proj": ("fsdp", "model"),
        "conv_w": (None, "model"),
        "conv_b": ("model",),
        "x_proj": ("model", None),
        "dt_w": (None, "model"),
        "dt_bias": ("model",),
        "A_log": ("model", None),
        "D": ("model",),
        "out_proj": ("model", "fsdp"),
    }


def _causal_conv1d(x, w, b, conv_state):
    """Depthwise causal conv along S. x (B,S,di), w (K,di).

    Returns (y, new_conv_state (B,K-1,di))."""
    kc = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], kc - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(kc))
    return y + b[None, None].astype(y.dtype), xp[:, -(kc - 1):]


def _ssm_combine(left, right):
    (al, bl), (ar, br) = left, right
    return al * ar, ar * bl + br


def selective_scan(dt, a_log, b_in, c_in, xi, h0, chunk=SCAN_CHUNK):
    """Chunked selective scan.

    dt (B,S,di) f32, a_log (di,N), b_in/c_in (B,S,N), xi (B,S,di).
    Returns (y (B,S,di), h_final (B,di,N)).
    """
    bsz, s, di = dt.shape
    n = a_log.shape[1]
    a = -jnp.exp(a_log.astype(jnp.float32))                    # (di, N)
    c = min(chunk, s)
    while s % c:
        c //= 2
    nc = s // c

    def to_chunks(t):
        return t.reshape(bsz, nc, c, *t.shape[2:]).swapaxes(0, 1)

    xs = (to_chunks(dt), to_chunks(b_in), to_chunks(c_in), to_chunks(xi))

    def step(h, inp):
        dt_c, b_c, c_c, x_c = inp                               # (B,c,...)
        da = jnp.exp(dt_c[..., None] * a[None, None])           # (B,c,di,N)
        dbx = (dt_c * x_c)[..., None] * b_c[:, :, None, :]      # (B,c,di,N)
        aa, bb = jax.lax.associative_scan(_ssm_combine, (da, dbx), axis=1)
        hs = aa * h[:, None] + bb                               # (B,c,di,N)
        y = jnp.einsum("bcdn,bcn->bcd", hs, c_c)
        return hs[:, -1], y

    h0 = jnp.zeros((bsz, di, n), jnp.float32) if h0 is None else h0
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1).reshape(bsz, s, di)
    return y, h_final


def mamba_block(ctx: QuantCtx, x: jax.Array, p, cfg: ModelConfig, name: str,
                state: Optional[Tuple] = None):
    """x (B,S,d) -> (out, new_state). state = (h (B,di,N), conv (B,K-1,di))."""
    h0, conv0 = state if state is not None else (None, None)
    dtr, n = cfg.dt_rank, cfg.mamba_d_state

    xz = ctx.dense(x, p["in_proj"], name + ".in_proj")
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, conv_state = _causal_conv1d(xi, p["conv_w"].astype(xi.dtype),
                                    p["conv_b"], conv0)
    xi = jax.nn.silu(xi)

    bcd = ctx.dense(xi, p["x_proj"], name + ".x_proj").astype(jnp.float32)
    dt_lo, b_in, c_in = jnp.split(bcd, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt_lo @ p["dt_w"].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))

    y, h = selective_scan(dt, p["A_log"], b_in, c_in,
                          xi.astype(jnp.float32), h0)
    y = y + p["D"].astype(jnp.float32)[None, None] * xi.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = ctx.dense(y, p["out_proj"], name + ".out_proj",
                    out_logical=("batch", None, None))
    return out, (h, conv_state)
