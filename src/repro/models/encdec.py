"""Encoder-decoder LM (seamless-m4t-large-v2 backbone).

Speech frontend is a stub per the assignment: the encoder consumes
precomputed frame embeddings (B, S_enc, d). 24-layer bidirectional encoder +
24-layer causal decoder with cross-attention; both stacks scan over layers.
The decoder serve path caches self-attention K/V and the (static) per-layer
cross-attention K/V computed once from the encoder memory at prefill.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.qat import QATConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.common import (ModelConfig, QuantCtx, make_prefill_slot,
                                 stacked_init, trunc_normal)
from repro.sharding.rules import shard_act


def _init_enc_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {"mixer_norm": jnp.ones((cfg.d_model,)),
            "attn": T._init_attn(k1, cfg),
            "ffn_norm": jnp.ones((cfg.d_model,)),
            "mlp": T._init_mlp(k2, cfg)}


def _init_dec_layer(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"self_norm": jnp.ones((cfg.d_model,)),
            "self_attn": T._init_attn(k1, cfg),
            "cross_norm": jnp.ones((cfg.d_model,)),
            "cross_attn": T._init_attn(k2, cfg),
            "ffn_norm": jnp.ones((cfg.d_model,)),
            "mlp": T._init_mlp(k3, cfg)}


def init_params(key, cfg: ModelConfig) -> Dict:
    ke, kd, kemb, kh = jax.random.split(key, 4)
    return {
        "embed": trunc_normal(kemb, (cfg.vocab, cfg.d_model)),
        "encoder": {
            "blocks": [stacked_init(lambda k: _init_enc_layer(k, cfg), ke,
                                    cfg.enc_layers)],
            "final_norm": jnp.ones((cfg.d_model,)),
        },
        "decoder": {
            "blocks": [stacked_init(lambda k: _init_dec_layer(k, cfg), kd,
                                    cfg.n_layers)],
            "final_norm": jnp.ones((cfg.d_model,)),
        },
        "lm_head": trunc_normal(kh, (cfg.d_model, cfg.vocab)),
    }


def param_axes(cfg: ModelConfig) -> Dict:
    def stackax(tree):
        return jax.tree_util.tree_map(
            lambda ax: (None,) + ax, tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))

    enc_layer = {"mixer_norm": (None,), "attn": T._attn_axes(cfg),
                 "ffn_norm": (None,), "mlp": T._mlp_axes(cfg)}
    dec_layer = {"self_norm": (None,), "self_attn": T._attn_axes(cfg),
                 "cross_norm": (None,), "cross_attn": T._attn_axes(cfg),
                 "ffn_norm": (None,), "mlp": T._mlp_axes(cfg)}
    return {
        "embed": ("vocab", "fsdp"),
        "encoder": {"blocks": [stackax(enc_layer)], "final_norm": (None,)},
        "decoder": {"blocks": [stackax(dec_layer)], "final_norm": (None,)},
        "lm_head": ("fsdp", "vocab"),
    }


def _encode(ctx: QuantCtx, params, cfg: ModelConfig, frames):
    x = frames.astype(cfg.compute_dtype)
    x = shard_act(x, ("batch", None, None))
    b, se, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(se)[None], (b, se))

    def body(xv, p):
        h = L.rms_norm(xv, p["mixer_norm"], cfg.norm_eps)
        out, _ = L.attention_block(ctx, h, p["attn"], cfg, positions,
                                   "enc.attn", causal=False)
        xv = xv + out
        h = L.rms_norm(xv, p["ffn_norm"], cfg.norm_eps)
        return xv + L.mlp_block(ctx, h, p["mlp"], cfg, "enc.mlp"), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["encoder"]["blocks"][0])
    return L.rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)


def _decode_stack(ctx: QuantCtx, params, cfg: ModelConfig, x, positions,
                  memory=None, cache=None, cache_len=None,
                  prefill: bool = False):
    """Decoder stack. In serve mode `cache` carries self K/V + cross K/V."""

    def body(carry, xs):
        xv = carry
        p, cs = xs
        h = L.rms_norm(xv, p["self_norm"], cfg.norm_eps)
        kv = None
        if cs is not None and not prefill:
            kv = (cs["k"], cs["v"])
        out, new_kv = L.attention_block(ctx, h, p["self_attn"], cfg,
                                        positions, "dec.self",
                                        kv_cache=kv, cache_len=cache_len)
        new_cs: Dict[str, Any] = {}
        if cs is not None:
            if prefill:
                k_new, v_new = new_kv
                new_cs["k"] = jax.lax.dynamic_update_slice_in_dim(
                    cs["k"], k_new.astype(cs["k"].dtype), 0, axis=1)
                new_cs["v"] = jax.lax.dynamic_update_slice_in_dim(
                    cs["v"], v_new.astype(cs["v"].dtype), 0, axis=1)
            else:
                new_cs["k"], new_cs["v"] = new_kv
        xv = xv + out

        # cross attention
        h = L.rms_norm(xv, p["cross_norm"], cfg.norm_eps)
        if cs is not None:
            ck, cv = cs["ck"], cs["cv"]
            if prefill:
                ck, cv = L.cross_kv_from_memory(ctx, memory, p["cross_attn"],
                                                cfg, "dec.cross")
            new_cs["ck"], new_cs["cv"] = ck, cv
        else:
            ck, cv = L.cross_kv_from_memory(ctx, memory, p["cross_attn"],
                                            cfg, "dec.cross")
        b, s, _ = h.shape
        q = ctx.dense(h, p["cross_attn"]["wq"], "dec.cross.wq") \
            .reshape(b, s, cfg.n_heads, cfg.hd)
        if s == 1:
            se = ck.shape[1]
            out = L.decode_attention(q, ck, cv,
                                     jnp.full((b,), se, jnp.int32))
        else:
            out = L.flash_attention(q, ck, cv, causal=False,
                                    chunk=cfg.seq_chunk)
        out = out.reshape(b, s, cfg.n_heads * cfg.hd)
        out = ctx.dense(out, p["cross_attn"]["wo"], "dec.cross.wo")
        xv = xv + out

        h = L.rms_norm(xv, p["ffn_norm"], cfg.norm_eps)
        xv = xv + L.mlp_block(ctx, h, p["mlp"], cfg, "dec.mlp")
        return xv, new_cs

    body_fn = jax.checkpoint(body) if cfg.remat else body
    blocks = params["decoder"]["blocks"][0]
    if cache is None:
        x, _ = jax.lax.scan(lambda c, p: (body_fn(c, (p, None))[0], None),
                            x, blocks)
        new_cache = None
    else:
        x, new_blocks = jax.lax.scan(body_fn, x, (blocks, cache["blocks"][0]))
        new_cache = {"blocks": [new_blocks]}
    return L.rms_norm(x, params["decoder"]["final_norm"], cfg.norm_eps), \
        new_cache


def make_model(cfg: ModelConfig, qat: Optional[QATConfig] = None):
    n_fmts = len(qat.formats) if qat else 0

    def _ctx(fmt_idx):
        if qat is None or not qat.enabled:
            return QuantCtx()
        idx = fmt_idx if fmt_idx is not None else jnp.int32(n_fmts)
        return QuantCtx(qat=qat, fmt_idx=idx)

    def train_loss(params, batch, fmt_idx=None):
        ctx = _ctx(fmt_idx)
        memory = _encode(ctx, params, cfg, batch["frame_embeds"])
        tokens = batch["tokens"]
        b, st = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0) \
            .astype(cfg.compute_dtype)
        x = shard_act(x, ("batch", None, None))
        positions = jnp.broadcast_to(jnp.arange(st)[None], (b, st))
        hidden, _ = _decode_stack(ctx, params, cfg, x, positions,
                                  memory=memory)
        mask = batch.get("mask", jnp.ones_like(tokens, jnp.float32))
        loss = T.chunked_ce_loss(ctx, hidden, params["lm_head"],
                                 batch["labels"],
                                 mask.astype(jnp.float32), cfg)
        return loss, {"ce": loss}

    def init_cache(b, s_max, dtype=None, s_enc=None, *, kv_layout="dense",
                   page_size=16, num_pages=None):
        if kv_layout != "dense":
            raise ValueError(
                f"kv_layout={kv_layout!r}: paged KV requires a pure-attention"
                " stack; the encdec family keeps per-slot cross-attention KV "
                "whose paging is unimplemented — use kv_layout='dense'")
        del page_size, num_pages
        dtype = dtype or cfg.compute_dtype
        s_enc = s_enc or max(1, s_max // max(cfg.audio_downsample, 1))
        blk = {
            "k": jnp.zeros((b, s_max, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((b, s_max, cfg.n_kv_heads, cfg.hd), dtype),
            "ck": jnp.zeros((b, s_enc, cfg.n_kv_heads, cfg.hd), dtype),
            "cv": jnp.zeros((b, s_enc, cfg.n_kv_heads, cfg.hd), dtype),
        }
        stack = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape),
            blk)
        return {"blocks": [stack]}

    def cache_axes():
        return {"blocks": [{
            "k": (None, "batch", "kv_seq", None, None),
            "v": (None, "batch", "kv_seq", None, None),
            "ck": (None, "batch", "kv_seq", None, None),
            "cv": (None, "batch", "kv_seq", None, None),
        }]}

    def prefill(params, batch, cache):
        ctx = QuantCtx()   # serving never fake-quantizes
        memory = _encode(ctx, params, cfg, batch["frame_embeds"])
        tokens = batch["tokens"]
        b, st = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0) \
            .astype(cfg.compute_dtype)
        positions = jnp.broadcast_to(jnp.arange(st)[None], (b, st))
        hidden, new_cache = _decode_stack(
            ctx, params, cfg, x, positions, memory=memory, cache=cache,
            cache_len=jnp.zeros((b,), jnp.int32), prefill=True)
        logits = hidden[:, -1].astype(jnp.float32) @ \
            params["lm_head"].astype(jnp.float32)
        return logits, new_cache, jnp.full((b,), st, jnp.int32)

    def serve_step(params, batch, cache, cache_len):
        ctx = QuantCtx()
        tokens = batch["tokens"]
        b = tokens.shape[0]
        x = jnp.take(params["embed"], tokens, axis=0) \
            .astype(cfg.compute_dtype)
        positions = cache_len[:, None]
        hidden, new_cache = _decode_stack(
            ctx, params, cfg, x, positions, cache=cache,
            cache_len=cache_len, prefill=False)
        logits = hidden[:, -1].astype(jnp.float32) @ \
            params["lm_head"].astype(jnp.float32)
        logits = shard_act(logits, ("batch", "vocab"))
        return logits, new_cache

    return T.ModelApi(
        cfg=cfg, qat=qat,
        init_params=functools.partial(init_params, cfg=cfg),
        param_axes=functools.partial(param_axes, cfg=cfg),
        train_loss=train_loss,
        init_cache=init_cache,
        cache_axes=cache_axes,
        prefill=prefill,
        serve_step=serve_step,
        prefill_slot=make_prefill_slot(prefill),
    )
