"""RWKV6 "Finch" block: attention-free time mixing with data-dependent decay.

Faithful structure per arXiv:2404.05892: token-shift lerps, a low-rank
("LoRA") data-dependent per-channel decay w_t = exp(-exp(d_t)), a per-head
bonus u for the current token, and the WKV matrix-state recurrence

    S_t = diag(w_t) S_{t-1} + k_t v_t^T,
    y_t = r_t (S_{t-1} + diag(u) k_t v_t^T).

Simplification noted in DESIGN.md: the five-way ddlerp token-shift mixers use
static lerp weights (RWKV-5.2 style); the decay keeps its full data-dependent
LoRA (the defining Finch feature). Decay/lora/bonus params are excluded from
MF-QAT (small vectors/low-rank, analogous to the paper excluding norms).

The WKV recurrence is computed in chunks: within a chunk the contribution of
the running state is a single matmul against the cumulative decay, so the MXU
sees (chunk x hd) x (hd x hd) GEMMs instead of 4096 rank-1 updates.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, QuantCtx, trunc_normal

WKV_CHUNK = 64
DECAY_LORA = 64


def init_rwkv_params(key, cfg: ModelConfig):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    ks = jax.random.split(key, 12)
    return {
        "time": {
            "mix_r": jnp.full((d,), 0.5), "mix_k": jnp.full((d,), 0.5),
            "mix_v": jnp.full((d,), 0.5), "mix_g": jnp.full((d,), 0.5),
            "mix_w": jnp.full((d,), 0.5),
            "decay_base": jnp.full((d,), -4.0),
            "decay_w1": trunc_normal(ks[0], (d, DECAY_LORA), std=0.01),
            "decay_w2": trunc_normal(ks[1], (DECAY_LORA, d), std=0.01),
            "bonus": trunc_normal(ks[2], (h, hd), std=0.1),
            "wr": trunc_normal(ks[3], (d, d)),
            "wk": trunc_normal(ks[4], (d, d)),
            "wv": trunc_normal(ks[5], (d, d)),
            "wg": trunc_normal(ks[6], (d, d)),
            "wo": trunc_normal(ks[7], (d, d), std=0.02 / cfg.n_layers ** 0.5),
            "ln_scale": jnp.ones((d,)),
        },
        "channel": {
            "mix_k": jnp.full((d,), 0.5), "mix_r": jnp.full((d,), 0.5),
            "w_key": trunc_normal(ks[8], (d, cfg.d_ff)),
            "w_value": trunc_normal(ks[9], (cfg.d_ff, d),
                                    std=0.02 / cfg.n_layers ** 0.5),
            "w_recept": trunc_normal(ks[10], (d, d)),
        },
    }


def rwkv_param_axes(cfg: ModelConfig):
    mm = ("fsdp", "model")
    return {
        "time": {
            "mix_r": (None,), "mix_k": (None,), "mix_v": (None,),
            "mix_g": (None,), "mix_w": (None,),
            "decay_base": ("model",),
            "decay_w1": ("fsdp", None), "decay_w2": (None, "model"),
            "bonus": ("heads", None),
            "wr": mm, "wk": mm, "wv": mm, "wg": mm,
            "wo": ("model", "fsdp"),
            "ln_scale": (None,),
        },
        "channel": {
            "mix_k": (None,), "mix_r": (None,),
            "w_key": ("fsdp", "mlp"), "w_value": ("mlp", "fsdp"),
            "w_recept": mm,
        },
    }


def _token_shift(x, shift_state):
    """Previous-token features. x (B,S,d); shift_state (B,1,d) or None."""
    prev = jnp.zeros_like(x[:, :1]) if shift_state is None else \
        shift_state.astype(x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _wkv_chunked(r, k, v, w, u, s0, chunk=WKV_CHUNK):
    """WKV recurrence in chunks.

    r,k,v,w: (B,S,H,hd) f32 (w = per-step decay factors in (0,1)).
    s0: (B,H,hd,hd) initial state. Returns (y (B,S,H,hd), s_final).

    Within a chunk: let W_t = prod_{i<=t} w_i (cumulative decay, exclusive of
    the step's own update ordering as below). Then
      y_t = r_t (S_in ⊙ W_{t-1} + sum_{j<t} [k_j ⊙ (W_{t-1}/W_j)] v_j^T
             + diag(u) k_t v_t^T)
    which is two GEMM-shaped contractions + one masked (c x c) attention-like
    product per chunk — MXU-friendly vs. 4096 rank-1 updates.
    """
    bsz, s, h, hd = r.shape
    c = min(chunk, s)
    while s % c:
        c //= 2
    nc = s // c

    def to_chunks(t):
        return t.reshape(bsz, nc, c, h, hd).swapaxes(0, 1)

    rs, ks_, vs, ws = map(to_chunks, (r, k, v, w))
    logw = jnp.log(jnp.maximum(ws, 1e-38))

    def step(s_in, inp):
        rc, kc, vc, lw = inp                        # (B,c,H,hd)
        cum = jnp.cumsum(lw, axis=1)                # W_t (inclusive)
        cum_prev = cum - lw                         # W_{t-1} (exclusive)
        wpre = jnp.exp(cum_prev)                    # decay applied to S_in
        # contribution of the carried state
        y_state = jnp.einsum("bchk,bhkv->bchv", rc * wpre, s_in)
        # intra-chunk pairwise term for j < t:
        # coeff_{t,j}[k] = exp(cum_prev_t[k] - cum_j[k]) <= 1 always (cum is
        # non-increasing), so the pairwise form is overflow-safe — the
        # factored exp(cum_prev)·exp(-cum) form is not under strong decay.
        diff = cum_prev[:, :, None] - cum[:, None]      # (B,c_t,c_j,H,hd)
        mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
        att = jnp.einsum("bthk,btjhk,bjhk->bhtj", rc,
                         jnp.exp(jnp.minimum(diff, 0.0)), kc)
        att = jnp.where(mask[None, None], att, 0.0)
        y_intra = jnp.einsum("bhtj,bjhv->bthv", att, vc)
        # current-token bonus term
        y_bonus = jnp.einsum("bchk,bchk,bchv->bchv", rc,
                             kc * u[None, None], vc)
        y = y_state + y_intra + y_bonus
        # state update: S_out = S_in ⊙ W_c + sum_j (W_c / W_j) k_j v_j^T
        wtot = jnp.exp(cum[:, -1])                  # (B,H,hd)
        s_out = s_in * wtot[..., None] + jnp.einsum(
            "bjhk,bjhv->bhkv", kc * jnp.exp(cum[:, -1:] - cum), vc)
        return s_out, y

    s0 = jnp.zeros((bsz, h, hd, hd), jnp.float32) if s0 is None else s0
    s_final, ys = jax.lax.scan(step, s0, (rs, ks_, vs, logw))
    y = ys.swapaxes(0, 1).reshape(bsz, s, h, hd)
    return y, s_final


def _group_norm_heads(x, scale, eps):
    """x (B,S,H,hd): normalize per head then flatten."""
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, -1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    b, s, h, hd = y.shape
    return y.reshape(b, s, h * hd) * scale[None, None]


def rwkv_time_mix(ctx: QuantCtx, x, p, cfg: ModelConfig, name: str,
                  state: Optional[Tuple] = None):
    """Returns (out, (shift_state, wkv_state))."""
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    shift0, wkv0 = state if state is not None else (None, None)
    xx = _token_shift(x, shift0)

    def mixed(m):
        return x + (xx - x) * p[m].astype(x.dtype)[None, None]

    r = ctx.dense(mixed("mix_r"), p["wr"], name + ".wr")
    k = ctx.dense(mixed("mix_k"), p["wk"], name + ".wk")
    v = ctx.dense(mixed("mix_v"), p["wv"], name + ".wv")
    g = jax.nn.silu(ctx.dense(mixed("mix_g"), p["wg"], name + ".wg"))

    # data-dependent decay (the Finch feature): d_t = base + lora(x_w)
    xw = mixed("mix_w").astype(jnp.float32)
    dlo = jnp.tanh(xw @ p["decay_w1"].astype(jnp.float32)) \
        @ p["decay_w2"].astype(jnp.float32)
    decay = jnp.exp(-jnp.exp(p["decay_base"].astype(jnp.float32)[None, None]
                             + dlo))                     # (B,S,d) in (0,1)

    def heads(t):
        return t.astype(jnp.float32).reshape(b, s, h, hd)

    y, wkv_state = _wkv_chunked(heads(r), heads(k), heads(v), heads(decay),
                                p["bonus"].astype(jnp.float32), wkv0)
    y = _group_norm_heads(y, p["ln_scale"].astype(jnp.float32), cfg.norm_eps)
    y = (y.astype(x.dtype)) * g
    out = ctx.dense(y, p["wo"], name + ".wo",
                    out_logical=("batch", None, None))
    return out, (x[:, -1:], wkv_state)


def rwkv_channel_mix(ctx: QuantCtx, x, p, cfg: ModelConfig, name: str,
                     state=None):
    """Returns (out, shift_state)."""
    xx = _token_shift(x, state)

    def mixed(m):
        return x + (xx - x) * p[m].astype(x.dtype)[None, None]

    kx = ctx.dense(mixed("mix_k"), p["w_key"], name + ".w_key",
                   out_logical=("batch", None, "mlp"))
    kx = jnp.square(jax.nn.relu(kx))
    vx = ctx.dense(kx, p["w_value"], name + ".w_value",
                   out_logical=("batch", None, None))
    rx = jax.nn.sigmoid(ctx.dense(mixed("mix_r"), p["w_recept"],
                                  name + ".w_recept"))
    return rx * vx, x[:, -1:]
