"""Transformer building blocks: norms, RoPE, flash attention, MLP, MoE.

Attention is a chunked flash implementation in pure jnp (online softmax over
KV chunks, O(S) memory) with optional sliding-window *banding* that slices
only the needed KV range per query chunk — SWA prefill costs O(S·W) compute,
not O(S^2). Decode uses a direct single-query path whose reductions partition
over a sequence-sharded KV cache.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, QuantCtx
from repro.sharding.rules import shard_act


# =============================================================================
# Norms / RoPE
# =============================================================================
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)) \
        .astype(dt)


def layer_norm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, -1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, -1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, D), positions (..., S) -> rotated (llama half-split)."""
    d = x.shape[-1]
    half = d // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]          # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# =============================================================================
# Flash attention (chunked online softmax)
# =============================================================================
def _attend_block(q, k, v, q_pos, k_pos, causal, window, scale):
    """One (cq x ck) score block with masking. q (B,cq,Hkv,G,D)."""
    s = jnp.einsum("bqkgd,btkd->bkgqt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    return s


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, chunk: int = 1024) -> jax.Array:
    """q (B,Sq,H,D), k/v (B,Skv,Hkv,D) -> (B,Sq,H,D).

    Scans query chunks (outer) and KV chunks (inner) with a running
    (max, denom, acc) online softmax. With a sliding window, only the banded
    KV range [t0-W, t0+cq) is sliced per query chunk.
    """
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = 1.0 / (d ** 0.5)
    cq = min(chunk, sq)
    while sq % cq:
        cq //= 2
    cq = max(cq, 1)

    banded = window is not None and causal and skv > window
    if banded:
        band = min(skv, window + cq)
    qg = q.reshape(b, sq, hkv, g, d)

    def q_step(_, qi):
        qc = jax.lax.dynamic_slice_in_dim(qg, qi * cq, cq, axis=1)
        q_pos = q_offset + qi * cq + jnp.arange(cq)

        if banded:
            start = jnp.clip(q_offset + qi * cq + cq - band, 0, skv - band)
            kc = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            k_pos = start + jnp.arange(band)
            s = _attend_block(qc, kc, vc, q_pos, k_pos, causal, window, scale)
            m = jnp.max(s, axis=-1, keepdims=True)
            p = jnp.exp(s - jax.lax.stop_gradient(m))
            num = jnp.einsum("bkgqt,btkd->bqkgd", p, vc.astype(jnp.float32))
            den = jnp.sum(p, axis=-1)                     # (b,hkv,g,cq)
            out = num / den.transpose(0, 3, 1, 2)[..., None]
            return None, out.reshape(b, cq, h, d).astype(q.dtype)

        ck = min(chunk, skv)
        while skv % ck:
            ck //= 2
        nk = skv // ck

        def kv_step(carry, ki):
            m, l, acc = carry
            kc = jax.lax.dynamic_slice_in_dim(k, ki * ck, ck, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, ki * ck, ck, axis=1)
            k_pos = ki * ck + jnp.arange(ck)
            s = _attend_block(qc, kc, vc, q_pos, k_pos, causal, window, scale)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqt,btkd->bkgqd", p, vc.astype(jnp.float32))
            acc = acc * alpha[..., None] + pv
            return (m_new, l, acc), None

        m0 = jnp.full((b, hkv, g, cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, cq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, cq, h, d)
        return None, out.astype(q.dtype)

    nq = sq // cq
    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array,
                     window: Optional[int] = None) -> jax.Array:
    """Single-token attention: q (B,1,H,D) over cache (B,Skv,Hkv,D).

    Non-scanned so the softmax reductions partition over a sequence-sharded
    cache (GSPMD turns them into psums over the `model` axis).
    """
    b, _, h, d = q.shape
    skv, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, d)
    s = jnp.einsum("bkgd,btkd->bkgt", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / (d ** 0.5)
    pos = jnp.arange(skv)
    valid = pos[None, :] < cache_len[:, None]                    # (B, Skv)
    if window is not None:
        valid &= pos[None, :] >= (cache_len[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    den = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgt,btkd->bkgd", p / den,
                     v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


def mixed_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                    q_offset: jax.Array, q_len: jax.Array,
                    window: Optional[int] = None) -> jax.Array:
    """Ragged multi-query attention: q (B,C,H,D) over cache (B,Skv,Hkv,D).

    The multi-query generalization of ``decode_attention`` for the unified
    mixed prefill+decode tick: query ``i`` of row ``b`` sits at logical
    position ``q_offset[b] + i``; lanes with ``i < q_len[b]`` attend
    causally (self-inclusive, so each query sees its own just-written K/V)
    over positions below the row's frontier ``q_offset + q_len``, within
    the sliding window; dead pad lanes output exact zeros. Deliberately the
    same op sequence as ``decode_attention`` (einsum / mask / max / exp /
    sum / div) with one extra query axis, so a ``q_len == 1`` row's output
    stays bit-identical to the single-query path on this backend — the
    mixed-vs-sequential stream-identity contract rests on that.
    """
    b, c, h, d = q.shape
    skv, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    qg = q.reshape(b, c, hkv, g, d)
    s = jnp.einsum("bikgd,btkd->bkgit", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / (d ** 0.5)
    pos = jnp.arange(skv)
    qpos = q_offset[:, None] + jnp.arange(c)[None]               # (B, C)
    live = jnp.arange(c)[None] < q_len[:, None]                  # (B, C)
    valid = pos[None, None, :] <= qpos[:, :, None]               # (B, C, Skv)
    valid &= pos[None, None, :] < (q_offset + q_len)[:, None, None]
    valid &= live[..., None]
    if window is not None:
        valid &= (qpos[:, :, None] - pos[None, None, :]) < window
    s = jnp.where(valid[:, None, None], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    den = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgit,btkd->bikgd", p / den,
                     v_cache.astype(jnp.float32))
    # Dead lanes divide 0/0 -> NaN; force the kernel's exact-zeros contract
    # (live lanes always have >= 1 valid position: their own).
    out = jnp.where(live[..., None, None, None], out, 0.0)
    return out.reshape(b, c, h, d).astype(q.dtype)


# =============================================================================
# Paged KV cache (block-table indirection over a shared page pool)
# =============================================================================
# Layout (see docs/serving_internals.md): each attention layer owns a page
# pool (num_pages, page_size, Hkv, D); a slot's KV lives in the physical pages
# its block-table row names, in logical order — position p maps to page
# row[p // page_size], offset p % page_size. Page 0 is a reserved scratch
# page: unmapped block-table entries point at it, so retired slots scribble
# there instead of on recycled pages, and every read of it is masked by
# cache_len. Values at any *valid* position (< cache_len) are bit-identical
# to the dense layout's, which is what makes dense-vs-paged token identity a
# testable contract rather than a tolerance.


def paged_prefill_update(pool: jax.Array, kv_new: jax.Array,
                         block_table: jax.Array,
                         start_pos=0) -> jax.Array:
    """Scatter prefill K/V (B, S, Hkv, D) into the pages each row maps.

    S is zero-padded up to a whole number of pages (matching the dense
    layout, whose cache is zero beyond the written range). Rows' mapped
    pages are disjoint by construction (the engine allocates each physical
    page to at most one slot), so the batched scatter never collides —
    except on the scratch page 0, where last-write-wins is harmless.

    ``start_pos`` (scalar, may be traced) is the logical position of the
    first written token — chunked prefill resumes at its cursor. It must be
    page-aligned (the engine aligns chunk boundaries to pages by
    construction), so the write covers pages
    ``start_pos // page_size .. + ceil(S/page_size)``.
    """
    b, s, hkv, d = kv_new.shape
    ps = pool.shape[1]
    n_p = -(-s // ps)
    pad = n_p * ps - s
    if pad:
        kv_new = jnp.pad(kv_new, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vals = kv_new.astype(pool.dtype).reshape(b * n_p, ps, hkv, d)
    ids = jax.lax.dynamic_slice_in_dim(block_table, start_pos // ps, n_p,
                                       axis=1).reshape(-1)
    return pool.at[ids].set(vals)


def paged_decode_append(pool: jax.Array, kv_tok: jax.Array,
                        block_table: jax.Array,
                        cache_len: jax.Array) -> jax.Array:
    """Write one token's K/V (B, 1, Hkv, D) at each slot's cache_len.

    The engine maps the destination page before the tick runs, so the
    translated (page, offset) is always a live page for active slots; free
    slots land on scratch page 0.
    """
    ps = pool.shape[1]
    phys = jnp.take_along_axis(block_table, (cache_len // ps)[:, None],
                               axis=1)[:, 0]
    return pool.at[phys, cache_len % ps].set(kv_tok[:, 0].astype(pool.dtype))


def mixed_cache_update(cache: jax.Array, kv_new: jax.Array,
                       cache_len: jax.Array, q_len: jax.Array) -> jax.Array:
    """Ragged multi-token append into a dense cache (B, Smax, Hkv, D).

    Row ``b``'s token ``i`` of ``kv_new`` (B, C, Hkv, D) lands at position
    ``cache_len[b] + i`` when ``i < q_len[b]``; pad lanes scatter out of
    bounds and are dropped. NOT ``dynamic_update_slice`` — that clamps the
    *start* index, so a width-C write for a decode row near capacity would
    slide backwards onto live positions; per-token drop semantics can
    never do that.
    """
    b, c = kv_new.shape[:2]
    smax = cache.shape[1]
    idx = cache_len[:, None] + jnp.arange(c)[None]
    idx = jnp.where(jnp.arange(c)[None] < q_len[:, None], idx, smax)
    bidx = jnp.broadcast_to(jnp.arange(b)[:, None], (b, c))
    return cache.at[bidx, idx].set(kv_new.astype(cache.dtype), mode="drop")


def paged_mixed_update(pool: jax.Array, kv_new: jax.Array,
                       block_table: jax.Array, cache_len: jax.Array,
                       q_len: jax.Array) -> jax.Array:
    """Ragged multi-token append through the block table.

    Position ``cache_len[b] + i`` (``i < q_len[b]``) maps to page
    ``block_table[b, pos // ps]``, offset ``pos % ps``; pad lanes and
    positions past the table redirect to scratch page 0 with zero values
    (collisions there are harmless — every read of page 0 is masked). The
    engine maps each row's pages before the tick (decode rows at page
    boundaries, the mid-prefill row per chunk), so valid writes always
    land on live pages, which are disjoint across slots. Unlike
    ``paged_prefill_update`` the final-chunk page tail is NOT zero-filled:
    garbage past the frontier stays finite-or-masked, the same invariant
    recycled pages already rely on.
    """
    ps = pool.shape[1]
    mp = block_table.shape[1]
    c = kv_new.shape[1]
    pos = cache_len[:, None] + jnp.arange(c)[None]               # (B, C)
    valid = jnp.arange(c)[None] < q_len[:, None]
    blk = jnp.clip(pos // ps, 0, mp - 1)
    phys = jnp.take_along_axis(block_table, blk, axis=1)
    phys = jnp.where(valid & (pos // ps < mp), phys, 0)
    vals = jnp.where(valid[..., None, None], kv_new.astype(pool.dtype), 0)
    return pool.at[phys, pos % ps].set(vals)


def paged_gather(pool: jax.Array, block_table: jax.Array) -> jax.Array:
    """Materialize each slot's logical KV view: (B, max_pages*ps, Hkv, D).

    A plain gather — positions come back in logical order, so the result
    drops into ``decode_attention`` exactly like a dense cache (garbage past
    cache_len is masked there, same as dense pad positions).

    This is the decode *fallback*, not the decode path: the default paged
    decode on TPU is the gather-free Pallas kernel in
    ``kernels/paged_attention.py``, which consumes the pool + block table
    directly and never materializes this dense view — attention reads scale
    with live tokens, not ``max_pages*ps``. The pair stays selectable via
    ``attn_impl="gather"`` (see ``paged_decode_attention`` and
    docs/serving_internals.md §5); chunked *prefill* still reads through
    this gather (its flash queries span the whole cache).
    """
    b, mp = block_table.shape
    pages = pool[block_table]                 # (B, MP, ps, Hkv, D)
    return pages.reshape(b, mp * pool.shape[1], *pool.shape[2:])


# =============================================================================
# Attention block
# =============================================================================
def attention_block(ctx: QuantCtx, x: jax.Array, p, cfg: ModelConfig,
                    positions: jax.Array, name: str,
                    kv_cache: Optional[Tuple] = None,
                    cache_len: Optional[jax.Array] = None,
                    cross_kv: Optional[Tuple] = None,
                    causal: bool = True,
                    block_table: Optional[jax.Array] = None,
                    chunk_start: Optional[jax.Array] = None,
                    q_len: Optional[jax.Array] = None,
                    attn_impl: str = "gather"):
    """Self- (or cross-) attention. Returns (out, new_kv) where new_kv is the
    (k, v) tensors produced at this layer (for cache building) or the updated
    cache in decode mode.

    With ``block_table`` set, ``kv_cache`` holds paged pools
    (num_pages, page_size, Hkv, D): the new token is appended through the
    block-table indirection and attention runs over the pool via
    ``paged_decode_attention`` — ``attn_impl="paged_kernel"`` consumes the
    block table directly in the gather-free Pallas kernel
    (kernels/paged_attention.py), ``"gather"`` materializes the slot's pages
    back into logical order first and feeds the same masked single-query
    softmax. Both read identical KV values at every valid position.

    With ``chunk_start`` set (chunked prefill; see docs/serving_internals.md
    "Admission & scheduling"), ``x`` is one prompt *chunk* whose first token
    sits at logical position ``chunk_start``: the chunk's K/V are written
    into ``kv_cache`` at that offset and its queries run flash attention
    over the whole cache with ``q_offset=chunk_start`` — the causal mask
    exposes exactly positions ``< chunk_start + S`` (everything this
    request's earlier chunks wrote, plus the chunk itself; stale data from a
    slot's previous occupant only ever sits at higher positions).

    With ``q_len`` set (the unified mixed prefill+decode tick; see
    docs/serving_internals.md §6), ``x`` is a ragged (B, C) batch: row
    ``b``'s first ``q_len[b]`` tokens are real and sit at positions
    ``cache_len[b] + i`` — decoding rows carry 1, the mid-prefill row its
    chunk. Each row's valid K/V are written at its own cursor (through the
    block table when paged), pad lanes are dropped, and attention runs the
    ragged multi-query path: ``mixed_attention`` on dense/gather,
    ``paged_mixed_attention`` (the MQ Pallas kernel) under
    ``attn_impl="paged_kernel"``."""
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    if cross_kv is None:
        q = ctx.dense(x, p["wq"], name + ".wq", p.get("bq"))
        k = ctx.dense(x, p["wk"], name + ".wk", p.get("bk"))
        v = ctx.dense(x, p["wv"], name + ".wv", p.get("bv"))
        q = q.reshape(b, s, h, hd)
        k = k.reshape(b, s, hkv, hd)
        v = v.reshape(b, s, hkv, hd)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        q = shard_act(q, ("batch", None, "heads", None))
        k = shard_act(k, ("batch", None, "kv_heads", None))
    else:
        q = ctx.dense(x, p["wq"], name + ".wq").reshape(b, s, h, hd)
        k, v = cross_kv

    if kv_cache is not None and chunk_start is not None:
        # chunked prefill: write this chunk's K/V at the cursor, then attend
        # the chunk's queries over the cache (same flash kernel as monolithic
        # prefill — q_offset shifts the causal mask to the cursor).
        kc, vc = kv_cache
        if block_table is not None:
            kc = paged_prefill_update(kc, k, block_table,
                                      start_pos=chunk_start)
            vc = paged_prefill_update(vc, v, block_table,
                                      start_pos=chunk_start)
            k_view = paged_gather(kc, block_table)
            v_view = paged_gather(vc, block_table)
        else:
            kc = jax.lax.dynamic_update_slice_in_dim(
                kc, k.astype(kc.dtype), chunk_start, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                vc, v.astype(vc.dtype), chunk_start, axis=1)
            k_view, v_view = kc, vc
        out = flash_attention(q, k_view, v_view, causal=True,
                              window=cfg.sliding_window,
                              q_offset=chunk_start, chunk=cfg.seq_chunk)
        new_kv = (kc, vc)
    elif kv_cache is not None and q_len is not None:
        # mixed prefill+decode tick: every row writes its q_len valid tokens
        # at its own cache_len cursor, then its queries attend causally at
        # that offset — decode rows and the mid-prefill chunk in ONE
        # executable.
        kc, vc = kv_cache
        if block_table is not None:
            from repro.kernels.paged_attention import paged_mixed_attention
            kc = paged_mixed_update(kc, k, block_table, cache_len, q_len)
            vc = paged_mixed_update(vc, v, block_table, cache_len, q_len)
            out = paged_mixed_attention(
                q, kc, vc, block_table, cache_len, q_len,
                window=cfg.sliding_window,
                mode="pallas" if attn_impl == "paged_kernel" else "fallback")
        else:
            kc = mixed_cache_update(kc, k, cache_len, q_len)
            vc = mixed_cache_update(vc, v, cache_len, q_len)
            out = mixed_attention(q, kc, vc, cache_len, q_len,
                                  window=cfg.sliding_window)
        new_kv = (kc, vc)
    elif kv_cache is not None and block_table is not None:
        # paged decode: append through the block table, then attend over the
        # pool — gather-free kernel or gather+masked-softmax fallback per
        # attn_impl (one shim, trace-time path counters).
        from repro.kernels.paged_attention import paged_decode_attention
        kc, vc = kv_cache
        kc = paged_decode_append(kc, k, block_table, cache_len)
        vc = paged_decode_append(vc, v, block_table, cache_len)
        out = paged_decode_attention(
            q, kc, vc, block_table, cache_len + 1,
            window=cfg.sliding_window,
            mode="pallas" if attn_impl == "paged_kernel" else "fallback")
        new_kv = (kc, vc)
    elif kv_cache is not None:
        # decode: write this token's k/v at each slot's own cache_len, attend
        # over the cache. Slots advance independently (continuous batching
        # admits/retires requests per slot), so the write index is per batch
        # element — the vmapped update lowers to a scatter.
        kc, vc = kv_cache
        upd = jax.vmap(
            functools.partial(jax.lax.dynamic_update_slice_in_dim, axis=0))
        kc = upd(kc, k.astype(kc.dtype), cache_len)
        vc = upd(vc, v.astype(vc.dtype), cache_len)
        out = decode_attention(q, kc, vc, cache_len + 1,
                               window=cfg.sliding_window)
        new_kv = (kc, vc)
    else:
        if cfg.flash_vjp:
            from repro.models.flash_vjp import flash_attention_vjp
            out = flash_attention_vjp(q, k, v, causal=causal,
                                      window=cfg.sliding_window,
                                      chunk=cfg.seq_chunk)
        else:
            out = flash_attention(q, k, v, causal=causal,
                                  window=cfg.sliding_window,
                                  chunk=cfg.seq_chunk)
        new_kv = (k, v)

    out = out.reshape(b, s, h * hd)
    out = ctx.dense(out, p["wo"], name + ".wo",
                    out_logical=("batch", None, None), tp_reduce=True)
    return out, new_kv


def cross_kv_from_memory(ctx: QuantCtx, memory: jax.Array, p, cfg: ModelConfig,
                         name: str):
    """Precompute encoder-side K/V for decoder cross-attention."""
    b, se, _ = memory.shape
    k = ctx.dense(memory, p["wk"], name + ".wk") \
        .reshape(b, se, cfg.n_kv_heads, cfg.hd)
    v = ctx.dense(memory, p["wv"], name + ".wv") \
        .reshape(b, se, cfg.n_kv_heads, cfg.hd)
    return k, v


# =============================================================================
# MLP / MoE
# =============================================================================
def mlp_block(ctx: QuantCtx, x: jax.Array, p, cfg: ModelConfig, name: str):
    if cfg.act == "swiglu":
        gate = ctx.dense(x, p["w_gate"], name + ".w_gate",
                         out_logical=("batch", None, "mlp"))
        up = ctx.dense(x, p["w_up"], name + ".w_up",
                       out_logical=("batch", None, "mlp"))
        hidden = jax.nn.silu(gate) * up
    else:
        hidden = jax.nn.gelu(
            ctx.dense(x, p["w_up"], name + ".w_up", p.get("b_up"),
                      out_logical=("batch", None, "mlp")))
    return ctx.dense(hidden, p["w_down"], name + ".w_down", p.get("b_down"),
                     out_logical=("batch", None, None), tp_reduce=True)


def moe_block(ctx: QuantCtx, x: jax.Array, p, cfg: ModelConfig, name: str):
    """Top-k routed MoE with *local* routing groups + capacity dispatch.

    Each batch row routes independently (GShard-style local groups): the
    top-C gather/scatter stays inside the row's data shard, so sharding the
    batch over (pod, data) never gathers the global token axis — the only
    cross-shard traffic is the (E, B, C, d) expert operand transpose, which
    GSPMD lowers to the expected EP all-to-all when experts divide `model`.
    Capacity C = cf·S·k/E per row; dropping is by gate magnitude
    (importance-based). Returns (out, aux_load_balance_loss).
    """
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_topk

    logits = ctx.dense(x, p["router"], name + ".router").astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # (B, S, E)
    top_vals, top_idx = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(top_vals, axis=-1)                    # (B, S, k)
    onehot = jax.nn.one_hot(top_idx, e, dtype=gates.dtype)       # (B, S, k, E)
    expert_gate = jnp.einsum("bsk,bske->bse", gates, onehot)

    cap = max(1, min(s, int(cfg.capacity_factor * s * k / e)))
    prio = expert_gate.transpose(0, 2, 1)                        # (B, E, S)
    top_gate, token_idx = jax.lax.top_k(prio, cap)               # (B, E, C)

    xe = jax.vmap(lambda xb, ib: xb[ib.reshape(-1)].reshape(e, cap, d))(
        x, token_idx)                                            # (B, E, C, d)
    xe = xe.transpose(1, 0, 2, 3)                                # (E, B, C, d)
    xe = shard_act(xe, ("experts", "batch", None, None))

    # Expert GEMMs run under vmap, where packed leaves arrive as batch
    # tracers the Pallas dispatch can't take yet — densify at point of use.
    ctx_e = ctx.no_qmm()

    def expert_ffn(pe, xi):                                      # xi (B, C, d)
        gate = ctx_e.dense(xi, pe["w_gate"], name + ".expert.w_gate")
        up = ctx_e.dense(xi, pe["w_up"], name + ".expert.w_up")
        return ctx_e.dense(jax.nn.silu(gate) * up, pe["w_down"],
                           name + ".expert.w_down")

    ye = jax.vmap(expert_ffn)(p["experts"], xe)                  # (E, B, C, d)
    ye = ye * top_gate.transpose(1, 0, 2)[..., None].astype(ye.dtype)
    ye = ye.transpose(1, 0, 2, 3)                                # (B, E, C, d)
    ye = shard_act(ye, ("batch", None, None, None))

    def scatter_row(yb, ib):
        return jnp.zeros((s, d), yb.dtype) \
            .at[ib.reshape(-1)].add(yb.reshape(e * cap, d))

    out = jax.vmap(scatter_row)(ye, token_idx)                   # (B, S, d)

    # Switch-style load-balance aux loss.
    frac_tokens = jnp.mean(onehot.sum(2), axis=(0, 1))           # (E,)
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = cfg.router_aux_coef * e * jnp.sum(frac_tokens * frac_probs)
    return out.astype(x.dtype), aux
