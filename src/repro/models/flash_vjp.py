"""Flash attention with a custom VJP (recompute-in-backward).

XLA autodiff through the chunked-softmax scans stacks every chunk's
probability block as a residual — O(S^2) backward memory, ~36 GB/device for
a 4k x batch-16 shard. The flash backward recomputes score blocks from
(q, k, v, out, lse) instead: O(S) residuals, the standard FlashAttention-2
recipe expressed in jnp scans (TPU Pallas flash uses the same structure).

Supports causal masking and sliding windows. The sliding-window backward
walks the same banded KV slices as the forward and read-modify-writes the
dk/dv band accumulators.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


def _mask(q_pos, k_pos, causal, window):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


def _chunk_len(total, chunk):
    c = min(chunk, total)
    while total % c:
        c //= 2
    return max(c, 1)


@functools.lru_cache(maxsize=None)
def _make_flash(causal: bool, window: Optional[int], chunk: int):
    """Build the custom-vjp flash fn for a static (causal, window, chunk)."""

    def fwd_pass(q, k, v):
        """Returns out (B,Sq,Hkv,G,D) and lse (B,Hkv,G,Sq), all f32."""
        b, sq, hkv, g, d = q.shape
        skv = k.shape[1]
        scale = 1.0 / (d ** 0.5)
        cq = _chunk_len(sq, chunk)
        ck = _chunk_len(skv, chunk)
        nq, nk = sq // cq, skv // ck
        banded = window is not None and causal and skv > window
        band = min(skv, window + cq) if banded else None

        def q_step(_, qi):
            qc = jax.lax.dynamic_slice_in_dim(q, qi * cq, cq, 1)
            q_pos = qi * cq + jnp.arange(cq)

            if banded:
                start = jnp.clip(qi * cq + cq - band, 0, skv - band)
                kc = jax.lax.dynamic_slice_in_dim(k, start, band, 1)
                vc = jax.lax.dynamic_slice_in_dim(v, start, band, 1)
                k_pos = start + jnp.arange(band)
                s = jnp.einsum("bqkgd,btkd->bkgqt", qc, kc) * scale
                s = jnp.where(_mask(q_pos, k_pos, causal, window)
                              [None, None, None], s, -jnp.inf)
                m = jnp.max(s, -1)
                p = jnp.exp(s - m[..., None])
                l = jnp.sum(p, -1)
                o = jnp.einsum("bkgqt,btkd->bqkgd", p, vc) / \
                    l.transpose(0, 3, 1, 2)[..., None]
                lse = m + jnp.log(l)
                return None, (o, lse)

            def kv_step(carry, ki):
                m, l, acc = carry
                kc = jax.lax.dynamic_slice_in_dim(k, ki * ck, ck, 1)
                vc = jax.lax.dynamic_slice_in_dim(v, ki * ck, ck, 1)
                k_pos = ki * ck + jnp.arange(ck)
                s = jnp.einsum("bqkgd,btkd->bkgqt", qc, kc) * scale
                s = jnp.where(_mask(q_pos, k_pos, causal, window)
                              [None, None, None], s, -jnp.inf)
                m_new = jnp.maximum(m, jnp.max(s, -1))
                alpha = jnp.exp(m - m_new)
                p = jnp.exp(s - m_new[..., None])
                l = l * alpha + jnp.sum(p, -1)
                acc = acc * alpha[..., None] + \
                    jnp.einsum("bkgqt,btkd->bkgqd", p, vc)
                return (m_new, l, acc), None

            m0 = jnp.full((b, hkv, g, cq), -jnp.inf, jnp.float32)
            l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
            a0 = jnp.zeros((b, hkv, g, cq, d), jnp.float32)
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          jnp.arange(nk))
            o = (acc / jnp.maximum(l, 1e-30)[..., None]) \
                .transpose(0, 3, 1, 2, 4)
            lse = m + jnp.log(jnp.maximum(l, 1e-30))
            return None, (o, lse)

        _, (outs, lses) = jax.lax.scan(q_step, None, jnp.arange(nq))
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hkv, g, d)
        lse = lses.transpose(1, 2, 3, 0, 4).reshape(b, hkv, g, sq)
        return out, lse

    @jax.custom_vjp
    def flash(q, k, v):
        return fwd_pass(q, k, v)[0]

    def flash_fwd(q, k, v):
        out, lse = fwd_pass(q, k, v)
        return out, (q, k, v, out, lse)

    def flash_bwd(res, dout):
        q, k, v, out, lse = res
        b, sq, hkv, g, d = q.shape
        skv = k.shape[1]
        scale = 1.0 / (d ** 0.5)
        cq = _chunk_len(sq, chunk)
        ck = _chunk_len(skv, chunk)
        nq, nk = sq // cq, skv // ck
        banded = window is not None and causal and skv > window
        band = min(skv, window + cq) if banded else None

        delta = jnp.sum(dout * out, -1)              # (B,Sq,Hkv,G)

        def q_step(carry, qi):
            dk_buf, dv_buf = carry
            qc = jax.lax.dynamic_slice_in_dim(q, qi * cq, cq, 1)
            doc = jax.lax.dynamic_slice_in_dim(dout, qi * cq, cq, 1)
            lse_c = jax.lax.dynamic_slice_in_dim(lse, qi * cq, cq, 3)
            del_c = jax.lax.dynamic_slice_in_dim(delta, qi * cq, cq, 1)
            q_pos = qi * cq + jnp.arange(cq)

            def block(kc, vc, k_pos):
                s = jnp.einsum("bqkgd,btkd->bkgqt", qc, kc) * scale
                s = jnp.where(_mask(q_pos, k_pos, causal, window)
                              [None, None, None], s, -jnp.inf)
                p = jnp.exp(s - lse_c[..., None])                 # (b,k,g,q,t)
                dp = jnp.einsum("bqkgd,btkd->bkgqt", doc, vc)
                ds = p * (dp - del_c.transpose(0, 2, 3, 1)[..., None])
                dq_blk = jnp.einsum("bkgqt,btkd->bqkgd", ds, kc) * scale
                dk_blk = jnp.einsum("bkgqt,bqkgd->btkd", ds, qc) * scale
                dv_blk = jnp.einsum("bkgqt,bqkgd->btkd", p, doc)
                return dq_blk, dk_blk, dv_blk

            if banded:
                start = jnp.clip(qi * cq + cq - band, 0, skv - band)
                kc = jax.lax.dynamic_slice_in_dim(k, start, band, 1)
                vc = jax.lax.dynamic_slice_in_dim(v, start, band, 1)
                dq_c, dk_blk, dv_blk = block(kc, vc, start + jnp.arange(band))
                cur_k = jax.lax.dynamic_slice_in_dim(dk_buf, start, band, 1)
                cur_v = jax.lax.dynamic_slice_in_dim(dv_buf, start, band, 1)
                dk_buf = jax.lax.dynamic_update_slice_in_dim(
                    dk_buf, cur_k + dk_blk, start, 1)
                dv_buf = jax.lax.dynamic_update_slice_in_dim(
                    dv_buf, cur_v + dv_blk, start, 1)
                return (dk_buf, dv_buf), dq_c

            def kv_step(carry2, ki):
                dk_b, dv_b, dq_acc = carry2
                kc = jax.lax.dynamic_slice_in_dim(k, ki * ck, ck, 1)
                vc = jax.lax.dynamic_slice_in_dim(v, ki * ck, ck, 1)
                dq_blk, dk_blk, dv_blk = block(kc, vc,
                                               ki * ck + jnp.arange(ck))
                cur_k = jax.lax.dynamic_slice_in_dim(dk_b, ki * ck, ck, 1)
                cur_v = jax.lax.dynamic_slice_in_dim(dv_b, ki * ck, ck, 1)
                dk_b = jax.lax.dynamic_update_slice_in_dim(
                    dk_b, cur_k + dk_blk, ki * ck, 1)
                dv_b = jax.lax.dynamic_update_slice_in_dim(
                    dv_b, cur_v + dv_blk, ki * ck, 1)
                return (dk_b, dv_b, dq_acc + dq_blk), None

            dq0 = jnp.zeros_like(qc)
            (dk_buf, dv_buf, dq_c), _ = jax.lax.scan(
                kv_step, (dk_buf, dv_buf, dq0), jnp.arange(nk))
            return (dk_buf, dv_buf), dq_c

        dk0 = jnp.zeros_like(k)
        dv0 = jnp.zeros_like(v)
        (dk, dv), dqs = jax.lax.scan(q_step, (dk0, dv0), jnp.arange(nq))
        dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hkv, g, d)
        return dq, dk, dv

    flash.defvjp(flash_fwd, flash_bwd)
    return flash


def flash_attention_vjp(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        chunk: int = 1024) -> jax.Array:
    """Drop-in for layers.flash_attention with O(S) backward memory.

    q (B,Sq,H,D), k/v (B,Skv,Hkv,D) -> (B,Sq,H,D).
    """
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    fn = _make_flash(causal, window, chunk)
    qg = q.reshape(b, sq, hkv, g, d).astype(jnp.float32)
    out = fn(qg, k.astype(jnp.float32), v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)
