"""Model zoo: one scan-based stack per family, MF-QAT plumbed everywhere."""
from typing import Optional

from repro.core.qat import QATConfig
from repro.models.common import ModelConfig, QuantCtx
from repro.models.transformer import ModelApi


def get_model(cfg: ModelConfig, qat: Optional[QATConfig] = None) -> ModelApi:
    if cfg.family == "encdec":
        from repro.models import encdec
        return encdec.make_model(cfg, qat)
    from repro.models import transformer
    return transformer.make_model(cfg, qat)
