"""Shared model infrastructure: configs, quantization context, param helpers.

Models are pure functions over nested-dict param pytrees. Every matmul weight
flows through ``QuantCtx.dense`` which injects MF-QAT fake-quantization (STE)
when enabled — this is where the paper's technique plugs into every
architecture. Block axis = 0 (the contraction dim of our (d_in, d_out)
weights), matching OCP MX dot-product blocking.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.qat import QATConfig
from repro.sharding.rules import shard_act


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (one instance per assigned arch)."""

    name: str
    family: str                     # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    rope_theta: float = 10000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    mlp_bias: bool = False
    sliding_window: Optional[int] = None
    norm_eps: float = 1e-5
    act: str = "swiglu"             # swiglu | gelu
    tie_embeddings: bool = False
    # MoE
    moe_experts: int = 0
    moe_topk: int = 2
    moe_every: int = 1              # MoE at layers where i % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # Hybrid (jamba): attention at layers where i % attn_every == attn_offset
    attn_every: int = 0             # 0 -> attention everywhere
    attn_offset: int = 0
    # Mamba
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0          # 0 -> ceil(d_model / 16)
    # RWKV
    rwkv_head_dim: int = 64
    # Encoder-decoder
    enc_layers: int = 0
    # Modality frontend stubs
    vision_tokens: int = 0          # llava anyres patch embeds
    audio_downsample: int = 0       # seamless: enc frames = seq // this
    # Numerics / misc
    compute_dtype: Any = jnp.bfloat16
    scan_group: int = 1             # layers per scan step (jamba period = 8)
    seq_chunk: int = 1024           # flash-attention / loss chunking
    flash_vjp: bool = True          # custom-VJP flash (O(S) bwd memory)
    seq_sharding: bool = False      # sequence-parallel residual stream (SP)
    remat: bool = True
    remat_inner: bool = False       # also remat each layer inside a group
    #                                 (peak bwd mem = 1 layer, not the group)
    unroll: bool = False            # python-loop layers (cost-model calib)
    max_seq: int = 8192             # rope table sizing hint (not a hard cap)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.scan_group == 0
        return self.n_layers // self.scan_group

    def is_attn_layer(self, i: int) -> bool:
        if self.family in ("ssm",):
            return False
        if self.attn_every <= 0:
            return True
        return i % self.attn_every == self.attn_offset

    def is_moe_layer(self, i: int) -> bool:
        if self.moe_experts <= 0:
            return False
        return i % self.moe_every == self.moe_offset

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.mamba_dt_rank or max(1, -(-self.d_model // 16))


def is_packed_leaf(w) -> bool:
    """True for the packed-MX weight containers the serving trees hold."""
    from repro.core.mx import MXTensor
    if isinstance(w, MXTensor):
        return True
    try:
        from repro.serve.packed_params import PackedInt4Leaf
        return isinstance(w, PackedInt4Leaf)
    except ImportError:
        return False


def _maybe_dequant_packed(w, dtype):
    """Dequantize packed-MX weight containers at their point of use.

    Containers sliced out of a scan keep stale static `block_axis` metadata;
    ``serving_axis=True`` re-derives the contraction dim as ndim-2 per our
    stacking convention (one shared implementation in serve/packed_params).
    """
    if not is_packed_leaf(w):
        return w
    from repro.serve.packed_params import densify_leaf
    # block_size=None: derived from the leaf itself (MXTensor carries its
    # fmt; PackedInt4Leaf's is computed from its shapes — the registry
    # default would be wrong for non-default anchor block sizes).
    return densify_leaf(w, None, dtype, serving_axis=True)


@dataclasses.dataclass
class QuantCtx:
    """Threads the MF-QAT config + traced format index through the forward.

    fmt_idx semantics (see fake_quant_switch): 0..len(formats)-1 selects a
    training format, len(formats) selects the FP pass-through branch.

    ``qmm`` is the serving-path matmul hook: ``(x, packed_leaf, name) -> y``.
    When set, packed-MX weight containers skip the XLA dequant below and are
    fed straight to the fused Pallas dequant-GEMM dispatch
    (``repro.kernels.dispatch.qmatmul``) — the weight never exists dense.

    ``tp_axis`` names the tensor-parallel mesh axis when the forward runs
    inside ``shard_map`` over head/ffn-sharded weights. Row-parallel
    projections (wo, w_down) then request a single ``psum`` per projection
    pair via ``dense(..., tp_reduce=True)``; everything else is local math
    on the shard. ``None`` (the default) is the single-device path and adds
    no collectives.
    """

    qat: Optional[QATConfig] = None
    fmt_idx: Optional[jax.Array] = None
    qmm: Optional[Any] = None
    tp_axis: Optional[str] = None

    def maybe_quant(self, w: jax.Array, name: str) -> jax.Array:
        if self.qat is None or not self.qat.enabled or self.fmt_idx is None:
            return w
        return self.qat.apply(w, name, self.fmt_idx)

    def no_qmm(self) -> "QuantCtx":
        """A copy without the fused-GEMM hook (densify-at-point-of-use).

        Used under transformations the dispatch layer doesn't support yet —
        e.g. the vmapped MoE expert GEMMs, where leaves arrive as batch
        tracers and pallas_call would need a batching rule.
        """
        if self.qmm is None:
            return self
        return dataclasses.replace(self, qmm=None)

    def dense(self, x: jax.Array, w, name: str,
              b: Optional[jax.Array] = None,
              out_logical: Optional[Tuple] = None, *,
              tp_reduce: bool = False) -> jax.Array:
        """y = x @ fake_quant(w) in the compute dtype.

        `w` may be a packed-MX container (MXTensor / PackedInt4Leaf): with a
        ``qmm`` hook it flows into the fused dequant-GEMM; otherwise it is
        dequantized right here — inside the layer scan — so only one layer's
        bf16 weights are ever resident (the XLA-level analogue of the Pallas
        contract; see serve/packed_params.py).

        ``tp_reduce=True`` marks a row-parallel projection: under tensor
        parallelism (``tp_axis`` set) the shard-local partial product is
        psum'd over the mesh axis BEFORE the bias add, so the (replicated)
        bias is applied exactly once.
        """
        if self.qmm is not None and is_packed_leaf(w):
            y = self.qmm(x, w, name)
        else:
            w = _maybe_dequant_packed(w, x.dtype)
            wq = self.maybe_quant(w, name).astype(x.dtype)
            y = jax.lax.dot_general(x, wq, (((x.ndim - 1,), (0,)), ((), ())),
                                    preferred_element_type=x.dtype)
        if tp_reduce and self.tp_axis is not None:
            y = jax.lax.psum(y, self.tp_axis)
        if b is not None:
            y = y + b.astype(x.dtype)
        if out_logical is not None:
            y = shard_act(y, out_logical)
        return y


NO_QUANT = QuantCtx()


# =============================================================================
# Slot-level cache surgery (continuous batching)
# =============================================================================
# Cache pytrees stack a leading group/layer axis, so the batch axis is 1 on
# every leaf across all families (attn KV, mamba/rwkv state, encdec cross-KV).
CACHE_BATCH_AXIS = 1


def is_paged_cache(cache) -> bool:
    """True for the paged KV layout (shared page pools + per-slot block
    table) — its pool leaves have NO batch axis, so the dense slot-surgery
    helpers below must not touch them."""
    return isinstance(cache, dict) and "block_table" in cache


def single_slot_cache(cache, batch_axis: int = CACHE_BATCH_AXIS):
    """A zeroed copy of ``cache`` with the batch axis shrunk to one slot."""
    return jax.tree_util.tree_map(
        lambda c: jnp.zeros(
            c.shape[:batch_axis] + (1,) + c.shape[batch_axis + 1:], c.dtype),
        cache)


def slice_cache_slot(cache, slot, batch_axis: int = CACHE_BATCH_AXIS):
    """Slice slot ``slot`` of a batched cache out as a batch-1 cache pytree.

    The read half of the read-modify-write a chunked prefill needs on the
    dense KV layout: unlike ``single_slot_cache`` (a zeroed scratch), the
    slice carries the slot's already-written KV so a later chunk can attend
    over earlier chunks.
    """
    return jax.tree_util.tree_map(
        lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=batch_axis),
        cache)


def insert_cache_slot(cache, single, slot, batch_axis: int = CACHE_BATCH_AXIS):
    """Write a batch-1 cache pytree into slot ``slot`` of a batched cache.

    ``slot`` may be traced; other slots' buffers are untouched, which is what
    lets a serving engine admit one request without re-prefilling the rest.
    """
    return jax.tree_util.tree_map(
        lambda big, sm: jax.lax.dynamic_update_slice_in_dim(
            big, sm.astype(big.dtype), slot, axis=batch_axis),
        cache, single)


def make_prefill_slot(prefill):
    """Derive a single-slot prefill-insert from a batched ``prefill``.

    Dense KV layout: runs ONE request (tokens ``(1, S)``) through a batch-1
    scratch cache and writes the result into slot ``slot`` of the live batched
    cache. Paged KV layout: no scratch/insert at all — the prompt's KV
    scatters straight into the pages the slot's block-table row maps, which
    cannot touch any other slot's pages (physical pages are allocated to at
    most one slot). Returns ``(logits (V,), new_cache, new_len scalar)``.
    """
    def prefill_slot(params, batch, cache, slot):
        if is_paged_cache(cache):
            row = jax.lax.dynamic_slice_in_dim(cache["block_table"], slot, 1,
                                               axis=0)
            logits, filled, clen = prefill(
                params, batch, dict(cache, block_table=row))
            return (logits[0],
                    dict(filled, block_table=cache["block_table"]), clen[0])
        small = single_slot_cache(cache)
        logits, filled, clen = prefill(params, batch, small)
        return logits[0], insert_cache_slot(cache, filled, slot), clen[0]
    return prefill_slot


def make_prefill_chunk_slot(prefill_chunk):
    """Derive a single-slot chunked prefill from a batched ``prefill_chunk``.

    Like ``make_prefill_slot`` but for one prompt *chunk* at cursor
    ``start_pos`` (chunked admission — docs/serving_internals.md "Admission
    & scheduling"). Paged KV: the chunk writes straight through the slot's
    block-table row, which is the isolation. Dense KV: the slot's cache row
    is sliced out (NOT a zeroed scratch — chunk N must see chunks
    0..N-1's KV), run through, and written back. Returns
    ``(logits (V,), new_cache, new_len scalar)``.
    """
    def prefill_chunk_slot(params, batch, cache, slot, start_pos):
        if is_paged_cache(cache):
            row = jax.lax.dynamic_slice_in_dim(cache["block_table"], slot, 1,
                                               axis=0)
            logits, filled, clen = prefill_chunk(
                params, batch, dict(cache, block_table=row), start_pos)
            return (logits[0],
                    dict(filled, block_table=cache["block_table"]), clen[0])
        small = slice_cache_slot(cache, slot)
        logits, filled, clen = prefill_chunk(params, batch, small, start_pos)
        return logits[0], insert_cache_slot(cache, filled, slot), clen[0]
    return prefill_chunk_slot


def spec_accept_counts(drafts, anchor_toks, budgets):
    """Per-row commit counts for a speculative verify tick (host side).

    ``drafts`` (B, k): the draft rung's greedy tokens for the burst.
    ``anchor_toks`` (B, k+1): argmax of ``ModelApi.verify_step`` logits —
    lane ``i`` is the verify format's own next token after consuming input
    token ``i`` (lane 0 after the committed last token, lane ``i>0`` after
    draft ``i-1``). A row accepts the longest prefix where
    ``drafts[:, i] == anchor_toks[:, i]`` — every accepted draft is, by
    construction, exactly the token plain verify-format decode would have
    emitted — then commits those ``m`` tokens plus the verify step's bonus
    token at lane ``m``: ``m + 1`` tokens total. The count is clamped to
    the row's remaining ``budgets`` entry (max_new / cache-capacity
    headroom), which is what keeps a speculative stream bit-identical to
    plain decode even at the retire boundary. Returns (B,) int64 commit
    counts (0 where ``budgets`` is 0; masked rows should pass budget 0).
    """
    import numpy as np
    drafts = np.asarray(drafts)
    anchor_toks = np.asarray(anchor_toks)
    b, k = drafts.shape
    if anchor_toks.shape != (b, k + 1):
        raise ValueError(
            f"anchor_toks {anchor_toks.shape} vs drafts {drafts.shape}")
    hit = drafts == anchor_toks[:, :k]                     # (B, k)
    # longest all-True prefix per row: index of first miss (k if none)
    m = np.where(hit.all(axis=1), k, hit.argmin(axis=1))
    return np.minimum(m + 1, np.asarray(budgets))


# =============================================================================
# Param init helpers
# =============================================================================
def trunc_normal(key, shape, std=0.02, dtype=jnp.float32):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def stacked_init(fn, key, n: int):
    """vmap an init over a leading layer/group dimension."""
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params)
               if hasattr(x, "size"))
