"""Generic decoder-only LM covering the dense / MoE / hybrid / SSM families.

One stack implementation serves 8 of the 10 assigned architectures:
mixtral-8x22b, mixtral-8x7b, jamba-1.5-large, llava backbone, qwen3-4b,
qwen2-72b, smollm-135m, starcoder2-3b, rwkv6-7b. The layer *pattern* within a
scan group is static (group size = the arch's period: 1 for homogeneous
stacks, 8 for Jamba's 1:7 attn:mamba interleave), and parameters are stacked
over groups so the whole stack lowers as one ``lax.scan`` — compile time and
HLO size stay flat in depth, which matters at 512 devices.

Entry points: ``train_loss``, ``prefill``, ``serve_step`` (one token against
a preallocated cache), all QAT-aware via QuantCtx.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.qat import QATConfig
from repro.models import layers as L
from repro.models import rwkv as R
from repro.models import ssm as S
from repro.models.common import (ModelConfig, QuantCtx,
                                 make_prefill_chunk_slot, make_prefill_slot,
                                 stacked_init, trunc_normal)
from repro.sharding.rules import shard_act


# =============================================================================
# Layer-kind plumbing
# =============================================================================
def mixer_kind(cfg: ModelConfig, j: int) -> str:
    """Mixer for in-group position j (pattern is periodic in scan_group)."""
    if cfg.family == "ssm":
        return "rwkv"
    if cfg.is_attn_layer(j):
        return "attn"
    return "mamba"


def ffn_kind(cfg: ModelConfig, j: int) -> str:
    if cfg.family == "ssm":
        return "cmix"
    return "moe" if cfg.is_moe_layer(j) else "mlp"


# =============================================================================
# Init
# =============================================================================
def _init_attn(key, cfg: ModelConfig):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": trunc_normal(ks[0], (d, h * hd)),
        "wk": trunc_normal(ks[1], (d, hkv * hd)),
        "wv": trunc_normal(ks[2], (d, hkv * hd)),
        "wo": trunc_normal(ks[3], (h * hd, d), std=0.02 / cfg.n_layers ** 0.5),
    }
    if cfg.qkv_bias:
        p.update(bq=jnp.zeros((h * hd,)), bk=jnp.zeros((hkv * hd,)),
                 bv=jnp.zeros((hkv * hd,)))
    if cfg.qk_norm:
        p.update(q_norm=jnp.ones((hd,)), k_norm=jnp.ones((hd,)))
    return p


def _attn_axes(cfg: ModelConfig):
    p = {"wq": ("fsdp", "model"), "wk": ("fsdp", "model"),
         "wv": ("fsdp", "model"), "wo": ("model", "fsdp")}
    if cfg.qkv_bias:
        p.update(bq=("model",), bk=("model",), bv=("model",))
    if cfg.qk_norm:
        p.update(q_norm=(None,), k_norm=(None,))
    return p


def _init_mlp(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    down_std = 0.02 / cfg.n_layers ** 0.5
    if cfg.act == "swiglu":
        p = {"w_gate": trunc_normal(ks[0], (d, f)),
             "w_up": trunc_normal(ks[1], (d, f)),
             "w_down": trunc_normal(ks[2], (f, d), std=down_std)}
    else:
        p = {"w_up": trunc_normal(ks[0], (d, f)),
             "w_down": trunc_normal(ks[1], (f, d), std=down_std)}
    if cfg.mlp_bias:
        p.update(b_up=jnp.zeros((f,)), b_down=jnp.zeros((d,)))
    return p


def _mlp_axes(cfg: ModelConfig):
    if cfg.act == "swiglu":
        p = {"w_gate": ("fsdp", "mlp"), "w_up": ("fsdp", "mlp"),
             "w_down": ("mlp", "fsdp")}
    else:
        p = {"w_up": ("fsdp", "mlp"), "w_down": ("mlp", "fsdp")}
    if cfg.mlp_bias:
        p.update(b_up=("mlp",), b_down=(None,))
    return p


def _init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe_experts
    ks = jax.random.split(key, 4)
    down_std = 0.02 / cfg.n_layers ** 0.5

    def one_expert(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"w_gate": trunc_normal(k1, (d, f)),
                "w_up": trunc_normal(k2, (d, f)),
                "w_down": trunc_normal(k3, (f, d), std=down_std)}

    return {"router": trunc_normal(ks[0], (d, e)),
            "experts": stacked_init(one_expert, ks[1], e)}


def _moe_axes(cfg: ModelConfig):
    return {"router": ("fsdp", None),
            "experts": {"w_gate": ("experts", "fsdp", "mlp"),
                        "w_up": ("experts", "fsdp", "mlp"),
                        "w_down": ("experts", "mlp", "fsdp")}}


def init_block(key, cfg: ModelConfig, j: int) -> Dict:
    """One layer (in-group position j)."""
    kmix, kffn = jax.random.split(key)
    mk, fk = mixer_kind(cfg, j), ffn_kind(cfg, j)
    p: Dict[str, Any] = {"mixer_norm": jnp.ones((cfg.d_model,))}
    if mk == "attn":
        p["attn"] = _init_attn(kmix, cfg)
    elif mk == "mamba":
        p["mamba"] = S.init_mamba_params(kmix, cfg)
    else:
        p["rwkv"] = R.init_rwkv_params(kmix, cfg)["time"]
    if fk != "cmix":
        p["ffn_norm"] = jnp.ones((cfg.d_model,))
        p["moe" if fk == "moe" else "mlp"] = \
            _init_moe(kffn, cfg) if fk == "moe" else _init_mlp(kffn, cfg)
    else:
        p["ffn_norm"] = jnp.ones((cfg.d_model,))
        p["cmix"] = R.init_rwkv_params(kffn, cfg)["channel"]
    return p


def block_axes(cfg: ModelConfig, j: int) -> Dict:
    mk, fk = mixer_kind(cfg, j), ffn_kind(cfg, j)
    p: Dict[str, Any] = {"mixer_norm": (None,), "ffn_norm": (None,)}
    if mk == "attn":
        p["attn"] = _attn_axes(cfg)
    elif mk == "mamba":
        p["mamba"] = S.mamba_param_axes(cfg)
    else:
        p["rwkv"] = R.rwkv_param_axes(cfg)["time"]
    if fk == "moe":
        p["moe"] = _moe_axes(cfg)
    elif fk == "mlp":
        p["mlp"] = _mlp_axes(cfg)
    else:
        p["cmix"] = R.rwkv_param_axes(cfg)["channel"]
    return p


def init_params(key, cfg: ModelConfig) -> Dict:
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    blocks = []
    bkeys = jax.random.split(k_blocks, cfg.scan_group)
    for j in range(cfg.scan_group):
        blocks.append(stacked_init(
            lambda k, j=j: init_block(k, cfg, j), bkeys[j], cfg.n_groups))
    params = {
        "embed": trunc_normal(k_embed, (cfg.vocab, cfg.d_model)),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = trunc_normal(k_head, (cfg.d_model, cfg.vocab))
    return params


def param_axes(cfg: ModelConfig) -> Dict:
    def stackax(tree):
        return jax.tree_util.tree_map(
            lambda ax: (None,) + ax, tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))

    axes = {
        "embed": ("vocab", "fsdp"),
        "blocks": [stackax(block_axes(cfg, j)) for j in range(cfg.scan_group)],
        "final_norm": (None,),
    }
    if not cfg.tie_embeddings:
        axes["lm_head"] = ("fsdp", "vocab")
    return axes


# =============================================================================
# Forward
# =============================================================================
def _layer(ctx: QuantCtx, x, p, cfg: ModelConfig, j: int, positions,
           cache_slice, cache_len, prefill: bool, block_table=None,
           chunk_start=None, q_len=None, attn_impl: str = "gather"):
    """One block. Returns (x, new_cache_slice).

    ``block_table`` (B, max_pages) selects the paged KV layout: attention
    cache slices hold page pools (``k_pages``/``v_pages``) instead of
    per-slot contiguous buffers, and all reads/writes go through the
    block-table indirection (see layers.py paged helpers). ``attn_impl``
    picks the paged *decode* read path — the gather-free Pallas kernel
    (``"paged_kernel"``, kernels/paged_attention.py) vs gather + masked
    softmax (``"gather"``); ignored outside paged decode.

    ``chunk_start`` (scalar, may be traced; implies ``prefill=True``)
    selects chunked prefill: ``x`` is one prompt chunk whose first token
    sits at that logical position, K/V are written at the cursor, and
    attention reads back the cache so the chunk sees every earlier chunk.
    Attention-only — recurrent mixers fold the prompt into their state in
    one pass and cannot resume mid-prompt, so they reject loudly.

    ``q_len`` (B,), decode-mode only, selects the unified mixed
    prefill+decode tick: ``x`` is a ragged (B, C) batch where row ``b``'s
    first ``q_len[b]`` tokens are real, each sitting at the row's own
    ``cache_len`` cursor — attention-only, same as chunked prefill (the
    mid-prefill row resumes mid-prompt).
    """
    mk, fk = mixer_kind(cfg, j), ffn_kind(cfg, j)
    name = f"blk{j}.{mk}"
    chunked = prefill and chunk_start is not None and cache_slice is not None
    mixed = q_len is not None and not prefill and cache_slice is not None
    if (chunked or mixed) and mk != "attn":
        raise ValueError(
            f"{'chunked prefill' if chunked else 'the mixed tick'} requires "
            f"attention mixers; layer {j} of "
            f"family {cfg.family!r} is {mk!r} (its recurrent state cannot "
            "resume mid-prompt) — use monolithic admission")
    new_cache: Dict[str, Any] = {}
    h = L.rms_norm(x, p["mixer_norm"], cfg.norm_eps)
    if mk == "attn":
        paged = cache_slice is not None and "k_pages" in cache_slice
        kv = None
        if cache_slice is not None and (chunked or not prefill):
            kv = (cache_slice["k_pages"], cache_slice["v_pages"]) if paged \
                else (cache_slice["k"], cache_slice["v"])
        out, new_kv = L.attention_block(
            ctx, h, p["attn"], cfg, positions, name,
            kv_cache=kv, cache_len=cache_len,
            block_table=block_table if paged else None,
            chunk_start=chunk_start if chunked else None,
            q_len=q_len if mixed else None,
            attn_impl=attn_impl)
        if cache_slice is not None:
            if chunked:
                new_cache = {"k_pages": new_kv[0], "v_pages": new_kv[1]} \
                    if paged else {"k": new_kv[0], "v": new_kv[1]}
            elif prefill and paged:
                k_new, v_new = new_kv
                new_cache = {
                    "k_pages": L.paged_prefill_update(
                        cache_slice["k_pages"], k_new, block_table),
                    "v_pages": L.paged_prefill_update(
                        cache_slice["v_pages"], v_new, block_table)}
            elif prefill:
                k_new, v_new = new_kv
                kc = jax.lax.dynamic_update_slice_in_dim(
                    cache_slice["k"], k_new.astype(cache_slice["k"].dtype),
                    0, axis=1)
                vc = jax.lax.dynamic_update_slice_in_dim(
                    cache_slice["v"], v_new.astype(cache_slice["v"].dtype),
                    0, axis=1)
                new_cache = {"k": kc, "v": vc}
            elif paged:
                new_cache = {"k_pages": new_kv[0], "v_pages": new_kv[1]}
            else:
                new_cache = {"k": new_kv[0], "v": new_kv[1]}
    elif mk == "mamba":
        state = None
        if cache_slice is not None and not prefill:
            state = (cache_slice["h"], cache_slice["conv"])
        out, (hst, conv) = S.mamba_block(ctx, h, p["mamba"], cfg, name,
                                         state=state)
        if cache_slice is not None:
            new_cache = {"h": hst, "conv": conv}
    else:  # rwkv time mix
        state = None
        if cache_slice is not None and not prefill:
            state = (cache_slice["shift_t"], cache_slice["wkv"])
        out, (shift_t, wkv) = R.rwkv_time_mix(ctx, h, p["rwkv"], cfg, name,
                                              state=state)
        if cache_slice is not None:
            new_cache = {"shift_t": shift_t, "wkv": wkv}
    x = x + out

    h = L.rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    name_f = f"blk{j}.{fk}"
    if fk == "mlp":
        out = L.mlp_block(ctx, h, p["mlp"], cfg, name_f)
    elif fk == "moe":
        out, aux = L.moe_block(ctx, h, p["moe"], cfg, name_f)
    else:
        state = None
        if cache_slice is not None and not prefill:
            state = cache_slice["shift_c"]
        out, shift_c = R.rwkv_channel_mix(ctx, h, p["cmix"], cfg, name_f,
                                          state=state)
        if cache_slice is not None:
            new_cache["shift_c"] = shift_c
    x = x + out
    return x, new_cache, aux


def forward_hidden(ctx: QuantCtx, params, cfg: ModelConfig, x, positions,
                   cache=None, cache_len=None, prefill: bool = False,
                   chunk_start=None, q_len=None, attn_impl: str = "gather"):
    """Run the block stack. x (B,S,d). Returns (hidden, new_cache, aux)."""
    # Sequence-parallel residual: the per-group saved activation (the scan
    # carry, which dominates train memory at depth) shards its seq dim over
    # `model` — a Megatron-SP analogue. No-op when seq doesn't divide.
    resid_axes = ("batch", "seq_sp" if (cfg.seq_sharding and x.shape[1] > 1)
                  else "seq", None)
    # Paged KV layout: the block table is per-slot and shared across layers
    # (each layer has its own pool of identical shape), so it rides outside
    # the scanned cache leaves and the scan body closes over it.
    block_table = cache.get("block_table") if cache is not None else None

    def group_body(carry, xs):
        xv, aux = carry
        group_params, group_cache = xs
        new_slices = []
        for j in range(cfg.scan_group):
            cs = group_cache[j] if group_cache is not None else None

            def layer_call(xv_, p_, cs_, _j=j):
                return _layer(ctx, xv_, p_, cfg, _j, positions, cs_,
                              cache_len, prefill, block_table, chunk_start,
                              q_len, attn_impl)

            if cfg.remat_inner and cfg.scan_group > 1:
                layer_call = jax.checkpoint(
                    layer_call,
                    policy=jax.checkpoint_policies.nothing_saveable)
            xv, nc, a = layer_call(xv, group_params[j], cs)
            new_slices.append(nc)
            aux = aux + a
        xv = shard_act(xv, resid_axes)
        return (xv, aux), new_slices

    body = group_body
    if cfg.remat:
        body = jax.checkpoint(group_body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    if cfg.unroll:
        # python loop over groups — exact HLO op counts (cost-model calib)
        carry = (x, jnp.zeros((), jnp.float32))
        new_blocks = []
        for g in range(cfg.n_groups):
            gp = jax.tree_util.tree_map(lambda t: t[g], params["blocks"])
            gc = jax.tree_util.tree_map(lambda t: t[g], cache["blocks"]) \
                if cache is not None else None
            carry, slices = body(carry, (gp, gc))
            new_blocks.append(slices)
        (x, aux) = carry
        if cache is not None:
            stacked = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *new_blocks)
            new_cache = {"blocks": stacked}
            if block_table is not None:
                new_cache["block_table"] = block_table
        else:
            new_cache = None
    elif cache is None:
        def body_nc(carry, gp):
            (xv, aux), ncs = body(carry, (gp, None))
            return (xv, aux), None

        (x, aux), _ = jax.lax.scan(body_nc, (x, jnp.zeros((), jnp.float32)),
                                   params["blocks"])
        new_cache = None
    else:
        (x, aux), new_blocks = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (params["blocks"], cache["blocks"]))
        new_cache = {"blocks": new_blocks}
        if block_table is not None:
            new_cache["block_table"] = block_table
    hidden = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return hidden, new_cache, aux


def _embed(params, cfg: ModelConfig, tokens, tp_axis=None):
    emb = params["embed"]
    if tp_axis is not None and emb.shape[0] != cfg.vocab:
        # Vocab-sharded table inside shard_map: each token's row lives on
        # exactly one shard. Offset the ids into the local range, mask the
        # out-of-range rows to zero, and psum — every shard contributes the
        # true row or an exact zero, so the sum is bit-identical to the
        # unsharded lookup.
        v_local = emb.shape[0]
        local = tokens - jax.lax.axis_index(tp_axis) * v_local
        ok = (local >= 0) & (local < v_local)
        rows = jnp.take(emb, jnp.where(ok, local, 0), axis=0)
        x = jnp.where(ok[..., None], rows, jnp.zeros((), emb.dtype))
        x = jax.lax.psum(x, tp_axis).astype(cfg.compute_dtype)
        return shard_act(x, ("batch", None, None))
    x = jnp.take(emb, tokens, axis=0).astype(cfg.compute_dtype)
    return shard_act(x, ("batch", None, None))


def _lm_head_w(params, cfg: ModelConfig):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def _head_logits(ctx: QuantCtx, params, cfg: ModelConfig, h_last):
    """lm-head projection over the last-position hidden states (B, d).

    In fused serving the params tree stays packed: a quantized lm_head leaf
    (non-default QAT exclusions) routes through the dequant-GEMM hook like
    every other projection instead of crashing on `.astype`.

    Under tensor parallelism the head weight is vocab-sharded (lm_head
    columns / tied embed rows), so the local matmul yields a vocab slice;
    a tiled all_gather reassembles the exact global logits (pure
    concatenation — no arithmetic, so bit-identical).
    """
    from repro.models.common import is_packed_leaf
    if not cfg.tie_embeddings and ctx.qmm is not None and \
            is_packed_leaf(params["lm_head"]):
        logits = ctx.qmm(h_last.astype(jnp.float32), params["lm_head"],
                         "lm_head")
    else:
        logits = jax.lax.dot_general(
            h_last.astype(jnp.float32),
            _lm_head_w(params, cfg).astype(jnp.float32),
            (((1,), (0,)), ((), ())))
    if ctx.tp_axis is not None and logits.shape[-1] != cfg.vocab:
        logits = jax.lax.all_gather(logits, ctx.tp_axis,
                                    axis=logits.ndim - 1, tiled=True)
    return logits


def _last_hidden(hidden, cache_len):
    """hidden (B, S, d) -> (B, d) at each row's own last valid position."""
    return jax.vmap(lambda h, i: jax.lax.dynamic_index_in_dim(
        h, i, 0, keepdims=False))(hidden, cache_len - 1)


def chunked_ce_loss(ctx: QuantCtx, hidden, head_w, labels, mask,
                    cfg: ModelConfig):
    """Cross entropy over vocab-sharded logits, chunked along seq."""
    b, s, d = hidden.shape
    c = min(cfg.seq_chunk, s)
    while s % c:
        c //= 2
    nc = s // c

    def chunk(carry, i):
        tot, cnt = carry
        hs = jax.lax.dynamic_slice_in_dim(hidden, i * c, c, axis=1)
        lb = jax.lax.dynamic_slice_in_dim(labels, i * c, c, axis=1)
        mk = jax.lax.dynamic_slice_in_dim(mask, i * c, c, axis=1)
        logits = jax.lax.dot_general(
            hs.astype(jnp.float32), head_w.astype(jnp.float32),
            (((2,), (0,)), ((), ())))                       # (B,c,V) f32
        logits = shard_act(logits, ("batch", None, "vocab"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        nll = (lse - tgt) * mk
        return (tot + jnp.sum(nll), cnt + jnp.sum(mk)), None

    (tot, cnt), _ = jax.lax.scan(
        chunk, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        jnp.arange(nc))
    return tot / jnp.maximum(cnt, 1.0)


# =============================================================================
# Model API
# =============================================================================
@dataclasses.dataclass
class ModelApi:
    cfg: ModelConfig
    qat: Optional[QATConfig]
    init_params: Callable
    param_axes: Callable
    train_loss: Callable          # (params, batch, fmt_idx) -> (loss, aux)
    init_cache: Callable          # (batch, cache_len, dtype) -> cache pytree
    cache_axes: Callable
    prefill: Callable             # (params, batch) -> (logits, cache, len)
    serve_step: Callable          # (params, batch, cache, len) -> (logits, cache)
    prefill_slot: Callable = None  # (params, batch(1,S), cache, slot)
    #                                -> (logits (V,), cache, len scalar);
    #                                single-request prefill-insert: fills one
    #                                slot without touching the others
    prefill_chunk: Callable = None  # (params, batch(B,C), cache, start_pos)
    #                                -> (logits, cache, len): one prompt
    #                                chunk at cursor start_pos (chunked
    #                                admission; attention families only)
    prefill_chunk_slot: Callable = None  # single-slot prefill_chunk:
    #                                (params, batch(1,C), cache, slot,
    #                                start_pos) -> (logits (V,), cache, len)
    mixed_step: Callable = None    # (params, batch{tokens (B,C), q_len (B,)},
    #                                cache, cache_len) -> (logits (B,V),
    #                                cache): ONE mixed prefill+decode tick —
    #                                decode rows at q_len 1, the mid-prefill
    #                                row at its chunk width, each at its own
    #                                cache_len cursor (attention-only)
    verify_step: Callable = None   # mixed_step's speculative sibling: same
    #                                (params, batch{tokens (B,C), q_len (B,)},
    #                                cache, cache_len) contract but logits at
    #                                ALL C positions -> (logits (B,C,V),
    #                                cache): the single-executable anchor-side
    #                                check of a k-token draft burst
    #                                (docs/serving_internals.md §9)
    with_qmm: Callable = None      # (qmm) -> ModelApi whose serving entry
    #                                points route packed weight leaves
    #                                through the fused dequant-GEMM hook
    with_serving: Callable = None  # (qmm=None, attn_impl="gather") ->
    #                                ModelApi with BOTH serving knobs baked
    #                                into the rebuilt entry points: the
    #                                dequant-GEMM hook and the paged decode
    #                                attention path ("gather" |
    #                                "paged_kernel"); the derived api's
    #                                with_qmm preserves its attn_impl, so
    #                                chaining composes rather than resetting
    attn_impl: str = "gather"      # paged decode read path the serving
    #                                entry points were built with
    tp_axis: Optional[str] = None  # tensor-parallel mesh axis the serving
    #                                entry points psum/all_gather over when
    #                                run inside shard_map (make_model
    #                                tp_axis=...); None = single-device math


def _cache_for_block(cfg: ModelConfig, j: int, b: int, s_max: int, dtype):
    mk = mixer_kind(cfg, j)
    c: Dict[str, Any] = {}
    if mk == "attn":
        c["k"] = jnp.zeros((b, s_max, cfg.n_kv_heads, cfg.hd), dtype)
        c["v"] = jnp.zeros((b, s_max, cfg.n_kv_heads, cfg.hd), dtype)
    elif mk == "mamba":
        c["h"] = jnp.zeros((b, cfg.mamba_d_inner, cfg.mamba_d_state),
                           jnp.float32)
        c["conv"] = jnp.zeros((b, cfg.mamba_d_conv - 1, cfg.mamba_d_inner),
                              dtype)
    else:
        hh = cfg.d_model // cfg.rwkv_head_dim
        c["shift_t"] = jnp.zeros((b, 1, cfg.d_model), dtype)
        c["wkv"] = jnp.zeros((b, hh, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                             jnp.float32)
        c["shift_c"] = jnp.zeros((b, 1, cfg.d_model), dtype)
    return c


def _cache_axes_for_block(cfg: ModelConfig, j: int):
    mk = mixer_kind(cfg, j)
    if mk == "attn":
        return {"k": (None, "batch", "kv_seq", None, None),
                "v": (None, "batch", "kv_seq", None, None)}
    if mk == "mamba":
        return {"h": (None, "batch", "model", None),
                "conv": (None, "batch", None, "model")}
    return {"shift_t": (None, "batch", None, None),
            "wkv": (None, "batch", "heads", None, None),
            "shift_c": (None, "batch", None, None)}


def make_model(cfg: ModelConfig, qat: Optional[QATConfig] = None, *,
               tp_axis: Optional[str] = None) -> ModelApi:
    """Build the ModelApi. ``tp_axis`` names the tensor-parallel mesh axis
    to reduce over when the serving entry points run inside ``shard_map``
    with head/ffn/vocab-sharded weights — pass ``cfg`` with the LOCAL head
    counts (and ``head_dim`` pinned) but the GLOBAL vocab (see
    serve/engine.py's mesh path and docs/serving_internals.md §11).
    Training entry points ignore it."""
    n_fmts = len(qat.formats) if qat else 0

    def _ctx(fmt_idx):
        if qat is None or not qat.enabled:
            return QuantCtx()
        idx = fmt_idx if fmt_idx is not None else jnp.int32(n_fmts)
        return QuantCtx(qat=qat, fmt_idx=idx)

    # ---- training ---------------------------------------------------------
    def train_loss(params, batch, fmt_idx=None):
        ctx = _ctx(fmt_idx)
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = _embed(params, cfg, tokens)
        extra = 0
        if cfg.vision_tokens > 0:
            ve = batch["vision_embeds"].astype(cfg.compute_dtype)
            x = jnp.concatenate([ve, x], axis=1)
            extra = ve.shape[1]
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                     (b, x.shape[1]))
        hidden, _, aux = forward_hidden(ctx, params, cfg, x, positions)
        hidden = hidden[:, extra:]
        labels = batch["labels"]
        mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
        loss = chunked_ce_loss(ctx, hidden, _lm_head_w(params, cfg),
                               labels, mask.astype(jnp.float32), cfg)
        return loss + aux, {"ce": loss, "aux": aux}

    # ---- serving ----------------------------------------------------------
    def init_cache(b, s_max, dtype=None, *, kv_layout="dense",
                   page_size=16, num_pages=None):
        """KV cache pytree.

        ``kv_layout="dense"`` (default): per-slot contiguous buffers
        (B, s_max, Hkv, D) — s_max HBM is committed per slot up front.

        ``kv_layout="paged"``: a shared page pool per layer
        (num_pages, page_size, Hkv, D) plus a per-slot ``block_table``
        (B, ceil(s_max/page_size)) of physical page ids, so s_max is a
        per-request *bound* and HBM is committed page-by-page as sequences
        grow. Physical page 0 is reserved scratch (unmapped entries point
        there); ``num_pages=None`` sizes the pool to dense-equivalent
        capacity + the scratch page. Requires a pure-attention stack —
        recurrent state (mamba/rwkv) has no sequence axis to page.
        """
        dtype = dtype or cfg.compute_dtype
        s_max = s_max + cfg.vision_tokens   # room for prepended image embeds
        if kv_layout == "dense":
            return {"blocks": [
                jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(x[None],
                                               (cfg.n_groups,) + x.shape),
                    _cache_for_block(cfg, j, b, s_max, dtype))
                for j in range(cfg.scan_group)]}
        if kv_layout != "paged":
            raise ValueError(f"unknown kv_layout {kv_layout!r}; "
                             "one of ('dense', 'paged')")
        bad = [mixer_kind(cfg, j) for j in range(cfg.scan_group)
               if mixer_kind(cfg, j) != "attn"]
        if bad:
            raise ValueError(
                f"kv_layout='paged' requires a pure-attention stack; "
                f"family {cfg.family!r} has {bad} mixers whose recurrent "
                "state cannot be paged — use kv_layout='dense'")
        pages_per_slot = -(-s_max // page_size)
        if num_pages is None:
            num_pages = b * pages_per_slot + 1   # + reserved scratch page 0
        pool = functools.partial(
            jnp.zeros, (cfg.n_groups, num_pages, page_size,
                        cfg.n_kv_heads, cfg.hd), dtype)
        return {"blocks": [{"k_pages": pool(), "v_pages": pool()}
                           for _ in range(cfg.scan_group)],
                "block_table": jnp.zeros((b, pages_per_slot), jnp.int32)}

    def cache_axes(kv_layout="dense"):
        if kv_layout == "paged":
            # pools (G, P, ps, Hkv, D): shard the page axis like the dense
            # sequence axis; the tiny block table replicates per batch row.
            ax = {"k_pages": (None, "kv_seq", None, None, None),
                  "v_pages": (None, "kv_seq", None, None, None)}
            return {"blocks": [dict(ax) for _ in range(cfg.scan_group)],
                    "block_table": ("batch", None)}
        return {"blocks": [_cache_axes_for_block(cfg, j)
                           for j in range(cfg.scan_group)]}

    def _serving_fns(qmm=None, attn_impl="gather"):
        """Build (prefill, serve_step) sharing one matmul hook.

        ``qmm=None`` is the XLA contract (packed leaves dequantized at point
        of use / pre-densified trees); a hook routes every packed projection
        through the fused Pallas dequant-GEMM dispatch. ``attn_impl`` bakes
        the paged decode attention path into serve_step: ``"paged_kernel"``
        (the gather-free block-table kernel, kernels/paged_attention.py) or
        ``"gather"`` (materialize + masked softmax). Prefill — monolithic
        and chunked — is unaffected (its flash queries span the cache).
        """
        if attn_impl not in ("gather", "paged_kernel"):
            raise ValueError(
                f"unknown attn_impl {attn_impl!r}; one of "
                "('gather', 'paged_kernel')")

        def prefill(params, batch, cache):
            """Process the full prompt, fill the cache, return last-pos
            logits.

            Serving never fake-quantizes: weights arrive already PTQ'd /
            SS-converted (running the QAT switch here would upcast weights to
            f32 and double the FSDP all-gather bytes — found via dry-run
            HLO).

            ``batch["lengths"]`` (B,), optional: true prompt lengths when
            tokens are right-padded to a length bucket. Attention is causal,
            so pad positions never influence real ones; logits are read at
            each row's own last real token and cache_len is the true length,
            which exactly masks the pad KV entries at decode.
            """
            ctx = QuantCtx(qmm=qmm, tp_axis=tp_axis)
            tokens = batch["tokens"]
            b, s = tokens.shape
            x = _embed(params, cfg, tokens, tp_axis)
            extra = 0
            if cfg.vision_tokens > 0:
                ve = batch["vision_embeds"].astype(cfg.compute_dtype)
                x = jnp.concatenate([ve, x], axis=1)
                extra = ve.shape[1]
            positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                         (b, x.shape[1]))
            hidden, new_cache, _ = forward_hidden(
                ctx, params, cfg, x, positions, cache=cache,
                cache_len=jnp.zeros((b,), jnp.int32), prefill=True)
            lengths = batch.get("lengths")
            if lengths is None:
                cache_len = jnp.full((b,), x.shape[1], jnp.int32)
                h_last = hidden[:, -1]
            else:
                cache_len = lengths.astype(jnp.int32) + extra
                h_last = _last_hidden(hidden, cache_len)
            logits = _head_logits(ctx, params, cfg, h_last)
            return logits, new_cache, cache_len

        def prefill_chunk(params, batch, cache, start_pos):
            """One prompt chunk at cursor ``start_pos`` (chunked admission).

            ``batch["tokens"]`` (B, C) is the prompt slice
            ``[start_pos, start_pos + C)`` (the final chunk may be
            right-padded); ``batch["lengths"]`` (B,) is the TRUE TOTAL
            prompt length. K/V land in the cache at the cursor and the
            chunk's queries attend over everything written so far, so
            running the chunks in order is bit-identical to monolithic
            ``prefill`` (see docs/serving_internals.md "Admission &
            scheduling"). Returns ``(logits, cache, new_len)`` with
            ``new_len = min(lengths, start_pos + C)`` — on the final chunk
            that is the true prompt length and ``logits`` is read at the
            last real token (earlier chunks' logits are discarded by the
            engine).
            """
            if cfg.vision_tokens > 0:
                raise ValueError(
                    "chunked prefill does not support prepended vision "
                    "embeds; use monolithic admission")
            ctx = QuantCtx(qmm=qmm, tp_axis=tp_axis)   # no fake-quant in
            #                                            serving (see prefill)
            tokens = batch["tokens"]
            b, c = tokens.shape
            x = _embed(params, cfg, tokens, tp_axis)
            start = jnp.asarray(start_pos, jnp.int32)
            positions = start + jnp.broadcast_to(jnp.arange(c)[None], (b, c))
            hidden, new_cache, _ = forward_hidden(
                ctx, params, cfg, x, positions, cache=cache,
                cache_len=jnp.zeros((b,), jnp.int32), prefill=True,
                chunk_start=start)
            new_len = jnp.minimum(batch["lengths"].astype(jnp.int32),
                                  start + c)
            h_last = _last_hidden(hidden, new_len - start)
            logits = _head_logits(ctx, params, cfg, h_last)
            return logits, new_cache, new_len

        def serve_step(params, batch, cache, cache_len):
            """One decode step: batch['tokens'] (B,1) against the cache."""
            ctx = QuantCtx(qmm=qmm, tp_axis=tp_axis)   # no fake-quant in
            #                                            serving (see prefill)
            tokens = batch["tokens"]
            b = tokens.shape[0]
            x = _embed(params, cfg, tokens, tp_axis)
            positions = cache_len[:, None]
            hidden, new_cache, _ = forward_hidden(
                ctx, params, cfg, x, positions, cache=cache,
                cache_len=cache_len, prefill=False, attn_impl=attn_impl)
            logits = _head_logits(ctx, params, cfg, hidden[:, -1])
            logits = shard_act(logits, ("batch", "vocab"))
            return logits, new_cache

        def mixed_step(params, batch, cache, cache_len):
            """One unified mixed prefill+decode tick (the single-executable
            scheduler; docs/serving_internals.md §6).

            ``batch["tokens"]`` (B, C): each row's new tokens, left-aligned;
            ``batch["q_len"]`` (B,): how many are real — decoding rows carry
            1, the (single) mid-prefill row carries its chunk, pad lanes are
            masked and never written. Row ``b``'s token ``i`` sits at
            logical position ``cache_len[b] + i``; K/V land there (through
            the block table when paged) and logits come back at each row's
            LAST real token — next-token logits for decode rows, chunk-final
            logits for the mid-prefill row (meaningful only on its final
            chunk; the engine discards the rest). Returns (logits, cache).
            """
            if cfg.vision_tokens > 0:
                raise ValueError(
                    "mixed_step does not support prepended vision embeds; "
                    "use sequential admission")
            ctx = QuantCtx(qmm=qmm, tp_axis=tp_axis)   # no fake-quant in
            #                                            serving (see prefill)
            tokens = batch["tokens"]
            q_len = batch["q_len"].astype(jnp.int32)
            b, c = tokens.shape
            x = _embed(params, cfg, tokens, tp_axis)
            positions = cache_len[:, None] + \
                jnp.broadcast_to(jnp.arange(c)[None], (b, c))
            hidden, new_cache, _ = forward_hidden(
                ctx, params, cfg, x, positions, cache=cache,
                cache_len=cache_len, prefill=False, q_len=q_len,
                attn_impl=attn_impl)
            h_last = _last_hidden(hidden, q_len)
            logits = _head_logits(ctx, params, cfg, h_last)
            logits = shard_act(logits, ("batch", "vocab"))
            return logits, new_cache

        def verify_step(params, batch, cache, cache_len):
            """One speculative-verify tick: logits at EVERY query position.

            Same contract as ``mixed_step`` — ``batch["tokens"]`` (B, C)
            left-aligned new tokens, ``batch["q_len"]`` (B,) how many are
            real, row ``b``'s token ``i`` at logical position
            ``cache_len[b] + i`` — but the head projects ALL C positions,
            returning logits (B, C, V) so the engine can compare every
            draft token against this format's own greedy choice in one
            executable. K/V for all C tokens land at the per-row cursor
            BEFORE attention reads them (the standard mixed
            write-then-attend order), so a verify pass overwrites whatever
            a draft pass wrote at those positions: each verify attempt is
            a pure function of the committed cache, which is what makes
            guard escalate-and-replay safe under speculation
            (docs/serving_internals.md §9). Pad lanes past a row's q_len
            return meaningless logits; callers must only read live lanes.
            """
            if cfg.vision_tokens > 0:
                raise ValueError(
                    "verify_step does not support prepended vision embeds; "
                    "disable speculative decoding for VLM configs")
            ctx = QuantCtx(qmm=qmm, tp_axis=tp_axis)   # no fake-quant in
            #                                            serving (see prefill)
            tokens = batch["tokens"]
            q_len = batch["q_len"].astype(jnp.int32)
            b, c = tokens.shape
            x = _embed(params, cfg, tokens, tp_axis)
            positions = cache_len[:, None] + \
                jnp.broadcast_to(jnp.arange(c)[None], (b, c))
            hidden, new_cache, _ = forward_hidden(
                ctx, params, cfg, x, positions, cache=cache,
                cache_len=cache_len, prefill=False, q_len=q_len,
                attn_impl=attn_impl)
            d = hidden.shape[-1]
            logits = _head_logits(ctx, params, cfg, hidden.reshape(b * c, d))
            logits = logits.reshape(b, c, -1)
            logits = shard_act(logits, ("batch", None, "vocab"))
            return logits, new_cache

        return prefill, serve_step, prefill_chunk, mixed_step, verify_step

    (prefill, serve_step, prefill_chunk, mixed_step,
     verify_step) = _serving_fns(None)

    def with_serving(qmm=None, attn_impl="gather"):
        p, s, pc, ms, vs = _serving_fns(qmm, attn_impl)
        return dataclasses.replace(
            api, prefill=p, serve_step=s, prefill_slot=make_prefill_slot(p),
            prefill_chunk=pc,
            prefill_chunk_slot=make_prefill_chunk_slot(pc),
            mixed_step=ms, verify_step=vs,
            attn_impl=attn_impl,
            # with_qmm on the derived api keeps ITS attn_impl (chaining must
            # not silently reset the decode path to the base default)
            with_qmm=lambda q: with_serving(qmm=q, attn_impl=attn_impl))

    def with_qmm(qmm):
        return with_serving(qmm=qmm)

    api = ModelApi(
        cfg=cfg, qat=qat,
        init_params=functools.partial(init_params, cfg=cfg),
        param_axes=functools.partial(param_axes, cfg=cfg),
        train_loss=train_loss,
        init_cache=init_cache,
        cache_axes=cache_axes,
        prefill=prefill,
        serve_step=serve_step,
        prefill_slot=make_prefill_slot(prefill),
        prefill_chunk=prefill_chunk,
        prefill_chunk_slot=make_prefill_chunk_slot(prefill_chunk),
        mixed_step=mixed_step,
        verify_step=verify_step,
        with_qmm=with_qmm,
        with_serving=with_serving,
        tp_axis=tp_axis,
    )
    return api
