"""AdamW in pure JAX (pytree), matching torch.optim.AdamW defaults.

Moments can be stored in a reduced dtype (bf16) for very large models
(Jamba-398B) — the update math always runs in f32.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    moment_dtype: Any = jnp.float32     # bf16 for 100B+ models
    grad_clip: Optional[float] = 1.0


def init_opt_state(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 lr_scale: jax.Array | float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * scale), grads)
    else:
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / b1c
        vhat = v32 / b2c
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                            + cfg.weight_decay * p32)
        return (p_new.astype(p.dtype), m32.astype(cfg.moment_dtype),
                v32.astype(cfg.moment_dtype))

    out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"step": step, "m": new_m, "v": new_v}, \
        {"grad_norm": gnorm}


def cosine_schedule(base_steps: int, warmup: int = 0, floor: float = 0.1):
    def sched(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, s / jnp.maximum(warmup, 1))
        prog = jnp.clip((s - warmup) / jnp.maximum(base_steps - warmup, 1),
                        0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos
    return sched
