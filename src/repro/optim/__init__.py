from repro.optim.adamw import (AdamWConfig, init_opt_state, adamw_update,
                               cosine_schedule, global_norm)
