"""Deterministic synthetic LM corpus (the container is offline — no WikiText).

A Zipf-ish Markov-chain token stream with enough structure that a small LM's
loss drops well below the unigram entropy: next-token logits follow a
per-state transition row (few successors per token) plus periodic copy
motifs. The stream is generated in self-contained 64k chunks — chunk i is a
pure function of (config, i) — so any absolute position is seekable in
O(needed chunks), which the resumable pipeline and far-offset eval splits
rely on.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

CHUNK = 65536


@dataclasses.dataclass(frozen=True)
class SyntheticConfig:
    vocab: int = 512
    seed: int = 0
    branch: int = 4           # successors per state
    copy_period: int = 64     # every k-th token repeats position t-k
    copy_prob: float = 0.3


@functools.lru_cache(maxsize=64)
def _transition_table(cfg: SyntheticConfig) -> np.ndarray:
    rng = np.random.default_rng(cfg.seed)
    return rng.integers(0, cfg.vocab, size=(cfg.vocab, cfg.branch))


@functools.lru_cache(maxsize=32)
def _gen_chunk(cfg: SyntheticConfig, ci: int) -> np.ndarray:
    """Self-contained chunk ci (state re-seeded per chunk => O(1) seek)."""
    table = _transition_table(cfg)
    decisions = np.random.default_rng(cfg.seed * 7919 + 2 + ci).random(CHUNK)
    picks = np.random.default_rng(cfg.seed * 7919 + 3 + ci).integers(
        0, cfg.branch, CHUNK)
    buf = np.empty(CHUNK, np.int32)
    hist = np.zeros(cfg.copy_period, np.int32)
    state = int((ci * 2654435761 + 1) % cfg.vocab)
    cp, cprob = cfg.copy_period, cfg.copy_prob
    for i in range(CHUNK):
        if i % cp == 0 and decisions[i] < cprob:
            tok = hist[i % cp]
        else:
            tok = table[state, picks[i]]
        buf[i] = tok
        hist[i % cp] = tok
        state = int(tok)
    return buf


def make_tokens(cfg: SyntheticConfig, n: int, start: int = 0) -> np.ndarray:
    """Tokens [start, start+n) — touches only the covering chunks."""
    out = np.empty(n, np.int32)
    first = start // CHUNK
    last = (start + n - 1) // CHUNK
    for ci in range(first, last + 1):
        buf = _gen_chunk(cfg, ci)
        lo = max(start, ci * CHUNK)
        hi = min(start + n, (ci + 1) * CHUNK)
        out[lo - start:hi - start] = buf[lo - ci * CHUNK:hi - ci * CHUNK]
    return out


def token_stream(cfg: SyntheticConfig, start: int = 0):
    """Iterator view (kept for API compatibility)."""
    pos = start
    while True:
        chunk = make_tokens(cfg, CHUNK - (pos % CHUNK), pos)
        for t in chunk:
            yield int(t)
        pos += len(chunk)
