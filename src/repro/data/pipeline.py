"""Resumable, shardable data pipeline over the deterministic synthetic corpus.

Batches are a pure function of (config, step): restart at step k reproduces
batch k exactly (required for checkpoint/restart to be bit-reproducible), and
each data-parallel host slices its own rows (no global shuffle state).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.data.synthetic import SyntheticConfig, make_tokens


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_examples: Optional[int] = None   # paper: 128 QAT examples, cycled


class LMDataset:
    """Next-token-prediction batches from the synthetic stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.scfg = SyntheticConfig(vocab=cfg.vocab, seed=cfg.seed)
        if cfg.n_examples is not None:
            n_tok = cfg.n_examples * (cfg.seq_len + 1)
            self._pool = make_tokens(self.scfg, n_tok).reshape(
                cfg.n_examples, cfg.seq_len + 1)
        else:
            self._pool = None

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        b, s = self.cfg.global_batch, self.cfg.seq_len
        if self._pool is not None:
            idx = (step * b + np.arange(b)) % self._pool.shape[0]
            seqs = self._pool[idx]
        else:
            start = step * b * (s + 1)
            seqs = make_tokens(self.scfg, b * (s + 1), start).reshape(b, s + 1)
        return {
            "tokens": seqs[:, :-1].astype(np.int32),
            "labels": seqs[:, 1:].astype(np.int32),
        }

    def epoch_steps(self) -> int:
        if self._pool is None:
            raise ValueError("infinite dataset has no epochs")
        return max(1, self._pool.shape[0] // self.cfg.global_batch)

    def iter_from(self, step: int) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(step)
            step += 1


def eval_batches(cfg: DataConfig, n_batches: int, offset: int = 10 ** 6):
    """Held-out eval split: the SAME generating process (same seed/table),
    a disjoint far-offset stream region (cheap: chunks seek in O(1))."""
    ds = LMDataset(dataclasses.replace(cfg, n_examples=None))
    return [ds.batch_at(offset + i) for i in range(n_batches)]
