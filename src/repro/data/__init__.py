from repro.data.synthetic import SyntheticConfig, make_tokens
from repro.data.pipeline import DataConfig, LMDataset, eval_batches
