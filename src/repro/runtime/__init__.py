from repro.runtime.fault import PreemptionGuard, StragglerMonitor, Watchdog
