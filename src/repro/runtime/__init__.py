from repro.runtime.fault import (FaultInjector, InjectedFault,
                                 PreemptionGuard, StragglerMonitor, Watchdog,
                                 random_plan)
