"""Fault tolerance: preemption handling, watchdog heartbeat, stragglers,
and deterministic fault injection for the serving engine's chaos tests.

What runs where on a real pod fleet:
  - PreemptionGuard: SIGTERM/SIGINT -> set a flag; the train loop checks it
    every step and checkpoints-then-exits cleanly (maps to Borg/GCE
    preemption notices). Re-entry resumes from LATEST. The serving engine
    uses the same guard: ``ElasticEngine.generate(..., guard=...)``
    snapshots its scheduler state at the next tick boundary and returns
    (docs/serving_internals.md §7).
  - Watchdog: a step-duration heartbeat; if a step exceeds `timeout_s`
    (hung collective / dead host), the registered callback fires — in
    production that aborts the job so the scheduler restarts it from the
    last checkpoint.

    **Callback-thread contract:** ``on_timeout`` runs on the *watchdog's
    daemon thread*, never on the caller's. An exception raised inside it
    kills only that thread — it cannot abort the loop being watched. A
    custom callback must therefore signal out-of-band (set a flag, send a
    signal, abort the process). The default callback does exactly that:
    it *records* a ``TimeoutError``, which ``heartbeat()`` / ``stop()``
    re-raise on the calling thread — so a hung-then-recovered step dies at
    its next heartbeat instead of the timeout being silently swallowed.
  - StragglerMonitor: rolling per-step stats; steps slower than
    `threshold x median` are flagged. On TPU pods persistent stragglers are
    handled by re-scheduling the slow host; the monitor exposes the signal
    and suggested action, and records events for the run report.
  - FaultInjector: a deterministic, plan-driven chaos hook for
    ``ElasticEngine``. Every primitive fires at an explicit scheduler-tick
    (or allocation-call) index, so a chaos run is exactly reproducible
    from its plan; ``random_plan`` derives a plan from a seed + rate for
    the benchmark's chaos sweep. The injector never mutates engine state
    itself — the engine calls its hooks and applies the returned effects,
    and every fired primitive is appended to ``events``.
"""
from __future__ import annotations

import collections
import dataclasses
import signal
import statistics
import threading
import time
from typing import (Callable, Dict, FrozenSet, List, Optional, Tuple, Union)


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._flag = threading.Event()
        self._prev = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:   # non-main thread (tests)
                pass
        return self

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False

    def _handler(self, signum, frame):
        self._flag.set()

    @property
    def preempted(self) -> bool:
        return self._flag.is_set()

    def trigger(self):      # for tests / manual drain
        self._flag.set()


class Watchdog:
    """Fires `on_timeout` if heartbeat() isn't called within timeout_s.

    ``on_timeout`` runs on the watchdog's daemon thread (see the module
    docstring for the callback-thread contract). With the default
    callback, a timeout is recorded and re-raised as ``TimeoutError`` from
    the *next* ``heartbeat()`` or from ``stop()`` — i.e. on the thread
    that owns the watched loop, where it can actually abort it.
    """

    def __init__(self, timeout_s: float,
                 on_timeout: Optional[Callable[[], None]] = None):
        self.timeout_s = timeout_s
        self.on_timeout = on_timeout or self._default
        self._last = time.monotonic()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._timeout_exc: Optional[TimeoutError] = None
        self.fired = False

    def _default(self):
        # Runs on the watchdog thread: raising here would kill only that
        # thread (the pre-fix bug), so record and let the caller's next
        # heartbeat()/stop() re-raise where it can abort the loop.
        self._timeout_exc = TimeoutError(
            f"watchdog: step exceeded the {self.timeout_s:.1f}s heartbeat "
            "timeout (raised at the next heartbeat on the caller's thread; "
            "the timeout itself fired on the watchdog thread)")

    def _reraise(self):
        if self._timeout_exc is not None:
            exc, self._timeout_exc = self._timeout_exc, None
            raise exc

    def start(self):
        self._last = time.monotonic()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def heartbeat(self):
        self._reraise()
        self._last = time.monotonic()

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=1)
        self._reraise()

    def _run(self):
        while not self._stop.wait(min(self.timeout_s / 4, 1.0)):
            if time.monotonic() - self._last > self.timeout_s:
                self.fired = True
                try:
                    self.on_timeout()
                finally:
                    return


class StragglerMonitor:
    def __init__(self, window: int = 50, threshold: float = 2.0):
        self.times = collections.deque(maxlen=window)
        self.threshold = threshold
        self.events: List[dict] = []

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if len(self.times) >= 8:
            med = statistics.median(self.times)
            if seconds > self.threshold * med:
                is_straggler = True
                self.events.append({
                    "step": step, "seconds": seconds, "median": med,
                    "action": "flag-host-for-reschedule",
                })
        self.times.append(seconds)
        return is_straggler

    @property
    def median(self) -> Optional[float]:
        return statistics.median(self.times) if self.times else None


class InjectedFault(RuntimeError):
    """An injector-raised fault. Subclasses ``RuntimeError`` deliberately:
    an injected page-allocation failure rides the engine's real
    pool-exhaustion handling paths (requeue / victim retirement), and an
    injected step crash is caught by the tick-replay guard — the chaos
    machinery exercises the production error paths, not parallel ones."""


@dataclasses.dataclass
class FaultInjector:
    """Deterministic chaos plan for ``ElasticEngine`` (pass as
    ``ElasticEngine(fault_injector=...)``).

    All primitives are keyed by the engine's per-``generate`` *scheduler
    tick* index (0-based loop iterations — not decode ticks) except
    ``fail_allocs``, which is keyed by the 0-based index of the
    ``_alloc_pages`` call since engine construction. Primitives fire
    **once** per key and are recorded in ``events`` — except a logit
    poison restricted by ``poison_fmt``, which re-fires on every replay
    attempt still running a listed format (that is the "bad rung" model:
    the fault follows the format, so escalation — not replay — clears it).

    Primitives (tentpole (c) of the fault-isolation layer):
      - ``poison_logits``: {tick: row} — overwrite one row's (or with
        row=None every row's) tick logits with NaN after the step runs.
      - ``poison_fmt``: restrict logit poison to these serving formats.
      - ``poison_pool``: {tick: physical page id} — the engine fills that
        page of every layer's K/V pool with NaN *before* the tick
        (persistent corruption: replay cannot clear it).
      - ``fail_allocs``: allocation-call indices that raise
        ``InjectedFault`` out of the page allocator.
      - ``raise_in_step``: ticks whose step executable raises
        ``InjectedFault`` before dispatch (transient crash; the replayed
        attempt runs clean).
      - ``preempt_at``: tick at which to ``trigger()`` the guard passed to
        ``generate`` — mid-tick, so the engine acts on it at the next tick
        boundary.
      - ``cancel_at``: {tick: rid} — request cancellation mid-flight.
    """
    poison_logits: Dict[int, Optional[int]] = \
        dataclasses.field(default_factory=dict)
    poison_fmt: Union[str, Tuple[str, ...], FrozenSet[str], None] = None
    fail_allocs: Tuple[int, ...] = ()
    raise_in_step: Tuple[int, ...] = ()
    preempt_at: Optional[int] = None
    poison_pool: Dict[int, int] = dataclasses.field(default_factory=dict)
    cancel_at: Dict[int, int] = dataclasses.field(default_factory=dict)
    events: List[dict] = dataclasses.field(default_factory=list, init=False)
    _fired: set = dataclasses.field(default_factory=set, init=False)

    def _fmts(self) -> Optional[FrozenSet[str]]:
        if self.poison_fmt is None:
            return None
        if isinstance(self.poison_fmt, str):
            return frozenset((self.poison_fmt,))
        return frozenset(self.poison_fmt)

    def _record(self, kind: str, **kw) -> None:
        self.events.append({"kind": kind, **kw})

    # ---- engine hooks ------------------------------------------------------
    def on_alloc(self, call_index: int) -> None:
        """Raises for allocation-call indices listed in ``fail_allocs``."""
        if call_index in self.fail_allocs \
                and ("alloc", call_index) not in self._fired:
            self._fired.add(("alloc", call_index))
            self._record("fail_alloc", call=call_index)
            raise InjectedFault(
                f"injected page-allocation failure (call {call_index})")

    def maybe_raise_step(self, tick: int) -> None:
        """Raises once per tick listed in ``raise_in_step`` — the replay
        attempt of the same tick runs clean (transient crash model)."""
        if tick in self.raise_in_step and ("step", tick) not in self._fired:
            self._fired.add(("step", tick))
            self._record("raise_in_step", tick=tick)
            raise InjectedFault(f"injected step-fn crash at tick {tick}")

    def maybe_poison_logits(self, tick: int, fmt: str, logits):
        """Returns (possibly poisoned) logits for this tick's attempt."""
        if tick not in self.poison_logits:
            return logits
        fmts = self._fmts()
        if fmts is not None:
            if fmt not in fmts:
                return logits       # escalated past the bad rung(s): clean
        elif ("logits", tick) in self._fired:
            return logits           # transient: fires once, replay is clean
        self._fired.add(("logits", tick))
        row = self.poison_logits[tick]
        self._record("poison_logits", tick=tick, row=row, fmt=fmt)
        import jax.numpy as jnp     # deferred: keep module import cheap
        nan = jnp.float32(jnp.nan)
        if row is None:
            return jnp.full_like(logits, nan)
        return logits.at[row].set(nan)

    def pool_poison_page(self, tick: int) -> Optional[int]:
        """Physical page id to NaN-fill before this tick (None = no-op)."""
        if tick in self.poison_pool and ("pool", tick) not in self._fired:
            self._fired.add(("pool", tick))
            page = self.poison_pool[tick]
            self._record("poison_pool", tick=tick, page=page)
            return page
        return None

    def maybe_preempt(self, tick: int, guard) -> None:
        if self.preempt_at == tick and guard is not None \
                and ("preempt", tick) not in self._fired:
            self._fired.add(("preempt", tick))
            self._record("preempt", tick=tick)
            guard.trigger()

    def cancel_rid(self, tick: int) -> Optional[int]:
        if tick in self.cancel_at and ("cancel", tick) not in self._fired:
            self._fired.add(("cancel", tick))
            rid = self.cancel_at[tick]
            self._record("cancel", tick=tick, rid=rid)
            return rid
        return None


def random_plan(seed: int, rate: float, horizon: int, slots: int,
                kinds: Tuple[str, ...] = ("poison_row", "raise_step",
                                          "fail_alloc")) -> FaultInjector:
    """Derive a reproducible FaultInjector from (seed, rate): each tick in
    ``[0, horizon)`` independently draws a fault with probability ``rate``
    and a kind/target uniformly from ``kinds``/``slots``. Used by
    ``serve_engine_bench.py --chaos``; the same (seed, rate, horizon,
    slots) always yields the same plan, so a chaos regression replays
    exactly."""
    import numpy as np
    rng = np.random.default_rng(seed)
    poison: Dict[int, Optional[int]] = {}
    raises: List[int] = []
    allocs: List[int] = []
    for t in range(horizon):
        if rng.random() >= rate:
            continue
        kind = kinds[int(rng.integers(len(kinds)))]
        if kind == "poison_row":
            poison[t] = int(rng.integers(slots))
        elif kind == "poison_all":
            poison[t] = None
        elif kind == "raise_step":
            raises.append(t)
        elif kind == "fail_alloc":
            # alloc-call indices roughly track ticks early in a run; the
            # exact mapping does not matter for a rate sweep, only that the
            # plan is deterministic.
            allocs.append(t)
        else:
            raise ValueError(f"unknown chaos kind {kind!r}")
    return FaultInjector(poison_logits=poison,
                         raise_in_step=tuple(raises),
                         fail_allocs=tuple(allocs))
