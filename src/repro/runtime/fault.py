"""Fault tolerance: preemption handling, watchdog heartbeat, stragglers.

What runs where on a real pod fleet:
  - PreemptionGuard: SIGTERM/SIGINT -> set a flag; the train loop checks it
    every step and checkpoints-then-exits cleanly (maps to Borg/GCE
    preemption notices). Re-entry resumes from LATEST.
  - Watchdog: a step-duration heartbeat; if a step exceeds `timeout_s`
    (hung collective / dead host), the registered callback fires — in
    production that aborts the job so the scheduler restarts it from the
    last checkpoint; here it raises.
  - StragglerMonitor: rolling per-step stats; steps slower than
    `threshold x median` are flagged. On TPU pods persistent stragglers are
    handled by re-scheduling the slow host; the monitor exposes the signal
    and suggested action, and records events for the run report.
"""
from __future__ import annotations

import collections
import signal
import statistics
import threading
import time
from typing import Callable, List, Optional


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._flag = threading.Event()
        self._prev = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:   # non-main thread (tests)
                pass
        return self

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False

    def _handler(self, signum, frame):
        self._flag.set()

    @property
    def preempted(self) -> bool:
        return self._flag.is_set()

    def trigger(self):      # for tests / manual drain
        self._flag.set()


class Watchdog:
    """Fires `on_timeout` if heartbeat() isn't called within timeout_s."""

    def __init__(self, timeout_s: float,
                 on_timeout: Optional[Callable[[], None]] = None):
        self.timeout_s = timeout_s
        self.on_timeout = on_timeout or self._default
        self._last = time.monotonic()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.fired = False

    @staticmethod
    def _default():
        raise TimeoutError("watchdog: training step exceeded timeout")

    def start(self):
        self._last = time.monotonic()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def heartbeat(self):
        self._last = time.monotonic()

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=1)

    def _run(self):
        while not self._stop.wait(min(self.timeout_s / 4, 1.0)):
            if time.monotonic() - self._last > self.timeout_s:
                self.fired = True
                try:
                    self.on_timeout()
                finally:
                    return


class StragglerMonitor:
    def __init__(self, window: int = 50, threshold: float = 2.0):
        self.times = collections.deque(maxlen=window)
        self.threshold = threshold
        self.events: List[dict] = []

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if len(self.times) >= 8:
            med = statistics.median(self.times)
            if seconds > self.threshold * med:
                is_straggler = True
                self.events.append({
                    "step": step, "seconds": seconds, "median": med,
                    "action": "flag-host-for-reschedule",
                })
        self.times.append(seconds)
        return is_straggler

    @property
    def median(self) -> Optional[float]:
        return statistics.median(self.times) if self.times else None
