"""Tensor-parallel elastic serving: a (1, 2) CPU mesh must be BIT-IDENTICAL
to the single-device engine.

The root conftest pins 2 CPU host devices (``XLA_FLAGS``) before jax loads,
so every test here runs on a real two-device platform. The house invariant
extends over the mesh axis: for any serving configuration, the token
streams of ``ElasticEngine(mesh=(1,2))`` equal the single-device engine's
exactly — greedy and seeded sampling both — because the sharded math is
arithmetically identical (per-kv-head attention is exactly parallel; the
only reductions that reorder are the two psums per layer, whose operands
are the same partial sums the single-device dot products produce).

Fast tier: {densify} x {dense, paged} x {mxint8, mxint4} x {greedy,
seeded}. The @slow matrix adds fused Pallas (interpret), the gather-free
paged kernel, the mixed scheduler, speculative decoding, and bf16.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import make_anchor
from repro.core.qat import QATConfig
from repro.launch.mesh import make_debug_mesh
from repro.models import get_model
from repro.serve.engine import ElasticEngine, Request

QAT = QATConfig(formats=("mxint4", "mxint8"), anchor="mxint8", block_size=32)

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs 2 host devices (root conftest pins them)")


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("smollm-135m")
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    anchor = make_anchor(params, QAT)
    return cfg, api, params, anchor


def _reqs(cfg, n=3, plen=8, max_new=6):
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab, plen).astype(np.int32),
                    max_new=max_new) for i in range(n)]


def _streams(setup, mesh, fmt, greedy, **kw):
    cfg, api, params, anchor = setup
    eng = ElasticEngine(api, anchor, batch_slots=2, max_len=48,
                        param_template=params, seed=0, mesh=mesh,
                        temperature=0.9, top_p=0.95, **kw)
    out = eng.generate(_reqs(cfg), greedy=greedy, fmt_override=fmt)
    return [r.out_tokens for r in out], eng


def _assert_identical(setup, fmt, greedy, **kw):
    single, _ = _streams(setup, None, fmt, greedy, **kw)
    meshed, eng = _streams(setup, make_debug_mesh(1, 2), fmt, greedy, **kw)
    assert single == meshed, (fmt, greedy, kw, single, meshed)
    assert all(len(t) > 0 for t in single)
    return eng


# ---- fast tier: densify contract, both layouts, both sampling modes -------
@pytest.mark.parametrize("fmt", ["mxint8", "mxint4"])
@pytest.mark.parametrize("greedy", [True, False])
def test_mesh_bit_identity_dense(setup, fmt, greedy):
    _assert_identical(setup, fmt, greedy, fused=False)


@pytest.mark.parametrize("greedy", [True, False])
def test_mesh_bit_identity_paged(setup, greedy):
    eng = _assert_identical(setup, "mxint8", greedy, fused=False,
                            kv_layout="paged", kv_page_size=8)
    # sharded pools change nothing about the host-side page bookkeeping:
    # every page allocated over the wave came back
    st = eng.stats
    assert st["kv_pages_alloc"] > 0
    assert st["kv_pages_alloc"] == st["kv_pages_freed"]
    assert st["mesh"] == "1x2"


# ---- slow tier: the full contract matrix ----------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("fmt", ["mxint8", "mxint4", "bf16"])
@pytest.mark.parametrize("greedy", [True, False])
@pytest.mark.parametrize("kw", [
    dict(fused=False, prefill_chunk=8, scheduler="mixed"),
    dict(fused=False, kv_layout="paged", kv_page_size=8, prefill_chunk=8,
         scheduler="mixed"),
], ids=["mixed-dense", "mixed-paged"])
def test_mesh_bit_identity_mixed(setup, fmt, greedy, kw):
    _assert_identical(setup, fmt, greedy, **kw)


@pytest.mark.slow
def test_mesh_bit_identity_fused(setup):
    """Fused Pallas dequant-GEMM (interpret mode on CPU) inside shard_map:
    the kernels see shard-local shapes (the tile-cache satellite) and the
    streams still match the single-device fused engine."""
    _assert_identical(setup, "mxint8", True, fused=True)


@pytest.mark.slow
def test_mesh_bit_identity_paged_kernel(setup):
    _assert_identical(setup, "mxint8", True, fused=False,
                      kv_layout="paged", kv_page_size=8,
                      attn_impl="paged_kernel")


@pytest.mark.slow
def test_mesh_bit_identity_speculative(setup):
    from repro.serve.policy import SpecConfig
    _assert_identical(setup, "mxint8", True, fused=False,
                      speculative=SpecConfig(draft_fmt="mxint4", k=2))


# ---- split-N repack (the nibble-interleave bug) ----------------------------
def test_repack_splitn_local_shards_decode_contiguous_columns(setup):
    """Split-N byte column j packs output columns (j, j + N/2) — a global
    interleave. Without the per-shard repack, a column-sharded mxint4 leaf
    decodes to a PERMUTED column set on each chip while wo/w_down shard
    their contraction rows contiguously, silently mispairing half the
    head / ff-block contributions (logits were off by ~0.2, not ulps).
    Every local shard must densify to exactly its contiguous submatrix."""
    from repro.serve.packed_params import PackedInt4Leaf, densify_leaf
    cfg, api, params, anchor = setup
    eng = ElasticEngine(api, anchor, batch_slots=2, max_len=48,
                        param_template=params, fused=False,
                        mesh=make_debug_mesh(1, 2))
    ref = ElasticEngine(api, anchor, batch_slots=2, max_len=48,
                        param_template=params, fused=False)
    w = eng.weights_for("mxint4")
    wr = ref.weights_for("mxint4")
    for name, axis in (("wq", 1), ("wo", 0)):   # column- and row-parallel
        leaf, rleaf = (t["blocks"][0]["attn"][name] for t in (w, wr))
        want = np.asarray(densify_leaf(rleaf, 32, jnp.float32,
                                       serving_axis=True))[0]
        got = np.concatenate(
            [np.asarray(densify_leaf(
                PackedInt4Leaf(
                    packed=jnp.asarray(ps.data)[0],
                    scale_exp=jnp.asarray(
                        leaf.scale_exp.addressable_shards[s].data)[0],
                    shape=leaf.shape, block_axis=leaf.block_axis,
                    fmt_name=leaf.fmt_name, layout=leaf.layout),
                32, jnp.float32, serving_axis=True))
             for s, ps in enumerate(leaf.packed.addressable_shards)],
            axis=axis)
        assert np.array_equal(want, got), name


# ---- per-chip accounting ---------------------------------------------------
def test_mesh_weight_bytes_per_chip_halved(setup):
    """Each chip streams ~1/2 of the packed tree at tp=2 (replicated norm
    vectors keep it just above exactly half)."""
    _, eng = _streams(setup, make_debug_mesh(1, 2), "mxint8", True,
                      fused=False)
    st = eng.stats
    ratio = st["weight_bytes_per_chip"]["mxint8"] / \
        st["weight_bytes"]["mxint8"]
    assert 0.5 <= ratio < 0.56, ratio


# ---- snapshot/resume mesh fingerprint --------------------------------------
def test_snapshot_on_mesh_refuses_single_device_resume(setup, tmp_path):
    """A snapshot taken on a mesh holds sharded-layout state; resuming on a
    single-device engine must fail loudly, naming the mesh field."""
    cfg, api, params, anchor = setup
    from repro.runtime.fault import FaultInjector, PreemptionGuard
    meshed = ElasticEngine(api, anchor, batch_slots=2, max_len=48,
                           param_template=params, seed=0, fused=False,
                           mesh=make_debug_mesh(1, 2),
                           fault_injector=FaultInjector(preempt_at=2))
    meshed.generate(_reqs(cfg, max_new=8), greedy=True,
                    fmt_override="mxint8", guard=PreemptionGuard(),
                    snapshot_dir=str(tmp_path))
    assert meshed.last_snapshot is not None
    single = ElasticEngine(api, anchor, batch_slots=2, max_len=48,
                           param_template=params, seed=0, fused=False)
    with pytest.raises(ValueError, match="mesh"):
        single.resume(str(tmp_path))


def test_snapshot_resume_on_same_mesh(setup, tmp_path):
    """Same mesh shape on both sides: the resumed wave finishes with the
    exact streams of the uninterrupted meshed run."""
    cfg, api, params, anchor = setup
    from repro.runtime.fault import FaultInjector, PreemptionGuard

    def eng(**kw):
        return ElasticEngine(api, anchor, batch_slots=2, max_len=48,
                             param_template=params, seed=0, fused=False,
                             mesh=make_debug_mesh(1, 2), **kw)
    full = eng().generate(_reqs(cfg, max_new=8), greedy=True,
                          fmt_override="mxint8")
    want = [r.out_tokens for r in full]
    e1 = eng(fault_injector=FaultInjector(preempt_at=2))
    e1.generate(_reqs(cfg, max_new=8), greedy=True, fmt_override="mxint8",
                guard=PreemptionGuard(), snapshot_dir=str(tmp_path))
    assert e1.last_snapshot is not None
    out = eng().resume(str(tmp_path))
    assert [r.out_tokens for r in out] == want


# ---- construction guards ---------------------------------------------------
def test_mesh_guard_messages(setup):
    cfg, api, params, anchor = setup
    import dataclasses as dc
    from jax.sharding import Mesh

    def build(mesh, api=api):
        return ElasticEngine(api, anchor, batch_slots=2, max_len=48,
                             param_template=params, mesh=mesh)

    with pytest.raises(ValueError, match="'model'"):
        build(Mesh(np.array(jax.devices()[:2]).reshape(1, 2),
                   ("data", "x")))
    with pytest.raises(ValueError, match="replicas"):
        # data axis > 1: DP belongs to ReplicaSet, not the engine
        build(Mesh(np.array(jax.devices()[:2]).reshape(2, 1),
                   ("data", "model")))
    # indivisible dims must be rejected up front, not at trace time
    bad_api = get_model(dc.replace(cfg, vocab=cfg.vocab - 1), None)
    with pytest.raises(ValueError, match="divisible"):
        ElasticEngine(bad_api, anchor, batch_slots=2, max_len=48,
                      mesh=make_debug_mesh(1, 2))


# ---- data-parallel replicas -------------------------------------------------
def test_replica_set_partitions_and_matches(setup):
    """Two single-device replicas: every request's stream equals the one a
    lone engine produces for it (the partition decides WHERE, never WHAT)."""
    from repro.serve.replicas import ReplicaSet
    cfg, api, params, anchor = setup
    kw = dict(batch_slots=2, max_len=48, param_template=params, seed=0,
              fused=False)
    lone = ElasticEngine(api, anchor, **kw)
    want = {r.rid: r.out_tokens
            for r in lone.generate(_reqs(cfg, n=4), greedy=True,
                                   fmt_override="mxint8")}
    rs = ReplicaSet(api, anchor, n_replicas=2, **kw)
    got = rs.generate(_reqs(cfg, n=4), greedy=True, fmt_override="mxint8")
    assert {r.rid: r.out_tokens for r in got} == want
    assert rs.stats["tokens_out"] == lone.stats["tokens_out"]
    assert [rs.home(r.rid) for r in got] == [0, 1, 0, 1]


def test_replica_meshes_disjoint():
    from repro.serve.replicas import replica_meshes
    meshes = replica_meshes(2, 1)
    devs = [d for m in meshes for d in m.devices.flat]
    assert len(set(devs)) == 2
    with pytest.raises(ValueError, match="device"):
        replica_meshes(2, 2)   # 4 needed, 2 present
