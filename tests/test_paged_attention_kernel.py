"""Gather-free paged-attention decode kernel: an adversarial paged-KV net.

Four lines of defense around kernels/paged_attention.py:

1. **Parity** — the interpret-mode kernel vs the gather + masked-softmax
   reference (`decode_attention(paged_gather(...))`), swept over batch,
   GQA ratio, page size, ragged cache_len (zero, page-boundary, max) and
   sliding window.
2. **Adversarial poison** — every non-allocated page, the scratch page 0,
   and the garbage tail beyond each slot's write frontier are filled with
   NaN / ±1e9 and the output must be BIT-identical to the zero-filled run.
   Zero-filled garbage (all prior tests) is too kind: a masking bug that
   multiplies a dead position by 0 survives it; NaN does not (0*NaN=NaN).
   The same poison corrupting the *gather* reference proves the case has
   teeth — gather's safety depends on zeroed pools, the kernel's does not.
3. **Block-table round-trip property** — random disjoint page assignments
   written through the real write path (`paged_prefill_update` +
   `paged_decode_append`) must read back through the kernel identically to
   the dense cache layout (hypothesis when installed, seeded sweep always).
4. **Engine token identity** — `attn_impl="paged_kernel"` vs `"gather"`
   streams must match token for token (greedy + seeded sampling, mxint8 +
   bf16, fused + densify contracts). Heavyweight matrix cases are
   `@pytest.mark.slow` per pytest.ini; one acceptance pair stays tier-1.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:
    from _hypothesis_stub import hypothesis, st

from repro.configs import get_reduced
from repro.core import make_anchor
from repro.core.qat import QATConfig
from repro.kernels import paged_attention as pa
from repro.models import get_model
from repro.models.layers import (decode_attention, paged_decode_append,
                                 paged_gather, paged_prefill_update)
from repro.serve.engine import ElasticEngine, Request

QAT = QATConfig(formats=("mxint4", "mxint8"), anchor="mxint8", block_size=32)


# =============================================================================
# Fixtures: random pools with disjoint per-slot page assignments
# =============================================================================
def _pool_case(seed, b, mp, ps, hkv, g, d=16):
    """Random q/pools + a random DISJOINT block table (pages shuffled, page 0
    reserved scratch) — the layout invariant the engine maintains."""
    rng = np.random.default_rng(seed)
    h = hkv * g
    n_pages = b * mp + 1
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pages, ps, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, ps, hkv, d)), jnp.float32)
    perm = rng.permutation(np.arange(1, n_pages))
    bt = np.zeros((b, mp), np.int32)
    return q, kp, vp, bt, perm


def _map_pages(bt, perm, lens, mp, ps):
    """Map each row's live pages (covering ``lens[i]`` tokens) from ``perm``;
    unmapped entries stay 0 (scratch), exactly like the engine free-list."""
    for i, n in enumerate(lens):
        k = -(-int(n) // ps)
        bt[i, :k] = perm[i * mp:i * mp + k]
    return jnp.asarray(bt)


def _gather_ref(q, kp, vp, bt, cl, window=None):
    return decode_attention(q, paged_gather(kp, bt), paged_gather(vp, bt),
                            cl, window=window)


def _kernel(q, kp, vp, bt, cl, window=None):
    return pa.paged_decode_attention(q, kp, vp, bt, cl, window=window,
                                     mode="pallas")


# =============================================================================
# 1. Parity sweep (kernel in interpret mode vs gather reference)
# =============================================================================
@pytest.mark.parametrize("b", [1, 3])
@pytest.mark.parametrize("g", [1, 4])
@pytest.mark.parametrize("ps", [8, 16])
@pytest.mark.parametrize("window", [None, 10])
def test_kernel_matches_gather_reference(b, g, ps, window):
    """Ragged cache_len per row: 1 (minimum), a page boundary, and the full
    table (max) — every page-count the block-table walk can see."""
    mp = 4
    q, kp, vp, bt, perm = _pool_case(0, b, mp, ps, hkv=2, g=g)
    lens = [1, 2 * ps, mp * ps][:b]
    cl = jnp.asarray(lens, jnp.int32)
    bt = _map_pages(bt, perm, lens, mp, ps)
    got = _kernel(q, kp, vp, bt, cl, window=window)
    want = _gather_ref(q, kp, vp, bt, cl, window=window)
    assert got.shape == want.shape == q.shape
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-5)


def test_cache_len_zero_yields_zeros_not_nan():
    """No valid key exists at cache_len=0: the dense-math reference NaNs
    (softmax over an empty set); the kernel defines the row as exact zeros.
    The engine never emits the case (decode appends before attending), but
    the kernel must not poison a batch that contains such a row."""
    q, kp, vp, bt, perm = _pool_case(1, 3, 4, 8, hkv=2, g=2)
    lens = [0, 9, 32]
    cl = jnp.asarray(lens, jnp.int32)
    bt = _map_pages(bt, perm, lens, 4, 8)
    got = _kernel(q, kp, vp, bt, cl)
    assert bool(jnp.all(got[0] == 0))
    assert bool(jnp.all(jnp.isfinite(got)))
    want = _gather_ref(q, kp, vp, bt, cl)
    np.testing.assert_allclose(np.asarray(got[1:], np.float32),
                               np.asarray(want[1:], np.float32),
                               rtol=1e-5, atol=1e-5)


def test_kernel_under_jit_with_traced_cache_len():
    """serve_step jits the kernel with cache_len traced — the scalar-prefetch
    operands must accept tracers, and retracing must not be length-dependent."""
    q, kp, vp, bt, perm = _pool_case(2, 2, 4, 8, hkv=2, g=2)
    bt = _map_pages(bt, perm, [5, 17], 4, 8)
    f = jax.jit(lambda cl: _kernel(q, kp, vp, bt, cl))
    for lens in ([5, 17], [8, 32], [1, 9]):
        cl = jnp.asarray(lens, jnp.int32)
        np.testing.assert_allclose(
            np.asarray(f(cl), np.float32),
            np.asarray(_gather_ref(q, kp, vp, bt, cl), np.float32),
            rtol=1e-5, atol=1e-5)


# =============================================================================
# 2. Adversarial poison: garbage must never enter the reduction
# =============================================================================
def _poison(kp, vp, bt, lens, ps):
    """NaN/±1e9 in every byte the kernel must not read: unallocated pages,
    scratch page 0, and the tail beyond each row's frontier inside its own
    last live page. K always gets NaN (tests the score mask before the
    running max); V alternates NaN / ±1e9 per page (NaN tests the PV-product
    mask — a zeroed probability is NOT enough, 0*NaN=NaN — and ±1e9 tests
    that 'approximately masked' would still be loud)."""
    kp_p, vp_p = np.array(kp), np.array(vp)
    used = set(np.asarray(bt).flatten().tolist()) - {0}
    for pg in range(kp_p.shape[0]):
        if pg not in used:
            kp_p[pg] = np.nan
            vp_p[pg] = np.nan if pg % 2 == 0 else 1e9
    for i, n in enumerate(lens):
        n = int(n)
        pg, off = n // ps, n % ps
        row = np.asarray(bt)[i]
        if off and pg < row.size and row[pg] != 0:
            kp_p[row[pg], off:] = np.nan
            vp_p[row[pg], off:] = np.nan if i % 2 == 0 else -1e9
    return jnp.asarray(kp_p), jnp.asarray(vp_p)


@pytest.mark.parametrize("window", [None, 10])
def test_kernel_ignores_nan_poisoned_dead_pages(window):
    q, kp, vp, bt, perm = _pool_case(3, 3, 4, 8, hkv=2, g=2)
    lens = [1, 9, 24]
    cl = jnp.asarray(lens, jnp.int32)
    bt = _map_pages(bt, perm, lens, 4, 8)
    clean = _kernel(q, kp, vp, bt, cl, window=window)
    kp_p, vp_p = _poison(kp, vp, bt, lens, 8)
    dirty = _kernel(q, kp_p, vp_p, bt, cl, window=window)
    # BIT-identical, not allclose: the poisoned values must contribute
    # exactly nothing, not approximately nothing.
    assert np.array_equal(np.asarray(clean), np.asarray(dirty))
    assert bool(jnp.all(jnp.isfinite(dirty)))


def test_poison_corrupts_the_gather_reference():
    """The adversarial case must have teeth: the same poison NaNs the gather
    path (0 * NaN = NaN in its masked PV product), which is why gather
    depends on the engine's zero-filled-pool invariant and the kernel's
    in-kernel masking is the stronger contract."""
    q, kp, vp, bt, perm = _pool_case(4, 2, 4, 8, hkv=2, g=2)
    lens = [9, 24]
    cl = jnp.asarray(lens, jnp.int32)
    bt = _map_pages(bt, perm, lens, 4, 8)
    kp_p, vp_p = _poison(kp, vp, bt, lens, 8)
    ref = _gather_ref(q, kp_p, vp_p, bt, cl)
    assert not bool(jnp.all(jnp.isfinite(ref)))


def test_serve_step_logits_survive_poisoned_pool():
    """Model-level: a full paged serve_step (scan over layers, per-layer
    pools) with attn_impl='paged_kernel' must produce identical logits with
    every non-allocated page and scratch page 0 poisoned."""
    cfg = get_reduced("smollm-135m")
    api = get_model(cfg, None).with_serving(attn_impl="paged_kernel")
    params = api.init_params(jax.random.PRNGKey(0))
    cache = api.init_cache(2, 32, kv_layout="paged", page_size=8)
    bt = np.zeros((2, 4), np.int32)
    bt[0, :2] = [1, 2]
    bt[1, :2] = [5, 6]
    cache["block_table"] = jnp.asarray(bt)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 9)), jnp.int32)
    _, cache, _ = jax.jit(api.prefill_slot)(
        params, {"tokens": toks}, cache, 0)
    _, cache, _ = jax.jit(api.prefill_slot)(
        params, {"tokens": toks[:, :5]}, cache, 1)
    step = jax.jit(api.serve_step)
    batch = {"tokens": jnp.asarray([[3], [4]], jnp.int32)}
    cache_len = jnp.asarray([9, 5], jnp.int32)
    logits, _ = step(params, batch, cache, cache_len)

    used = {1, 2, 5, 6}
    poisoned = dict(cache)
    poisoned["blocks"] = []
    for blk in cache["blocks"]:
        mask = np.asarray([pg not in used
                           for pg in range(blk["k_pages"].shape[1])])
        sel = jnp.asarray(mask)[None, :, None, None, None]
        poisoned["blocks"].append({
            "k_pages": jnp.where(sel, jnp.asarray(
                jnp.nan, blk["k_pages"].dtype), blk["k_pages"]),
            "v_pages": jnp.where(sel, jnp.asarray(
                jnp.nan, blk["v_pages"].dtype), blk["v_pages"])})
    logits_p, _ = step(params, batch, poisoned, cache_len)
    assert np.array_equal(np.asarray(logits), np.asarray(logits_p))
    assert bool(jnp.all(jnp.isfinite(logits_p)))


# =============================================================================
# 3. Block-table translation round-trip (real write path, property-style)
# =============================================================================
def _check_roundtrip(seed, ps, lens):
    """Writes through paged_prefill_update + paged_decode_append, reads
    through the kernel, and must match the dense cache layout exactly."""
    rng = np.random.default_rng(seed)
    b = len(lens)
    hkv, g, d = 2, 2, 16
    h = hkv * g
    mp = max(-(-(int(n) + 1) // ps) for n in lens)
    s_max = mp * ps
    n_pages = b * mp + 1
    perm = rng.permutation(np.arange(1, n_pages))
    bt = np.zeros((b, mp), np.int32)
    bt = _map_pages(bt, perm, [int(n) + 1 for n in lens], mp, ps)

    k_new = jnp.asarray(rng.normal(size=(b, s_max, hkv, d)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(b, s_max, hkv, d)), jnp.float32)
    k_tok = jnp.asarray(rng.normal(size=(b, 1, hkv, d)), jnp.float32)
    v_tok = jnp.asarray(rng.normal(size=(b, 1, hkv, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    cl = jnp.asarray(lens, jnp.int32)

    # paged: prefill-scatter the (padded) prompt, then append one token at
    # each row's cache_len — exactly what a decode tick does.
    kp = paged_prefill_update(jnp.zeros((n_pages, ps, hkv, d)), k_new, bt)
    vp = paged_prefill_update(jnp.zeros((n_pages, ps, hkv, d)), v_new, bt)
    kp = paged_decode_append(kp, k_tok, bt, cl)
    vp = paged_decode_append(vp, v_tok, bt, cl)

    # dense: same values, contiguous per-slot buffers.
    upd = jax.vmap(lambda c, t, n: jax.lax.dynamic_update_slice_in_dim(
        c, t, n, axis=0))
    kd = upd(k_new, k_tok, cl)
    vd = upd(v_new, v_tok, cl)

    got = _kernel(q, kp, vp, bt, cl + 1)
    want = decode_attention(q, kd, vd, cl + 1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-5)


@hypothesis.given(seed=st.integers(0, 2**31 - 1),
                  ps=st.sampled_from([8, 16]),
                  lens=st.lists(st.integers(0, 40), min_size=1, max_size=4))
@hypothesis.settings(deadline=None, max_examples=25)
def test_block_table_roundtrip_property(seed, ps, lens):
    _check_roundtrip(seed, ps, lens)


@pytest.mark.parametrize("seed,ps,lens", [
    (0, 8, [0, 7, 8]),        # empty row, sub-page, exact page
    (1, 8, [15, 16, 17]),     # page-boundary straddle
    (2, 16, [5, 31, 40]),     # bigger pages, multi-page rows
    (3, 8, [39]),             # single slot near table max
])
def test_block_table_roundtrip_seeded(seed, ps, lens):
    """Always-run slice of the property above (hypothesis skips when the
    stub is active — see tests/_hypothesis_stub.py)."""
    _check_roundtrip(seed, ps, lens)


# =============================================================================
# 4. Engine-level token identity + knob validation
# =============================================================================
def _setup(arch="smollm-135m"):
    cfg = get_reduced(arch)
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    anchor = make_anchor(params, QAT)
    return cfg, api, params, anchor


def _engine(api, anchor, params, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("kv_layout", "paged")
    kw.setdefault("kv_page_size", 8)
    return ElasticEngine(api, anchor, param_template=params, **kw)


def _reqs(cfg, n, max_new=5, plen=8, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab, plen)
                    .astype(np.int32), max_new=max_new) for i in range(n)]


@pytest.mark.parametrize("fused", [True, False])
def test_engine_identity_kernel_vs_gather(fused):
    """Acceptance gate (fast slice): greedy mxint8 streams identical across
    attn impls under both packed-serving contracts, with the path counters
    proving which attention implementation actually traced."""
    cfg, api, params, anchor = _setup()
    streams, reads = {}, {}
    for impl in ("gather", "paged_kernel"):
        pa.reset_stats()
        eng = _engine(api, anchor, params, fused=fused, attn_impl=impl)
        reqs = _reqs(cfg, 3, max_new=5, seed=7)
        eng.generate(reqs, fmt_override="mxint8")
        st_ = pa.stats()
        if impl == "paged_kernel":
            assert st_["pallas"] >= 1 and st_["fallback"] == 0, st_
        else:
            assert st_["fallback"] >= 1 and st_["pallas"] == 0, st_
        streams[impl] = [r.out_tokens for r in reqs]
        reads[impl] = eng.stats["attn_tokens_read"]
    assert streams["gather"] == streams["paged_kernel"]
    # the kernel's accounted reads cover live pages only — strictly fewer
    # tokens than gather's full-logical-view reads on this workload
    assert 0 < reads["paged_kernel"] < reads["gather"]


@pytest.mark.slow
@pytest.mark.parametrize("fmt", ["mxint8", "bf16"])
@pytest.mark.parametrize("fused", [True, False])
def test_engine_identity_matrix_greedy(fmt, fused):
    cfg, api, params, anchor = _setup()
    streams = {}
    for impl in ("gather", "paged_kernel"):
        eng = _engine(api, anchor, params, fused=fused, attn_impl=impl)
        reqs = _reqs(cfg, 4, max_new=6, seed=11)
        eng.generate(reqs, fmt_override=fmt)
        streams[impl] = [r.out_tokens for r in reqs]
    assert streams["gather"] == streams["paged_kernel"]


@pytest.mark.slow
@pytest.mark.parametrize("fmt", ["mxint8", "bf16"])
def test_engine_identity_seeded_sampling(fmt):
    """Sampling depends only on logits + per-slot RNG streams; identical
    streams across attn impls means the kernel's logits are close enough
    that every categorical draw lands on the same token."""
    cfg, api, params, anchor = _setup()
    streams = {}
    for impl in ("gather", "paged_kernel"):
        eng = _engine(api, anchor, params, attn_impl=impl, seed=3,
                      temperature=1.0, top_p=0.9)
        reqs = _reqs(cfg, 3, max_new=5, seed=13)
        eng.generate(reqs, greedy=False, fmt_override=fmt)
        streams[impl] = [r.out_tokens for r in reqs]
    assert streams["gather"] == streams["paged_kernel"]


@pytest.mark.slow
def test_engine_identity_sliding_window():
    """A windowed arch forces the in-kernel window mask through the engine:
    streams must still match the gather path token for token."""
    cfg, api, params, anchor = _setup()
    wcfg = dataclasses.replace(cfg, sliding_window=8)
    wapi = get_model(wcfg, None)
    streams = {}
    for impl in ("gather", "paged_kernel"):
        eng = _engine(wapi, anchor, params, attn_impl=impl)
        reqs = _reqs(wcfg, 3, max_new=8, plen=12, seed=5)
        eng.generate(reqs, fmt_override="mxint8")
        streams[impl] = [r.out_tokens for r in reqs]
    assert streams["gather"] == streams["paged_kernel"]


def test_attn_impl_validation():
    cfg, api, params, anchor = _setup()
    with pytest.raises(ValueError, match="requires kv_layout='paged'"):
        ElasticEngine(api, anchor, batch_slots=2, max_len=32,
                      param_template=params, kv_layout="dense",
                      attn_impl="paged_kernel")
    with pytest.raises(ValueError, match="unknown attn_impl"):
        ElasticEngine(api, anchor, batch_slots=2, max_len=32,
                      param_template=params, kv_layout="paged",
                      attn_impl="flash")
    with pytest.raises(ValueError, match="unknown attn_impl"):
        api.with_serving(attn_impl="bogus")
    with pytest.raises(ValueError, match="unknown paged-attention mode"):
        pa.resolve_mode("gathered")
