"""Dry-run tooling: HLO collective parsing, input specs, mesh construction."""
import jax
import numpy as np
import pytest

# Lock the device count BEFORE importing repro.launch.dryrun anywhere in
# this module. The root conftest pins 2 CPU devices (mesh-serving tests);
# dryrun's import must respect a pre-set host-device-count flag and NOT
# bump it to its 512-device default.
jax.devices()


def test_parse_collective_bytes():
    from repro.launch.dryrun import parse_collective_bytes
    hlo = """
  %ag = f32[16,512]{1,0} all-gather(%x), replica_groups=...
  %ar = bf16[8,128]{1,0} all-reduce(%y), to_apply=%add
  %rs = f32[4,64]{1,0} reduce-scatter(%z), dimensions={0}
  %a2a = s8[32]{0} all-to-all(%w)
  %cp = f32[2,2]{1,0} collective-permute(%v)
  %ags = (f32[16,512]{1,0}, u32[]) all-gather-start(%x2)
  %not = f32[9,9]{1,0} add(%a, %b)
"""
    out = parse_collective_bytes(hlo)
    assert out["all-gather"] == 16 * 512 * 4 * 2      # ag + ag-start
    assert out["all-reduce"] == 8 * 128 * 2
    assert out["reduce-scatter"] == 4 * 64 * 4
    assert out["all-to-all"] == 32
    assert out["collective-permute"] == 16
    # all-reduce weighted 2x in the ring estimate
    assert out["total_weighted"] == (out["all-gather"]
                                     + 2 * out["all-reduce"]
                                     + out["reduce-scatter"]
                                     + out["all-to-all"]
                                     + out["collective-permute"])


def test_batch_specs_per_family():
    from repro.configs import SHAPES, get_config
    from repro.launch.dryrun import batch_specs
    for arch, extra in [("qwen3-4b", None),
                        ("llava-next-mistral-7b", "vision_embeds"),
                        ("seamless-m4t-large-v2", "frame_embeds")]:
        cfg = get_config(arch)
        b = batch_specs(cfg, SHAPES["train_4k"], "train")
        assert b["tokens"].shape == (256, 4096)
        if extra:
            assert extra in b
        d = batch_specs(cfg, SHAPES["decode_32k"], "decode")
        assert d["tokens"].shape == (128, 1)
        assert extra is None or extra not in d


def test_decode_cache_rule():
    from repro.configs import SHAPES, decode_cache_len, get_config
    assert decode_cache_len(get_config("mixtral-8x7b"),
                            SHAPES["long_500k"]) == 4096   # SWA-bounded
    assert decode_cache_len(get_config("rwkv6-7b"),
                            SHAPES["decode_32k"]) == 32768
    assert decode_cache_len(get_config("qwen2-72b"),
                            SHAPES["decode_32k"]) == 32768


def test_make_debug_mesh_single_device():
    from repro.launch.mesh import make_debug_mesh
    m = make_debug_mesh(1, 1)
    assert m.axis_names == ("data", "model")
    assert int(np.prod(m.devices.shape)) == 1


def test_production_mesh_requires_many_devices():
    """On this 2-device test process (conftest.py pins the count) the
    production mesh must refuse — proving the dry-run's 512-device env is
    NOT leaking into tests: importing repro.launch.dryrun must leave a
    pre-set host-device-count flag alone (the satellite regression for the
    old unconditional XLA_FLAGS overwrite)."""
    from repro.launch import dryrun  # noqa: F401 — import must not clobber
    from repro.launch.mesh import make_production_mesh
    assert len(jax.devices()) == 2
    with pytest.raises(Exception):
        make_production_mesh(multi_pod=False)


def test_merged_xla_flags_appends_and_skips():
    """The flag-merge rule itself: append to existing flags, never
    overwrite; skip (None) when a host device count is already pinned."""
    from repro.launch.dryrun import _merged_xla_flags
    # empty env: just the device-count flag
    assert _merged_xla_flags("", 512) == \
        "--xla_force_host_platform_device_count=512"
    # unrelated pre-set flags are preserved, not clobbered
    merged = _merged_xla_flags("--xla_cpu_foo=1", 512)
    assert merged.startswith("--xla_cpu_foo=1 ")
    assert merged.endswith("--xla_force_host_platform_device_count=512")
    # a pre-set device count wins: skip entirely
    assert _merged_xla_flags(
        "--xla_force_host_platform_device_count=2", 512) is None
    assert _merged_xla_flags(
        "--xla_cpu_foo=1 --xla_force_host_platform_device_count=2",
        512) is None
