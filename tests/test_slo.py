"""SLO classes and the measured serving cost model (serve/slo.py).

Unit coverage for ``SLOClass``/``CostModel`` plus the engine-level
behaviors the SLO machinery adds: arrival-gated admission, tiered
admission order, and snapshot round-trips of the new per-request fields.
The contract under test throughout: SLO machinery moves *requests* and
*formats*, never tokens — see test_serve_engine.py for the paired
bit-identity cases.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import make_anchor
from repro.core.qat import QATConfig
from repro.models import get_model
from repro.serve.engine import ElasticEngine, Request, RequestStatus
from repro.serve.policy import FormatPolicy
from repro.serve.slo import TIERS, CostModel, SLOClass, tier_rank

QAT = QATConfig(formats=("mxint4", "mxint8"), anchor="mxint8",
                block_size=32)


def _engine(slots=2, max_len=48, **kw):
    cfg = get_reduced("smollm-135m")
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    anchor = make_anchor(params, QAT)
    eng = ElasticEngine(api, anchor, batch_slots=slots, max_len=max_len,
                        param_template=params, **kw)
    return cfg, eng


def _req(cfg, rid, *, plen=6, max_new=3, **kw):
    rng = np.random.default_rng(100 + rid)
    return Request(rid=rid,
                   prompt=rng.integers(1, cfg.vocab, plen).astype(np.int32),
                   max_new=max_new, **kw)


# ---------------------------------------------------------------- SLOClass

def test_slo_class_validation_and_rank():
    assert SLOClass.latency().rank < SLOClass.throughput().rank \
        < SLOClass.best_effort().rank
    assert SLOClass().tier == "best_effort"
    with pytest.raises(ValueError):
        SLOClass(tier="platinum")
    with pytest.raises(ValueError):
        SLOClass(ttft_ms=0.0)
    with pytest.raises(ValueError):
        SLOClass(tpot_ms=-1.0)


def test_slo_class_dict_roundtrip():
    for slo in (SLOClass.latency(ttft_ms=120.0, tpot_ms=9.0),
                SLOClass.throughput(ttft_ms=500.0),
                SLOClass.best_effort()):
        assert SLOClass.from_dict(slo.to_dict()) == slo


def test_tier_rank_none_is_best_effort():
    assert tier_rank(None) == TIERS.index("best_effort")
    assert tier_rank(SLOClass.latency()) == 0
    assert tier_rank(SLOClass.latency()) < tier_rank(None)


# ---------------------------------------------------------------- CostModel

def test_cost_model_seed_and_raw_predict():
    cm = CostModel(hbm_bytes_per_s=1e9)
    assert not cm.has_estimate("mxint8")
    assert cm.raw_predict_s("mxint8", 4) is None
    cm.seed("mxint8", 2e6, 1e5)        # 2ms base + 0.1ms/row at 1 GB/s
    assert cm.has_estimate("mxint8")
    assert not cm.measured("mxint8")
    assert cm.raw_predict_s("mxint8", 0) == pytest.approx(2e-3)
    assert cm.raw_predict_s("mxint8", 4) == pytest.approx(2.4e-3)
    # Unmeasured + no prior: predicted == raw roofline (factor 1.0).
    assert cm.predict_ms("mxint8", 4) == pytest.approx(2.4)


def test_cost_model_observe_calibrates_factor():
    cm = CostModel(hbm_bytes_per_s=1e9, ema=0.5, min_ticks=2)
    cm.seed("mxint8", 1e6, 0.0)        # raw = 1ms regardless of rows
    cm.observe("mxint8", 1, 3e-3)      # first obs sets factor outright
    assert cm.terms["mxint8"].factor == pytest.approx(3.0)
    assert not cm.measured("mxint8")   # min_ticks=2 not reached yet
    cm.observe("mxint8", 1, 5e-3)      # EWMA: 0.5*3 + 0.5*5
    assert cm.terms["mxint8"].factor == pytest.approx(4.0)
    assert cm.measured("mxint8") and cm.any_measured()
    assert cm.predict_ms("mxint8", 1) == pytest.approx(4.0)


def test_cost_model_prior_factor_for_unmeasured_rung():
    """A rung with no observations borrows the median measured factor —
    calibrated vs raw-roofline predictions must never compete."""
    cm = CostModel(hbm_bytes_per_s=1e9, min_ticks=1)
    cm.seed("mxint8", 1e6, 0.0)
    cm.seed("mxint4", 5e5, 0.0)
    cm.observe("mxint8", 1, 10e-3)     # factor 10 on the measured rung
    assert cm.predict_ms("mxint8", 1) == pytest.approx(10.0)
    # mxint4 raw is 0.5ms; borrowed factor 10 -> 5ms, not 0.5ms.
    assert cm.predict_ms("mxint4", 1) == pytest.approx(5.0)


def test_cost_model_observe_refreshes_per_row_term():
    cm = CostModel(hbm_bytes_per_s=1e9, min_ticks=1)
    cm.seed("mxint8", 1e6, 1e5)
    cm.observe("mxint8", 2, 2e-3, attn_bytes_per_row=2e5)
    assert cm.terms["mxint8"].per_row_s == pytest.approx(2e-4)
    # factor uses the refreshed raw: 2ms / (1ms + 2*0.2ms) = 10/7
    assert cm.terms["mxint8"].factor == pytest.approx(2.0 / 1.4)


def test_cost_model_unseeded_observe_bootstraps_flat_term():
    cm = CostModel(hbm_bytes_per_s=1e9, min_ticks=1)
    cm.observe("bf16", 3, 4e-3)
    assert cm.has_estimate("bf16")
    assert cm.terms["bf16"].per_row_s == 0.0
    assert cm.predict_ms("bf16", 1) == pytest.approx(4.0)
    assert cm.predict_ms("bf16", 7) == pytest.approx(4.0)  # rows-flat


def test_cost_model_snapshot_and_validation():
    with pytest.raises(ValueError):
        CostModel(hbm_bytes_per_s=1e9, ema=0.0)
    cm = CostModel(hbm_bytes_per_s=1e9)
    cm.seed("mxint8", 1e6, 1e5)
    snap = cm.snapshot()
    assert set(snap) == {"mxint8"}
    assert set(snap["mxint8"]) == {"base_s", "per_row_s", "factor",
                                   "ticks_observed", "predict_1row_ms"}
    assert snap["mxint8"]["ticks_observed"] == 0


def test_cost_model_from_roofline_seeds_every_format():
    cfg = get_reduced("smollm-135m")
    cm = CostModel.from_roofline(cfg, ("mxint4", "mxint8", "bf16"),
                                 max_len=64, kv_layout="paged",
                                 kv_page_size=8, hbm_bytes_per_s=1e9)
    for f in ("mxint4", "mxint8", "bf16"):
        assert cm.has_estimate(f)
        assert cm.raw_predict_s(f, 1) > 0
    # The analytic shape the policy relies on: narrower formats stream
    # fewer weight bytes per tick.
    assert cm.terms["mxint4"].base_s < cm.terms["mxint8"].base_s \
        < cm.terms["bf16"].base_s
    # Attention term is format-independent (KV stays at compute dtype).
    assert cm.terms["mxint4"].per_row_s \
        == pytest.approx(cm.terms["mxint8"].per_row_s)


# ------------------------------------------------- engine: arrivals & tiers

def test_engine_rejects_unknown_admission_order():
    with pytest.raises(ValueError):
        _engine(admission_order="sjf")


@pytest.mark.slow
def test_arrival_tick_gates_admission():
    """A request is invisible to the scheduler before its arrival tick:
    the engine idles (or serves others) until it comes due, then stamps
    ``arrival_s``/``admitted_tick``."""
    cfg, eng = _engine(slots=2)
    now = _req(cfg, 0, max_new=2)
    late = _req(cfg, 1, max_new=2, arrival_tick=4)
    eng.generate([now, late], fmt_override="mxint8")
    assert now.status is RequestStatus.COMPLETED
    assert late.status is RequestStatus.COMPLETED
    assert now.admitted_tick == 0
    assert late.admitted_tick >= 4
    assert late.arrival_s is not None and late.ttft_s >= late.arrival_s


@pytest.mark.slow
def test_slo_admission_order_serves_latency_tier_first():
    """With one slot and simultaneous arrivals, ``admission_order="slo"``
    admits the latency-tier request before earlier-queued lower tiers;
    FIFO admits by queue position. Token streams are unaffected either
    way (per-slot RNG is keyed by rid, not admission order)."""
    def run(order):
        cfg, eng = _engine(slots=1, admission_order=order)
        reqs = [_req(cfg, 0, max_new=2, slo=SLOClass.best_effort()),
                _req(cfg, 1, max_new=2, slo=SLOClass.throughput()),
                _req(cfg, 2, max_new=2, slo=SLOClass.latency(
                    ttft_ms=1e4, tpot_ms=1e4))]
        eng.generate(reqs, fmt_override="mxint8")
        assert all(r.status is RequestStatus.COMPLETED for r in reqs)
        return {r.rid: r.admitted_tick for r in reqs}, \
            {r.rid: r.out_tokens for r in reqs}

    fifo_adm, fifo_tok = run("fifo")
    slo_adm, slo_tok = run("slo")
    assert fifo_adm[0] < fifo_adm[1] < fifo_adm[2]      # queue position
    assert slo_adm[2] < slo_adm[1] < slo_adm[0]         # tier rank
    assert fifo_tok == slo_tok                          # streams untouched


@pytest.mark.slow
def test_snapshot_roundtrip_preserves_slo_fields(tmp_path):
    """Snapshot/resume carries the new per-request fields (slo, tenant,
    arrival/admission stamps, sampling params) and the per-slot sampling
    lanes, and the resumed engine finishes the wave identically."""
    from repro.runtime.fault import FaultInjector, PreemptionGuard

    def build(order, injector=None):
        cfg, eng = _engine(slots=2, admission_order=order,
                           temperature=0.8, top_p=0.9,
                           fault_injector=injector)
        reqs = [_req(cfg, 0, max_new=6, slo=SLOClass.latency(
                         ttft_ms=1e4, tpot_ms=1e4),
                     tenant="interactive", temperature=0.7, top_p=0.95),
                _req(cfg, 1, max_new=6, tenant="bulk", arrival_tick=1)]
        return cfg, eng, reqs

    cfg, eng, reqs = build("slo", FaultInjector(preempt_at=3))
    eng.generate(list(reqs), fmt_override="mxint8", greedy=False,
                 guard=PreemptionGuard(), snapshot_dir=str(tmp_path))
    assert not all(r.done for r in reqs)       # genuinely interrupted
    _, eng2, _ = build("slo")
    resumed = eng2.resume(str(tmp_path))
    by_rid = {r.rid: r for r in resumed}
    assert by_rid[0].slo == SLOClass.latency(ttft_ms=1e4, tpot_ms=1e4)
    assert by_rid[0].tenant == "interactive"
    assert by_rid[0].temperature == 0.7 and by_rid[0].top_p == 0.95
    assert by_rid[1].tenant == "bulk" and by_rid[1].arrival_tick == 1

    # Reference: the same wave run straight through, no snapshot detour.
    cfg3, eng3, ref = build("slo")
    eng3.generate(list(ref), fmt_override="mxint8", greedy=False)
    assert {r.rid: r.out_tokens for r in ref} \
        == {r.rid: r.out_tokens for r in resumed}


@pytest.mark.slow
def test_stats_expose_cost_model_and_admission_order():
    """After a wave with a cost model attached, stats() reports the
    calibrated terms; the engine re-seeds the model from *measured* packed
    bytes when it builds a format's serving tree."""
    cfg = get_reduced("smollm-135m")
    pol = FormatPolicy(anchor="mxint8",
                       ladder=((6, "mxint4"), (0, "mxint8")),
                       cost=CostModel.from_roofline(
                           cfg, ("mxint4", "mxint8"), max_len=48))
    seeded_base = pol.cost.terms["mxint8"].base_s
    _, eng = _engine(slots=2, policy=pol, admission_order="slo")
    reqs = [_req(cfg, i, max_new=6,
                 slo=SLOClass.latency(ttft_ms=1e4, tpot_ms=1e4))
            for i in range(2)]
    eng.generate(reqs, fmt_override="mxint8")
    st = eng.stats
    assert st["admission_order"] == "slo"
    assert "mxint8" in st["cost_model"]
    term = st["cost_model"]["mxint8"]
    # Re-seeded from the measured packed tree (exact bytes, not analytic).
    # The analytic seed must have been close — it feeds the policy before
    # the first wave — but the term of record is the measured one.
    assert term["base_s"] * pol.cost.hbm_bytes_per_s \
        == pytest.approx(st["weight_bytes"]["mxint8"])
    assert term["base_s"] == pytest.approx(seeded_base, rel=0.05)
    # Clean pure-decode ticks were observed (first one skipped as jit
    # warmup), so the rung is on its way to "measured".
    assert term["ticks_observed"] >= 1
    assert term["predict_1row_ms"] > 0
