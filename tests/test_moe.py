"""MoE local-group routing: numerics vs a naive dense-routing reference."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig, QuantCtx
from repro.models.layers import moe_block


def _cfg(capacity_factor=8.0):
    return ModelConfig(name="t", family="moe", n_layers=2, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                       moe_experts=4, moe_topk=2,
                       capacity_factor=capacity_factor,
                       compute_dtype=jnp.float32)


def _params(cfg, seed=0):
    rng = np.random.default_rng(seed)
    e, d, f = cfg.moe_experts, cfg.d_model, cfg.d_ff
    return {
        "router": jnp.asarray(rng.normal(size=(d, e)) * 0.1, jnp.float32),
        "experts": {
            "w_gate": jnp.asarray(rng.normal(size=(e, d, f)) * 0.1,
                                  jnp.float32),
            "w_up": jnp.asarray(rng.normal(size=(e, d, f)) * 0.1,
                                jnp.float32),
            "w_down": jnp.asarray(rng.normal(size=(e, f, d)) * 0.1,
                                  jnp.float32),
        },
    }


def naive_moe(x, p, cfg):
    """Every expert on every token, combine by top-k gates. No capacity."""
    b, s, d = x.shape
    logits = x @ p["router"]
    top_vals, top_idx = jax.lax.top_k(logits, cfg.moe_topk)
    gates = jax.nn.softmax(top_vals, axis=-1)

    def expert(ei):
        h = jax.nn.silu(x @ p["experts"]["w_gate"][ei]) * \
            (x @ p["experts"]["w_up"][ei])
        return h @ p["experts"]["w_down"][ei]

    ys = jnp.stack([expert(e) for e in range(cfg.moe_experts)])  # (E,B,S,d)
    out = jnp.zeros_like(x)
    for k in range(cfg.moe_topk):
        sel = jnp.take_along_axis(
            ys.transpose(1, 2, 0, 3), top_idx[..., k:k + 1, None],
            axis=2)[:, :, 0]
        out = out + gates[..., k:k + 1] * sel
    return out


def test_moe_matches_naive_with_no_drop_capacity():
    cfg = _cfg(capacity_factor=8.0)   # C >= S: nothing dropped
    p = _params(cfg)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(3, 16, 32)),
                    jnp.float32)
    got, aux = moe_block(QuantCtx(), x, p, cfg, "moe")
    want = naive_moe(x, p, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    assert float(aux) > 0


def test_moe_capacity_drops_lowest_gates():
    cfg = _cfg(capacity_factor=0.5)   # force dropping
    p = _params(cfg)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(2, 32, 32)),
                    jnp.float32)
    got, _ = moe_block(QuantCtx(), x, p, cfg, "moe")
    full = naive_moe(x, p, cfg)
    # dropped tokens make outputs differ, but kept ones should dominate:
    # the output is never *larger* than the no-drop result in aggregate
    assert float(jnp.linalg.norm(got)) <= float(jnp.linalg.norm(full)) * 1.2
    assert not np.allclose(np.asarray(got), np.asarray(full))


def test_moe_rows_route_independently():
    """Permuting batch rows permutes outputs (no cross-row interaction)."""
    cfg = _cfg(capacity_factor=1.0)
    p = _params(cfg)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(4, 16, 32)),
                    jnp.float32)
    out1, _ = moe_block(QuantCtx(), x, p, cfg, "moe")
    perm = jnp.asarray([2, 0, 3, 1])
    out2, _ = moe_block(QuantCtx(), x[perm], p, cfg, "moe")
    np.testing.assert_allclose(np.asarray(out1[perm]), np.asarray(out2),
                               rtol=1e-5, atol=1e-6)


def test_moe_grads_flow_to_all_parts():
    cfg = _cfg(capacity_factor=2.0)
    p = _params(cfg)
    x = jnp.asarray(np.random.default_rng(4).normal(size=(2, 16, 32)),
                    jnp.float32)

    def loss(pp):
        out, aux = moe_block(QuantCtx(), x, pp, cfg, "moe")
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(p)
    for leaf in jax.tree_util.tree_leaves(g):
        assert float(jnp.linalg.norm(leaf)) > 0
