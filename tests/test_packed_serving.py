"""Packed-MX serving params: numerics + eval_shape lowering contract."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core import get_format, make_anchor
from repro.core.anchor import materialize
from repro.core.qat import QATConfig
from repro.models import get_model
from repro.serve.packed_params import (densify_params, make_packed_params,
                                       make_packed_serve_step)

QAT = QATConfig(formats=("mxint4", "mxint8"), block_size=32)


def _setup(arch="smollm-135m"):
    cfg = get_reduced(arch)
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    anchor = make_anchor(params, QAT, get_format("mxint8", 32))
    return cfg, api, params, anchor


def test_densify_int8_matches_materialize():
    cfg, api, params, anchor = _setup()
    packed = make_packed_params(anchor, params, target_bits=8,
                                dtype=jnp.float32)
    dense = densify_params(packed, 32, jnp.float32)
    want = materialize(anchor, params, dtype=jnp.float32)
    for a, b in zip(jax.tree_util.tree_leaves(dense),
                    jax.tree_util.tree_leaves(want)):
        if jnp.issubdtype(a.dtype, jnp.floating):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=0, atol=0)


def test_densify_int4_matches_ss_path():
    from repro.core.anchor import convert
    cfg, api, params, anchor = _setup()
    packed = make_packed_params(anchor, params, target_bits=4,
                                dtype=jnp.float32)
    dense = densify_params(packed, 32, jnp.float32)
    want = materialize(convert(anchor, get_format("mxint4", 32)), params,
                       dtype=jnp.float32)
    for a, b in zip(jax.tree_util.tree_leaves(dense),
                    jax.tree_util.tree_leaves(want)):
        if jnp.issubdtype(a.dtype, jnp.floating):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=0, atol=0)


def test_packed_serve_step_runs_and_matches_dense():
    cfg, api, params, anchor = _setup()
    b, s = 2, 16
    cache = api.init_cache(b, s + 4)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (b, s)), jnp.int32)
    _, cache, clen = jax.jit(api.prefill)(
        materialize(anchor, params, dtype=cfg.compute_dtype),
        {"tokens": toks}, cache)

    packed = make_packed_params(anchor, params, target_bits=8,
                                dtype=cfg.compute_dtype)
    step = jax.jit(make_packed_serve_step(api, 32))
    nxt = {"tokens": toks[:, -1:]}
    logits_p, _ = step(packed, nxt, cache, clen)

    dense = materialize(anchor, params, dtype=cfg.compute_dtype)
    logits_d, _ = jax.jit(api.serve_step)(dense, nxt, cache, clen)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_d),
                               rtol=1e-4, atol=1e-4)


def test_packed_params_int4_are_smaller_in_memory():
    cfg, api, params, anchor = _setup()
    p8 = make_packed_params(anchor, params, target_bits=8)
    p4 = make_packed_params(anchor, params, target_bits=4)

    def weight_bytes(tree):
        total = 0
        for leaf in jax.tree_util.tree_leaves(tree):
            total += leaf.size * leaf.dtype.itemsize
        return total

    assert weight_bytes(p4) < weight_bytes(p8)


def test_eval_shape_composes():
    """The dry-run contract: packed params build abstractly (no allocation)."""
    cfg, api, params, anchor = _setup()
    params_s = jax.eval_shape(api.init_params, jax.random.PRNGKey(0))
    packed_s = jax.eval_shape(
        lambda p: make_packed_params(
            make_anchor(p, QAT, get_format("mxint8", 32)), p, target_bits=4),
        params_s)
    leaves = jax.tree_util.tree_leaves(packed_s)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    assert any(l.dtype == jnp.uint8 for l in leaves)   # packed nibbles
