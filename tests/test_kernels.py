"""Pallas kernels vs pure-jnp oracles: shape/dtype/format sweeps.

All kernels run in interpret mode on CPU — the kernel bodies execute exactly
as written for TPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MXTensor, get_format
from repro.kernels import ops, ref

INT_FORMATS = [f"mxint{b}" for b in (2, 4, 6, 8)]
FP_FORMATS = [f"mxfp{b}" for b in (4, 5, 6, 8)]


def _rand(shape, seed=0, dtype=np.float32, scale=1.0):
    x = np.random.default_rng(seed).normal(size=shape) * scale
    return jnp.asarray(x.astype(dtype))


# ---------------------------------------------------------------------------
# mx_quantize
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", INT_FORMATS + FP_FORMATS)
@pytest.mark.parametrize("shape", [(8, 128), (32, 256), (4, 16, 64)])
def test_mx_quantize_matches_ref(name, shape):
    fmt = get_format(name, 32)
    v = _rand(shape, seed=1)
    got = ops.mx_quantize(v, fmt, axis=-1, interpret=True)
    want_codes, want_scales = ref.ref_mx_quantize(v, fmt, axis=-1)
    np.testing.assert_array_equal(np.asarray(got.codes), np.asarray(want_codes))
    np.testing.assert_array_equal(np.asarray(got.scale_exp),
                                  np.asarray(want_scales))


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_mx_quantize_dtypes(dtype):
    fmt = get_format("mxint8", 32)
    v = _rand((16, 128), seed=2).astype(dtype)
    got = ops.mx_quantize(v, fmt, interpret=True)
    want_codes, _ = ref.ref_mx_quantize(v, fmt, axis=-1)
    np.testing.assert_array_equal(np.asarray(got.codes), np.asarray(want_codes))


@pytest.mark.parametrize("bs", [16, 32, 64])
def test_mx_quantize_block_sizes(bs):
    fmt = get_format("mxint4", bs)
    v = _rand((8, 256), seed=3)
    got = ops.mx_quantize(v, fmt, interpret=True)
    want_codes, want_scales = ref.ref_mx_quantize(v, fmt, axis=-1)
    np.testing.assert_array_equal(np.asarray(got.codes), np.asarray(want_codes))
    np.testing.assert_array_equal(np.asarray(got.scale_exp),
                                  np.asarray(want_scales))


# ---------------------------------------------------------------------------
# fake_quant
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", INT_FORMATS + FP_FORMATS)
@pytest.mark.parametrize("shape", [(8, 128), (64, 512)])
def test_fake_quant_matches_ref(name, shape):
    fmt = get_format(name, 32)
    v = _rand(shape, seed=4, scale=2.5)
    got = ops.fake_quant(v, fmt, axis=-1, interpret=True)
    want = ref.ref_fake_quant(v, fmt, axis=-1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=0)


def test_fake_quant_axis0():
    fmt = get_format("mxint4", 32)
    v = _rand((128, 48), seed=5)
    got = ops.fake_quant(v, fmt, axis=0, interpret=True)
    want = ref.ref_fake_quant(v, fmt, axis=0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# ss_convert
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bh,bl", [(8, 2), (8, 4), (8, 6), (6, 4), (4, 2)])
def test_ss_convert_int_matches_ref(bh, bl):
    high = get_format(f"mxint{bh}", 32)
    low = get_format(f"mxint{bl}", 32)
    v = _rand((16, 256), seed=6)
    t = ops.mx_quantize(v, high, interpret=True)
    got = ops.ss_convert(t, low, interpret=True)
    want_codes, want_scales = ref.ref_ss_convert(
        t.codes, t.scale_exp, high, low, block_axis=-1)
    np.testing.assert_array_equal(np.asarray(got.codes), np.asarray(want_codes))
    np.testing.assert_array_equal(np.asarray(got.scale_exp),
                                  np.asarray(want_scales))


@pytest.mark.parametrize("bh,bl", [(8, 4), (8, 6), (8, 5), (6, 4), (5, 4)])
def test_ss_convert_fp_matches_ref(bh, bl):
    high = get_format(f"mxfp{bh}", 32)
    low = get_format(f"mxfp{bl}", 32)
    v = _rand((16, 256), seed=7)
    t = ops.mx_quantize(v, high, interpret=True)
    got = ops.ss_convert(t, low, interpret=True)
    want_codes, want_scales = ref.ref_ss_convert(
        t.codes, t.scale_exp, high, low, block_axis=-1)
    np.testing.assert_array_equal(np.asarray(got.codes), np.asarray(want_codes))
    np.testing.assert_array_equal(np.asarray(got.scale_exp),
                                  np.asarray(want_scales))


# ---------------------------------------------------------------------------
# mx_matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["mxint8", "mxint4", "mxfp8", "mxfp4"])
@pytest.mark.parametrize("mnk", [(8, 128, 64), (16, 256, 128), (32, 128, 256)])
def test_mx_matmul_matches_ref(name, mnk):
    m, n, k = mnk
    fmt = get_format(name, 32)
    x = _rand((m, k), seed=8, dtype=np.float32)
    w = _rand((k, n), seed=9)
    t = ops.mx_quantize(w, fmt, axis=0, interpret=True)
    codes, scales = ops.to_weight_layout(t)   # (K,N), (K/bs,N)
    got = ops.mx_matmul(x, codes, scales, fmt, interpret=True)
    want = ref.ref_mx_matmul(x, codes, scales, fmt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_mx_matmul_activation_dtypes(dtype):
    fmt = get_format("mxint8", 32)
    x = _rand((16, 128), seed=10).astype(dtype)
    w = _rand((128, 256), seed=11)
    t = ops.mx_quantize(w, fmt, axis=0, interpret=True)
    codes, scales = ops.to_weight_layout(t)
    got = ops.mx_matmul(x, codes, scales, fmt, interpret=True)
    want = ref.ref_mx_matmul(x.astype(jnp.float32), codes, scales, fmt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("mnk", [(8, 256, 64), (16, 512, 128)])
def test_mx_matmul_int4_packed_matches_ref(mnk):
    m, n, k = mnk
    fmt = get_format("mxint4", 32)
    x = _rand((m, k), seed=12)
    w = _rand((k, n), seed=13)
    t = ops.mx_quantize(w, fmt, axis=0, interpret=True)
    codes, scales = ops.to_weight_layout(t)
    packed = ops.pack_int4_splitn(codes)
    assert packed.shape == (k, n // 2)
    got = ops.mx_matmul_int4(x, packed, scales, fmt, interpret=True)
    want = ref.ref_mx_matmul_int4_packed(x, packed, scales, fmt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
    # and the packed path equals the unpacked path exactly
    unpacked = ops.mx_matmul(x, codes, scales, fmt, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(unpacked),
                               rtol=1e-6, atol=1e-6)


def test_mx_matmul_explicit_tiles():
    fmt = get_format("mxint8", 32)
    x = _rand((64, 256), seed=14)
    w = _rand((256, 512), seed=15)
    t = ops.mx_quantize(w, fmt, axis=0, interpret=True)
    codes, scales = ops.to_weight_layout(t)
    a = ops.mx_matmul(x, codes, scales, fmt, interpret=True,
                      tm=32, tn=128, tk=64)
    b = ops.mx_matmul(x, codes, scales, fmt, interpret=True,
                      tm=64, tn=256, tk=128)
    # different K tilings reorder the f32 accumulation
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                               atol=1e-3)


# ---------------------------------------------------------------------------
# end-to-end: kernel pipeline == core pipeline
# ---------------------------------------------------------------------------
def test_kernel_pipeline_equals_core_pipeline():
    """quantize -> ss -> dequant-matmul via kernels == via core ops."""
    from repro.core import dequantize, quantize, slice_and_scale
    fmt8 = get_format("mxint8", 32)
    fmt4 = get_format("mxint4", 32)
    x = _rand((8, 128), seed=16)
    w = _rand((128, 128), seed=17)

    tk = ops.mx_quantize(w, fmt8, axis=0, interpret=True)
    tk4 = ops.ss_convert(tk, fmt4, interpret=True)
    codes, scales = ops.to_weight_layout(tk4)
    got = ops.mx_matmul(x, codes, scales, fmt4, interpret=True)

    tc = quantize(w, fmt8, axis=0)
    tc4 = slice_and_scale(tc, fmt4)
    want = x @ dequantize(tc4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
