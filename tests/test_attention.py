"""Flash attention (both paths) vs exact reference, values and gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.flash_vjp import flash_attention_vjp
from repro.models.layers import decode_attention, flash_attention


def exact_attention(q, k, v, causal=True, window=None):
    """O(S^2) reference. q (B,S,H,D), k/v (B,S,Hkv,D)."""
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, d).astype(jnp.float32)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k.astype(jnp.float32)) / d ** 0.5
    qp, kp = jnp.arange(sq), jnp.arange(skv)
    m = jnp.ones((sq, skv), bool)
    if causal:
        m &= kp[None] <= qp[:, None]
    if window is not None:
        m &= (qp[:, None] - kp[None]) < window
    s = jnp.where(m[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d).astype(q.dtype)


def _qkv(b=2, s=96, h=4, hkv=2, d=16, seed=0, skv=None):
    rng = np.random.default_rng(seed)
    skv = skv or s
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, skv, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, skv, hkv, d)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("window", [None, 24])
@pytest.mark.parametrize("chunk", [16, 32, 96])
def test_flash_matches_exact(window, chunk):
    q, k, v = _qkv()
    got = flash_attention(q, k, v, causal=True, window=window, chunk=chunk)
    want = exact_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [None, 24])
@pytest.mark.parametrize("chunk", [16, 32])
def test_flash_vjp_matches_exact_values(window, chunk):
    q, k, v = _qkv(seed=1)
    got = flash_attention_vjp(q, k, v, causal=True, window=window,
                              chunk=chunk)
    want = exact_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [None, 24])
@pytest.mark.parametrize("chunk", [16, 32])
def test_flash_vjp_gradients_match_exact(window, chunk):
    q, k, v = _qkv(seed=2, s=64)

    def loss_flash(q, k, v):
        o = flash_attention_vjp(q, k, v, causal=True, window=window,
                                chunk=chunk)
        return jnp.sum(jnp.sin(o))

    def loss_exact(q, k, v):
        return jnp.sum(jnp.sin(exact_attention(q, k, v, causal=True,
                                               window=window)))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_exact, argnums=(0, 1, 2))(q, k, v)
    for a, b, nm in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-5, err_msg=nm)


def test_flash_noncausal_cross_attention():
    q, k, v = _qkv(seed=3, s=32, skv=80)
    got = flash_attention(q, k, v, causal=False, chunk=16)
    want = exact_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_exact_last_row():
    """Decode over a cache == last row of full causal attention."""
    b, s, h, hkv, d = 2, 40, 4, 2, 16
    q, k, v = _qkv(b=b, s=s, h=h, hkv=hkv, d=d, seed=4)
    full = exact_attention(q, k, v, causal=True)
    got = decode_attention(q[:, -1:], k, v,
                           jnp.full((b,), s, jnp.int32))
    np.testing.assert_allclose(np.asarray(got[:, 0]),
                               np.asarray(full[:, -1]),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_sliding_window():
    b, s = 2, 64
    q, k, v = _qkv(b=b, s=s, seed=5)
    w = 16
    full = exact_attention(q, k, v, causal=True, window=w)
    got = decode_attention(q[:, -1:], k, v, jnp.full((b,), s, jnp.int32),
                           window=w)
    np.testing.assert_allclose(np.asarray(got[:, 0]),
                               np.asarray(full[:, -1]),
                               rtol=2e-5, atol=2e-5)
