"""Validate the analytic roofline cost model against compiled HLO counts.

Strategy: build small *unrolled* configs (python-loop layers, no remat, no
inner scans: seq_chunk >= seq), compile train/prefill/decode on 1 device, and
compare ``cost_analysis()['flops']`` with the analytic prediction. The
analytic model must land within a modest band — it feeds §Roofline.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.shapes import ShapeSpec
from repro.launch import costmodel as cm
from repro.launch._compat import compiled_cost
from repro.models import get_model
from repro.models.common import ModelConfig


def _tiny_dense():
    return ModelConfig(
        name="tiny", family="dense", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab=512, compute_dtype=jnp.float32,
        seq_chunk=4096, remat=False, unroll=True, flash_vjp=False)


def _compiled_flops(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return float(compiled_cost(c).get("flops", 0.0))


def test_prefill_flops_close():
    cfg = _tiny_dense()
    api = get_model(cfg, None)
    shape = ShapeSpec("t", seq_len=128, global_batch=2, kind="prefill")
    params = jax.eval_shape(api.init_params, jax.random.PRNGKey(0))
    cache = jax.eval_shape(lambda: api.init_cache(2, 128))
    batch = {"tokens": jax.ShapeDtypeStruct((2, 128), jnp.int32)}
    got = _compiled_flops(api.prefill, params, batch, cache)
    want = cm.flops_prefill(cfg, shape)["total"]
    assert 0.6 < got / want < 1.7, (got, want)


def test_train_flops_close():
    cfg = _tiny_dense()
    api = get_model(cfg, None)
    shape = ShapeSpec("t", seq_len=128, global_batch=2, kind="train")
    params = jax.eval_shape(api.init_params, jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((2, 128), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 128), jnp.int32)}

    def loss_and_grad(p, b):
        return jax.value_and_grad(
            lambda pp: api.train_loss(pp, b, None)[0])(p)

    got = _compiled_flops(loss_and_grad, params, batch)
    # analytic model includes remat (x8); this config has remat off (x6)
    want = cm.flops_train(cfg, shape)["total"] * 6.0 / 8.0
    assert 0.5 < got / want < 1.8, (got, want)


def test_decode_flops_close():
    cfg = _tiny_dense()
    api = get_model(cfg, None)
    shape = ShapeSpec("t", seq_len=256, global_batch=4, kind="decode")
    params = jax.eval_shape(api.init_params, jax.random.PRNGKey(0))
    cache = jax.eval_shape(lambda: api.init_cache(4, 256))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 1), jnp.int32)}
    lens = jax.ShapeDtypeStruct((4,), jnp.int32)
    got = _compiled_flops(api.serve_step, params, batch, cache, lens)
    want = cm.flops_decode(cfg, shape)["total"]
    assert 0.4 < got / want < 2.0, (got, want)


def test_param_counts_match_init():
    """Analytic total_params == actual init param count (matmuls+embeds)."""
    from repro.configs import get_config
    for arch in ["qwen3-4b", "smollm-135m", "mixtral-8x7b"]:
        cfg = get_config(arch)
        api = get_model(cfg, None)
        shapes = jax.eval_shape(api.init_params, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(s.shape))
                     for s in jax.tree_util.tree_leaves(shapes))
        pred = cm.total_params(cfg)
        # analytic skips norms/biases/small vectors — within 2%
        assert 0.98 < pred / actual < 1.02, (arch, pred, actual)


def test_known_param_magnitudes():
    """Sanity: headline param counts are in the right ballpark."""
    from repro.configs import get_config
    assert 6.5e9 < cm.total_params(get_config("llava-next-mistral-7b")) < 8e9
    assert 65e9 < cm.total_params(get_config("qwen2-72b")) < 80e9
    assert 1.2e11 < cm.total_params(get_config("mixtral-8x22b")) < 1.5e11
    assert 3.3e11 < cm.total_params(get_config("jamba-1.5-large-398b")) < 4.6e11
    assert 1.1e8 < cm.total_params(get_config("smollm-135m")) < 1.7e8
    assert 6e9 < cm.total_params(get_config("rwkv6-7b")) < 9e9


def test_roofline_terms_reasonable():
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES
    mesh = cm.MeshDesc(pod=1, data=16, model=16)
    r = cm.roofline(get_config("qwen2-72b"), SHAPES["train_4k"], mesh)
    assert r["t_compute"] > 0 and r["t_memory"] > 0 and r["t_collective"] > 0
    assert r["dominant"] in ("compute", "memory", "collective")
    assert 0 < r["useful_ratio"] <= 1.2
    # decode must be memory-bound at bf16 weights
    r2 = cm.roofline(get_config("qwen2-72b"), SHAPES["decode_32k"], mesh)
    assert r2["dominant"] in ("memory", "collective")
    # int4 weights strictly shrink decode memory; on a weights-dominated
    # cell (SWA-bounded cache, batch 1) the cut approaches 8x
    r4 = cm.roofline(get_config("qwen2-72b"), SHAPES["decode_32k"], mesh,
                     weight_bits_decode=4)
    assert r4["t_memory"] < r2["t_memory"]
    m16 = cm.roofline(get_config("mixtral-8x7b"), SHAPES["long_500k"], mesh,
                      weight_bits_decode=16)
    m4 = cm.roofline(get_config("mixtral-8x7b"), SHAPES["long_500k"], mesh,
                     weight_bits_decode=4)
    assert m4["t_memory"] < m16["t_memory"] * 0.5   # rest is the KV band


# ---- serving roofline terms vs a real engine (the cost-model seed) ---------
# serve_* terms feed serve.slo.CostModel.from_roofline; the contract is that
# they agree with what the packed-weight engine MEASURES: weight_stream_bytes
# over each cached serving tree, and the attn_read_bytes counter a decode
# wave accumulates, per format x {dense, paged}.

def _serve_engine(**kw):
    from repro.configs import get_reduced
    from repro.core import make_anchor
    from repro.core.qat import QATConfig
    from repro.models import get_model as _gm
    from repro.serve.engine import ElasticEngine
    cfg = get_reduced("smollm-135m")
    api = _gm(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    anchor = make_anchor(params, QATConfig(
        formats=("mxint4", "mxint8"), anchor="mxint8", block_size=32))
    eng = ElasticEngine(api, anchor, batch_slots=2, max_len=48,
                        param_template=params, **kw)
    return cfg, eng


def test_serve_weight_stream_bytes_matches_packed_trees():
    """Analytic per-tick weight stream vs the real packed containers, per
    format (bf16 = the dense pseudo-format). No generate needed — the
    bytes are a property of the cached tree. Norm vectors are the only
    thing the analytic term drops, so the band is tight."""
    cfg, eng = _serve_engine()
    for fmt in ("mxint4", "mxint8", "bf16"):
        eng.weights_for(fmt)
    measured = eng.stats["weight_bytes"]
    for fmt in ("mxint4", "mxint8", "bf16"):
        analytic = cm.serve_weight_stream_bytes(cfg, fmt, block_size=32)
        assert analytic == pytest.approx(measured[fmt], rel=0.02), \
            (fmt, analytic, measured[fmt])
    assert measured["mxint4"] < measured["mxint8"] < measured["bf16"]


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_serve_attn_bytes_match_engine_counters(layout):
    """Analytic attention bytes/row/tick vs the engine's own accounting
    over a real decode wave, under the gather read path (span == the
    whole logical view for every batch row): the counter must equal
    decode_ticks * slots * span exactly, and the byte multiplier must be
    the same K+V-at-compute-dtype constant on both sides."""
    import numpy as np
    from repro.serve.engine import Request
    kw = {"kv_layout": layout}
    if layout == "paged":
        kw.update(kv_page_size=8, attn_impl="gather")
    cfg, eng = _serve_engine(**kw)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab, 8).astype(np.int32),
                    max_new=3) for i in range(3)]
    eng.generate(reqs, fmt_override="mxint8")
    decode_ticks = sum(t["decode"] for t in eng.tick_trace)
    assert decode_ticks > 0
    span = cm.serve_attn_read_span(cfg, 48, layout, kv_page_size=8)
    st = eng.stats
    assert st["attn_tokens_read"] == decode_ticks * eng.slots * span
    assert st["attn_read_bytes"] == pytest.approx(
        st["attn_tokens_read"] * cm.serve_attn_bytes_per_row(cfg, 1))


def test_serve_roofline_terms_scale_with_mesh():
    """Satellite regression: the serving roofline is PER CHIP. A tensor-
    parallel engine streams 1/n_model of the weight bytes and 1/n_model of
    every KV token's bytes per chip, so seeding the cost model from the
    unsharded terms would predict tick times n_model x too slow. Both
    terms must divide exactly by the mesh's 'model' axis size."""
    from repro.configs import get_reduced
    cfg = get_reduced("smollm-135m")
    fmts = ("mxint4", "mxint8", "bf16")
    base = cm.serve_roofline_terms(cfg, fmts, max_len=48)
    tp2 = cm.serve_roofline_terms(cfg, fmts, max_len=48, n_model=2)
    for f in fmts:
        assert tp2[f]["weight_bytes"] == \
            pytest.approx(base[f]["weight_bytes"] / 2)
        assert tp2[f]["attn_bytes_per_row"] == \
            pytest.approx(base[f]["attn_bytes_per_row"] / 2)
    with pytest.raises(ValueError):
        cm.serve_roofline_terms(cfg, fmts, max_len=48, n_model=0)


def test_costmodel_from_roofline_per_chip_seed():
    """CostModel.from_roofline(n_model=2) must seed per-chip byte terms —
    halved predictions at the same per-chip HBM bandwidth."""
    from repro.configs import get_reduced
    from repro.serve.slo import CostModel
    cfg = get_reduced("smollm-135m")
    c1 = CostModel.from_roofline(cfg, ("mxint8",), max_len=48)
    c2 = CostModel.from_roofline(cfg, ("mxint8",), max_len=48, n_model=2)
    p1 = c1.raw_predict_s("mxint8", rows=2)
    p2 = c2.raw_predict_s("mxint8", rows=2)
    assert p1 is not None and p2 is not None
    assert p2 == pytest.approx(p1 / 2)


def test_meshed_engine_seeds_per_chip_bytes():
    """A meshed engine's cost-model seed and stats must report the per-chip
    weight stream (~1/2 the global bytes at tp=2; replicated norm vectors
    keep it from being exact)."""
    import jax
    if len(jax.devices()) < 2:
        pytest.skip("needs 2 host devices (root conftest provides them)")
    from repro.launch.mesh import make_debug_mesh
    from repro.serve.policy import FormatPolicy
    from repro.serve.slo import CostModel
    cfg, eng1 = _serve_engine()
    _, eng2 = _serve_engine(
        mesh=make_debug_mesh(1, 2),
        policy=FormatPolicy("mxint8", cost=CostModel()))
    for fmt in ("mxint8", "bf16"):
        eng1.weights_for(fmt)
        eng2.weights_for(fmt)
        g = eng1.stats["weight_bytes"][fmt]
        local = eng2.stats["weight_bytes_per_chip"][fmt]
        assert 0.5 <= local / g < 0.56, (fmt, local, g)
        # the cost model was seeded with the per-chip number
        cost = eng2.policy.cost
        assert cost.terms[fmt].base_s == pytest.approx(
            local / cost.hbm_bytes_per_s)
