"""One mixed prefill+decode batch per tick: the unified-scheduler net.

Four lines of defense around the mixed tick (serve/engine.py "mixed"
scheduler + models' ``mixed_step`` + the multi-query kernel in
kernels/paged_attention.py):

1. **MQ kernel parity** — the multi-query Pallas kernel (interpret mode)
   vs the gather + masked-softmax reference (``mixed_attention(
   paged_gather(...))``), swept over ragged per-row query spans whose
   cursors sit AT, just past, and just before page boundaries
   (``q_offset % page_size in {0, 1, page_size-1}``), q-block tilings
   (``tq``), sliding window, and the q_len==1 collapse onto the
   single-query kernel (bit-identical — decode rows cost and compute
   exactly what they did before the refactor).
2. **Adversarial poison** — unallocated pages, scratch page 0, dead query
   lanes and the tail beyond each row's frontier are NaN / ±1e9; outputs
   must be BIT-identical to the zero-filled run. The per-lane causal mask
   makes this strictly harder than the single-query case: an executed page
   may be dead for SOME lanes only, so the running-max update must guard
   lanes whose max is still -inf (exp(-inf - -inf) = NaN).
3. **Scheduler identity** — token streams under ``scheduler="mixed"``
   (chunk rides the decode batch, ONE executable per tick) must match
   ``scheduler="sequential"`` (PR 4's chunk-then-decode, two executables)
   bit for bit across {fused, densify} x {dense, paged} x {gather,
   paged_kernel} x {greedy, seeded} x {mxint8, bf16}. Heavyweight matrix
   cases are ``@pytest.mark.slow`` per pytest.ini; an acceptance slice
   stays tier-1.
4. **Scheduler invariants** — exactly one executable per work tick
   (asserted from tick_trace ``execs``, with the sequential scheduler
   demonstrably running two), pool exhaustion mid-chunk under the mixed
   scheduler still releases-and-requeues without leaking pages, knob
   validation, and the ``mixed_step`` hook surviving ``with_qmm`` /
   ``with_serving`` chaining in either order.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import make_anchor
from repro.core.qat import QATConfig
from repro.kernels import paged_attention as pa
from repro.models import get_model
from repro.models.layers import mixed_attention, paged_gather
from repro.serve.engine import ElasticEngine, Request

QAT = QATConfig(formats=("mxint4", "mxint8"), anchor="mxint8", block_size=32)
PS = 8          # page size
CHUNK = 8       # prefill chunk (== one page, the paged-layout default)


# =============================================================================
# Fixtures
# =============================================================================
@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("smollm-135m")
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    anchor = make_anchor(params, QAT)
    return cfg, api, params, anchor


def _mq_case(seed, rows, ps=PS, c=8, hkv=2, g=2, d=16):
    """Random q/pools + disjoint block table for a mixed batch. ``rows`` is
    a list of (q_offset, q_len); row i's live span is q_offset+q_len tokens
    (the chunk's KV is in the pool before attention runs, exactly as
    ``paged_mixed_update`` leaves it)."""
    rng = np.random.default_rng(seed)
    b, h = len(rows), hkv * g
    mp = max(-(-(qo + ql) // ps) for qo, ql in rows)
    n_pages = b * mp + 1
    q = jnp.asarray(rng.normal(size=(b, c, h, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pages, ps, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, ps, hkv, d)), jnp.float32)
    perm = rng.permutation(np.arange(1, n_pages))
    bt = np.zeros((b, mp), np.int32)
    for i, (qo, ql) in enumerate(rows):
        k = -(-(qo + ql) // ps)
        bt[i, :k] = perm[i * mp:i * mp + k]
    qo = jnp.asarray([r[0] for r in rows], jnp.int32)
    ql = jnp.asarray([r[1] for r in rows], jnp.int32)
    return q, kp, vp, jnp.asarray(bt), qo, ql


def _mq_kernel(q, kp, vp, bt, qo, ql, window=None, tq=None):
    return pa.paged_mixed_attention(q, kp, vp, bt, qo, ql, window=window,
                                    mode="pallas", tq=tq)


def _mq_gather_ref(q, kp, vp, bt, qo, ql, window=None):
    return mixed_attention(q, paged_gather(kp, bt), paged_gather(vp, bt),
                           qo, ql, window=window)


# The adversarial span set: cursors at a page boundary, one past it, and one
# before it; chunks that end on / straddle boundaries; a decode row; a
# zero-cursor first chunk.
BOUNDARY_ROWS = [(PS, CHUNK),          # cursor % ps == 0, chunk == one page
                 (PS + 1, CHUNK - 3),  # cursor % ps == 1
                 (PS - 1, CHUNK),      # cursor % ps == ps-1 (straddles)
                 (2 * PS - 3, 1),      # decode row mid-page
                 (0, CHUNK - 1)]       # first chunk from zero


# =============================================================================
# 1. MQ kernel parity
# =============================================================================
@pytest.mark.parametrize("window", [None, 10])
@pytest.mark.parametrize("tq", [None, 4, 2])
def test_mq_kernel_matches_gather_reference(window, tq):
    q, kp, vp, bt, qo, ql = _mq_case(0, BOUNDARY_ROWS)
    got = _mq_kernel(q, kp, vp, bt, qo, ql, window=window, tq=tq)
    want = _mq_gather_ref(q, kp, vp, bt, qo, ql, window=window)
    assert got.shape == want.shape == q.shape
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("window", [None, 12])
def test_mq_q_len_one_collapses_to_single_query_kernel(window):
    """A mixed batch of pure decode rows is the same page walk and online
    softmax as the single-query kernel, with pad lanes exact zeros. The
    match is ULP-scale, not bit-exact: the MQ contraction carries a q axis,
    and the backend may vectorize the two dot shapes differently (the
    engine-level identity tests below hold the contract that matters —
    identical token streams)."""
    rows = [(8, 1), (23, 1), (16, 1)]
    q, kp, vp, bt, qo, ql = _mq_case(1, rows, c=4)
    mq = np.asarray(_mq_kernel(q, kp, vp, bt, qo, ql, window=window))
    sq = pa.paged_decode_attention(q[:, :1], kp, vp, bt, qo + 1,
                                   window=window, mode="pallas")
    np.testing.assert_allclose(np.asarray(sq, np.float32),
                               mq[:, :1].astype(np.float32),
                               rtol=1e-6, atol=1e-6)
    assert np.all(mq[:, 1:] == 0)


def test_mq_kernel_under_jit_with_traced_spans():
    """The engine jits mixed_step with q_offset/q_len traced — the scalar-
    prefetch operands must accept tracers and retracing must not depend on
    the span values."""
    q, kp, vp, bt, qo, ql = _mq_case(2, BOUNDARY_ROWS)
    f = jax.jit(lambda o, n: _mq_kernel(q, kp, vp, bt, o, n))
    for rows in (BOUNDARY_ROWS, [(0, 8), (8, 8), (15, 1), (9, 2), (1, 1)]):
        o = jnp.asarray([r[0] for r in rows], jnp.int32)
        n = jnp.asarray([r[1] for r in rows], jnp.int32)
        np.testing.assert_allclose(
            np.asarray(f(o, n), np.float32),
            np.asarray(_mq_gather_ref(q, kp, vp, bt, o, n), np.float32),
            rtol=1e-5, atol=1e-5)


def test_pages_read_mq_collapses_to_pages_read():
    """The host-side cost mirror: a decode row (q_len=1 at offset L-1) must
    account exactly like the single-query walk for L live tokens."""
    for ps in (8, 16):
        for window in (None, 10, 64):
            for L in (1, 7, 8, 9, 31, 32, 40):
                assert pa.pages_read_mq(L - 1, 1, ps, window) == \
                    pa.pages_read(L, ps, window), (ps, window, L)


# =============================================================================
# 2. Adversarial poison
# =============================================================================
def _poison_mq(kp, vp, bt, rows, ps):
    """NaN/±1e9 in every byte the MQ kernel must not read: unallocated pages
    (incl. scratch page 0) and the tail beyond each row's frontier
    (q_offset + q_len) inside its last live page."""
    kp_p, vp_p = np.array(kp), np.array(vp)
    used = set(np.asarray(bt).flatten().tolist()) - {0}
    for pg in range(kp_p.shape[0]):
        if pg not in used:
            kp_p[pg] = np.nan
            vp_p[pg] = np.nan if pg % 2 == 0 else 1e9
    for i, (qo, ql) in enumerate(rows):
        n = qo + ql
        pg, off = n // ps, n % ps
        row = np.asarray(bt)[i]
        if off and pg < row.size and row[pg] != 0:
            kp_p[row[pg], off:] = np.nan
            vp_p[row[pg], off:] = np.nan if i % 2 == 0 else -1e9
    return jnp.asarray(kp_p), jnp.asarray(vp_p)


@pytest.mark.parametrize("window", [None, 10])
def test_mq_kernel_ignores_poisoned_pool(window):
    q, kp, vp, bt, qo, ql = _mq_case(3, BOUNDARY_ROWS)
    clean = np.asarray(_mq_kernel(q, kp, vp, bt, qo, ql, window=window))
    kp_p, vp_p = _poison_mq(kp, vp, bt, BOUNDARY_ROWS, PS)
    dirty = np.asarray(_mq_kernel(q, kp_p, vp_p, bt, qo, ql, window=window))
    # BIT-identical, not allclose: poisoned values contribute exactly nothing
    assert np.array_equal(clean, dirty)
    assert np.all(np.isfinite(dirty))
    # dead query lanes (beyond each row's q_len) are exact zeros even with
    # the pool poisoned — the engine's sampler never sees them, but a NaN
    # there would poison the whole row through the output projection
    for i, (_, ql_i) in enumerate(BOUNDARY_ROWS):
        assert np.all(dirty[i, ql_i:] == 0), i


def test_poison_corrupts_the_mq_gather_reference():
    """Teeth check: the same poison NaNs the gather path (0 * NaN = NaN in
    its masked PV product) — gather's safety still depends on the engine's
    zero-filled-pool invariant; the MQ kernel's does not."""
    q, kp, vp, bt, qo, ql = _mq_case(4, BOUNDARY_ROWS)
    kp_p, vp_p = _poison_mq(kp, vp, bt, BOUNDARY_ROWS, PS)
    ref = _mq_gather_ref(q, kp_p, vp_p, bt, qo, ql)
    assert not bool(jnp.all(jnp.isfinite(ref)))


def test_mixed_step_logits_survive_poisoned_pool():
    """Model-level: a full paged mixed_step (scan over layers, ragged
    q_len=[chunk, 1]) with attn_impl='paged_kernel' produces identical
    logits with every non-allocated page and scratch page 0 poisoned."""
    cfg = get_reduced("smollm-135m")
    api = get_model(cfg, None).with_serving(attn_impl="paged_kernel")
    params = api.init_params(jax.random.PRNGKey(0))
    cache = api.init_cache(2, 32, kv_layout="paged", page_size=PS)
    bt = np.zeros((2, 4), np.int32)
    bt[0, :2] = [1, 2]       # fill row: chunk [8:16) -> pages 1,2
    bt[1, :2] = [5, 6]       # decode row at position 9 -> pages 5,6
    cache["block_table"] = jnp.asarray(bt)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
    _, cache, _ = jax.jit(api.prefill_chunk_slot)(
        params, {"tokens": prompt, "lengths": jnp.asarray([16])}, cache, 0, 0)
    _, cache, _ = jax.jit(api.prefill_slot)(
        params, {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (1, 9)), jnp.int32)}, cache, 1)
    step = jax.jit(api.mixed_step)
    tok2d = np.zeros((2, 8), np.int32)
    tok2d[0] = np.asarray(rng.integers(0, cfg.vocab, 8))
    tok2d[1, 0] = 3
    batch = {"tokens": jnp.asarray(tok2d),
             "q_len": jnp.asarray([8, 1], jnp.int32)}
    cache_len = jnp.asarray([8, 9], jnp.int32)
    logits, _ = step(params, batch, cache, cache_len)

    used = {1, 2, 5, 6}
    poisoned = dict(cache)
    poisoned["blocks"] = []
    for blk in cache["blocks"]:
        mask = np.asarray([pg not in used
                           for pg in range(blk["k_pages"].shape[1])])
        sel = jnp.asarray(mask)[None, :, None, None, None]
        poisoned["blocks"].append({
            "k_pages": jnp.where(sel, jnp.asarray(
                jnp.nan, blk["k_pages"].dtype), blk["k_pages"]),
            "v_pages": jnp.where(sel, jnp.asarray(
                jnp.nan, blk["v_pages"].dtype), blk["v_pages"])})
    logits_p, _ = step(params, batch, poisoned, cache_len)
    assert np.array_equal(np.asarray(logits), np.asarray(logits_p))
    assert bool(jnp.all(jnp.isfinite(logits_p)))


# =============================================================================
# 3. Scheduler identity: mixed vs sequential, token for token
# =============================================================================
def _engine(api, anchor, params, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_len", 48)
    return ElasticEngine(api, anchor, param_template=params, **kw)


def _reqs(cfg, n, max_new=5, plens=(8, 21, 13), seed=7):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, plens[i % len(plens)])
                    .astype(np.int32), max_new=max_new) for i in range(n)]


def _streams(api, anchor, params, cfg, scheduler, *, greedy=True,
             fmt="mxint8", n=4, **kw):
    eng = _engine(api, anchor, params, prefill_chunk=CHUNK,
                  scheduler=scheduler, **kw)
    reqs = _reqs(cfg, n)
    eng.generate(reqs, greedy=greedy, fmt_override=fmt)
    assert all(r.done for r in reqs)
    return [r.out_tokens for r in reqs], eng


@pytest.mark.parametrize("kv,fused,impl", [
    ("dense", False, "gather"),
    ("paged", True, "gather"),
    ("paged", True, "paged_kernel"),
])
def test_mixed_matches_sequential_greedy(setup, kv, fused, impl):
    """Acceptance gate (fast slice): greedy streams bit-identical mixed vs
    sequential, across KV layouts / serving contracts / attention impls —
    with the path counters proving the MQ kernel actually traced."""
    cfg, api, params, anchor = setup
    kw = dict(fused=fused)
    if kv == "paged":
        kw.update(kv_layout="paged", kv_page_size=PS, attn_impl=impl)
    seq, _ = _streams(api, anchor, params, cfg, "sequential", **kw)
    pa.reset_stats()
    mixed, eng = _streams(api, anchor, params, cfg, "mixed", **kw)
    assert seq == mixed
    if impl == "paged_kernel":
        st = pa.stats()
        assert st["pallas_mq"] >= 1 and st["fallback_mq"] == 0, st


@pytest.mark.slow
@pytest.mark.parametrize("fmt", ["mxint8", "bf16"])
@pytest.mark.parametrize("greedy", [True, False])
@pytest.mark.parametrize("kv,fused,impl", [
    ("dense", False, "gather"), ("dense", True, "gather"),
    ("paged", False, "gather"), ("paged", True, "gather"),
    ("paged", False, "paged_kernel"), ("paged", True, "paged_kernel"),
])
def test_mixed_matches_sequential_matrix(setup, fmt, greedy, kv, fused, impl):
    """The full acceptance matrix: {fused, densify} x {dense, paged} x
    {gather, paged_kernel} x {greedy, seeded} at mxint8 + bf16."""
    cfg, api, params, anchor = setup
    kw = dict(fused=fused)
    if kv == "paged":
        kw.update(kv_layout="paged", kv_page_size=PS, attn_impl=impl)
    if not greedy:
        kw.update(seed=3, temperature=1.0, top_p=0.9)
    seq, _ = _streams(api, anchor, params, cfg, "sequential", greedy=greedy,
                      fmt=fmt, **kw)
    mixed, _ = _streams(api, anchor, params, cfg, "mixed", greedy=greedy,
                        fmt=fmt, **kw)
    assert seq == mixed


def test_mixed_matches_monolithic(setup):
    """Transitivity anchor: mixed == sequential == monolithic — asserted
    directly so a joint drift in both chunked schedulers cannot hide."""
    cfg, api, params, anchor = setup
    eng = _engine(api, anchor, params)
    reqs = _reqs(cfg, 4)
    eng.generate(reqs, fmt_override="mxint8")
    mono = [r.out_tokens for r in reqs]
    mixed, _ = _streams(api, anchor, params, cfg, "mixed")
    assert mono == mixed


# =============================================================================
# 4. Scheduler invariants + knob validation
# =============================================================================
def test_exactly_one_executable_per_tick(setup):
    """THE refactor's claim, from the engine's own trace: under the mixed
    scheduler every work tick dispatches exactly one executable — including
    ticks that carry a prefill chunk AND a decode step — while the
    sequential scheduler demonstrably needs two for those ticks."""
    cfg, api, params, anchor = setup
    wl = lambda: _reqs(cfg, 3, plens=(30, 8, 8), seed=2)

    eng = _engine(api, anchor, params, prefill_chunk=CHUNK, scheduler="mixed")
    eng.generate(wl(), fmt_override="mxint8")
    assert eng.tick_trace, "mixed run recorded no ticks"
    coalesced = 0
    for t in eng.tick_trace:
        assert t["execs"] <= 1, t
        if t["prefill_chunks"] == 1 and t["decode"] == 1:
            coalesced += 1
            assert t["execs"] == 1
            assert t["decode_rows"] >= 1
    assert coalesced >= 1, "workload never coalesced a chunk into a decode"

    seq = _engine(api, anchor, params, prefill_chunk=CHUNK,
                  scheduler="sequential")
    seq.generate(wl(), fmt_override="mxint8")
    assert max(t["execs"] for t in seq.tick_trace) == 2
    # the per-tick work bound is unchanged by the refactor
    for t in eng.tick_trace:
        assert t["prefill_chunks"] <= 1 and t["prefill_tokens"] <= CHUNK


def test_exhaustion_mid_chunk_requeues_not_leaks_mixed(setup):
    """Pool exhaustion mid-chunk under the mixed scheduler: release the
    partial admission's pages, requeue, retry after a retire — streams match
    a roomy run and alloc == freed (no leak), exactly as sequential."""
    cfg, api, params, anchor = setup
    rng = np.random.default_rng(1)
    p0 = rng.integers(0, cfg.vocab, 6).astype(np.int32)
    p1 = rng.integers(0, cfg.vocab, 22).astype(np.int32)
    mk = lambda: [Request(rid=0, prompt=p0.copy(), max_new=8),
                  Request(rid=1, prompt=p1.copy(), max_new=3)]

    roomy = _engine(api, anchor, params, max_len=32, kv_layout="paged",
                    kv_page_size=PS, prefill_chunk=CHUNK, scheduler="mixed")
    ref = mk()
    roomy.generate(ref, fmt_override="mxint8")

    eng = _engine(api, anchor, params, max_len=32, kv_layout="paged",
                  kv_page_size=PS, prefill_chunk=CHUNK, scheduler="mixed",
                  kv_num_pages=5)
    reqs = mk()
    eng.generate(reqs, fmt_override="mxint8")
    st = eng.stats
    assert all(r.done for r in reqs)
    assert st["admission_requeues"] >= 1
    assert st["kv_pages_alloc"] == st["kv_pages_freed"]       # no leak
    assert [r.out_tokens for r in reqs] == [r.out_tokens for r in ref]


def test_scheduler_knob_validation(setup):
    cfg, api, params, anchor = setup
    with pytest.raises(ValueError, match="prefill_chunk"):
        _engine(api, anchor, params, scheduler="mixed")
    with pytest.raises(ValueError, match="unknown scheduler"):
        _engine(api, anchor, params, prefill_chunk=CHUNK,
                scheduler="interleaved")
    # auto resolution: chunked admission defaults to the unified tick,
    # monolithic stays sequential
    assert _engine(api, anchor, params,
                   prefill_chunk=CHUNK).scheduler == "mixed"
    assert _engine(api, anchor, params).scheduler == "sequential"


def test_mixed_step_survives_api_chaining(setup):
    """The small-fix regression: ``mixed_step`` must survive ``with_qmm`` /
    ``with_serving`` chaining in either order, keeping the chained
    attn_impl — and the three knobs (fused qmm x paged_kernel x mixed
    scheduler) must compose end-to-end against the all-default path."""
    cfg, api, params, anchor = setup
    from repro.kernels.dispatch import make_qmm
    qmm = make_qmm(block_size=32, mode="pallas")

    a = api.with_serving(attn_impl="paged_kernel").with_qmm(qmm)
    b = api.with_qmm(qmm).with_serving(attn_impl="paged_kernel")
    for chained in (a, b):
        assert chained.mixed_step is not None
        assert chained.attn_impl == "paged_kernel"

    # three-knob composition: every knob flipped at once vs none
    kw = dict(kv_layout="paged", kv_page_size=PS)
    base, _ = _streams(api, anchor, params, cfg, "sequential", n=3,
                       fused=False, attn_impl="gather", **kw)
    full, _ = _streams(api, anchor, params, cfg, "mixed", n=3,
                       fused=True, attn_impl="paged_kernel", **kw)
    assert base == full


def test_execs_per_tick_invariant_survives_speculation(setup):
    """tick_trace splits ``draft_execs``/``verify_execs`` out of ``execs``
    precisely so this file's one-executable-per-tick claim stays
    assertable when speculation is on: a tick's PLAIN executables are
    ``execs - draft_execs - verify_execs``, and under the mixed scheduler
    that difference never exceeds one (a speculative tick replaces the
    single decode executable with the draft burst + one verify)."""
    from repro.serve.policy import SpecConfig
    cfg, api, params, anchor = setup
    eng = _engine(api, anchor, params, prefill_chunk=CHUNK,
                  scheduler="mixed", max_len=64,
                  speculative=SpecConfig(draft_fmt="mxint4", k=4))
    eng.generate(_reqs(cfg, 3, plens=(30, 8, 8), seed=2),
                 fmt_override="mxint8")
    assert any(t["draft_execs"] for t in eng.tick_trace), "never drafted"
    for t in eng.tick_trace:
        plain = t["execs"] - t["draft_execs"] - t["verify_execs"]
        assert 0 <= plain <= 1, t
        # spec only ever replaces the pure-decode executable: chunk ticks
        # keep the coalesced single-exec shape with no draft burst
        if t["prefill_chunks"]:
            assert t["draft_execs"] == 0 and t["verify_execs"] == 0, t
