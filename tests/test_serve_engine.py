"""Elastic serving engine: anchor -> SS -> serve at multiple precisions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import make_anchor, storage_bytes
from repro.core.qat import QATConfig
from repro.models import get_model
from repro.serve.engine import ElasticEngine, Request
from repro.serve.policy import FormatPolicy

QAT = QATConfig(formats=("mxint4", "mxint8"), anchor="mxint8", block_size=32)


def _engine(arch="smollm-135m", slots=2, max_len=48):
    cfg = get_reduced(arch)
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    anchor = make_anchor(params, QAT)
    eng = ElasticEngine(api, anchor, batch_slots=slots, max_len=max_len,
                        param_template=params)
    return cfg, api, params, eng


def test_generate_batched_requests():
    cfg, api, params, eng = _engine()
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8).astype(
        np.int32), max_new=5) for i in range(4)]
    out = eng.generate(reqs, fmt_override="mxint8")
    for r in out:
        assert len(r.out_tokens) >= 5 or r.done
        assert r.fmt_used == "mxint8"
    assert eng.stats["formats_cached"] == ["mxint8"]


def test_format_switch_via_policy():
    cfg, api, params, eng = _engine()
    eng.policy = FormatPolicy(anchor="mxint8",
                              ladder=((3, "mxint4"), (0, "mxint8")),
                              hysteresis=0)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 6).astype(
        np.int32), max_new=3) for i in range(6)]
    eng.generate(reqs)
    # deep queue at admission -> low precision used at least once
    assert "mxint4" in eng.stats["formats_cached"]


def test_ss_weights_match_direct_ptq():
    """Engine weights at mxint4 == direct quantization path within 1 ulp."""
    from repro.core import dequantize, get_format, quantize, slice_and_scale
    cfg, api, params, eng = _engine()
    w4 = eng.weights_for("mxint4")
    # pick one quantized leaf and compare against hand conversion
    w = params["blocks"][0]["attn"]["wq"][0]          # (d, H*hd)
    t8 = quantize(w, get_format("mxint8", 32), axis=0)
    t4 = slice_and_scale(t8, get_format("mxint4", 32))
    want = dequantize(t4, dtype=jnp.float32)
    got = w4["blocks"][0]["attn"]["wq"][0].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=0)


def test_greedy_output_consistency_high_precision():
    """mxint8-served greedy tokens ≈ fp-served greedy tokens (most match)."""
    cfg, api, params, eng = _engine(max_len=64)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, 12).astype(np.int32)

    r8 = eng.generate([Request(rid=0, prompt=prompt, max_new=8)],
                      fmt_override="mxint8")[0]

    # fp reference: greedy decode with raw params
    cache = api.init_cache(eng.slots, eng.max_len)
    toks = np.zeros((eng.slots, 12), np.int32)
    toks[0] = prompt
    logits, cache, clen = jax.jit(api.prefill)(
        params, {"tokens": jnp.asarray(toks)}, cache)
    fp_tokens = [int(jnp.argmax(logits[0]))]
    cur = jnp.asarray([[fp_tokens[-1]], [0]], jnp.int32)[:eng.slots]
    for _ in range(7):
        logits, cache = jax.jit(api.serve_step)(params, {"tokens": cur},
                                                cache, clen)
        clen = clen + 1
        nxt = int(jnp.argmax(logits[0]))
        fp_tokens.append(nxt)
        cur = cur.at[0, 0].set(nxt)
    agree = sum(a == b for a, b in zip(r8.out_tokens, fp_tokens))
    assert agree >= 5, (r8.out_tokens, fp_tokens)


def test_policy_ladder_and_hysteresis():
    p = FormatPolicy(anchor="mxint8",
                     ladder=((32, "mxint4"), (8, "mxint6"), (0, "mxint8")),
                     hysteresis=2)
    assert p.pick(0) == "mxint8"
    assert p.pick(10) == "mxint8"      # hysteresis holds once
    assert p.pick(10) == "mxint6"      # then switches
    assert p.pick(100) == "mxint6"
    assert p.pick(100) == "mxint4"


def test_anchor_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint.anchor_ckpt import load_anchor, save_anchor
    cfg, api, params, eng = _engine()
    path = str(tmp_path / "anchor_ck")
    nbytes = save_anchor(path, eng.anchor)
    loaded = load_anchor(path)
    assert loaded.fmt_name == eng.anchor.fmt_name
    for k in eng.anchor.quantized:
        np.testing.assert_array_equal(
            np.asarray(loaded.quantized[k].codes),
            np.asarray(eng.anchor.quantized[k].codes))
        np.testing.assert_array_equal(
            np.asarray(loaded.quantized[k].scale_exp),
            np.asarray(eng.anchor.quantized[k].scale_exp))
    # true storage saving vs f32
    f32 = sum(x.size * 4 for x in jax.tree_util.tree_leaves(params))
    assert nbytes < f32 * 0.75


def test_anchor_int4_checkpoint_half_of_int8(tmp_path):
    """Packed MXINT4 checkpoint ≈ half the bytes of MXINT8 (elastic tiers)."""
    from repro.checkpoint.anchor_ckpt import save_anchor
    from repro.core import convert, get_format
    cfg, api, params, eng = _engine()
    n8 = save_anchor(str(tmp_path / "a8"), eng.anchor)
    a4 = convert(eng.anchor, get_format("mxint4", 32))
    n4 = save_anchor(str(tmp_path / "a4"), a4)
    q_frac = sum(t.codes.size for t in eng.anchor.quantized.values())
    assert n4 < n8  # strictly smaller; ratio depends on raw-leaf fraction
