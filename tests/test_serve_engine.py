"""Elastic serving engine: packed-weight continuous batching, slot-level
admission, batch-pinned formats, packed-vs-dense equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import make_anchor, storage_bytes
from repro.core.qat import QATConfig
from repro.models import get_model
from repro.serve.engine import ElasticEngine, Request
from repro.serve.policy import FormatPolicy

QAT = QATConfig(formats=("mxint4", "mxint8"), anchor="mxint8", block_size=32)


def _engine(arch="smollm-135m", slots=2, max_len=48, **kw):
    cfg = get_reduced(arch)
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    anchor = make_anchor(params, QAT)
    eng = ElasticEngine(api, anchor, batch_slots=slots, max_len=max_len,
                        param_template=params, **kw)
    return cfg, api, params, eng


def _reqs(cfg, n, max_new=5, plen=8, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab, plen)
                    .astype(np.int32), max_new=max_new) for i in range(n)]


def test_generate_batched_requests():
    cfg, api, params, eng = _engine()
    out = eng.generate(_reqs(cfg, 4), fmt_override="mxint8")
    for r in out:
        assert len(r.out_tokens) >= 5 or r.done
        assert r.fmt_used == "mxint8"
    assert eng.stats["formats_cached"] == ["mxint8"]


@pytest.mark.slow
def test_stats_report_packed_containers():
    """The serving tree really is packed: MXTensor at 8 bits, nibble-packed
    PackedInt4Leaf at 4 bits, and the byte footprint orders 4 < 8 < bf16."""
    cfg, api, params, eng = _engine()
    eng.generate(_reqs(cfg, 1, max_new=2), fmt_override="mxint8")
    eng.generate(_reqs(cfg, 1, max_new=2), fmt_override="mxint4")
    eng.generate(_reqs(cfg, 1, max_new=2), fmt_override="bf16")
    st = eng.stats
    assert st["containers"]["mxint8"] == ["MXTensor"]
    assert st["containers"]["mxint4"] == ["PackedInt4Leaf"]
    assert st["containers"]["bf16"] == ["dense"]
    wb = st["weight_bytes"]
    assert wb["mxint4"] < wb["mxint8"] < wb["bf16"]


@pytest.mark.parametrize("fmt", ["mxint8", "mxint4"])
def test_packed_matches_dense_token_for_token(fmt):
    """Densify-inside-jit serves the same codes as the eager dense path:
    greedy token streams agree exactly at mxint8 and mxint4."""
    streams = {}
    for packed in (True, False):
        cfg, api, params, eng = _engine(packed=packed)
        reqs = _reqs(cfg, 3, max_new=6, seed=7)
        eng.generate(reqs, fmt_override=fmt)
        streams[packed] = [r.out_tokens for r in reqs]
    assert streams[True] == streams[False]


@pytest.mark.parametrize("fmt", ["mxint8", "mxint4"])
def test_fused_kernel_serving_matches_densify(fmt):
    """The tentpole contract: serving through the Pallas dequant-GEMM
    dispatch (interpret off TPU) produces the same greedy tokens as the
    densify-inside-jit path, and the fused kernels are actually live."""
    from repro.kernels import dispatch
    streams = {}
    for fused in (True, False):
        cfg, api, params, eng = _engine(fused=fused)
        if fused:
            dispatch.reset_stats()
        reqs = _reqs(cfg, 3, max_new=5, seed=7)
        eng.generate(reqs, fmt_override=fmt)
        if fused:
            st = dispatch.stats()
            hits = st["pallas_int4" if fmt == "mxint4" else "pallas"]
            assert hits > 0, f"fused engine never hit the kernel: {st}"
        streams[fused] = [r.out_tokens for r in reqs]
    assert streams[True] == streams[False]


@pytest.mark.slow
def test_sampling_per_slot_streams_and_determinism():
    """Regression for the correlated-sampling bug: two identical prompts
    admitted in one wave must draw from independent per-slot streams (the
    old engine fed every slot jax.random.PRNGKey(ticks)), while the same
    (seed, rid) always reproduces the same stream."""
    def run(seed):
        cfg, api, params, eng = _engine(seed=seed, temperature=1.0,
                                        top_p=0.95)
        prompt = (np.arange(8) % cfg.vocab).astype(np.int32)
        reqs = [Request(rid=r, prompt=prompt.copy(), max_new=6)
                for r in (0, 1)]
        eng.generate(reqs, greedy=False, fmt_override="mxint8")
        return [r.out_tokens for r in reqs]

    a, b, c = run(0), run(0), run(5)
    assert a[0] != a[1]          # same prompt, different slots/rids
    assert a == b                # reproducible from (seed, rid)
    assert a != c                # engine seed matters


@pytest.mark.slow
def test_per_request_sampling_params_bit_identical_to_solo():
    """Per-request temperature/top_p (serve/slo.py PR): three requests
    with different sampling params share one batch wave, and each stream
    is bit-identical to the same request served ALONE — the per-slot
    sampling lanes feed the vmapped sampler without coupling rows, and
    the engine-level values remain the defaults for requests that carry
    none."""
    def solo(req):
        cfg, api, params, eng = _engine(temperature=0.9, top_p=0.85)
        eng.generate([req], greedy=False, fmt_override="mxint8")
        return req.out_tokens

    def fresh_reqs(cfg):
        prompt = (np.arange(8) % cfg.vocab).astype(np.int32)
        return [
            Request(rid=0, prompt=prompt.copy(), max_new=6,
                    temperature=0.7, top_p=0.95),
            Request(rid=1, prompt=prompt.copy(), max_new=6,
                    temperature=1.3),            # engine top_p applies
            Request(rid=2, prompt=prompt.copy(), max_new=6),  # defaults
        ]

    cfg, api, params, eng = _engine(temperature=0.9, top_p=0.85)
    batch = fresh_reqs(cfg)
    eng.generate(list(batch), greedy=False, fmt_override="mxint8")
    for ref, want in zip(fresh_reqs(cfg), batch):
        assert solo(ref) == want.out_tokens, want.rid


@pytest.mark.slow
def test_top_p_collapse_equals_greedy():
    """top_p -> 0 keeps only the argmax token: sampled == greedy stream
    (checks the nucleus mask keeps exactly the top-1 prefix)."""
    cfg, api, params, eng = _engine(temperature=1.0, top_p=1e-6)
    reqs = _reqs(cfg, 2, max_new=5, seed=11)
    eng.generate(reqs, greedy=False, fmt_override="mxint8")
    sampled = [r.out_tokens for r in reqs]

    cfg2, api2, params2, eng2 = _engine()
    reqs2 = _reqs(cfg2, 2, max_new=5, seed=11)
    eng2.generate(reqs2, greedy=True, fmt_override="mxint8")
    assert sampled == [r.out_tokens for r in reqs2]


@pytest.mark.slow
def test_prefill_length_bucketing_caps_compiles():
    """Mixed prompt lengths within one power-of-two bucket share a single
    prefill executable, and exact masking keeps greedy tokens identical to
    the unbucketed run."""
    cfg, api, params, eng = _engine()
    prompts = [_reqs(cfg, 1, plen=9 + i, seed=20 + i)[0].prompt
               for i in range(4)]                       # lens 9..12 -> 16
    reqs = [Request(rid=i, prompt=p, max_new=4)
            for i, p in enumerate(prompts)]
    eng.generate(reqs, fmt_override="mxint8")
    assert eng.stats["prefill_traces"] == 1

    cfg2, api2, params2, eng2 = _engine(bucket_prompts=False)
    reqs2 = [Request(rid=i, prompt=p.copy(), max_new=4)
             for i, p in enumerate(prompts)]
    eng2.generate(reqs2, fmt_override="mxint8")
    assert eng2.stats["prefill_traces"] == len(prompts)
    assert [r.out_tokens for r in reqs] == [r.out_tokens for r in reqs2]


@pytest.mark.slow
def test_staggered_arrivals_finish_independently():
    """Requests with different lengths retire per slot; a later arrival is
    admitted into the freed slot WITHOUT re-prefilling the active one (the
    long request's token stream is identical to a solo run)."""
    cfg, api, params, eng_solo = _engine()
    solo = Request(rid=0, prompt=_reqs(cfg, 1, seed=3)[0].prompt, max_new=10)
    eng_solo.generate([solo], fmt_override="mxint8")

    cfg2, api2, params2, eng = _engine()
    prompts = _reqs(cfg2, 3, seed=3)
    lens = [10, 3, 4]
    reqs = [Request(rid=i, prompt=prompts[i].prompt, max_new=lens[i])
            for i in range(3)]
    eng.generate(reqs, fmt_override="mxint8")     # slots=2: rid2 waits
    assert [len(r.out_tokens) for r in reqs] == lens
    assert all(r.done for r in reqs)
    assert reqs[0].out_tokens == solo.out_tokens


@pytest.mark.slow
def test_format_pinned_for_batch_lifetime():
    """Regression: the policy may want to switch formats as the queue drains,
    but numerics never change mid-sequence — every request admitted while the
    batch is live shares one pinned format, and the policy is consulted once
    per drained->busy transition."""
    cfg, api, params, eng = _engine()
    eng.policy = FormatPolicy(anchor="mxint8",
                              ladder=((4, "mxint4"), (0, "mxint8")),
                              hysteresis=0)
    reqs = _reqs(cfg, 6, max_new=4)
    # staggered lengths: some slot stays busy until the queue is empty, so
    # this is ONE batch even though the queue drains below the ladder step
    for i, r in enumerate(reqs):
        r.max_new = [9, 3, 4, 5, 6, 7][i]
    eng.generate(reqs)                 # queue=6 at pick time -> mxint4
    assert {r.fmt_used for r in reqs} == {"mxint4"}
    assert eng.policy.history == ["mxint4"]        # one pick per wave
    assert eng.stats["formats_cached"] == ["mxint4"]

    late = _reqs(cfg, 1, max_new=3, seed=9)
    eng.generate(late)                 # fresh wave, queue=1 -> mxint8
    assert late[0].fmt_used == "mxint8"


@pytest.mark.slow
def test_format_switch_via_policy():
    cfg, api, params, eng = _engine()
    eng.policy = FormatPolicy(anchor="mxint8",
                              ladder=((3, "mxint4"), (0, "mxint8")),
                              hysteresis=0)
    eng.generate(_reqs(cfg, 6, max_new=3, plen=6, seed=1))
    # deep queue at admission -> low precision used at least once
    assert "mxint4" in eng.stats["formats_cached"]


def test_ss_weights_match_direct_ptq():
    """Engine dense view at mxint4 == direct SS conversion, bit-exact; the
    packed tree densifies to the same values (same codes)."""
    from repro.core import dequantize, get_format, quantize, slice_and_scale
    from repro.serve.packed_params import densify_params
    cfg, api, params, eng = _engine()
    w4_dense = eng.dense_weights_for("mxint4")
    w = params["blocks"][0]["attn"]["wq"][0]          # (d, H*hd)
    t8 = quantize(w, get_format("mxint8", 32), axis=0)
    t4 = slice_and_scale(t8, get_format("mxint4", 32))
    want = dequantize(t4, dtype=jnp.float32)
    got = w4_dense["blocks"][0]["attn"]["wq"][0].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=0)
    w4_packed = densify_params(eng.weights_for("mxint4"), 32, jnp.float32)
    got_p = w4_packed["blocks"][0]["attn"]["wq"][0]
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(want),
                               rtol=0, atol=0)


@pytest.mark.slow
def test_greedy_output_consistency_high_precision():
    """mxint8-served greedy tokens ≈ fp-served greedy tokens (most match)."""
    cfg, api, params, eng = _engine(max_len=64)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, 12).astype(np.int32)

    r8 = eng.generate([Request(rid=0, prompt=prompt, max_new=8)],
                      fmt_override="mxint8")[0]

    # fp reference: greedy decode with raw params
    cache = api.init_cache(eng.slots, eng.max_len)
    toks = np.zeros((eng.slots, 12), np.int32)
    toks[0] = prompt
    logits, cache, clen = jax.jit(api.prefill)(
        params, {"tokens": jnp.asarray(toks)}, cache)
    fp_tokens = [int(jnp.argmax(logits[0]))]
    cur = jnp.asarray([[fp_tokens[-1]], [0]], jnp.int32)[:eng.slots]
    for _ in range(7):
        logits, cache = jax.jit(api.serve_step)(params, {"tokens": cur},
                                                cache, clen)
        clen = clen + 1
        nxt = int(jnp.argmax(logits[0]))
        fp_tokens.append(nxt)
        cur = cur.at[0, 0].set(nxt)
    agree = sum(a == b for a, b in zip(r8.out_tokens, fp_tokens))
    assert agree >= 5, (r8.out_tokens, fp_tokens)


def test_prefill_slot_leaves_other_slots_alone():
    """ModelApi.prefill_slot writes exactly one slot of the batched cache."""
    cfg = get_reduced("smollm-135m")
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    cache = api.init_cache(2, 32)
    toks = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab,
                                                         (1, 8)), jnp.int32)
    _, c1, l1 = jax.jit(api.prefill_slot)(params, {"tokens": toks}, cache, 0)
    assert int(l1) == 8
    for a, b in zip(jax.tree_util.tree_leaves(c1),
                    jax.tree_util.tree_leaves(cache)):
        # slot 1 (batch axis 1) untouched
        np.testing.assert_array_equal(np.asarray(a[:, 1]),
                                      np.asarray(b[:, 1]))
    assert any(np.abs(np.asarray(a[:, 0])).sum() > 0
               for a in jax.tree_util.tree_leaves(c1))


def test_policy_ladder_and_hysteresis():
    p = FormatPolicy(anchor="mxint8",
                     ladder=((32, "mxint4"), (8, "mxint6"), (0, "mxint8")),
                     hysteresis=2)
    assert p.pick(0) == "mxint8"
    assert p.pick(10) == "mxint8"      # hysteresis holds once
    assert p.pick(10) == "mxint6"
    assert p.pick(100) == "mxint6"
    assert p.pick(100) == "mxint4"


def test_anchor_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint.anchor_ckpt import load_anchor, save_anchor
    cfg, api, params, eng = _engine()
    path = str(tmp_path / "anchor_ck")
    nbytes = save_anchor(path, eng.anchor)
    loaded = load_anchor(path)
    assert loaded.fmt_name == eng.anchor.fmt_name
    for k in eng.anchor.quantized:
        np.testing.assert_array_equal(
            np.asarray(loaded.quantized[k].codes),
            np.asarray(eng.anchor.quantized[k].codes))
        np.testing.assert_array_equal(
            np.asarray(loaded.quantized[k].scale_exp),
            np.asarray(eng.anchor.quantized[k].scale_exp))
    # true storage saving vs f32
    f32 = sum(x.size * 4 for x in jax.tree_util.tree_leaves(params))
    assert nbytes < f32 * 0.75


def test_anchor_int4_checkpoint_half_of_int8(tmp_path):
    """Packed MXINT4 checkpoint ≈ half the bytes of MXINT8 (elastic tiers)."""
    from repro.checkpoint.anchor_ckpt import save_anchor
    from repro.core import convert, get_format
    cfg, api, params, eng = _engine()
    n8 = save_anchor(str(tmp_path / "a8"), eng.anchor)
    a4 = convert(eng.anchor, get_format("mxint4", 32))
    n4 = save_anchor(str(tmp_path / "a4"), a4)
    q_frac = sum(t.codes.size for t in eng.anchor.quantized.values())
    assert n4 < n8  # strictly smaller; ratio depends on raw-leaf fraction
