"""Fault-tolerance primitives (runtime/fault.py): PreemptionGuard flagging,
the Watchdog's cross-thread re-raise contract, StragglerMonitor flagging,
and the FaultInjector's fire-once / fire-per-attempt semantics.

The Watchdog tests pin the daemon-thread bug fix: the default timeout
callback runs on the WATCHDOG's thread, where a raise would kill only that
thread and the timeout would be silently swallowed. The contract is that
the recorded TimeoutError re-raises from the next ``heartbeat()`` (or from
``stop()``) on the caller's thread — where it can actually abort the
watched loop.
"""
import time

import pytest

from repro.runtime.fault import (FaultInjector, InjectedFault,
                                 PreemptionGuard, StragglerMonitor, Watchdog,
                                 random_plan)


# ---- PreemptionGuard -------------------------------------------------------
def test_guard_starts_clear_and_latches():
    g = PreemptionGuard()
    assert not g.preempted
    g.trigger()
    assert g.preempted
    g.trigger()                      # idempotent
    assert g.preempted


def test_guard_context_restores_handlers():
    import signal
    prev = signal.getsignal(signal.SIGTERM)
    with PreemptionGuard() as g:
        assert signal.getsignal(signal.SIGTERM) != prev
        assert not g.preempted
    assert signal.getsignal(signal.SIGTERM) == prev


# ---- Watchdog --------------------------------------------------------------
def test_watchdog_timeout_reraises_on_callers_thread():
    """The daemon thread records; heartbeat() raises HERE — the pre-fix
    behavior raised on the watchdog thread and the caller never saw it."""
    wd = Watchdog(timeout_s=0.05).start()
    deadline = time.monotonic() + 2.0
    while not wd.fired and time.monotonic() < deadline:
        time.sleep(0.01)             # hang without heartbeating
    assert wd.fired
    with pytest.raises(TimeoutError, match="heartbeat"):
        wd.heartbeat()
    # one-shot: the recorded exception is consumed by the re-raise
    wd.heartbeat()
    wd.stop()


def test_watchdog_stop_reraises_pending_timeout():
    """A loop that ends without another heartbeat still sees the timeout:
    stop() is the last re-raise point."""
    wd = Watchdog(timeout_s=0.05).start()
    deadline = time.monotonic() + 2.0
    while not wd.fired and time.monotonic() < deadline:
        time.sleep(0.01)
    with pytest.raises(TimeoutError):
        wd.stop()


def test_watchdog_healthy_loop_never_raises():
    wd = Watchdog(timeout_s=0.5).start()
    for _ in range(5):
        time.sleep(0.02)
        wd.heartbeat()
    wd.stop()
    assert not wd.fired


def test_watchdog_custom_callback_fires_off_thread():
    hits = []
    wd = Watchdog(timeout_s=0.05, on_timeout=lambda: hits.append(1)).start()
    deadline = time.monotonic() + 2.0
    while not wd.fired and time.monotonic() < deadline:
        time.sleep(0.01)
    wd.stop()                        # custom callback: nothing to re-raise
    assert hits == [1]


# ---- StragglerMonitor ------------------------------------------------------
def test_straggler_flagged_only_after_window_fills():
    mon = StragglerMonitor(window=50, threshold=2.0)
    for s in range(8):
        assert not mon.record(s, 0.1)      # warmup: never flags
    assert mon.record(8, 0.5)              # 5x median
    assert not mon.record(9, 0.15)
    assert mon.events[0]["action"] == "flag-host-for-reschedule"


# ---- FaultInjector ---------------------------------------------------------
def test_alloc_fault_fires_once_per_index():
    fi = FaultInjector(fail_allocs=(3,))
    fi.on_alloc(2)                         # not listed: no-op
    with pytest.raises(InjectedFault):
        fi.on_alloc(3)
    fi.on_alloc(3)                         # fired: subsequent calls clean
    assert [e["kind"] for e in fi.events] == ["fail_alloc"]


def test_step_fault_fires_once_per_tick():
    fi = FaultInjector(raise_in_step=(5,))
    fi.maybe_raise_step(4)
    with pytest.raises(InjectedFault):
        fi.maybe_raise_step(5)
    fi.maybe_raise_step(5)                 # the replay attempt runs clean


def test_transient_logit_poison_fires_once():
    import jax.numpy as jnp
    fi = FaultInjector(poison_logits={1: 0})
    lg = jnp.zeros((2, 4))
    out = fi.maybe_poison_logits(1, "mxint8", lg)
    assert bool(jnp.isnan(out[0]).all()) and bool(jnp.isfinite(out[1]).all())
    again = fi.maybe_poison_logits(1, "mxint8", lg)   # replay: clean
    assert bool(jnp.isfinite(again).all())


def test_fmt_scoped_poison_follows_the_format():
    """The "bad rung" model: the poison re-fires on every attempt still at
    a listed format, and clears only once escalation leaves it behind."""
    import jax.numpy as jnp
    fi = FaultInjector(poison_logits={1: None}, poison_fmt="mxint4")
    lg = jnp.zeros((2, 4))
    assert bool(jnp.isnan(fi.maybe_poison_logits(1, "mxint4", lg)).all())
    assert bool(jnp.isnan(fi.maybe_poison_logits(1, "mxint4", lg)).all())
    assert bool(jnp.isfinite(fi.maybe_poison_logits(1, "mxint6", lg)).all())


def test_cancel_preempt_and_pool_primitives():
    fi = FaultInjector(cancel_at={2: 7}, preempt_at=3, poison_pool={4: 1})
    assert fi.cancel_rid(1) is None
    assert fi.cancel_rid(2) == 7
    assert fi.cancel_rid(2) is None        # fire-once
    g = PreemptionGuard()
    fi.maybe_preempt(2, g)
    assert not g.preempted
    fi.maybe_preempt(3, g)
    assert g.preempted
    assert fi.pool_poison_page(4) == 1
    assert fi.pool_poison_page(4) is None


def test_random_plan_is_reproducible_and_rate_scaled():
    a = random_plan(seed=9, rate=0.3, horizon=100, slots=4)
    b = random_plan(seed=9, rate=0.3, horizon=100, slots=4)
    assert a.poison_logits == b.poison_logits
    assert a.raise_in_step == b.raise_in_step
    assert a.fail_allocs == b.fail_allocs
    n = len(a.poison_logits) + len(a.raise_in_step) + len(a.fail_allocs)
    assert 10 <= n <= 50               # ~30 expected; loose determinism band
    assert random_plan(seed=10, rate=0.3, horizon=100,
                       slots=4).poison_logits != a.poison_logits
