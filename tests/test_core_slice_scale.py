"""Slice-and-Scale correctness: the paper's §3.3/§3.4 equivalence claims."""
try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
except ImportError:      # property tests skip; unit tests below still run
    from _hypothesis_stub import hnp, hypothesis, st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (dequantize, get_format, quantize, slice_and_scale)
from repro.core.slice_scale import _rshift_rne


def _rand(shape, seed=0, scale=1.0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=shape).astype(np.float32) * scale)


@pytest.mark.parametrize("bl", range(2, 8))
def test_ssmxint_scale_exactly_matches_direct(bl):
    """X_l from SS == X_l from direct quantization (§3.3: 'theoretically
    equivalent ... accounting for the difference in e_max(b)')."""
    v = _rand((8, 256), seed=11, scale=5.0)
    hi = quantize(v, get_format("mxint8", 32))
    ss = slice_and_scale(hi, get_format(f"mxint{bl}", 32))
    direct = quantize(v, get_format(f"mxint{bl}", 32))
    np.testing.assert_array_equal(np.asarray(ss.scale_exp),
                                  np.asarray(direct.scale_exp))


@pytest.mark.parametrize("bl", range(2, 8))
def test_ssmxint_codes_within_one_ulp_of_direct(bl):
    """Element codes may differ only by double rounding: |diff| ≤ 1."""
    v = _rand((8, 256), seed=12)
    hi = quantize(v, get_format("mxint8", 32))
    ss = slice_and_scale(hi, get_format(f"mxint{bl}", 32))
    direct = quantize(v, get_format(f"mxint{bl}", 32))
    diff = np.abs(np.asarray(ss.codes, np.int32) -
                  np.asarray(direct.codes, np.int32))
    assert diff.max() <= 1


@pytest.mark.parametrize("bl", [4, 5, 6, 7])
def test_ssmxfp_scale_matches_direct(bl):
    v = _rand((8, 256), seed=13, scale=2.0)
    hi = quantize(v, get_format("mxfp8", 32))
    ss = slice_and_scale(hi, get_format(f"mxfp{bl}", 32))
    direct = quantize(v, get_format(f"mxfp{bl}", 32))
    np.testing.assert_array_equal(np.asarray(ss.scale_exp),
                                  np.asarray(direct.scale_exp))


@pytest.mark.parametrize("kind,bh,bl", [("int", 8, 4), ("int", 6, 2),
                                        ("fp", 8, 4), ("fp", 6, 4),
                                        ("fp", 8, 6)])
def test_ss_mse_close_to_direct(kind, bh, bl):
    """App. C claim: SS MSE ≈ direct-quantization MSE."""
    v = _rand((100, 1024), seed=14)
    hi = quantize(v, get_format(f"mx{kind}{bh}", 64))
    ss_v = dequantize(slice_and_scale(hi, get_format(f"mx{kind}{bl}", 64)))
    dr_v = dequantize(quantize(v, get_format(f"mx{kind}{bl}", 64)))
    mse_ss = float(jnp.mean((v - ss_v) ** 2))
    mse_dr = float(jnp.mean((v - dr_v) ** 2))
    # Paper App. C: "SSMXFP exhibits a modestly larger relative gap at
    # intermediate bitwidths" — double rounding costs ≤ ~2x in MSE, tiny abs.
    assert mse_ss <= mse_dr * 2.0 + 1e-9


def test_ss_identity():
    v = _rand((4, 64), seed=15)
    hi = quantize(v, get_format("mxint8", 32))
    same = slice_and_scale(hi, get_format("mxint8", 32))
    np.testing.assert_array_equal(np.asarray(same.codes), np.asarray(hi.codes))


def test_ss_chain_composes():
    """8→6→4 equals 8→4 in scale; codes within 1 (associativity of shifts
    up to double rounding)."""
    v = _rand((8, 256), seed=16)
    hi = quantize(v, get_format("mxint8", 32))
    via6 = slice_and_scale(slice_and_scale(hi, get_format("mxint6", 32)),
                           get_format("mxint4", 32))
    direct4 = slice_and_scale(hi, get_format("mxint4", 32))
    np.testing.assert_array_equal(np.asarray(via6.scale_exp),
                                  np.asarray(direct4.scale_exp))
    diff = np.abs(np.asarray(via6.codes, np.int32) -
                  np.asarray(direct4.codes, np.int32))
    assert diff.max() <= 1


# ---------------------------------------------------------------------------
# Integer round-to-nearest-even shift: exhaustive + property
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("de", [1, 2, 3, 4, 5, 6])
def test_rshift_rne_exhaustive_int8(de):
    p = jnp.arange(-128, 128, dtype=jnp.int32)
    got = np.asarray(_rshift_rne(p, de))
    want = np.asarray(jnp.round(p.astype(jnp.float64) / (1 << de))).astype(np.int64)
    np.testing.assert_array_equal(got, want)


@hypothesis.given(
    codes=hnp.arrays(np.int32, (64,), elements=st.integers(-127, 127)),
    de=st.integers(0, 6),
)
@hypothesis.settings(deadline=None, max_examples=60)
def test_prop_rshift_rne_matches_float_round(codes, de):
    got = np.asarray(_rshift_rne(jnp.asarray(codes), de))
    want = np.round(codes / float(1 << de)).astype(np.int64)  # numpy RNE
    np.testing.assert_array_equal(got, want)


@hypothesis.given(
    arr=hnp.arrays(np.float32, (2, 64),
                   elements=st.floats(-1e3, 1e3, width=32,
                                      allow_nan=False, allow_infinity=False)),
    bl=st.integers(2, 7),
)
@hypothesis.settings(deadline=None, max_examples=40)
def test_prop_ss_reconstruction_bounded(arr, bl):
    """SS reconstruction error ≤ direct error + 1 target quantum per element."""
    v = jnp.asarray(arr)
    lo = get_format(f"mxint{bl}", 32)
    hi = quantize(v, get_format("mxint8", 32))
    ss_v = np.asarray(dequantize(slice_and_scale(hi, lo)), np.float64)
    dr = quantize(v, lo)
    dr_v = np.asarray(dequantize(dr), np.float64)
    quantum = np.exp2(np.asarray(dr.scale_exp, np.float64))
    quantum = np.repeat(quantum.reshape(2, 2), 32, -1).reshape(2, 64)
    assert np.all(np.abs(ss_v - dr_v) <= quantum + 1e-30)
