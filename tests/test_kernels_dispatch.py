"""qmatmul dispatch layer: fused Pallas (interpret) vs the dense reference.

The serving contract under test: packed weight leaves (MXTensor, split-N
PackedInt4Leaf) go straight into the fused dequant-GEMM with shape padding,
and the result matches x @ dequantize(leaf) within fp32 tolerance for every
serving format — including split-N int4 whose half_n doesn't divide the
tile size.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import get_format
from repro.core.mx import dequantize, quantize
from repro.kernels import dispatch
from repro.serve.packed_params import PackedInt4Leaf, pack_leaf_int4

FORMATS = ["mxint8", "mxfp8", "mxint6", "mxint4"]
# (M, K, N): deliberately tile-hostile — M < 8, N not a multiple of the
# lane tile, K needing padding to the tk multiple.
SHAPES = [(3, 96, 80), (8, 128, 130), (16, 64, 256), (5, 160, 48)]


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape)
                       .astype(np.float32))


def _leaf(w, fmt):
    t = quantize(w, fmt, axis=0)
    if fmt.kind == "int" and fmt.bits == 4:
        return t, pack_leaf_int4(t)
    return t, t


@pytest.mark.parametrize("name", FORMATS)
@pytest.mark.parametrize("mnk", SHAPES)
def test_qmatmul_pallas_matches_dense_reference(name, mnk):
    m, k, n = mnk
    fmt = get_format(name, 32)
    x = _rand((m, k), seed=1)
    w = _rand((k, n), seed=2)
    t, leaf = _leaf(w, fmt)
    want = np.asarray(x @ dequantize(t, jnp.float32))
    got = np.asarray(dispatch.qmatmul(x, leaf, mode="pallas"))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("name", ["mxint8", "mxint4"])
def test_qmatmul_densify_matches_pallas(name):
    fmt = get_format(name, 32)
    x = _rand((4, 64), seed=3)
    w = _rand((64, 96), seed=4)
    t, leaf = _leaf(w, fmt)
    a = np.asarray(dispatch.qmatmul(x, leaf, mode="pallas"))
    b = np.asarray(dispatch.qmatmul(x, leaf, mode="densify"))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", [130, 258, 82])
def test_qmatmul_int4_splitn_padded_n_regression(n):
    """The raw int4 kernel requires half_n % tn == 0; the dispatch wrapper
    pads both nibble halves and re-splices the output, so odd / non-tile
    half widths (65, 129, 41) must come out exact."""
    fmt = get_format("mxint4", 32)
    k = 64
    x = _rand((6, k), seed=5)
    w = _rand((k, n), seed=6)
    t = quantize(w, fmt, axis=0)
    leaf = pack_leaf_int4(t)
    assert leaf.layout == "splitn"
    assert leaf.packed.shape == (k, n // 2)
    want = np.asarray(x @ dequantize(t, jnp.float32))
    got = np.asarray(dispatch.qmatmul(x, leaf, mode="pallas"))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_qmatmul_batched_x_and_dtype():
    """x may carry leading dims (B, S, K) and a non-f32 dtype; output shape
    and dtype follow x."""
    fmt = get_format("mxint8", 32)
    x = _rand((2, 3, 64), seed=7).astype(jnp.bfloat16)
    w = _rand((64, 48), seed=8)
    t, leaf = _leaf(w, fmt)
    got = dispatch.qmatmul(x, leaf, mode="pallas")
    assert got.shape == (2, 3, 48) and got.dtype == jnp.bfloat16
    want = x.astype(jnp.float32).reshape(-1, 64) @ dequantize(t, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(got, np.float32).reshape(-1, 48), np.asarray(want),
        rtol=2e-2, atol=2e-2)


def test_qmatmul_legacy_splitk_leaf_falls_back():
    """Split-K nibble layout has no fused kernel: pallas mode must silently
    densify (and stay correct) rather than feed the kernel a wrong layout."""
    fmt = get_format("mxint4", 32)
    x = _rand((4, 64), seed=9)
    w = _rand((64, 96), seed=10)
    t = quantize(w, fmt, axis=0)
    leaf = pack_leaf_int4(t, layout="splitk")
    want = np.asarray(x @ dequantize(t, jnp.float32))
    dispatch.reset_stats()
    got = np.asarray(dispatch.qmatmul(x, leaf, mode="pallas"))
    st = dispatch.stats()
    assert st["densify"] == 1 and st["pallas_int4"] == 0
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_dispatch_counters_see_pallas_hits():
    """The CI smoke contract: pallas mode increments the kernel counters
    (this is what keeps the dispatch from silently regressing to the
    fallback)."""
    fmt8 = get_format("mxint8", 32)
    fmt4 = get_format("mxint4", 32)
    x = _rand((4, 64), seed=11)
    w = _rand((64, 64), seed=12)
    _, leaf8 = _leaf(w, fmt8)
    _, leaf4 = _leaf(w, fmt4)
    dispatch.reset_stats()
    dispatch.qmatmul(x, leaf8, mode="pallas")
    dispatch.qmatmul(x, leaf4, mode="pallas")
    dispatch.qmatmul(x, leaf8, mode="densify")
    st = dispatch.stats()
    assert st["pallas"] == 1 and st["pallas_int4"] == 1 \
        and st["densify"] == 1


def test_tile_registration_overrides_table():
    fmt = get_format("mxint8", 32)
    base = dispatch.select_tiles(7, 64, 96, fmt)
    dispatch.register_tiles(7, 64, 96, "mxint8", (8, 48, 32))
    try:
        assert dispatch.select_tiles(7, 64, 96, fmt) == (8, 48, 32)
        # registered tiles actually run (and stay correct)
        x = _rand((7, 64), seed=13)
        w = _rand((64, 96), seed=14)
        t, leaf = _leaf(w, fmt)
        got = dispatch.qmatmul(x, leaf, mode="pallas")
        want = x @ dequantize(t, jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)
    finally:
        dispatch._TILE_CACHE.pop((7, 64, 96, 32, "mxint8", "mx"), None)
    assert dispatch.select_tiles(7, 64, 96, fmt) == base


def test_tile_cache_keys_on_block_size():
    """Regression (tensor-parallel serving PR): the tile cache must key on
    block_size. An entry tuned at bs=64 (tk=64) applied to a bs=96 call
    with the same (M, K, N) gives a tk that doesn't divide the scale
    blocking — ``kp // bs`` truncates and the kernel reads wrong scales —
    so cross-block-size hits must be misses."""
    fmt64 = get_format("mxint8", 64)
    fmt32 = get_format("mxint8", 32)
    dispatch.register_tiles(7, 192, 96, "mxint8", (8, 48, 64),
                            block_size=64)
    try:
        assert dispatch.select_tiles(7, 192, 96, fmt64) == (8, 48, 64)
        # same (m, k, n), different block size: the bs=64 entry must NOT
        # apply — the key includes block_size, so the bs=32 lookup falls
        # back to the heuristic, whose tk always divides its own blocking.
        t32 = dispatch.select_tiles(7, 192, 96, fmt32)
        assert t32 != (8, 48, 64)
        assert t32[2] % 32 == 0
    finally:
        dispatch._TILE_CACHE.pop((7, 192, 96, 64, "mxint8", "mx"), None)


def test_tile_cache_local_shard_shapes_hit_globals_miss():
    """Regression (tensor-parallel serving PR): under shard_map the kernel
    traces with per-shard LOCAL shapes. An entry registered at the local
    shape must hit; the global-shape entry must miss (heuristic fallback)
    rather than hand the shard tiles that don't divide it."""
    fmt = get_format("mxint8", 32)
    n_global, tp = 256, 2
    n_local = n_global // tp
    # global-shape registration with tiles that would NOT divide the local
    # shard (tn=256 > n_local): must not leak into the local-shape lookup
    dispatch.register_tiles(8, 64, n_global, "mxint8", (8, 256, 64))
    dispatch.register_tiles(8, 64, n_local, "mxint8", (8, 64, 32))
    try:
        assert dispatch.select_tiles(8, 64, n_local, fmt) == (8, 64, 32)
        assert dispatch.select_tiles(8, 64, n_global, fmt) == (8, 256, 64)
        # the registered local tiles actually run on a local-shaped GEMM
        x = _rand((8, 64), seed=18)
        w = _rand((64, n_local), seed=19)
        t, leaf = _leaf(w, fmt)
        got = dispatch.qmatmul(x, leaf, mode="pallas")
        want = x @ dequantize(t, jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)
    finally:
        dispatch._TILE_CACHE.pop((8, 64, n_global, 32, "mxint8", "mx"), None)
        dispatch._TILE_CACHE.pop((8, 64, n_local, 32, "mxint8", "mx"), None)


def test_tile_cache_ignores_misaligned_entries():
    """A hand-registered entry violating the kernel's alignment rules
    (tm not a sublane multiple / tk not a block-size multiple) is ignored
    — heuristic fallback — never applied to corrupt the scale padding."""
    fmt = get_format("mxint8", 32)
    dispatch.register_tiles(16, 64, 96, "mxint8", (7, 48, 48))  # bad tm+tk
    try:
        tm, tn, tk = dispatch.select_tiles(16, 64, 96, fmt)
        assert (tm, tn, tk) != (7, 48, 48)
        assert tm % 8 == 0 and tk % fmt.block_size == 0
    finally:
        dispatch._TILE_CACHE.pop((16, 64, 96, 32, "mxint8", "mx"), None)


def test_select_tiles_divide_padded_dims():
    for (m, k, n) in [(1, 32, 8), (300, 544, 1000), (8, 96, 130)]:
        for name in ("mxint8", "mxint4"):
            fmt = get_format(name, 32)
            kind = "int4" if name == "mxint4" else "mx"
            tm, tn, tk = dispatch.select_tiles(m, k, n, fmt, kind)
            assert tm % 8 == 0 and tk % fmt.block_size == 0
            n_eff = n // 2 if kind == "int4" else n
            # padding to the tile multiple must stay bounded
            assert -(-m // tm) * tm < m + tm
            assert -(-n_eff // tn) * tn < n_eff + tn
            assert -(-k // tk) * tk < k + tk


def test_mode_resolution():
    assert dispatch.resolve_mode("pallas") == "pallas"
    assert dispatch.resolve_mode("densify") == "densify"
    assert dispatch.resolve_mode(None) in ("pallas", "densify")
    assert dispatch.resolve_mode("auto") == dispatch.default_mode()
    with pytest.raises(ValueError):
        dispatch.resolve_mode("nope")


def test_qmatmul_rejects_wrong_axis_leaf():
    """A non-square MXTensor quantized along the wrong axis (scales
    (K, N/bs) instead of (N, K/bs)) must fail loudly, not return garbage."""
    fmt = get_format("mxint8", 32)
    x = _rand((4, 64), seed=16)
    w = _rand((64, 96), seed=17)
    t_bad = quantize(w, fmt, axis=-1)       # blocks along N: wrong for serving
    with pytest.raises(ValueError, match="serving layout"):
        dispatch.qmatmul(x, t_bad, mode="pallas")
    with pytest.raises(ValueError, match="serving layout"):
        dispatch.qmatmul(x, t_bad, mode="densify")


@pytest.mark.parametrize("bs", [16, 64])
def test_qmatmul_nondefault_block_size(bs):
    """Block sizes ride on the leaves (MXTensor.fmt / PackedInt4Leaf shapes),
    never the registry default — parity must hold at 16 and 64."""
    k, n = 128, 96
    x = _rand((4, k), seed=18)
    w = _rand((k, n), seed=19)
    for name in ("mxint8", "mxint4"):
        fmt = get_format(name, bs)
        t = quantize(w, fmt, axis=0)
        leaf = pack_leaf_int4(t) if name == "mxint4" else t
        want = np.asarray(x @ dequantize(t, jnp.float32))
        for mode in ("pallas", "densify"):
            got = np.asarray(dispatch.qmatmul(x, leaf, mode=mode))
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4,
                                       err_msg=f"{name} bs={bs} {mode}")


def test_splitn_leaf_densify_roundtrip():
    """Split-N packing is lossless: densified leaf == dequantized tensor."""
    from repro.serve.packed_params import unpack_leaf_int4
    fmt = get_format("mxint4", 32)
    w = _rand((64, 130), seed=15)
    t = quantize(w, fmt, axis=0)
    leaf = pack_leaf_int4(t)
    np.testing.assert_array_equal(
        np.asarray(unpack_leaf_int4(leaf, 32, jnp.float32)),
        np.asarray(dequantize(t, jnp.float32)))
