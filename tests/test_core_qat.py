"""QAT machinery: STE gradients, format switches, schedules, packing, anchor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (QATConfig, fake_quant, fake_quant_anchored,
                        fake_quant_anchored_switch, fake_quant_switch,
                        fp_schedule, get_format, interleaved_schedule,
                        make_anchor, materialize, convert, dequantize,
                        quantize, quantize_dequantize, sequential_schedule,
                        single_format_schedule, storage_bytes, ptq_pytree)
from repro.core.packed import (pack_np, unpack_np, pack_int4_jnp,
                               unpack_int4_jnp)


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape)
                       .astype(np.float32))


def test_ste_gradient_is_identity():
    w = _rand((8, 64), 0)
    fmt = get_format("mxint4", 32)
    g = jax.grad(lambda x: jnp.sum(fake_quant(x, fmt) * 3.0))(w)
    np.testing.assert_allclose(np.asarray(g), 3.0)


def test_ste_anchored_gradient_is_identity():
    w = _rand((8, 64), 1)
    g = jax.grad(lambda x: jnp.sum(
        fake_quant_anchored(x, get_format("mxint8", 32),
                            get_format("mxint4", 32))))(w)
    np.testing.assert_allclose(np.asarray(g), 1.0)


def test_switch_matches_static_branches():
    w = _rand((8, 64), 2)
    fmts = tuple(get_format(n, 32) for n in ["mxint2", "mxint4", "mxint8"])
    for i, f in enumerate(fmts):
        got = fake_quant_switch(w, fmts, jnp.int32(i))
        want = quantize_dequantize(w, f, axis=-1)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # idx == len(formats) -> pass-through (FP baseline branch)
    got = fake_quant_switch(w, fmts, jnp.int32(len(fmts)))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(w))


def test_anchored_switch_matches_manual_pipeline():
    w = _rand((8, 64), 3)
    anchor = get_format("mxint8", 32)
    fmts = tuple(get_format(f"mxint{b}", 32) for b in [2, 4, 6])
    for i, f in enumerate(fmts):
        got = fake_quant_anchored_switch(w, anchor, fmts, jnp.int32(i))
        want = fake_quant_anchored(w, anchor, f)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_switch_jits_without_recompile():
    w = _rand((8, 64), 4)
    fmts = tuple(get_format(n, 32) for n in ["mxint2", "mxint4"])
    f = jax.jit(lambda x, i: fake_quant_switch(x, fmts, i))
    f(w, jnp.int32(0))
    n0 = f._cache_size()
    f(w, jnp.int32(1))
    assert f._cache_size() == n0


def test_schedules():
    seq = sequential_schedule(4, 32)
    assert seq.shape == (128,) and seq[0] == 0 and seq[-1] == 3
    assert (np.diff(seq) >= 0).all()     # increasing-bit order (paper §3.2)
    inter = interleaved_schedule(3, 10)
    assert set(inter) == {0, 1, 2}
    assert (np.bincount(inter, minlength=3) >= 3).all()
    fp = fp_schedule(5, 4)
    assert (fp == 4).all()
    sf = single_format_schedule(2, 5)
    assert (sf == 2).all()


def test_qat_config_param_filter():
    cfg = QATConfig(formats=("mxint4",))
    assert cfg.is_quantized_path("['decoder']['layers']['attn']['wq']")
    assert not cfg.is_quantized_path("['embed_tokens']['weight']")
    assert not cfg.is_quantized_path("['lm_head']['w']")
    assert not cfg.is_quantized_path("['layers']['norm']['scale']")
    assert not cfg.is_quantized_path("['mamba']['conv1d']['w']")


def test_qat_apply_skips_vectors_and_excluded():
    cfg = QATConfig(formats=("mxint2",), block_size=32)
    w2d = _rand((64, 32), 5)
    v1d = _rand((64,), 6)
    idx = jnp.int32(0)
    out = cfg.apply(w2d, "['mlp']['w1']", idx)
    assert not np.allclose(np.asarray(out), np.asarray(w2d))
    np.testing.assert_array_equal(
        np.asarray(cfg.apply(v1d, "['mlp']['w1']", idx)), np.asarray(v1d))
    np.testing.assert_array_equal(
        np.asarray(cfg.apply(w2d, "['embed']['w']", idx)), np.asarray(w2d))


# ---------------------------------------------------------------------------
# Packing
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits,lo,hi,signed", [
    (2, -1, 1, True), (3, -3, 3, True), (4, -7, 7, True), (5, -15, 15, True),
    (6, -31, 31, True), (7, -63, 63, True), (8, -127, 127, True),
    (4, 0, 15, False), (8, 0, 255, False),
])
def test_pack_roundtrip(bits, lo, hi, signed):
    rng = np.random.default_rng(bits)
    codes = rng.integers(lo, hi + 1, size=(7, 96)).astype(
        np.int8 if signed else np.uint8)
    buf, shape = pack_np(codes, bits)
    back = unpack_np(buf, bits, shape, signed)
    np.testing.assert_array_equal(back, codes)
    # true compression
    if bits in (2, 4, 6):
        assert buf.nbytes < codes.size


def test_int4_jnp_pack_roundtrip():
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(-7, 8, size=(16, 128)).astype(np.int8))
    packed = pack_int4_jnp(codes)
    assert packed.shape == (16, 64)
    back = unpack_int4_jnp(packed)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


# ---------------------------------------------------------------------------
# Anchor pipeline
# ---------------------------------------------------------------------------
def _tiny_params():
    return {
        "embed": {"weight": _rand((128, 32), 7)},
        "layer0": {"wq": _rand((32, 32), 8), "wo": _rand((32, 32), 9),
                   "norm": {"scale": jnp.ones((32,))}},
        "lm_head": {"w": _rand((32, 128), 10)},
    }


def test_anchor_roundtrip_and_storage():
    params = _tiny_params()
    cfg = QATConfig(formats=("mxint4",), anchor="mxint8", block_size=32)
    am = make_anchor(params, cfg)
    assert set(am.quantized) == {"['layer0']['wq']", "['layer0']['wo']"}
    # anchor materialization ≈ ptq at mxint8
    dense = materialize(am, params, dtype=jnp.float32)
    want = ptq_pytree(params, cfg, get_format("mxint8", 32))
    np.testing.assert_allclose(np.asarray(dense["layer0"]["wq"]),
                               np.asarray(want["layer0"]["wq"]), atol=0)
    # storage: quantized leaves shrink ~4x vs f32 (int8 elems + 1 scale/32)
    q_bytes = sum(t.nbytes_logical for t in am.quantized.values())
    q_f32 = sum(int(np.prod(t.shape)) * 4 for t in am.quantized.values())
    assert q_bytes < q_f32 * 0.27
    f32_bytes = sum(x.size * 4 for x in jax.tree_util.tree_leaves(params))
    assert storage_bytes(am) < f32_bytes


def test_anchor_convert_matches_ss():
    params = _tiny_params()
    cfg = QATConfig(formats=("mxint4",), anchor="mxint8", block_size=32)
    am = make_anchor(params, cfg)
    lo = convert(am, get_format("mxint4", 32))
    assert lo.fmt_name == "mxint4"
    # equals quantize->ss by hand
    hand = quantize(params["layer0"]["wq"], get_format("mxint8", 32), axis=0)
    from repro.core import slice_and_scale
    hand4 = slice_and_scale(hand, get_format("mxint4", 32))
    np.testing.assert_array_equal(
        np.asarray(lo.quantized["['layer0']['wq']"].codes),
        np.asarray(hand4.codes))
