"""End-to-end training: loss decreases, checkpoint/restart is exact,
schedules drive the right formats, fault-tolerance machinery works."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.qat import QATConfig
from repro.data.pipeline import DataConfig, LMDataset
from repro.models import get_model
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, make_schedule, run_training
from repro.train.state import TrainState, build_train_step

QAT = QATConfig(formats=("mxint4", "mxint8"), block_size=32)


def _setup(arch="smollm-135m", n_examples=16, seq=64, batch=4):
    cfg = get_reduced(arch)
    api = get_model(cfg, QAT)
    data = LMDataset(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                global_batch=batch, n_examples=n_examples))
    return cfg, api, data


def test_loss_decreases_multiformat():
    cfg, api, data = _setup()
    out = run_training(api, data, AdamWConfig(lr=3e-3),
                       LoopConfig(total_steps=30, schedule="multiformat"))
    hist = out["history"]
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.3, (first, last)
    # schedule visited both formats in increasing order
    fmts = [h["fmt_idx"] for h in hist]
    assert fmts[0] == 0 and fmts[-1] == 1


def test_checkpoint_restart_is_exact(tmp_path):
    cfg, api, data = _setup()
    ck = str(tmp_path / "ckpt")
    opt = AdamWConfig(lr=1e-3)
    # run 10 steps straight
    full = run_training(api, data, opt,
                        LoopConfig(total_steps=10, schedule="interleaved"))
    # run 6 steps, checkpoint, then resume to 10
    part = run_training(api, data, opt,
                        LoopConfig(total_steps=6, schedule="interleaved",
                                   ckpt_dir=ck, ckpt_every=3))
    resumed = run_training(api, data, opt,
                           LoopConfig(total_steps=10, schedule="interleaved",
                                      ckpt_dir=ck, ckpt_every=100))
    assert resumed["history"][0]["step"] == 6
    a = jax.tree_util.tree_leaves(full["state"].params)
    b = jax.tree_util.tree_leaves(resumed["state"].params)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_preemption_checkpoints_and_stops(tmp_path):
    cfg, api, data = _setup()
    ck = str(tmp_path / "ckpt")
    from repro.runtime.fault import PreemptionGuard

    calls = {}

    def on_step(step, metrics):
        if step == 4:
            # simulate SIGTERM mid-run
            import repro.train.loop as L
            calls["guard"].trigger()

    # patch: intercept the guard the loop creates
    orig_enter = PreemptionGuard.__enter__

    def patched_enter(self):
        calls["guard"] = self
        return orig_enter(self)

    PreemptionGuard.__enter__ = patched_enter
    try:
        out = run_training(api, data, AdamWConfig(),
                           LoopConfig(total_steps=100, ckpt_dir=ck,
                                      ckpt_every=1000),
                           on_step=on_step)
    finally:
        PreemptionGuard.__enter__ = orig_enter
    assert out["preempted"]
    assert out["last_step"] == 5
    from repro.checkpoint import io as ckpt_io
    assert ckpt_io.latest_step(ck) == 5


def test_schedules():
    s = make_schedule("multiformat", 4, 40)
    assert len(s) == 40 and list(np.unique(s)) == [0, 1, 2, 3]
    assert (np.diff(s) >= 0).all()
    s2 = make_schedule("single:2", 4, 10)
    assert (s2 == 2).all()
    s3 = make_schedule("fp", 4, 10)
    assert (s3 == 4).all()


def test_microbatch_grad_accum_matches_full_batch():
    cfg, api, data = _setup(batch=4)
    opt = AdamWConfig(lr=1e-3, grad_clip=None)
    params = api.init_params(jax.random.PRNGKey(0))
    from repro.optim.adamw import init_opt_state
    state = TrainState(params, init_opt_state(params, opt),
                       jnp.zeros((), jnp.int32))
    batch = jax.tree_util.tree_map(jnp.asarray, data.batch_at(0))
    s1 = jax.jit(build_train_step(api, opt, microbatch=1))
    s2 = jax.jit(build_train_step(api, opt, microbatch=2))
    st1, m1 = s1(state, batch, jnp.int32(0))
    st2, m2 = s2(state, batch, jnp.int32(0))
    # CE is a mean over tokens -> microbatched mean == full mean
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    # accumulation-order noise passes through AdamW's rsqrt: loose-ish rtol
    for a, b in zip(jax.tree_util.tree_leaves(st1.params),
                    jax.tree_util.tree_leaves(st2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-3, atol=3e-5)


def test_straggler_monitor_and_watchdog():
    from repro.runtime.fault import StragglerMonitor, Watchdog
    mon = StragglerMonitor(window=20, threshold=2.0)
    for i in range(10):
        assert not mon.record(i, 1.0)
    assert mon.record(10, 5.0)
    assert mon.events[0]["action"] == "flag-host-for-reschedule"

    fired = []
    wd = Watchdog(0.2, on_timeout=lambda: fired.append(1)).start()
    import time
    time.sleep(0.7)
    wd.stop()
    assert fired
