"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
asserting shapes + no NaNs; plus prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (SHAPES, applicable, get_config, get_reduced,
                           list_archs)
from repro.core.qat import QATConfig
from repro.models import get_model

ARCHS = list_archs()
QAT = QATConfig(formats=("mxint4", "mxint8"), block_size=32)


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.vision_tokens, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        batch["frame_embeds"] = jnp.asarray(
            rng.normal(size=(b, s // 2, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch):
    cfg = get_reduced(arch)
    api = get_model(cfg, QAT)
    params = api.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, aux = jax.jit(api.train_loss)(params, batch, jnp.int32(0))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss={loss}"
    # gradients flow and are finite
    g = jax.grad(lambda p: api.train_loss(p, batch, jnp.int32(1))[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                         for x in jax.tree_util.tree_leaves(g)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_param_axes_structure_matches(arch):
    cfg = get_reduced(arch)
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(1))
    axes = api.param_axes()
    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    ta = jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda x: 0, params))
    tb = jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda x: 0, axes, is_leaf=is_ax))
    assert ta == tb, arch
    # every axes tuple has the same rank as its param
    flat_p = jax.tree_util.tree_leaves(params)
    flat_a = jax.tree_util.tree_leaves(axes, is_leaf=is_ax)
    for p, a in zip(flat_p, flat_a):
        assert len(a) == p.ndim, (arch, p.shape, a)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_full_forward(arch):
    """Teacher-forced decode after prefill ≈ logits of a longer prefill."""
    cfg = get_reduced(arch)
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(2))
    b, s = 2, 16
    batch = _batch(cfg, b=b, s=s, seed=3)

    cache = api.init_cache(b, s + 8)
    logits_p, cache, cache_len = jax.jit(api.prefill)(params, batch, cache)
    assert logits_p.shape == (b, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits_p))), arch

    nxt = {"tokens": batch["tokens"][:, -1:]}
    logits_d, cache = jax.jit(api.serve_step)(params, nxt, cache, cache_len)
    assert logits_d.shape == (b, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits_d))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_consistency_with_prefill(arch):
    """Prefill of s+1 tokens == prefill(s) then decode(token s) (same logits).

    Tolerance is loose for chunked-scan state reorders (f32 accumulation).
    MoE capacity is raised to no-drop: capacity-based token dropping depends
    on the total token count, which legitimately differs between the two
    paths (documented routing semantics, not a numerical bug).
    """
    import dataclasses
    cfg = get_reduced(arch)
    if cfg.moe_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.moe_experts))
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(4))
    b, s = 2, 12
    full = _batch(cfg, b=b, s=s + 1, seed=5)
    part = {k: (v[:, :s] if k in ("tokens", "labels") else v)
            for k, v in full.items()}

    cache1 = api.init_cache(b, s + 4)
    _, cache1, len1 = jax.jit(api.prefill)(params, part, cache1)
    step = {"tokens": full["tokens"][:, s:s + 1]}
    logits_inc, _ = jax.jit(api.serve_step)(params, step, cache1, len1)

    cache2 = api.init_cache(b, s + 4)
    logits_full, _, _ = jax.jit(api.prefill)(params, full, cache2)

    np.testing.assert_allclose(np.asarray(logits_inc),
                               np.asarray(logits_full),
                               rtol=2e-2, atol=2e-2)


def test_full_configs_have_exact_assigned_numbers():
    want = {
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
    }
    for arch, (l, d, h, kv, ff, v) in want.items():
        c = get_config(arch)
        got = (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab)
        assert got == (l, d, h, kv, ff, v), (arch, got)
    assert get_config("mixtral-8x22b").moe_experts == 8
    assert get_config("jamba-1.5-large-398b").moe_experts == 16
    assert get_config("jamba-1.5-large-398b").attn_every == 8
    assert get_config("qwen3-4b").qk_norm
    assert get_config("qwen2-72b").qkv_bias
    assert get_config("smollm-135m").tie_embeddings
    assert get_config("seamless-m4t-large-v2").enc_layers == 24


def test_long500k_applicability_rule():
    long = SHAPES["long_500k"]
    runs = {a for a in ARCHS if applicable(get_config(a), long)}
    assert runs == {"rwkv6-7b", "jamba-1.5-large-398b",
                    "mixtral-8x22b", "mixtral-8x7b"}


def test_multiformat_switch_changes_loss():
    """Different format indices produce different (quantization) losses."""
    cfg = get_reduced("qwen3-4b")
    qat = QATConfig(formats=("mxint2", "mxint8"), block_size=32)
    api = get_model(cfg, qat)
    params = api.init_params(jax.random.PRNGKey(6))
    batch = _batch(cfg, seed=7)
    f = jax.jit(api.train_loss)
    l2 = float(f(params, batch, jnp.int32(0))[0])   # mxint2
    l8 = float(f(params, batch, jnp.int32(1))[0])   # mxint8
    lf = float(f(params, batch, jnp.int32(2))[0])   # fp passthrough
    assert l2 != l8
    assert abs(l8 - lf) < abs(l2 - lf)  # 8-bit closer to fp than 2-bit
