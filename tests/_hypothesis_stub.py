"""Fallback when ``hypothesis`` is not installed (see requirements-dev.txt).

Property tests decorated with ``hypothesis.given(...)`` become zero-argument
tests that skip at run time; plain unit tests in the same module keep running.
Strategy constructors (``st.*``, ``hnp.*``) evaluate at import time inside the
``given(...)`` call, so they just return inert placeholders.
"""
import pytest


class _AnyStrategy:
    """Accepts any attribute/call chain, returns an inert placeholder."""

    def __getattr__(self, name):
        return self

    def __call__(self, *args, **kwargs):
        return self


class _HypothesisStub:
    HealthCheck = _AnyStrategy()

    def given(self, *args, **kwargs):
        def deco(fn):
            def _skipped():
                pytest.skip("hypothesis not installed")
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco

    def settings(self, *args, **kwargs):
        return lambda fn: fn


hypothesis = _HypothesisStub()
st = _AnyStrategy()
hnp = _AnyStrategy()
