"""Property tests for FormatPolicy.pick() (serve/policy.py).

The pick contract, pinned as properties (hypothesis when installed, via
tests/_hypothesis_stub.py otherwise) with seeded always-run twins:

  - monotonicity: more load never picks a WIDER format — true of the
    threshold table (load axis) and of the cost path (occupancy axis);
  - a quarantined rung is never handed out by a free-running pick;
  - ``fmt_override`` wins over load, cost, quarantine and hysteresis,
    and leaves the hysteresis state untouched;
  - the cost-model pick degrades to the threshold table whenever there is
    no model, no budget in the wave, or no measurement yet — an engine
    without SLOs behaves bit-identically to the pre-cost-model policy.
"""
try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:      # property tests skip; seeded twins still run
    from _hypothesis_stub import hypothesis, st

from repro.serve.policy import FormatPolicy
from repro.serve.slo import CostModel

LADDER = ((32, "mxint4"), (8, "mxint6"), (0, "mxint8"))
FMTS = [f for _, f in LADDER]                   # narrow -> wide


def _policy(**kw):
    return FormatPolicy(anchor="mxint8", ladder=LADDER, **kw)


def _width(fmt):
    return FMTS.index(fmt)                      # 0 = narrowest


def _measured_cost(per_fmt_ms=(1.0, 2.0, 4.0), rows_slope_ms=0.5):
    """A fully measured model where wider rungs are strictly slower and
    every rung's cost grows with occupancy — the shape the analytic seed
    guarantees (more weight bytes per tick at higher precision)."""
    cm = CostModel(hbm_bytes_per_s=1e9, min_ticks=1)
    for fmt, ms in zip(FMTS, per_fmt_ms):
        cm.seed(fmt, ms * 1e6, rows_slope_ms * 1e6)
        cm.observe(fmt, 0, ms * 1e-3)           # factor == 1.0 exactly
    assert cm.any_measured()
    return cm


# ------------------------------------------------------- monotonicity

@hypothesis.given(st.integers(0, 64), st.integers(0, 64),
                  st.integers(0, 2048))
@hypothesis.settings(deadline=None, max_examples=80)
def test_threshold_pick_monotone_in_load(a, b, prefill_tokens):
    """More queued work never yields a wider format (fresh policies, so
    hysteresis is inert and the table alone decides)."""
    lo, hi = sorted((a, b))
    f_lo = _policy().pick(lo, prefill_tokens=prefill_tokens)
    f_hi = _policy().pick(hi, prefill_tokens=prefill_tokens)
    assert _width(f_hi) <= _width(f_lo)


def test_threshold_pick_monotone_in_load_seeded():
    picks = [_policy().pick(q) for q in range(0, 64)]
    widths = [_width(f) for f in picks]
    assert widths == sorted(widths, reverse=True)
    assert picks[0] == "mxint8" and picks[-1] == "mxint4"
    assert "mxint6" in picks                     # middle rung reachable


@hypothesis.given(st.integers(1, 16), st.integers(1, 16),
                  st.floats(0.5, 50.0, allow_nan=False))
@hypothesis.settings(deadline=None, max_examples=80)
def test_cost_pick_monotone_in_occupancy(r1, r2, budget_ms):
    """The cost path's load axis is decode occupancy: more live rows can
    only shrink the feasible set, so the pick never widens with rows."""
    lo, hi = sorted((r1, r2))
    f_lo = _policy(cost=_measured_cost()).pick(
        0, tpot_budget_ms=budget_ms, decode_rows=lo)
    f_hi = _policy(cost=_measured_cost()).pick(
        0, tpot_budget_ms=budget_ms, decode_rows=hi)
    assert _width(f_hi) <= _width(f_lo)


def test_cost_pick_monotone_in_occupancy_and_budget_seeded():
    for budget in (0.1, 1.4, 3.1, 6.0, 40.0):
        widths = [_width(_policy(cost=_measured_cost()).pick(
            0, tpot_budget_ms=budget, decode_rows=r)) for r in range(1, 12)]
        assert widths == sorted(widths, reverse=True), (budget, widths)
    # ... and a looser budget never narrows the pick at fixed occupancy.
    for rows in (1, 4, 9):
        widths = [_width(_policy(cost=_measured_cost()).pick(
            0, tpot_budget_ms=b, decode_rows=rows))
            for b in (0.1, 1.0, 2.0, 4.0, 8.0, 100.0)]
        assert widths == sorted(widths), (rows, widths)


def test_cost_pick_widest_feasible_else_fastest():
    # base 1/2/4 ms + 0.5 ms/row; at 1 row: 1.5 / 2.5 / 4.5 ms. Fresh
    # policies per case — hysteresis is a separate concern.
    assert _policy(cost=_measured_cost()).pick(
        0, tpot_budget_ms=100.0, decode_rows=1) == "mxint8"
    assert _policy(cost=_measured_cost()).pick(
        0, tpot_budget_ms=3.0, decode_rows=1) == "mxint6"
    # Nothing fits a 1ms budget -> fastest predicted rung.
    assert _policy(cost=_measured_cost()).pick(
        0, tpot_budget_ms=1.0, decode_rows=1) == "mxint4"


# -------------------------------------------------------- quarantine

@hypothesis.given(st.sets(st.sampled_from(["mxint4", "mxint6"])),
                  st.integers(0, 64), st.booleans())
@hypothesis.settings(deadline=None, max_examples=60)
def test_pick_never_returns_quarantined(quarantined, load, with_cost):
    pol = _policy(cost=_measured_cost() if with_cost else None)
    for f in quarantined:
        pol.quarantine(f)
    got = pol.pick(load, tpot_budget_ms=0.1 if with_cost else None,
                   decode_rows=4)
    assert got not in pol.quarantined


def test_pick_never_returns_quarantined_seeded():
    for quarantined in ((), ("mxint4",), ("mxint6",),
                        ("mxint4", "mxint6")):
        for load in (0, 10, 40):
            for with_cost, budget in ((False, None), (True, 0.1),
                                      (True, 100.0)):
                pol = _policy(
                    cost=_measured_cost() if with_cost else None)
                for f in quarantined:
                    pol.quarantine(f)
                got = pol.pick(load, tpot_budget_ms=budget,
                               decode_rows=4)
                assert got not in pol.quarantined, \
                    (quarantined, load, with_cost, budget, got)


def test_quarantine_everything_still_serves_anchor():
    pol = _policy(cost=_measured_cost())
    for f in FMTS:
        pol.quarantine(f)                 # anchor is silently exempt
    assert pol.quarantined == {"mxint4", "mxint6"}
    assert pol.pick(64, tpot_budget_ms=0.01, decode_rows=16) == "mxint8"


# ----------------------------------------------------------- override

@hypothesis.given(st.sampled_from(FMTS + ["bf16"]), st.integers(0, 64),
                  st.booleans())
@hypothesis.settings(deadline=None, max_examples=60)
def test_override_always_wins(override, load, with_cost):
    pol = _policy(cost=_measured_cost() if with_cost else None)
    pol.quarantine("mxint4")
    pol.quarantine("mxint6")
    got = pol.pick(load, tpot_budget_ms=0.1 if with_cost else None,
                   decode_rows=8, override=override)
    assert got == override
    assert pol.history[-1] == override


def test_override_leaves_hysteresis_untouched():
    """Operator overrides must not perturb the free-running trajectory:
    the pick sequence after an override equals the sequence without it."""
    loads = [0, 0, 40, 40, 40, 0, 0, 0]

    def run(with_override):
        pol = _policy(hysteresis=2)
        out = []
        for i, q in enumerate(loads):
            if with_override and i == 3:
                pol.pick(q, override="bf16")
            out.append(pol.pick(q))
        return out

    assert run(True) == run(False)


# -------------------------------------------- cost-model degradation

def test_cost_pick_degrades_to_threshold_table():
    """No model / no budget / nothing measured -> the threshold table
    decides, pick-for-pick, over a whole load trajectory (hysteresis
    included). This is the bit-identity contract for engines without
    SLOs."""
    loads = [0, 2, 40, 41, 42, 9, 9, 1, 0, 33, 0, 0]

    def trajectory(pol, **kw):
        return [pol.pick(q, prefill_tokens=16 * q, **kw) for q in loads]

    baseline = trajectory(_policy())

    seeded_only = CostModel(hbm_bytes_per_s=1e9)      # no observations
    for i, f in enumerate(FMTS):
        seeded_only.seed(f, (i + 1) * 1e6, 1e5)
    assert not seeded_only.any_measured()
    assert trajectory(_policy(cost=seeded_only),
                      tpot_budget_ms=1.0, decode_rows=4) == baseline

    # Measured model but a wave with no TPOT budget -> table again.
    assert trajectory(_policy(cost=_measured_cost()),
                      tpot_budget_ms=None, decode_rows=4) == baseline

    # No model at all, budget present -> table.
    assert trajectory(_policy(), tpot_budget_ms=1.0,
                      decode_rows=4) == baseline


def test_cost_pick_takes_over_once_measured():
    cm = CostModel(hbm_bytes_per_s=1e9, min_ticks=1)
    for i, f in enumerate(FMTS):
        cm.seed(f, (i + 1) * 1e6, 0.0)
    pol = _policy(cost=cm)
    # Unmeasured: deep queue -> table says mxint4.
    assert pol.pick(64, tpot_budget_ms=100.0, decode_rows=1) == "mxint4"
    cm.observe("mxint8", 1, 3e-3)
    # Measured + roomy budget: the same deep queue now picks the anchor —
    # quality is the objective, the SLO the constraint.
    pol2 = _policy(cost=cm)
    assert pol2.pick(64, tpot_budget_ms=100.0, decode_rows=1) == "mxint8"


def test_escalate_walks_toward_anchor():
    pol = _policy()
    assert pol.escalate("mxint4") == "mxint6"
    assert pol.escalate("mxint6") == "mxint8"
    assert pol.escalate("mxint8") is None
    assert pol.escalate("bf16") is None
