"""Deterministic bursty multi-tenant workloads (benchmarks/workloads.py).

Two contracts: the generator is a pure function of (tenants, horizon,
seed) — same triple, same trace token-for-token — and under a saturating
burst the engine's tiered admission starves no tenant: every request
reaches a terminal status and every admission wait is bounded by the
wave's own tick count.
"""
import os
import sys

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "benchmarks"))

from workloads import (TenantSpec, default_tenants, generate_workload,
                       tenant_summary, trace_fingerprint)

from repro.configs import get_reduced
from repro.core import make_anchor
from repro.core.qat import QATConfig
from repro.models import get_model
from repro.serve.engine import ElasticEngine, Request, RequestStatus
from repro.serve.slo import SLOClass


def test_tenant_spec_validation():
    with pytest.raises(ValueError):
        TenantSpec(name="x", tier="premium")
    with pytest.raises(ValueError):
        TenantSpec(name="x", rate=-0.1)
    assert TenantSpec(name="x").slo() is None          # plain best-effort
    slo = TenantSpec(name="x", tier="latency", ttft_ms=100.0,
                     tpot_ms=8.0).slo()
    assert slo == SLOClass(ttft_ms=100.0, tpot_ms=8.0, tier="latency")
    # A budget-carrying best-effort tenant still gets an SLO object (the
    # bench scores its attainment even though admission ranks it last).
    assert TenantSpec(name="x", ttft_ms=50.0).slo().tier == "best_effort"


def test_same_seed_same_trace():
    tenants = default_tenants(ttft_ms=150.0, tpot_ms=10.0)
    kw = dict(horizon=32, vocab=512, prompt_cap=47)
    a = generate_workload(tenants, seed=7, **kw)
    b = generate_workload(tenants, seed=7, **kw)
    c = generate_workload(tenants, seed=8, **kw)
    assert trace_fingerprint(a) == trace_fingerprint(b)
    assert trace_fingerprint(a) != trace_fingerprint(c)
    assert len(a) > 0


def test_trace_shape_and_ordering():
    tenants = default_tenants()
    reqs = generate_workload(tenants, horizon=24, vocab=512,
                             prompt_cap=47, seed=3)
    assert [r.rid for r in reqs] == list(range(len(reqs)))
    order = [t.name for t in tenants]
    keys = [(r.arrival_tick, order.index(r.tenant)) for r in reqs]
    assert keys == sorted(keys)                 # (tick, tenant) order
    for r in reqs:
        assert 1 <= r.prompt.size <= 47
        assert r.prompt.dtype == np.int32
        assert (r.prompt >= 1).all() and (r.prompt < 512).all()
    tiers = {r.tenant: (None if r.slo is None else r.slo.tier)
             for r in reqs}
    assert tiers.get("interactive") == "latency"
    assert tiers.get("bulk") == "throughput"
    if "scavenger" in tiers:                    # budget-less -> no SLO
        assert tiers["scavenger"] is None


def test_bursts_land_on_schedule():
    spec = TenantSpec(name="b", tier="throughput", rate=0.0,
                      burst_every=4, burst_size=2)
    reqs = generate_workload([spec], horizon=9, vocab=512, prompt_cap=31,
                             seed=0)
    ticks = [r.arrival_tick for r in reqs]
    assert ticks == [4, 4, 8, 8]                # t=0 never bursts


def test_unclipped_prompts_can_exceed_capacity():
    """clip_prompts=False keeps the lognormal tail — that is how the bench
    exercises the fail-fast admission-reject path."""
    spec = TenantSpec(name="t", rate=2.0, prompt_median=20.0,
                      prompt_sigma=1.0)
    reqs = generate_workload([spec], horizon=30, vocab=512, prompt_cap=23,
                             seed=1, clip_prompts=False)
    assert max(r.prompt.size for r in reqs) > 23
    clipped = generate_workload([spec], horizon=30, vocab=512,
                                prompt_cap=23, seed=1)
    assert max(r.prompt.size for r in clipped) <= 23


def test_tenant_summary_accounting():
    reqs = [Request(rid=0, prompt=np.ones(4, np.int32), max_new=2,
                    tenant="a", arrival_tick=0),
            Request(rid=1, prompt=np.ones(4, np.int32), max_new=2,
                    tenant="a", arrival_tick=2),
            Request(rid=2, prompt=np.ones(4, np.int32), max_new=2,
                    tenant="b", arrival_tick=5)]
    reqs[0].admitted_tick = 1
    reqs[1].admitted_tick = 9
    reqs[0].out_tokens.extend([3, 4])
    s = tenant_summary(reqs)
    assert s["a"]["requests"] == 2 and s["a"]["tokens_out"] == 2
    assert s["a"]["wait_ticks_p50"] == 7 and s["a"]["wait_ticks_max"] == 7
    assert s["b"]["wait_ticks_max"] is None     # never admitted
    assert s["a"]["statuses"] == {"queued": 2}


@pytest.mark.slow
def test_saturating_burst_starves_no_tenant():
    """Fairness under backpressure: a burst far beyond slot capacity, with
    tiered admission ranking the bursty tenant LAST — every request still
    reaches a terminal status and every admission wait is bounded by the
    wave's own length. Tier priority reorders service; it never denies
    it (FIFO within tier guarantees progress once higher tiers drain)."""
    cfg = get_reduced("smollm-135m")
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    anchor = make_anchor(params, QATConfig(
        formats=("mxint4", "mxint8"), anchor="mxint8", block_size=32))
    eng = ElasticEngine(api, anchor, batch_slots=2, max_len=48,
                        param_template=params, admission_order="slo")
    tenants = [
        TenantSpec(name="vip", tier="latency", rate=0.4, prompt_median=6.0,
                   prompt_sigma=0.3, max_new=3, ttft_ms=1e4, tpot_ms=1e4),
        TenantSpec(name="flood", tier="best_effort", rate=0.0,
                   burst_every=2, burst_size=4, prompt_median=8.0,
                   prompt_sigma=0.3, max_new=3),
    ]
    reqs = generate_workload(tenants, horizon=6, vocab=cfg.vocab,
                             prompt_cap=eng.prompt_capacity, seed=5)
    assert sum(r.tenant == "flood" for r in reqs) >= 8   # saturating
    assert sum(r.tenant == "vip" for r in reqs) >= 1
    eng.generate(reqs, fmt_override="mxint8")

    ticks = len(eng.tick_trace)
    for r in reqs:
        assert r.status is RequestStatus.COMPLETED, (r.rid, r.status)
        assert r.admitted_tick is not None
        assert 0 <= r.admitted_tick - r.arrival_tick <= ticks
    s = tenant_summary(reqs)
    for name in ("vip", "flood"):
        assert s[name]["statuses"] == {"completed": s[name]["requests"]}
        assert s[name]["wait_ticks_max"] <= ticks
    st = eng.stats
    assert st["kv_pages_alloc"] == st["kv_pages_freed"]
