"""Chaos suite for the fault-isolated serving engine (engine.py +
runtime/fault.py + policy escalate/quarantine + snapshot/resume).

The contract under test (docs/serving_internals.md §7 "Failure model &
degradation ladder"):

  - every request ends in exactly ONE terminal RequestStatus, with the
    error recorded in stats()["failures"] for non-COMPLETED terminals;
  - a fault confined to one request (poisoned row, oversized prompt,
    deadline, cancellation, pool starvation) retires THAT request; the
    survivors' token streams are bit-identical to a fault-free run;
  - batch-wide numeric faults escalate the pinned format one ladder rung
    toward the anchor and REPLAY the tick from pre-tick state — a
    transient fault therefore leaves ALL streams bit-identical;
  - the page free list never leaks: kv_pages_alloc == kv_pages_freed once
    the wave drains, in every scenario;
  - a PreemptionGuard interruption snapshots at the tick boundary and a
    FRESH engine resumes with bit-identical remaining streams.
"""
import numpy as np
import jax
import pytest

from repro.configs import get_reduced
from repro.core import make_anchor
from repro.core.qat import QATConfig
from repro.models import get_model
from repro.runtime.fault import FaultInjector, PreemptionGuard
from repro.serve.engine import ElasticEngine, Request, RequestStatus

QAT = QATConfig(formats=("mxint4", "mxint6", "mxint8"), anchor="mxint8",
                block_size=32)
PS = 8


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("smollm-135m")
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    anchor = make_anchor(params, QAT)
    return cfg, api, params, anchor


def _engine(api, anchor, params, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("kv_layout", "paged")
    kw.setdefault("kv_page_size", PS)
    return ElasticEngine(api, anchor, param_template=params, **kw)


def _reqs(cfg, n, max_new=5, plen=8, seed=7):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab, plen)
                    .astype(np.int32), max_new=max_new) for i in range(n)]


def _baseline(setup, n=3, **kw):
    cfg, api, params, anchor = setup
    eng = _engine(api, anchor, params, **kw)
    reqs = _reqs(cfg, n)
    eng.generate(reqs, fmt_override="mxint8")
    return [r.out_tokens for r in reqs]


def _assert_no_leak(eng):
    st = eng.stats
    assert st["kv_pages_alloc"] == st["kv_pages_freed"], \
        (st["kv_pages_alloc"], st["kv_pages_freed"])


def _assert_all_terminal(reqs):
    for r in reqs:
        assert r.done and r.status.terminal, (r.rid, r.status)
        if r.status is not RequestStatus.COMPLETED:
            assert r.error, (r.rid, r.status)


# ---- row-confined numeric fault -------------------------------------------
def test_row_poison_at_anchor_confines_to_one_request(setup):
    """NaN traced to ONE row at the anchor rung: that request retires
    FAILED_NUMERIC with no poisoned token in its stream; every survivor's
    stream is bit-identical to the fault-free run."""
    cfg, api, params, anchor = setup
    base = _baseline(setup)
    fi = FaultInjector(poison_logits={2: 0})
    eng = _engine(api, anchor, params, fault_injector=fi)
    reqs = _reqs(cfg, 3)
    eng.generate(reqs, fmt_override="mxint8")
    _assert_all_terminal(reqs)
    assert reqs[0].status is RequestStatus.FAILED_NUMERIC
    assert "anchor rung" in reqs[0].error
    # the poisoned tick's would-be token never entered the stream
    assert all(np.isfinite(t) for t in reqs[0].out_tokens)
    for r, b in zip(reqs, base):
        if r.status is RequestStatus.COMPLETED:
            assert r.out_tokens == b
    assert eng.stats["request_statuses"]["failed_numeric"] == 1
    assert eng.stats["failures"][0]["rid"] == 0
    _assert_no_leak(eng)


def test_transient_step_crash_replays_bit_identical(setup):
    """An InjectedFault out of the step executable retries at the SAME
    format; since the attempt is a pure function of pre-tick state, ALL
    streams match the fault-free run bit for bit."""
    cfg, api, params, anchor = setup
    base = _baseline(setup)
    fi = FaultInjector(raise_in_step=(1, 3))
    eng = _engine(api, anchor, params, fault_injector=fi)
    reqs = _reqs(cfg, 3)
    eng.generate(reqs, fmt_override="mxint8")
    assert [r.out_tokens for r in reqs] == base
    assert all(r.status is RequestStatus.COMPLETED for r in reqs)
    assert eng.stats["ticks_replayed"] >= 2
    assert eng.stats["fmt_escalations"] == 0      # same-format replay
    _assert_no_leak(eng)


def test_step_crash_beyond_retry_budget_raises(setup):
    """A fault that persists past max_step_retries is not a transient —
    the engine refuses to spin and re-raises (supervisor's problem)."""
    from repro.runtime.fault import InjectedFault
    cfg, api, params, anchor = setup
    fi = FaultInjector(raise_in_step=(2,))
    eng = _engine(api, anchor, params, fault_injector=fi,
                  max_step_retries=0)
    with pytest.raises(InjectedFault):
        eng.generate(_reqs(cfg, 2), fmt_override="mxint8")


# ---- format-ladder degradation --------------------------------------------
def test_bad_rung_escalates_and_quarantines(setup):
    """Batch-wide NaN that follows the FORMAT (the bad-rung model): the
    engine walks mxint4 -> mxint6, replays the tick, finishes every stream
    finite, and quarantines the bad rung from future picks."""
    cfg, api, params, anchor = setup
    fi = FaultInjector(poison_logits={2: None}, poison_fmt="mxint4")
    eng = _engine(api, anchor, params, fault_injector=fi)
    reqs = _reqs(cfg, 3)
    eng.generate(reqs, fmt_override="mxint4")
    assert all(r.status is RequestStatus.COMPLETED for r in reqs)
    st = eng.stats
    assert st["fmt_escalations"] == 1
    ev = st["escalation_events"][0]
    assert (ev["from"], ev["to"]) == ("mxint4", "mxint6")
    assert st["quarantined_formats"] == ["mxint4"]
    # the escalated batch's requests carry the new rung exactly (rid 2
    # admits after the wave drains, where fmt_override re-picks mxint4 —
    # override is explicit operator intent and bypasses quarantine)
    assert reqs[0].fmt_used == reqs[1].fmt_used == "mxint6"
    assert eng.policy.pick(queue_depth=64) != "mxint4"   # quarantine holds
    _assert_no_leak(eng)


def test_double_escalation_reaches_anchor(setup):
    """Two bad rungs: mxint4 -> mxint6 -> mxint8 within one tick's replay
    loop; the anchor serves every stream to completion."""
    cfg, api, params, anchor = setup
    fi = FaultInjector(poison_logits={2: None},
                       poison_fmt=("mxint4", "mxint6"))
    eng = _engine(api, anchor, params, fault_injector=fi)
    reqs = _reqs(cfg, 3)
    eng.generate(reqs, fmt_override="mxint4")
    assert all(r.status is RequestStatus.COMPLETED for r in reqs)
    st = eng.stats
    assert st["fmt_escalations"] == 2
    assert [e["to"] for e in st["escalation_events"]] == \
        ["mxint6", "mxint8"]
    assert sorted(st["quarantined_formats"]) == ["mxint4", "mxint6"]
    assert reqs[0].fmt_used == reqs[1].fmt_used == "mxint8"
    _assert_no_leak(eng)


def test_escalation_exhausted_retires_rows_not_wave(setup):
    """Poison that follows the ANCHOR has nowhere to escalate: the affected
    (= all consumed) rows retire FAILED_NUMERIC, and queued work admits on
    later ticks and completes untouched."""
    cfg, api, params, anchor = setup
    fi = FaultInjector(poison_logits={2: None}, poison_fmt="mxint8")
    eng = _engine(api, anchor, params, fault_injector=fi)
    reqs = _reqs(cfg, 3)       # 2 slots: rids 0,1 active at tick 2; rid 2 queued
    eng.generate(reqs, fmt_override="mxint8")
    _assert_all_terminal(reqs)
    assert reqs[0].status is RequestStatus.FAILED_NUMERIC
    assert reqs[1].status is RequestStatus.FAILED_NUMERIC
    assert reqs[2].status is RequestStatus.COMPLETED
    assert eng.stats["fmt_escalations"] == 0
    _assert_no_leak(eng)


def test_final_chunk_poison_at_anchor_fails_that_admission(setup):
    """Chunked admission: only the FINAL chunk's logits are consumed (they
    seed the first token), so that is where the guard bites — the filling
    request retires FAILED_NUMERIC and the queue behind it is served."""
    cfg, api, params, anchor = setup
    fi = FaultInjector(poison_logits={2: None}, poison_fmt="mxint8")
    eng = _engine(api, anchor, params, batch_slots=1, prefill_chunk=PS,
                  fault_injector=fi)
    rng = np.random.default_rng(3)
    reqs = [Request(rid=0, prompt=rng.integers(0, cfg.vocab, 20)
                    .astype(np.int32), max_new=3),     # final chunk: tick 2
            Request(rid=1, prompt=rng.integers(0, cfg.vocab, 8)
                    .astype(np.int32), max_new=3)]
    eng.generate(reqs, fmt_override="mxint8")
    assert reqs[0].status is RequestStatus.FAILED_NUMERIC
    assert "final-chunk" in reqs[0].error or "final chunk" in reqs[0].error
    assert reqs[0].out_tokens == []        # never sampled a token
    assert reqs[1].status is RequestStatus.COMPLETED
    _assert_no_leak(eng)


# ---- injected pool corruption ---------------------------------------------
def test_pool_poison_of_unmapped_page_is_harmless(setup):
    """NaN-filling a physical page NO row maps cannot perturb any stream —
    the block table is the only path from pages to attention."""
    cfg, api, params, anchor = setup
    base = _baseline(setup)
    eng0 = _engine(api, anchor, params)
    last_page = eng0.stats["kv_total_pages"] - 1   # allocated last, if ever
    fi = FaultInjector(poison_pool={1: last_page})
    eng = _engine(api, anchor, params, fault_injector=fi)
    reqs = _reqs(cfg, 3)
    eng.generate(reqs, fmt_override="mxint8")
    assert [r.out_tokens for r in reqs] == base
    assert all(r.status is RequestStatus.COMPLETED for r in reqs)
    _assert_no_leak(eng)


def test_pool_poison_of_live_page_retires_its_row(setup):
    """Persistent HBM corruption of a LIVE page: replay re-reads the same
    NaNs, so recovery must come from retiring the row that maps the page —
    at the anchor rung that is FAILED_NUMERIC for exactly that request."""
    cfg, api, params, anchor = setup
    base = _baseline(setup)
    # page 1 is the first page popped: slot 0's prompt page
    fi = FaultInjector(poison_pool={2: 1})
    eng = _engine(api, anchor, params, fault_injector=fi)
    reqs = _reqs(cfg, 3)
    eng.generate(reqs, fmt_override="mxint8")
    _assert_all_terminal(reqs)
    assert reqs[0].status is RequestStatus.FAILED_NUMERIC
    for r, b in zip(reqs, base):
        if r.status is RequestStatus.COMPLETED:
            assert r.out_tokens == b
    _assert_no_leak(eng)


# ---- capacity faults -------------------------------------------------------
def test_injected_alloc_failure_retries_and_completes(setup):
    """A transient allocation failure requeues the admission (pages
    untouched) and the retry next tick serves it: same streams, one
    requeue, no leak."""
    cfg, api, params, anchor = setup
    base = _baseline(setup)
    fi = FaultInjector(fail_allocs=(0,))   # first-ever admission alloc
    eng = _engine(api, anchor, params, fault_injector=fi)
    reqs = _reqs(cfg, 3)
    eng.generate(reqs, fmt_override="mxint8")
    assert [r.out_tokens for r in reqs] == base
    assert all(r.status is RequestStatus.COMPLETED for r in reqs)
    assert eng.stats["admission_requeues"] >= 1
    _assert_no_leak(eng)


def test_decode_starvation_retires_largest_holder(setup):
    """Real exhaustion mid-decode with no admission to roll back: the
    LARGEST page-holder retires FAILED_CAPACITY (frees the most pages) and
    the smaller request completes — with the same stream as a roomy run."""
    cfg, api, params, anchor = setup
    rng = np.random.default_rng(5)
    mk = lambda: [Request(rid=0, prompt=p0.copy(), max_new=12),
                  Request(rid=1, prompt=p1.copy(), max_new=12)]
    p0 = rng.integers(0, cfg.vocab, 8).astype(np.int32)    # 2 pages held
    p1 = rng.integers(0, cfg.vocab, 16).astype(np.int32)   # 3 pages held
    roomy = _engine(api, anchor, params)
    ref = mk()
    roomy.generate(ref, fmt_override="mxint8")

    eng = _engine(api, anchor, params, kv_num_pages=6)  # 5 allocatable
    reqs = mk()
    eng.generate(reqs, fmt_override="mxint8")           # must NOT raise
    _assert_all_terminal(reqs)
    assert reqs[1].status is RequestStatus.FAILED_CAPACITY
    assert "largest page-holder" in reqs[1].error
    assert reqs[0].status is RequestStatus.COMPLETED
    assert reqs[0].out_tokens == ref[0].out_tokens
    _assert_no_leak(eng)


def test_oversized_prompt_fails_fast_queue_unharmed(setup):
    """A prompt past capacity costs itself, never the queue behind it."""
    cfg, api, params, anchor = setup
    base = _baseline(setup)
    rng = np.random.default_rng(9)
    big = Request(rid=99, prompt=rng.integers(0, cfg.vocab, 40)
                  .astype(np.int32), max_new=3)        # > max_len - 1 = 31
    eng = _engine(api, anchor, params)
    reqs = [big] + _reqs(cfg, 3)
    eng.generate(reqs, fmt_override="mxint8")
    assert big.status is RequestStatus.FAILED_CAPACITY
    assert "exceeds capacity" in big.error
    assert [r.out_tokens for r in reqs[1:]] == base
    _assert_no_leak(eng)


# ---- deadlines & cancellation ----------------------------------------------
def test_deadline_and_cancel_are_per_request(setup):
    """A zero deadline and an injected cancellation each retire exactly
    their own request at a tick boundary; the survivor's stream is
    bit-identical to the fault-free run."""
    cfg, api, params, anchor = setup
    base = _baseline(setup)
    fi = FaultInjector(cancel_at={0: 2})
    eng = _engine(api, anchor, params, fault_injector=fi)
    reqs = _reqs(cfg, 3)
    reqs[1].deadline_s = 0.0
    eng.generate(reqs, fmt_override="mxint8")
    assert reqs[0].status is RequestStatus.COMPLETED
    assert reqs[0].out_tokens == base[0]
    assert reqs[1].status is RequestStatus.TIMED_OUT
    assert "deadline" in reqs[1].error
    assert reqs[2].status is RequestStatus.CANCELLED
    counts = eng.stats["request_statuses"]
    assert counts == {"completed": 1, "timed_out": 1, "cancelled": 1}
    _assert_no_leak(eng)


def test_client_cancel_mid_flight(setup):
    """Request.cancel() from outside the loop retires the request at the
    next tick boundary, pages freed."""
    cfg, api, params, anchor = setup
    eng = _engine(api, anchor, params)
    reqs = _reqs(cfg, 2)
    reqs[0].cancel()                      # pre-cancelled: dies at tick 0
    eng.generate(reqs, fmt_override="mxint8")
    assert reqs[0].status is RequestStatus.CANCELLED
    assert reqs[0].out_tokens == []
    assert reqs[1].status is RequestStatus.COMPLETED
    _assert_no_leak(eng)


# ---- preemption, snapshot, resume ------------------------------------------
def test_preempt_snapshot_fresh_engine_resume_bit_identical(setup, tmp_path):
    """The headline resilience claim: an injected preemption mid-wave
    snapshots at the tick boundary; a FRESH engine (same config) resumes
    and every finished stream is bit-identical to the uninterrupted run.
    The leak invariant spans BOTH processes."""
    cfg, api, params, anchor = setup
    base = _baseline(setup)
    fi = FaultInjector(preempt_at=2)
    g = PreemptionGuard()
    eng = _engine(api, anchor, params, fault_injector=fi)
    reqs = _reqs(cfg, 3)
    eng.generate(reqs, fmt_override="mxint8", guard=g,
                 snapshot_dir=str(tmp_path))
    assert g.preempted
    assert eng.last_snapshot is not None
    assert not all(r.done for r in reqs)  # genuinely interrupted
    assert eng.stats["snapshots_saved"] == 1

    fresh = _engine(api, anchor, params)  # no injector, no shared state
    done = fresh.resume(str(tmp_path))
    assert all(r.status is RequestStatus.COMPLETED for r in done)
    assert [r.out_tokens for r in done] == base
    assert fresh.stats["resumes"] == 1
    _assert_no_leak(fresh)


def test_resume_fingerprint_mismatch_raises(setup, tmp_path):
    """Resuming onto a differently-configured engine must refuse loudly,
    naming the differing facts — never corrupt streams silently."""
    cfg, api, params, anchor = setup
    fi = FaultInjector(preempt_at=1)
    g = PreemptionGuard()
    eng = _engine(api, anchor, params, fault_injector=fi)
    eng.generate(_reqs(cfg, 2), fmt_override="mxint8", guard=g,
                 snapshot_dir=str(tmp_path))
    other = _engine(api, anchor, params, max_len=64)
    with pytest.raises(ValueError, match="fingerprint mismatch") as ei:
        other.resume(str(tmp_path))
    assert "max_len" in str(ei.value)     # the differing fact is named


# ---- chaos storm (slow) ----------------------------------------------------
@pytest.mark.slow
def test_seeded_chaos_storm_invariants(setup):
    """random_plan at a high rate over many requests: whatever fires, every
    request terminates with a status, the free list balances, and the
    engine's failure ledger matches the per-request terminals."""
    cfg, api, params, anchor = setup
    fi = random_plan_storm()
    eng = _engine(api, anchor, params, fault_injector=fi)
    reqs = _reqs(cfg, 8, max_new=6)
    eng.generate(reqs, fmt_override="mxint8")
    _assert_all_terminal(reqs)
    st = eng.stats
    assert sum(st["request_statuses"].values()) == len(reqs)
    assert len(st["failures"]) == sum(
        1 for r in reqs if r.status is not RequestStatus.COMPLETED)
    _assert_no_leak(eng)


def random_plan_storm():
    from repro.runtime.fault import random_plan
    return random_plan(seed=13, rate=0.25, horizon=40, slots=2,
                       kinds=("poison_row", "raise_step", "fail_alloc"))


@pytest.mark.slow
def test_mixed_scheduler_survives_row_poison(setup):
    """The mixed (prefill+decode coalesced) tick path under a row poison:
    fault confined, survivors identical to its own fault-free run."""
    cfg, api, params, anchor = setup
    streams = {}
    for chaos in (False, True):
        fi = FaultInjector(poison_logits={4: 0}) if chaos else None
        eng = _engine(api, anchor, params, prefill_chunk=PS,
                      fault_injector=fi)
        reqs = _reqs(cfg, 3, max_new=6)
        eng.generate(reqs, fmt_override="mxint8")
        streams[chaos] = reqs
        _assert_no_leak(eng)
    _assert_all_terminal(streams[True])
    clean = {r.rid: r.out_tokens for r in streams[False]}
    for r in streams[True]:
        if r.status is RequestStatus.COMPLETED:
            assert r.out_tokens == clean[r.rid]
    assert any(r.status is RequestStatus.FAILED_NUMERIC
               for r in streams[True])


# ---- speculative decoding x fault machinery (docs §9 x §7) ----------------
def test_spec_verify_poison_escalates_without_double_commit(setup):
    """A batch-wide NaN during a speculative VERIFY tick must ride the
    standard escalate-and-replay path WITHOUT re-running (or re-committing)
    the drafts: verify overwrites draft-written KV before attending, so a
    replay at the next rung is a pure function of pre-tick committed state.

    Identity needs the rung-per-token schedule aligned across runs, so both
    use chunked admission (prompt = one chunk): tick 0/1 admit, tick 2 is
    the first pure-decode tick for both — poisoned at mxint6 — so BOTH runs
    emit the same tokens at mxint6 up to that point and escalate to mxint8
    for the rest, and the spec stream must match plain bit for bit."""
    from repro.serve.policy import SpecConfig
    cfg, api, params, anchor = setup
    streams = {}
    engines = {}
    for spec in (None, SpecConfig(draft_fmt="mxint4", k=4)):
        fi = FaultInjector(poison_logits={t: None for t in range(2, 64)},
                           poison_fmt="mxint6")
        eng = _engine(api, anchor, params, max_len=48, prefill_chunk=PS,
                      fault_injector=fi, speculative=spec)
        reqs = _reqs(cfg, 2, max_new=8)
        eng.generate(reqs, fmt_override="mxint6")
        assert all(r.status is RequestStatus.COMPLETED for r in reqs)
        streams[spec is not None] = [r.out_tokens for r in reqs]
        engines[spec is not None] = eng
        _assert_no_leak(eng)
    assert streams[True] == streams[False]
    for eng in engines.values():
        st = eng.stats
        assert st["fmt_escalations"] == 1
        ev = st["escalation_events"][0]
        assert (ev["from"], ev["to"]) == ("mxint6", "mxint8")
    # the escalated tick replayed ONLY the verify executable: its trace
    # entry shows two verify attempts over a single k-deep draft burst
    replayed = [t for t in engines[True].tick_trace
                if t["verify_execs"] >= 2]
    assert len(replayed) == 1
    assert 1 <= replayed[0]["draft_execs"] <= 4
    # no double commit anywhere: exact token counts on every stream
    assert all(len(s) == 8 for s in streams[True])
    assert engines[True].stats["spec_ticks"] >= 1


def test_spec_draft_quarantine_falls_back_to_plain_decode(setup):
    """A sick DRAFT rung mid-wave (NaN logits under the guard) quarantines
    that rung and reverts to plain pinned-format decode for the rest of the
    wave — nothing from the abandoned burst was committed, so the streams
    stay bit-identical to a never-speculated run (pinned at the anchor, the
    rung schedule is trivially aligned)."""
    from repro.serve.policy import SpecConfig
    cfg, api, params, anchor = setup
    eng_p = _engine(api, anchor, params, max_len=48)
    reqs_p = _reqs(cfg, 2, max_new=16)
    eng_p.generate(reqs_p, fmt_override="mxint8")
    fi = FaultInjector(poison_logits={2: None}, poison_fmt="mxint4")
    eng = _engine(api, anchor, params, max_len=48, fault_injector=fi,
                  speculative=SpecConfig(draft_fmt="mxint4", k=4))
    reqs = _reqs(cfg, 2, max_new=16)
    eng.generate(reqs, fmt_override="mxint8")
    assert all(r.status is RequestStatus.COMPLETED for r in reqs)
    assert [r.out_tokens for r in reqs] == [r.out_tokens for r in reqs_p]
    st = eng.stats
    assert "mxint4" in st["quarantined_formats"]
    assert st["spec_aborts"] == 1
    assert st["faults_detected"] >= 1
    assert st["fmt_escalations"] == 0        # pinned rung never misbehaved
    assert st["spec_ticks"] >= 1             # it DID speculate before t=2
    # after the quarantine tick, every remaining tick is plain decode
    aborted = max(i for i, t in enumerate(eng.tick_trace)
                  if t["draft_execs"] or t["verify_execs"])
    assert all(t["draft_execs"] == 0 and t["verify_execs"] == 0
               for t in eng.tick_trace[aborted + 1:])
    _assert_no_leak(eng)
