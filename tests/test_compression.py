"""MX gradient compression with error feedback: unbiasedness over steps,
bytes accounting, and shard_map wiring on a 1-device pod mesh."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.compression import (compressed_bytes, ef_compress_leaf,
                                     compressed_pod_allreduce,
                                     init_error_state, shard_map)
from repro.core.formats import get_format
from repro.core.mx import dequantize


def test_ef_compress_roundtrip_error_bounded():
    fmt = get_format("mxint8", 32)
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(67, 33)), jnp.float32)  # awkward shape
    err = jnp.zeros_like(g)
    t, new_err = ef_compress_leaf(g, err, fmt)
    flat = dequantize(t).reshape(-1)[:g.size].reshape(g.shape)
    # int8 blocks: relative error small
    assert float(jnp.max(jnp.abs(flat - g))) < 0.02 * float(jnp.max(jnp.abs(g)))
    np.testing.assert_allclose(np.asarray(new_err), np.asarray(g - flat),
                               atol=1e-7)


def test_error_feedback_removes_bias():
    """Accumulated EF-compressed updates converge to accumulated true grads."""
    fmt = get_format("mxint4", 32)   # coarse: bias obvious without EF
    rng = np.random.default_rng(1)
    g_const = jnp.asarray(rng.normal(size=(128,)), jnp.float32) * 0.01

    err = jnp.zeros_like(g_const)
    acc_ef = jnp.zeros_like(g_const)
    acc_noef = jnp.zeros_like(g_const)
    for _ in range(50):
        t, err = ef_compress_leaf(g_const, err, fmt)
        acc_ef = acc_ef + dequantize(t).reshape(-1)[:128]
        t2, _ = ef_compress_leaf(g_const, jnp.zeros_like(err), fmt)
        acc_noef = acc_noef + dequantize(t2).reshape(-1)[:128]
    true = g_const * 50
    err_ef = float(jnp.linalg.norm(acc_ef - true) / jnp.linalg.norm(true))
    err_noef = float(jnp.linalg.norm(acc_noef - true) / jnp.linalg.norm(true))
    assert err_ef < 0.05
    assert err_ef < err_noef * 0.5 or err_noef < 1e-6


def test_compressed_bytes_accounting():
    params = {"a": jnp.zeros((1000, 100)), "b": jnp.zeros((999,))}
    b8 = compressed_bytes(params, "mxint8")
    f32 = (1000 * 100 + 999) * 4
    assert b8 < f32 * 0.27   # ~4x compression minus scale overhead


def test_pod_allreduce_shard_map_single_device():
    """Wire through shard_map on a pod-axis mesh of size 1 (CPU container);
    numerics = identity reduce + error feedback."""
    mesh = jax.make_mesh((1,), ("pod",))
    grads = {"w": jnp.asarray(np.random.default_rng(2).normal(size=(64, 32)),
                              jnp.float32)}
    err = init_error_state(grads)

    from jax.sharding import PartitionSpec as P

    fn = shard_map(
        functools.partial(compressed_pod_allreduce, fmt_name="mxint8"),
        mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        check_vma=False)
    red, new_err = jax.jit(fn)(grads, err)
    # npod=1: reduced grad == dequant(quant(g)) and err == residual
    assert red["w"].shape == grads["w"].shape
    np.testing.assert_allclose(np.asarray(red["w"]), np.asarray(grads["w"]),
                               atol=0.05)
    np.testing.assert_allclose(
        np.asarray(grads["w"] - red["w"]), np.asarray(new_err["w"]),
        atol=1e-6)
