"""Paged KV-cache serving: dense-vs-paged token identity, page-pool
exhaustion, page recycling, and block-table isolation.

The contract under test (docs/serving_internals.md): the paged layout is a
pure re-indexing of KV storage — every valid position holds bit-identical
values to the dense layout, so greedy AND seeded-sampling token streams must
match exactly, under both packed-serving contracts (fused Pallas dispatch /
XLA densify-inside-jit) and at both a packed format (mxint8) and the dense
bf16 pseudo-format.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import make_anchor
from repro.core.qat import QATConfig
from repro.models import get_model
from repro.serve.engine import ElasticEngine, Request

QAT = QATConfig(formats=("mxint4", "mxint8"), anchor="mxint8", block_size=32)
PS = 8  # page size; max_len=32 -> 4 pages/slot, divides so gathered
#         Skv == dense Skv and softmax reductions see identical shapes


def _setup(arch="smollm-135m"):
    cfg = get_reduced(arch)
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    anchor = make_anchor(params, QAT)
    return cfg, api, params, anchor


def _engine(api, anchor, params, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_len", 32)
    return ElasticEngine(api, anchor, param_template=params, **kw)


def _reqs(cfg, n, max_new=5, plen=8, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab, plen)
                    .astype(np.int32), max_new=max_new) for i in range(n)]


@pytest.mark.parametrize("fmt", ["mxint8", "bf16"])
@pytest.mark.parametrize("fused", [True, False])
def test_paged_matches_dense_token_for_token(fmt, fused):
    """Acceptance gate: greedy streams identical across KV layouts, for both
    serving contracts, at a packed format and the bf16 pseudo-format (where
    both contracts serve the same dense step — still both exercised)."""
    cfg, api, params, anchor = _setup()
    streams = {}
    for layout in ("dense", "paged"):
        eng = _engine(api, anchor, params, fused=fused, kv_layout=layout,
                      kv_page_size=PS)
        reqs = _reqs(cfg, 3, max_new=5, seed=7)
        eng.generate(reqs, fmt_override=fmt)
        streams[layout] = [r.out_tokens for r in reqs]
    assert streams["dense"] == streams["paged"]


@pytest.mark.slow
def test_paged_matches_dense_seeded_sampling():
    """Sampling depends only on logits + per-slot RNG streams; identical
    logits across layouts means identical sampled streams."""
    cfg, api, params, anchor = _setup()
    streams = {}
    for layout in ("dense", "paged"):
        eng = _engine(api, anchor, params, kv_layout=layout, kv_page_size=PS,
                      seed=3, temperature=1.0, top_p=0.9)
        reqs = _reqs(cfg, 3, max_new=5, seed=11)
        eng.generate(reqs, greedy=False, fmt_override="mxint8")
        streams[layout] = [r.out_tokens for r in reqs]
    assert streams["dense"] == streams["paged"]


def test_page_pool_exhaustion_retires_not_raises():
    """An undersized pool must never silently truncate — and since the
    fault-isolation PR it must not kill the wave either: kv_num_pages=3
    gives 2 allocatable pages of 8 tokens, each admission holds 2 (prompt +
    first decode write), so decoding past position 16 starves the pool.
    The largest page-holder retires FAILED_CAPACITY with the pool error
    recorded, generate() returns normally, and nothing leaks."""
    cfg, api, params, anchor = _setup()
    eng = _engine(api, anchor, params, kv_layout="paged", kv_page_size=PS,
                  kv_num_pages=3)
    reqs = _reqs(cfg, 2, max_new=12, seed=1)
    eng.generate(reqs, fmt_override="mxint8")     # must NOT raise
    from repro.serve.engine import RequestStatus
    assert all(r.done for r in reqs)
    assert all(r.status is RequestStatus.FAILED_CAPACITY for r in reqs)
    assert all("KV pool exhausted" in r.error for r in reqs)
    st = eng.stats
    assert st["kv_pages_alloc"] == st["kv_pages_freed"]       # no leak
    assert len(st["failures"]) == 2


def test_pages_recycled_across_retire_admit_churn():
    """6 requests through 2 slots with a pool that only fits the concurrent
    pair: completes iff retire returns pages to the free list, and the
    streams still match a roomy dense run. Allocation stats prove reuse."""
    cfg, api, params, anchor = _setup()
    dense = _engine(api, anchor, params)
    ref = _reqs(cfg, 6, max_new=6, seed=7)
    dense.generate(ref, fmt_override="mxint8")

    # per request: pages for 8 prompt tokens + first write (2) + decode
    # growth to position 13 (<16) -> 2 pages; pool = 2 slots * 2 + scratch
    eng = _engine(api, anchor, params, kv_layout="paged", kv_page_size=PS,
                  kv_num_pages=5)
    reqs = _reqs(cfg, 6, max_new=6, seed=7)
    eng.generate(reqs, fmt_override="mxint8")
    assert all(r.done for r in reqs)
    assert [r.out_tokens for r in reqs] == [r.out_tokens for r in ref]
    st = eng.stats
    assert st["kv_pages_alloc"] == st["kv_pages_freed"] == 12  # 6 reqs x 2
    assert st["kv_pages_alloc"] > st["kv_total_pages"] - 1     # reuse proven
    assert st["kv_pages_hwm"] <= st["kv_total_pages"] - 1


def test_non_divisible_prompt_len_vs_page_size():
    """Regression: prompt_len % page_size != 0 (unbucketed, so the raw length
    reaches the page math) pads the final page and stays token-identical."""
    cfg, api, params, anchor = _setup()
    streams = {}
    for layout in ("dense", "paged"):
        eng = _engine(api, anchor, params, kv_layout=layout, kv_page_size=PS,
                      bucket_prompts=False)
        reqs = _reqs(cfg, 2, max_new=5, plen=13, seed=5)   # 13 % 8 != 0
        eng.generate(reqs, fmt_override="mxint8")
        streams[layout] = [r.out_tokens for r in reqs]
    assert streams["dense"] == streams["paged"]


def test_prefill_slot_writes_only_mapped_pages():
    """ModelApi.prefill_slot under the paged layout scatters into exactly the
    pages the slot's block-table row maps — other slots' pages stay zero."""
    cfg, api, params, anchor = _setup()
    cache = api.init_cache(2, 32, kv_layout="paged", page_size=PS)
    n_pages = cache["blocks"][0]["k_pages"].shape[1]
    assert n_pages == 2 * 4 + 1            # slots * pages_per_slot + scratch
    bt = np.zeros((2, 4), np.int32)
    bt[0, :2] = [1, 2]
    bt[1, :2] = [5, 6]
    cache["block_table"] = jnp.asarray(bt)
    toks = jnp.asarray(np.random.default_rng(0)
                       .integers(0, cfg.vocab, (1, 9)), jnp.int32)
    _, filled, clen = jax.jit(api.prefill_slot)(
        params, {"tokens": toks}, cache, 0)
    assert int(clen) == 9
    pool = np.asarray(filled["blocks"][0]["k_pages"])
    assert np.abs(pool[:, 1:3]).sum() > 0          # slot 0's pages written
    assert np.abs(pool[:, 3:]).sum() == 0          # slot 1 + spares untouched
    assert np.abs(pool[:, 0]).sum() == 0           # scratch untouched


def test_paged_rejects_recurrent_families():
    """Recurrent state has no sequence axis to page — constructing a paged
    engine (or cache) for such a family must fail loudly."""
    cfg = get_reduced("rwkv6-7b")
    api = get_model(cfg, None)
    params = api.init_params(jax.random.PRNGKey(0))
    anchor = make_anchor(params, QAT)
    with pytest.raises(ValueError, match="pure-attention"):
        ElasticEngine(api, anchor, batch_slots=2, max_len=32,
                      param_template=params, kv_layout="paged")
    with pytest.raises(ValueError, match="pure-attention"):
        api.init_cache(2, 32, kv_layout="paged")
