"""Infrastructure tests: checkpoint IO, sharding rules, data pipeline."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import io as ckpt_io
from repro.data.pipeline import DataConfig, LMDataset, eval_batches
from repro.data.synthetic import SyntheticConfig, make_tokens
from repro.launch.mesh import make_debug_mesh
from repro.sharding.rules import (DEFAULT_RULES, LogicalRules, spec_for_axes,
                                  param_shardings)


# ---------------------------------------------------------------------------
# checkpoint io
# ---------------------------------------------------------------------------
def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
            "b": {"c": jnp.arange(5, dtype=jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    root = str(tmp_path / "ck")
    t = _tree()
    ckpt_io.save(root, 3, t)
    assert ckpt_io.latest_step(root) == 3
    got, manifest = ckpt_io.restore(root, jax.eval_shape(lambda: t))
    assert manifest["step"] == 3
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]),
                                  np.asarray(t["b"]["c"]))


def test_keep_n_gc(tmp_path):
    root = str(tmp_path / "ck")
    for s in range(6):
        ckpt_io.save(root, s, _tree(s), keep_n=2)
    steps = sorted(d for d in os.listdir(root) if d.startswith("step_"))
    assert len(steps) == 2
    assert ckpt_io.latest_step(root) == 5


def test_atomicity_tmpdir_never_latest(tmp_path):
    root = str(tmp_path / "ck")
    ckpt_io.save(root, 1, _tree())
    # a leftover tmp dir from a crashed writer must not be visible
    os.makedirs(os.path.join(root, "step_000000009.tmp.999"))
    assert ckpt_io.latest_step(root) == 1


def test_restore_missing_key_raises(tmp_path):
    root = str(tmp_path / "ck")
    ckpt_io.save(root, 1, {"a": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        ckpt_io.restore(root, {"a": jnp.zeros((2,)),
                               "extra": jnp.zeros((3,))})


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------
def test_spec_resolution_divisibility():
    mesh = make_debug_mesh(1, 1)  # 1x1 (single CPU device)
    # axes exist but size 1 -> always divisible, single-axis entries
    spec = spec_for_axes((64, 32), ("fsdp", "model"), mesh)
    assert isinstance(spec, P)

    # fabricate a fake mesh object with sizes to test resolution logic
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        class devices:
            shape = (2, 16, 16)
    rules = LogicalRules(dict(DEFAULT_RULES))
    # vocab 49152 % 16 == 0 -> model used
    s = spec_for_axes((49152, 576), ("vocab", "fsdp"), FakeMesh, rules)
    assert s[0] == "model"
    # 576 % 32 == 0 -> ('pod','data') both used
    assert s[1] == ("pod", "data")
    # 9 heads don't divide 16 -> replicated
    s2 = spec_for_axes((9, 64), ("heads", None), FakeMesh, rules)
    assert s2[0] is None
    # each mesh axis used at most once
    s3 = spec_for_axes((16, 16), ("model", "model"), FakeMesh, rules)
    assert s3[0] == "model" and s3[1] is None
    # partial prefix: dim 32 divisible by pod(2) and data(16) -> both (32)
    s4 = spec_for_axes((32,), ("batch",), FakeMesh, rules)
    assert s4[0] == ("pod", "data")
    # dim 2 only divisible by pod
    s5 = spec_for_axes((2,), ("batch",), FakeMesh, rules)
    assert s5[0] == "pod"


def test_param_shardings_tree():
    mesh = make_debug_mesh(1, 1)
    axes = {"w": ("fsdp", "model"), "b": ("model",)}
    shapes = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32),
              "b": jax.ShapeDtypeStruct((32,), jnp.float32)}
    sh = param_shardings(axes, shapes, mesh)
    assert sh["w"].mesh is mesh


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_batches_deterministic_by_step():
    cfg = DataConfig(vocab=256, seq_len=32, global_batch=4)
    ds1, ds2 = LMDataset(cfg), LMDataset(cfg)
    b1, b2 = ds1.batch_at(7), ds2.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds1.batch_at(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=256, seq_len=32, global_batch=2)
    b = LMDataset(cfg).batch_at(0)
    # labels[t] == tokens[t+1] within the underlying stream windows
    assert b["tokens"].shape == b["labels"].shape == (2, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_epoch_pool_cycles_128_examples():
    cfg = DataConfig(vocab=256, seq_len=16, global_batch=8, n_examples=128)
    ds = LMDataset(cfg)
    assert ds.epoch_steps() == 16
    first = ds.batch_at(0)
    again = ds.batch_at(16)   # one full epoch later -> same examples
    np.testing.assert_array_equal(first["tokens"], again["tokens"])


def test_eval_split_disjoint():
    cfg = DataConfig(vocab=256, seq_len=32, global_batch=2)
    train = LMDataset(cfg).batch_at(0)
    evalb = eval_batches(cfg, 1)[0]
    assert not np.array_equal(train["tokens"], evalb["tokens"])


def test_stream_has_structure():
    """A bigram model predicts the synthetic stream far above chance."""
    toks = make_tokens(SyntheticConfig(vocab=64, seed=0), 20000)
    import collections
    nxt = collections.defaultdict(collections.Counter)
    for a, b in zip(toks[:-1], toks[1:]):
        nxt[a][b] += 1
    correct = sum(nxt[a].most_common(1)[0][1] for a in nxt)
    acc = correct / (len(toks) - 1)
    assert acc > 0.25    # chance would be ~1/64
