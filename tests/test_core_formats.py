"""Unit tests for MX format metadata."""
import pytest

from repro.core.formats import (MXFP, MXINT, delta_e, get_format)


def test_registry_names():
    for b in range(2, 9):
        assert get_format(f"mxint{b}").bits == b
    for b, (e, m) in {4: (2, 1), 5: (2, 2), 6: (3, 2), 7: (3, 3), 8: (4, 3)}.items():
        f = get_format(f"mxfp{b}")
        assert (f.ebits, f.mbits) == (e, m)
        assert f.bits == b


def test_emax_int_matches_paper():
    # Paper §3.3: for signed MXINT, Δe = b_h − b_l.
    for bh in range(3, 9):
        for bl in range(2, bh):
            assert delta_e(MXINT[bh], MXINT[bl]) == bh - bl


def test_emax_fp_values():
    # E4M3 max 448 (emax 8), E3M2 max 28 (emax 4), E2M1 max 6 (emax 2).
    assert MXFP[8].emax == 8 and MXFP[8].fp_max == 448.0
    assert MXFP[6].emax == 4 and MXFP[6].fp_max == 28.0
    assert MXFP[4].emax == 2 and MXFP[4].fp_max == 6.0
    assert MXFP[5].emax == 2 and MXFP[5].fp_max == 7.0
    assert MXFP[7].emax == 4 and MXFP[7].fp_max == 30.0


def test_delta_e_fp():
    assert delta_e(MXFP[8], MXFP[4]) == 6
    assert delta_e(MXFP[8], MXFP[6]) == 4
    assert delta_e(MXFP[6], MXFP[4]) == 2
    assert delta_e(MXFP[5], MXFP[4]) == 0  # same η: mantissa slice only


def test_cross_kind_rejected():
    with pytest.raises(ValueError):
        delta_e(MXINT[8], MXFP[4])


def test_block_size_override():
    f = get_format("mxint4", block_size=64)
    assert f.block_size == 64
    assert get_format("mxint4").block_size == 32
